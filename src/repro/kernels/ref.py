"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``<kernel>_ref`` matches the kernel's contract exactly (same argument
shapes/dtypes, same output), built only from jnp ops.  Kernel tests sweep
shapes/dtypes and ``assert_allclose`` kernel-vs-ref.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def crossbar_reduce_ref(
    image: jax.Array,     # (num_tiles, tile_rows, dim)
    tile_ids: jax.Array,  # (batch, max_tiles) int32, -1 padding
    bitmaps: jax.Array,   # (batch, max_tiles, tile_rows) float 0/1
) -> jax.Array:
    """Oracle for :func:`repro.kernels.ops.crossbar_reduce`.

    out[b] = sum_s bitmaps[b, s] @ image[tile_ids[b, s]]   (padding slots 0)
    """
    num_tiles = image.shape[0]

    def per_query(tids, bms):
        tiles = image[jnp.clip(tids, 0, num_tiles - 1)]          # (S, R, D)
        part = jnp.einsum("sr,srd->sd", bms, tiles)              # (S, D)
        return (part * (tids >= 0)[:, None]).sum(axis=0)

    return jax.vmap(per_query)(tile_ids, bitmaps.astype(image.dtype)).astype(image.dtype)


def crossbar_reduce_blocked_ref(
    image: jax.Array,     # (num_tiles, tile_rows, dim)
    tile_ids: jax.Array,  # (nb, max_tiles) int32, -1 padding — per BLOCK
    bitmaps: jax.Array,   # (nb, max_tiles, q_block, tile_rows) float 0/1
) -> jax.Array:
    """Oracle for the query-blocked kernel layout.

    Expands the blocked form back to the flat per-query layout (every
    query of a block shares the block's tile list) and reuses
    :func:`crossbar_reduce_ref`.  Output is (nb * q_block, dim),
    block-major query order.
    """
    nb, s, q_block, r = bitmaps.shape
    flat_ids = jnp.repeat(tile_ids, q_block, axis=0)              # (nb*q, S)
    flat_bms = bitmaps.transpose(0, 2, 1, 3).reshape(nb * q_block, s, r)
    return crossbar_reduce_ref(image, flat_ids, flat_bms)


def embedding_bag_ref(
    table: jax.Array,     # (rows, dim)
    indices: jax.Array,   # (batch, bag) int32, -1 padding
) -> jax.Array:
    """Oracle for the padded embedding-bag (gather+sum) kernel."""
    rows = table.shape[0]
    take = table[jnp.clip(indices, 0, rows - 1)]                 # (B, K, D)
    return (take * (indices >= 0)[..., None]).sum(axis=1).astype(table.dtype)


def onehot_matmul_ref(onehot: jax.Array, dense: jax.Array) -> jax.Array:
    """Oracle for the MXU one-hot matmul micro-kernel."""
    return (onehot.astype(jnp.float32) @ dense.astype(jnp.float32)).astype(dense.dtype)


def fused_decode_attention_ref(q, k_q, k_s, v_q, v_s, length):
    """Oracle for :func:`repro.kernels.decode_attention` — dequantize the
    whole cache and run a masked flash accumulation in one shot.

    Returns (out_unnormalized (b,kvh,g,hd) f32, m (b,kvh,g), l (b,kvh,g)).
    """
    b, S, kvh, hd = k_q.shape
    k = k_q.astype(jnp.float32) * k_s.astype(jnp.float32)[..., None]
    v = v_q.astype(jnp.float32) * v_s.astype(jnp.float32)[..., None]
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32), k) * scale
    mask = jnp.arange(S)[None, None, None, :] < length
    s = jnp.where(mask, s, -1e30)
    m = s.max(axis=-1)
    w = jnp.exp(s - m[..., None])
    l = w.sum(axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", w, v)
    return out, m, l
