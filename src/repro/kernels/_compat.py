"""Version shims for jax.experimental.pallas.tpu API drift."""

from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
