"""Pallas TPU kernel: tiled embedding reduction with dynamic READ/MAC switch.

TPU-native re-expression of the ReCross crossbar datapath (DESIGN.md §2):

  * a "crossbar" is a ``(tile_rows, dim)`` tile of the permuted embedding
    image, fetched HBM→VMEM on demand via **scalar-prefetch indexing**
    (``tile_ids`` plays the role of crossbar selection; the BlockSpec
    index_map *is* the crossbar decoder),
  * the MAC path multiplies the wordline bitmap against the tile on the
    MXU (``bitmap @ tile``, a one-hot matmul — the in-memory MAC),
  * the READ path (popcount ≤ 1, ReCross §III-D) skips the MXU entirely
    and dynamically slices the single active row out of VMEM — the
    dynamic-switch ADC as a datapath branch,
  * partial sums accumulate in a float32 VMEM scratch (the "ADC output
    register"), written back once per query.

Two layouts (DESIGN.md §3):

**Flat** — ``bitmaps (batch, max_tiles, tile_rows)``, grid
``(batch, max_tiles)``: one query per grid row, one ``(1, tile_rows)``
bitmap per tile DMA.

**Query-blocked** — ``bitmaps (nb, max_tiles, q_block, tile_rows)`` with
``tile_ids (nb, max_tiles)`` *shared by the whole block* (the host
compiler deduplicates the block's tile set; correlated queries share hot
tiles, so the union stays near one query's tile count).  Grid shrinks to
``(batch // q_block, max_tiles)`` and the MAC becomes a
``(q_block, tile_rows) @ (tile_rows, dim)`` matmul — one tile DMA is
amortized over ``q_block`` queries and the MXU sees a real LHS instead of
a single row.  The accumulator widens to a ``(q_block, dim)`` VMEM
scratch (the multi-buffered "ADC output register": one live partial sum
per in-flight query of the block), flushed once per block.

VMEM budget per grid step: one ``(tile_rows, dim)`` tile + one
``(q_block, dim)`` f32 accumulator + one ``(q_block, tile_rows)`` bitmap.
With the production defaults (tile_rows=64, dim ≤ 8192, bf16, q_block ≤ 8)
that is ≲ 1.3 MiB ≪ VMEM; block shapes are asserted MXU-aligned
(dim % 128 == 0, tile_rows % 8 == 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(
    pad_ids_ref,    # scalar-prefetch: (batch, max_tiles) int32, -1 padding
    safe_ids_ref,   # scalar-prefetch: ids clipped to >= 0 (feeds index_map)
    bitmap_ref,     # VMEM (1, 1, tile_rows)
    tile_ref,       # VMEM (1, tile_rows, dim) — the selected crossbar tile
    out_ref,        # VMEM (1, dim)
    acc_ref,        # scratch VMEM (1, dim) float32
    *,
    max_tiles: int,
    dynamic_switch: bool,
):
    b = pl.program_id(0)
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm = bitmap_ref[0, 0, :].astype(jnp.float32)          # (tile_rows,)
    count = jnp.sum(bm)

    def mac_path():
        tile = tile_ref[0].astype(jnp.float32)            # (tile_rows, dim)
        return jnp.dot(
            bm.reshape(1, -1), tile, preferred_element_type=jnp.float32
        )                                                  # (1, dim)

    def read_path():
        # single active wordline: pure row copy, no MXU issue
        row = jnp.argmax(bm).astype(jnp.int32)
        val = tile_ref[0, pl.ds(row, 1), :].astype(jnp.float32)  # (1, dim)
        return val * (count > 0).astype(jnp.float32)

    if dynamic_switch:
        contrib = lax.cond(count <= 1.0, read_path, mac_path)
    else:
        contrib = mac_path()

    # mask padding slots (tile_id < 0); their bitmaps are zero anyway, but
    # the read path must not leak tile row 0 if a nonzero bitmap were paired
    # with a padding id by a buggy caller.
    valid = (pad_ids_ref[b, s] >= 0).astype(jnp.float32)
    acc_ref[...] += contrib * valid

    @pl.when(s == max_tiles - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _blocked_kernel(
    pad_ids_ref,    # scalar-prefetch: (nb, max_tiles) int32, -1 padding
    safe_ids_ref,   # scalar-prefetch: ids clipped to >= 0 (feeds index_map)
    bitmap_ref,     # VMEM (1, 1, q_block, tile_rows)
    tile_ref,       # VMEM (1, tile_rows, dim) — shared by the whole block
    out_ref,        # VMEM (1, q_block, dim)
    acc_ref,        # scratch VMEM (q_block, dim) float32 — one row per query
    *,
    max_tiles: int,
    dynamic_switch: bool,
):
    n = pl.program_id(0)
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm = bitmap_ref[0, 0].astype(jnp.float32)         # (q_block, tile_rows)
    q_block, tile_rows = bm.shape
    count = jnp.sum(bm)

    def mac_path():
        tile = tile_ref[0].astype(jnp.float32)        # (tile_rows, dim)
        return jnp.dot(bm, tile, preferred_element_type=jnp.float32)

    def read_path():
        # exactly one active wordline in the whole block: copy that row
        # into the single active query's accumulator lane, no MXU issue
        flat = bm.reshape(-1)
        idx = jnp.argmax(flat).astype(jnp.int32)
        row = jnp.remainder(idx, tile_rows)
        q = idx // tile_rows
        val = tile_ref[0, pl.ds(row, 1), :].astype(jnp.float32)   # (1, dim)
        lane = (
            lax.broadcasted_iota(jnp.int32, (q_block, 1), 0) == q
        ).astype(jnp.float32)
        return lane * val * (count > 0).astype(jnp.float32)

    if dynamic_switch:
        contrib = lax.cond(count <= 1.0, read_path, mac_path)
    else:
        contrib = mac_path()

    valid = (pad_ids_ref[n, s] >= 0).astype(jnp.float32)
    acc_ref[...] += contrib * valid

    @pl.when(s == max_tiles - 1)
    def _flush():
        out_ref[...] = acc_ref[...][None].astype(out_ref.dtype)


def crossbar_reduce_pallas(
    image: jax.Array,     # (num_tiles, tile_rows, dim)
    tile_ids: jax.Array,  # (batch | nb, max_tiles) int32, -1 padding
    bitmaps: jax.Array,   # flat (batch, max_tiles, tile_rows)
                          # or blocked (nb, max_tiles, q_block, tile_rows)
    *,
    dynamic_switch: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Raw pallas_call wrapper (no custom_vjp; see ops.crossbar_reduce).

    Dispatches on the bitmap rank: 3-D bitmaps run the flat one-query-per-
    grid-row kernel; 4-D bitmaps run the query-blocked kernel (``q_block``
    queries share each tile DMA; see ``repro.core.reduction.
    block_compiled_queries`` for the host-side block compiler).  The
    blocked form returns ``(nb * q_block, dim)`` — block-major query
    order, matching the flat batch order the block compiler consumed.
    """
    num_tiles, tile_rows, dim = image.shape
    batch, max_tiles = tile_ids.shape
    if bitmaps.ndim == 4:
        nb, s_blk, q_block, r = bitmaps.shape
        if (nb, s_blk, r) != (batch, max_tiles, tile_rows):
            raise ValueError(
                f"blocked bitmaps {bitmaps.shape} inconsistent with "
                f"tile_ids {tile_ids.shape} / tile_rows {tile_rows}"
            )
    elif bitmaps.shape != (batch, max_tiles, tile_rows):
        raise ValueError(f"bitmaps shape {bitmaps.shape} inconsistent")
    else:
        q_block = None
    if dim % 128 != 0:
        raise ValueError(f"dim={dim} must be a multiple of 128 (MXU lanes)")
    if tile_rows % 8 != 0:
        raise ValueError(f"tile_rows={tile_rows} must be a multiple of 8 (sublanes)")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    # clip padding ids to 0 for the block index map (masked in-kernel)
    safe_ids = jnp.maximum(tile_ids, 0).astype(jnp.int32)
    padded_ids = tile_ids.astype(jnp.int32)

    if q_block is None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # padded_ids (mask), safe_ids (index map)
            grid=(batch, max_tiles),
            in_specs=[
                pl.BlockSpec((1, 1, tile_rows), lambda b, s, pad, safe: (b, s, 0)),
                pl.BlockSpec((1, tile_rows, dim), lambda b, s, pad, safe: (safe[b, s], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, dim), lambda b, s, pad, safe: (b, 0)),
            scratch_shapes=[pltpu.VMEM((1, dim), jnp.float32)],
        )
        kernel = functools.partial(
            _kernel, max_tiles=max_tiles, dynamic_switch=dynamic_switch
        )
        out_shape = jax.ShapeDtypeStruct((batch, dim), image.dtype)
    else:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(batch, max_tiles),
            in_specs=[
                pl.BlockSpec(
                    (1, 1, q_block, tile_rows), lambda n, s, pad, safe: (n, s, 0, 0)
                ),
                pl.BlockSpec(
                    (1, tile_rows, dim), lambda n, s, pad, safe: (safe[n, s], 0, 0)
                ),
            ],
            out_specs=pl.BlockSpec((1, q_block, dim), lambda n, s, pad, safe: (n, 0, 0)),
            scratch_shapes=[pltpu.VMEM((q_block, dim), jnp.float32)],
        )
        kernel = functools.partial(
            _blocked_kernel, max_tiles=max_tiles, dynamic_switch=dynamic_switch
        )
        out_shape = jax.ShapeDtypeStruct((batch, q_block, dim), image.dtype)

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(padded_ids, safe_ids, bitmaps, image)
    if q_block is not None:
        out = out.reshape(batch * q_block, dim)
    return out
