"""Pallas TPU kernel: tiled embedding reduction with dynamic READ/MAC switch.

TPU-native re-expression of the ReCross crossbar datapath (DESIGN.md §2):

  * a "crossbar" is a ``(tile_rows, dim)`` tile of the permuted embedding
    image, fetched HBM→VMEM on demand via **scalar-prefetch indexing**
    (``tile_ids`` plays the role of crossbar selection; the BlockSpec
    index_map *is* the crossbar decoder),
  * the MAC path multiplies the wordline bitmap against the tile on the
    MXU (``bitmap @ tile``, a one-hot matmul — the in-memory MAC),
  * the READ path (popcount ≤ 1, ReCross §III-D) skips the MXU entirely
    and dynamically slices the single active row out of VMEM — the
    dynamic-switch ADC as a datapath branch,
  * partial sums accumulate in a float32 VMEM scratch (the "ADC output
    register"), written back once per query.

Grid: ``(batch, max_tiles)`` — batch-parallel, tile-sequential so the
accumulator carries across the inner dimension.

VMEM budget per grid step: one ``(tile_rows, dim)`` tile + one
``(1, dim)`` f32 accumulator + one ``(1, tile_rows)`` bitmap.  With the
production defaults (tile_rows=64 padded to 128-friendly dims,
dim ≤ 8192, bf16) that is ≤ 64·8192·2 B = 1 MiB ≪ VMEM; block shapes are
asserted MXU-aligned (dim % 128 == 0, tile_rows % 8 == 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    pad_ids_ref,    # scalar-prefetch: (batch, max_tiles) int32, -1 padding
    safe_ids_ref,   # scalar-prefetch: ids clipped to >= 0 (feeds index_map)
    bitmap_ref,     # VMEM (1, 1, tile_rows)
    tile_ref,       # VMEM (1, tile_rows, dim) — the selected crossbar tile
    out_ref,        # VMEM (1, dim)
    acc_ref,        # scratch VMEM (1, dim) float32
    *,
    max_tiles: int,
    dynamic_switch: bool,
):
    b = pl.program_id(0)
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm = bitmap_ref[0, 0, :].astype(jnp.float32)          # (tile_rows,)
    count = jnp.sum(bm)

    def mac_path():
        tile = tile_ref[0].astype(jnp.float32)            # (tile_rows, dim)
        return jnp.dot(
            bm.reshape(1, -1), tile, preferred_element_type=jnp.float32
        )                                                  # (1, dim)

    def read_path():
        # single active wordline: pure row copy, no MXU issue
        row = jnp.argmax(bm).astype(jnp.int32)
        val = tile_ref[0, pl.ds(row, 1), :].astype(jnp.float32)  # (1, dim)
        return val * (count > 0).astype(jnp.float32)

    if dynamic_switch:
        contrib = lax.cond(count <= 1.0, read_path, mac_path)
    else:
        contrib = mac_path()

    # mask padding slots (tile_id < 0); their bitmaps are zero anyway, but
    # the read path must not leak tile row 0 if a nonzero bitmap were paired
    # with a padding id by a buggy caller.
    valid = (pad_ids_ref[b, s] >= 0).astype(jnp.float32)
    acc_ref[...] += contrib * valid

    @pl.when(s == max_tiles - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def crossbar_reduce_pallas(
    image: jax.Array,     # (num_tiles, tile_rows, dim)
    tile_ids: jax.Array,  # (batch, max_tiles) int32, -1 padding
    bitmaps: jax.Array,   # (batch, max_tiles, tile_rows)
    *,
    dynamic_switch: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Raw pallas_call wrapper (no custom_vjp; see ops.crossbar_reduce)."""
    num_tiles, tile_rows, dim = image.shape
    batch, max_tiles = tile_ids.shape
    if bitmaps.shape != (batch, max_tiles, tile_rows):
        raise ValueError(f"bitmaps shape {bitmaps.shape} inconsistent")
    if dim % 128 != 0:
        raise ValueError(f"dim={dim} must be a multiple of 128 (MXU lanes)")
    if tile_rows % 8 != 0:
        raise ValueError(f"tile_rows={tile_rows} must be a multiple of 8 (sublanes)")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    # clip padding ids to 0 for the block index map (masked in-kernel)
    safe_ids = jnp.maximum(tile_ids, 0).astype(jnp.int32)
    padded_ids = tile_ids.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # padded_ids (mask), safe_ids (index map)
        grid=(batch, max_tiles),
        in_specs=[
            pl.BlockSpec((1, 1, tile_rows), lambda b, s, pad, safe: (b, s, 0)),
            pl.BlockSpec((1, tile_rows, dim), lambda b, s, pad, safe: (safe[b, s], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda b, s, pad, safe: (b, 0)),
        scratch_shapes=[pltpu.VMEM((1, dim), jnp.float32)],
    )

    kernel = functools.partial(
        _kernel, max_tiles=max_tiles, dynamic_switch=dynamic_switch
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, dim), image.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(padded_ids, safe_ids, bitmaps, image)
