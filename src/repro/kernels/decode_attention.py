"""Pallas TPU kernel: fused flash-decode attention over an int8 KV cache.

Motivation (§Perf decode iterations): XLA-auto lowering of int8-KV decode
materializes the dequantized bf16 cache in HBM (measured 70 GiB/dev on
minicpm decode_32k), defeating the quantization.  This kernel streams
int8 K/V blocks HBM→VMEM, dequantizes IN VMEM, and runs the online-softmax
accumulation — the dequantized cache never exists in HBM, so the decode
memory term gets the full int8 saving (1.78×).

Contract (cache part of one decode step, per layer):

    out_w, m, l = fused_decode_attention(q, k_q, k_s, v_q, v_s, length)

  q:    (b, kvh, g, hd)        — one new token's queries, GQA-grouped
  k_q:  (b, S, kvh, hd) int8   — quantized keys,  k_s (b, S, kvh) scales
  v_q:  (b, S, kvh, hd) int8   — quantized values, v_s (b, S, kvh) scales
  length: scalar int32         — valid prefix (positions >= length masked)

Returns the UNNORMALIZED flash state over the cache: ``out_w`` =
Σ softmax-weights·V before division, with row max ``m`` and denominator
``l`` — the caller merges the new token's own K/V via the standard
two-softmax combine (see serve/decode.py), keeping the kernel oblivious
to the cache-update policy.

Grid: ``(b, kvh, S//block_s)`` — the S dimension is the reduction, scanned
with VMEM scratch carries (m, l, acc).  VMEM per step: one
``(block_s, hd)`` int8 K block + V block + scales + (g, block_s) scores:
< 0.5 MiB at block_s=512, hd=128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(
    len_ref,       # scalar prefetch: (1,) int32 valid length
    q_ref,         # VMEM (1, 1, g, hd)
    kq_ref,        # VMEM (1, block_s, 1, hd) int8
    ks_ref,        # VMEM (1, block_s, 1)
    vq_ref,        # VMEM (1, block_s, 1, hd) int8
    vs_ref,        # VMEM (1, block_s, 1)
    out_ref,       # VMEM (1, 1, g, hd) f32 — unnormalized
    m_ref,         # VMEM (1, 1, g) f32
    l_ref,         # VMEM (1, 1, g) f32
    acc_ref,       # scratch VMEM (g, hd) f32
    m_scr,         # scratch VMEM (g, 1) f32
    l_scr,         # scratch VMEM (g, 1) f32
    *,
    block_s: int,
    num_blocks: int,
    scale: float,
):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0, 0].astype(jnp.float32)                        # (g, hd)
    k = kq_ref[0, :, 0].astype(jnp.float32) * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
    v = vq_ref[0, :, 0].astype(jnp.float32) * vs_ref[0, :, 0].astype(jnp.float32)[:, None]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale   # (g, block_s)
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    s = jnp.where(pos < len_ref[0], s, -1e30)

    m_prev = m_scr[...]                                        # (g, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    w = jnp.exp(s - m_new)                                     # (g, block_s)
    l_scr[...] = l_scr[...] * corr + w.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        w, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(s_idx == num_blocks - 1)
    def _flush():
        out_ref[0, 0] = acc_ref[...]
        m_ref[0, 0] = m_scr[..., 0]
        l_ref[0, 0] = l_scr[..., 0]


def fused_decode_attention_pallas(
    q: jax.Array,        # (b, kvh, g, hd)
    k_q: jax.Array,      # (b, S, kvh, hd) int8
    k_s: jax.Array,      # (b, S, kvh)
    v_q: jax.Array,
    v_s: jax.Array,
    length: jax.Array,   # scalar int32
    *,
    block_s: int = 512,
    interpret: bool | None = None,
):
    b, kvh, g, hd = q.shape
    S = k_q.shape[1]
    if S % block_s != 0:
        raise ValueError(f"S={S} must be a multiple of block_s={block_s}")
    if hd % 128 != 0 and hd < 128:
        # small head dims still work (lanes pad); only assert sanity
        pass
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    num_blocks = S // block_s
    scale = 1.0 / (hd ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, num_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, hi, si, ln: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda bi, hi, si, ln: (bi, si, hi, 0)),
            pl.BlockSpec((1, block_s, 1), lambda bi, hi, si, ln: (bi, si, hi)),
            pl.BlockSpec((1, block_s, 1, hd), lambda bi, hi, si, ln: (bi, si, hi, 0)),
            pl.BlockSpec((1, block_s, 1), lambda bi, hi, si, ln: (bi, si, hi)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, hi, si, ln: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda bi, hi, si, ln: (bi, hi, 0)),
            pl.BlockSpec((1, 1, g), lambda bi, hi, si, ln: (bi, hi, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _kernel, block_s=block_s, num_blocks=num_blocks, scale=scale
    )
    out_shapes = [
        jax.ShapeDtypeStruct((b, kvh, g, hd), jnp.float32),
        jax.ShapeDtypeStruct((b, kvh, g), jnp.float32),
        jax.ShapeDtypeStruct((b, kvh, g), jnp.float32),
    ]
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.reshape(length.astype(jnp.int32), (1,)), q, k_q, k_s, v_q, v_s)
