"""Pallas TPU kernel: padded embedding-bag (gather + sum).

The *naive/nMARS datapath*: each query gathers its rows directly by row id
(no grouping, no tiling locality) and sums them.  Serves two roles:

  * the baseline the ReCross kernel is compared against in benchmarks,
  * the production gather for LM token embedding where every lookup is
    single-hot (the READ-path regime).

Scalar-prefetched ``indices`` drive the BlockSpec index_map so each grid
step DMAs exactly one ``(block_rows, dim)`` slab of the table containing
the needed row — the HBM traffic model is one row-granule per lookup, like
a real gather.

Grid: ``(batch, bag)``; accumulation in f32 VMEM scratch as usual.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(
    pad_idx_ref,   # scalar-prefetch (batch, bag) int32 row ids, -1 pad
    block_ref,     # scalar-prefetch (batch, bag) int32 block index
    offset_ref,    # scalar-prefetch (batch, bag) int32 row-within-block
    row_ref,       # VMEM (1, block_rows, dim) — slab holding the row
    out_ref,       # VMEM (1, dim)
    acc_ref,       # scratch VMEM (1, dim) f32
    *,
    bag: int,
):
    b = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    off = offset_ref[b, k]
    valid = (pad_idx_ref[b, k] >= 0).astype(jnp.float32)
    row = row_ref[0, pl.ds(off, 1), :].astype(jnp.float32)  # (1, dim)
    acc_ref[...] += row * valid

    @pl.when(k == bag - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def embedding_bag_pallas(
    table: jax.Array,    # (rows, dim); rows % block_rows == 0 after padding
    indices: jax.Array,  # (batch, bag) int32, -1 padding
    *,
    block_rows: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    rows, dim = table.shape
    batch, bag = indices.shape
    if dim % 128 != 0:
        raise ValueError(f"dim={dim} must be a multiple of 128")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    pad_rows = (-rows) % block_rows
    if pad_rows:
        table = jnp.pad(table, ((0, pad_rows), (0, 0)))

    idx = indices.astype(jnp.int32)
    safe = jnp.maximum(idx, 0)
    block = safe // block_rows
    offset = safe % block_rows

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(batch, bag),
        in_specs=[
            pl.BlockSpec(
                (1, block_rows, dim), lambda b, k, pad, blk, off: (blk[b, k], 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda b, k, pad, blk, off: (b, 0)),
        scratch_shapes=[pltpu.VMEM((1, dim), jnp.float32)],
    )

    return pl.pallas_call(
        functools.partial(_kernel, bag=bag),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, dim), table.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(idx, block, offset, table.reshape(-1, block_rows, dim))
