"""Public jit'd wrappers for the Pallas kernels, with gradients.

``crossbar_reduce`` is differentiable w.r.t. the image (embedding training
through the ReCross layout): the VJP is the transpose one-hot scatter,
expressed with pure-jnp ops (a scatter-add has no MXU win, so no custom
kernel is warranted for the backward on TPU — XLA's scatter is fine).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.crossbar_reduce import crossbar_reduce_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels import ref as _ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def crossbar_reduce(image, tile_ids, bitmaps, dynamic_switch=True):
    """out[b] = Σ_s bitmaps[b,s] @ image[tile_ids[b,s]]  (Pallas forward).

    Args:
      image: (num_tiles, tile_rows, dim) permuted/replicated table image.
      tile_ids: (batch, max_tiles) int32, -1 padded.
      bitmaps: (batch, max_tiles, tile_rows) 0/1 activation masks.
      dynamic_switch: take the READ path for popcount<=1 tiles (§III-D).

    Returns:
      (batch, dim) reduced embeddings, image dtype.
    """
    return crossbar_reduce_pallas(
        image, tile_ids, bitmaps, dynamic_switch=dynamic_switch
    )


def _cr_fwd(image, tile_ids, bitmaps, dynamic_switch):
    out = crossbar_reduce_pallas(
        image, tile_ids, bitmaps, dynamic_switch=dynamic_switch
    )
    return out, (image, tile_ids, bitmaps)


def _cr_bwd(dynamic_switch, res, g):
    image, tile_ids, bitmaps = res
    (num_tiles, tile_rows, dim), dtype = image.shape, image.dtype
    # d_image[t] += Σ_{b,s: ids[b,s]==t} bitmaps[b,s]^T ⊗ g[b]
    valid = (tile_ids >= 0)
    outer = jnp.einsum(
        "bsr,bd->bsrd", bitmaps.astype(jnp.float32), g.astype(jnp.float32)
    ) * valid[..., None, None]
    flat = outer.reshape(-1, tile_rows, dim)
    ids = jnp.maximum(tile_ids, 0).reshape(-1)
    d_image = jnp.zeros((num_tiles, tile_rows, dim), jnp.float32).at[ids].add(flat)
    return d_image.astype(dtype), None, None


crossbar_reduce.defvjp(_cr_fwd, _cr_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def crossbar_reduce_blocked(image, tile_ids, bitmaps, dynamic_switch=True):
    """Query-blocked reduction: out[n*q+k] = Σ_s bitmaps[n,s,k] @ image[tile_ids[n,s]].

    Args:
      image: (num_tiles, tile_rows, dim) permuted/replicated table image.
      tile_ids: (nb, max_tiles) int32, -1 padded — the *block's* shared
        tile schedule (see reduction.block_compiled_queries).
      bitmaps: (nb, max_tiles, q_block, tile_rows) 0/1 activation masks.
      dynamic_switch: READ path when the block's popcount <= 1 (§III-D).

    Returns:
      (nb * q_block, dim) reduced embeddings in block-major query order.
    """
    return crossbar_reduce_pallas(
        image, tile_ids, bitmaps, dynamic_switch=dynamic_switch
    )


def _crb_fwd(image, tile_ids, bitmaps, dynamic_switch):
    out = crossbar_reduce_pallas(
        image, tile_ids, bitmaps, dynamic_switch=dynamic_switch
    )
    return out, (image, tile_ids, bitmaps)


def _crb_bwd(dynamic_switch, res, g):
    image, tile_ids, bitmaps = res
    (num_tiles, tile_rows, dim), dtype = image.shape, image.dtype
    nb, max_tiles, q_block, _ = bitmaps.shape
    gq = g.reshape(nb, q_block, dim)
    # d_image[t] += Σ_{n,s: ids[n,s]==t} Σ_k bitmaps[n,s,k]^T ⊗ g[n,k]
    valid = (tile_ids >= 0)
    outer = jnp.einsum(
        "nskr,nkd->nsrd", bitmaps.astype(jnp.float32), gq.astype(jnp.float32)
    ) * valid[..., None, None]
    flat = outer.reshape(-1, tile_rows, dim)
    ids = jnp.maximum(tile_ids, 0).reshape(-1)
    d_image = jnp.zeros((num_tiles, tile_rows, dim), jnp.float32).at[ids].add(flat)
    return d_image.astype(dtype), None, None


crossbar_reduce_blocked.defvjp(_crb_fwd, _crb_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def embedding_bag(table, indices):
    """out[b] = Σ_k table[indices[b,k]]  (-1 padded; Pallas forward)."""
    return embedding_bag_pallas(table, indices)


def _eb_fwd(table, indices):
    return embedding_bag_pallas(table, indices), (table, indices)


def _eb_bwd(res, g):
    table, indices = res
    (rows, dim), dtype = table.shape, table.dtype
    valid = (indices >= 0).astype(jnp.float32)[..., None]   # (B, K, 1)
    contrib = g.astype(jnp.float32)[:, None, :] * valid     # (B, K, D)
    ids = jnp.maximum(indices, 0).reshape(-1)
    d_table = (
        jnp.zeros((rows, dim), jnp.float32)
        .at[ids]
        .add(contrib.reshape(-1, dim))
    )
    return d_table.astype(dtype), None


embedding_bag.defvjp(_eb_fwd, _eb_bwd)


# Re-export oracles so tests and docs have one import point.
crossbar_reduce_ref = _ref.crossbar_reduce_ref
crossbar_reduce_blocked_ref = _ref.crossbar_reduce_blocked_ref
embedding_bag_ref = _ref.embedding_bag_ref
