"""Sharded multi-table crossbar reduction over the ``model`` mesh axis.

The serving-scale entry point (DESIGN.md §4): each model shard holds its
slice of the fused multi-table crossbar image (``repro.dist.shard_plan``)
and runs the query-blocked Pallas kernel over its *own* tile schedule
(``repro.core.reduction.shard_block_queries``); the per-shard partial
sums are combined with a psum-scatter-style reduction.

Combine / DMA overlap: the block axis is split into ``combine_chunks``
contiguous chunks, each lowered as kernel-then-combine.  Chunk *c*'s
reduce-scatter has no data dependence on chunk *c+1*'s pallas_call, whose
grid is ``("parallel", "arbitrary")``, so XLA's async collectives overlap
chunk *c*'s ICI transfer with chunk *c+1*'s HBM→VMEM tile DMAs — the TPU
re-expression of "overlap the cross-shard combine with the next block's
tile fetches".

Two execution paths, numerically identical:

  * **emulation** (``mesh=None``) — a host loop over the shard axis with
    an f32 partial-sum accumulator; runs on a single device of any
    backend (tests, CPU benchmarks).
  * **shard_map** (``mesh=`` a mesh whose ``axis_name`` axis has size
    ``num_shards``) — each device runs its shard's kernel; partials
    combine with ``lax.psum_scatter`` over the embedding dim (payload is
    OUTPUT-sized, never table-sized) + ``all_gather``, or plain
    ``lax.psum`` when the dim does not divide.

Both paths dispatch through ``functools.lru_cache``-keyed ``jax.jit``
wrappers (DESIGN.md §7.2): the serving loop re-invokes one flush shape
over and over, so repeat flushes skip retracing and — crucially for the
async engine — a dispatch returns immediately with the computation
executing asynchronously, which is what the double-buffered
host-compile / device-execute overlap overlaps with.

This is inference-path machinery: no custom VJP (training through the
sharded image goes through the single-shard ``crossbar_reduce`` entries).
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.kernels.crossbar_reduce import crossbar_reduce_pallas


def _shard_map():
    try:
        return jax.shard_map
    except AttributeError:  # jax < 0.5
        from jax.experimental.shard_map import shard_map

        return shard_map


# Bound on each jit-dispatch cache below.  The caches are keyed on the
# participants tuple (plus static knobs), and an adversarial mix of
# owner-set flush shapes can mint a fresh participants tuple per flush —
# unbounded caches would pin every retraced executable forever.  64
# distinct keys per path comfortably covers every steady-state policy
# (global: 1; per-shard: S; owner-set: S + the small sets that survive
# ``owner_set_max`` pooling) while evicting the long tail LRU-style.
DISPATCH_CACHE_MAXSIZE = 64


@functools.lru_cache(maxsize=DISPATCH_CACHE_MAXSIZE)
def _emulated_fn(shards, chunks, dynamic_switch, interpret):
    """jit-cached single-device emulation of the sharded reduction.

    Keyed by the participating shard ids + static knobs; jax.jit's own
    cache handles shapes.  Caching matters twice: repeat flushes of one
    shape skip retracing (the serving loop's per-flush host cost), and
    a jitted dispatch returns immediately with the computation running
    ASYNCHRONOUSLY — without it the §7 engine's host-compile /
    device-execute overlap would have nothing to overlap with off-TPU.
    """

    def fn(images, tile_ids, bitmaps):
        nb, q_block = bitmaps.shape[1], bitmaps.shape[3]
        dim = images.shape[-1]
        bounds = _chunk_bounds(nb, chunks)
        out = jnp.zeros((nb * q_block, dim), jnp.float32)
        for p, s in enumerate(shards):
            parts = [
                crossbar_reduce_pallas(
                    images[s], tile_ids[p][c0:c1], bitmaps[p][c0:c1],
                    dynamic_switch=dynamic_switch, interpret=interpret,
                ).astype(jnp.float32)
                for c0, c1 in bounds
            ]
            out = out + jnp.concatenate(parts, axis=0)
        return out.astype(images.dtype)

    return jax.jit(fn)


@functools.lru_cache(maxsize=DISPATCH_CACHE_MAXSIZE)
def _mesh_fn(mesh, axis_name, chunks, dynamic_switch, interpret, scatter):
    """jit-cached shard_map reduction (full-axis combine)."""

    def local(img, ids, bms):
        img, ids, bms = img[0], ids[0], bms[0]
        bounds = _chunk_bounds(ids.shape[0], chunks)
        outs = []
        for c0, c1 in bounds:
            part = crossbar_reduce_pallas(
                img, ids[c0:c1], bms[c0:c1],
                dynamic_switch=dynamic_switch, interpret=interpret,
            ).astype(jnp.float32)
            # chunk c's combine is independent of chunk c+1's kernel →
            # XLA overlaps this collective with the next chunk's DMAs
            if scatter:
                part = lax.psum_scatter(
                    part, axis_name, scatter_dimension=1, tiled=True
                )
            else:
                part = lax.psum(part, axis_name)
            outs.append(part)
        out = jnp.concatenate(outs, axis=0)
        if scatter:
            out = lax.all_gather(out, axis_name, axis=1, tiled=True)
        return out[None]

    return jax.jit(_shard_map()(
        local,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name)),
        out_specs=P(axis_name),
        # pallas_call has no replication rule; replication is
        # re-established explicitly by the psum/all_gather combine
        check_rep=False,
    ))


@functools.lru_cache(maxsize=DISPATCH_CACHE_MAXSIZE)
def _mesh_subset_fn(mesh, axis_name, chunks, dynamic_switch, interpret,
                    groups):
    """jit-cached shard_map reduction combining only a participant
    subgroup (DESIGN.md §7.1): ``groups`` partitions the mesh axis into
    EQUAL-SIZED index groups — the participants as one group, the
    non-participants chunked to the same size (TPU lowering rejects
    unequal ``axis_index_groups``, so this fn is only dispatched when
    the participant count divides the mesh) — and the per-chunk
    ``lax.psum`` rings each subgroup independently: a 2-owner flush on
    an 8-shard mesh moves combine traffic over 2 shards, while the
    non-participants (whose schedules are empty) all-reduce zeros
    among themselves.  psum (not psum_scatter) because a scatter's
    per-shard slice width would depend on the subgroup size, and the
    payload is output-sized either way."""

    index_groups = [list(g) for g in groups]

    def local(img, ids, bms):
        img, ids, bms = img[0], ids[0], bms[0]
        bounds = _chunk_bounds(ids.shape[0], chunks)
        outs = []
        for c0, c1 in bounds:
            part = crossbar_reduce_pallas(
                img, ids[c0:c1], bms[c0:c1],
                dynamic_switch=dynamic_switch, interpret=interpret,
            ).astype(jnp.float32)
            outs.append(lax.psum(
                part, axis_name, axis_index_groups=index_groups
            ))
        return jnp.concatenate(outs, axis=0)[None]

    return jax.jit(_shard_map()(
        local,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name)),
        out_specs=P(axis_name),
        check_rep=False,
    ))


@functools.lru_cache(maxsize=DISPATCH_CACHE_MAXSIZE)
def _mesh_single_fn(mesh, axis_name, chunks, dynamic_switch, interpret):
    """jit-cached shard_map reduction with NO combine — the
    single-participant flush path (the participant's stacked output is
    the result; non-participants run empty masked grids)."""

    def local(img, ids, bms):
        img, ids, bms = img[0], ids[0], bms[0]
        bounds = _chunk_bounds(ids.shape[0], chunks)
        parts = [
            crossbar_reduce_pallas(
                img, ids[c0:c1], bms[c0:c1],
                dynamic_switch=dynamic_switch, interpret=interpret,
            ).astype(jnp.float32)
            for c0, c1 in bounds
        ]
        return jnp.concatenate(parts, axis=0)[None]

    return jax.jit(_shard_map()(
        local,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name)),
        out_specs=P(axis_name),
        check_rep=False,
    ))


_DISPATCH_CACHES = {
    "emulated": _emulated_fn,
    "mesh": _mesh_fn,
    "mesh_subset": _mesh_subset_fn,
    "mesh_single": _mesh_single_fn,
}


def dispatch_cache_stats() -> dict:
    """Hit/miss/size counters of the bounded jit-dispatch caches.

    Process-global (the caches are module-level, shared by every server
    in the process); surfaced by ``ShardedEmbeddingServer.report()``.  A
    "hit" is a flush that reused a cached dispatcher — jax.jit's own
    shape cache then decides whether the *executable* was also reused.
    """
    out = {}
    hits = misses = 0
    for name, fn in _DISPATCH_CACHES.items():
        info = fn.cache_info()
        out[name] = {
            "hits": info.hits, "misses": info.misses,
            "currsize": info.currsize, "maxsize": info.maxsize,
        }
        hits += info.hits
        misses += info.misses
    out["total"] = {"hits": hits, "misses": misses,
                    "maxsize": DISPATCH_CACHE_MAXSIZE}
    return out


def clear_dispatch_caches() -> None:
    """Drops every cached dispatcher (tests that count hits exactly)."""
    for fn in _DISPATCH_CACHES.values():
        fn.cache_clear()


def _chunk_bounds(nb: int, combine_chunks: int) -> list[tuple[int, int]]:
    """Contiguous, roughly equal block-axis chunks (static)."""
    chunks = max(1, min(combine_chunks, nb)) if nb else 1
    if nb == 0:
        return [(0, 0)]
    base, rem = divmod(nb, chunks)
    bounds, start = [], 0
    for c in range(chunks):
        end = start + base + (1 if c < rem else 0)
        bounds.append((start, end))
        start = end
    return bounds


def crossbar_reduce_sharded(
    images: jax.Array,    # (S, local_tiles, tile_rows, dim) stacked shard images
    tile_ids: jax.Array,  # (P, nb, max_tiles) int32 shard-local ids, -1 pad
    bitmaps: jax.Array,   # (P, nb, max_tiles, q_block, tile_rows)
    *,
    mesh=None,
    axis_name: str = "model",
    combine: str = "psum_scatter",
    combine_chunks: int = 1,
    dynamic_switch: bool = True,
    interpret: bool | None = None,
    shard_ids=None,       # (P,) global shard ids of the stacked schedules
) -> jax.Array:
    """Shard-local query-blocked reduction + cross-shard combine.

    Args:
      images: per-shard local images from ``ShardPlan.build_shard_images``
        (trailing padding tiles zero).  Always the full ``S``-deep stack,
        even for a subset dispatch.
      tile_ids / bitmaps: stacked shard-local blocked batch from
        ``shard_block_queries`` (every shard shares the block axis).
      mesh: run under shard_map on this mesh's ``axis_name`` axis (size
        must equal the shard count); ``None`` emulates on one device.
      combine: "psum_scatter" (reduce-scatter over the embedding dim +
        all-gather; falls back to psum when dim % shards != 0) or "psum".
      combine_chunks: block-axis chunks for combine/DMA overlap.
      shard_ids: when the batch was compiled for a shard *subset*
        (``participants=`` — the scheduler's per-shard and owner-set
        flushes, DESIGN.md §7), the global shard id of each stacked
        schedule.  Emulation runs only the participating shards'
        kernels; under shard_map the subset schedules scatter into a
        full-``S`` stack of empty (all ``-1``) schedules and the
        combine shrinks with the subset: a single participant skips the
        collective entirely, a multi-shard subset whose size divides
        the mesh rings only its participants via grouped psum
        (``axis_index_groups`` — equal group sizes are a TPU lowering
        requirement), and any other subset (plus the full stack) runs
        the full-axis combine with exact-zero payloads from
        non-participants.  ``None`` = all shards.

    Returns:
      ``(nb * q_block, dim)`` summed reduction in block-major query
      order — the same contract as ``crossbar_reduce_blocked``.
    """
    S, _, _, dim = images.shape
    if shard_ids is None:
        if tile_ids.shape[0] != S or bitmaps.shape[0] != S:
            raise ValueError(
                f"shard axes disagree: images {images.shape[0]}, "
                f"tile_ids {tile_ids.shape[0]}, bitmaps {bitmaps.shape[0]}"
            )
        part = np.arange(S, dtype=np.int64)
    else:
        part = np.asarray(shard_ids, dtype=np.int64)
        if tile_ids.shape[0] != part.size or bitmaps.shape[0] != part.size:
            raise ValueError(
                f"shard_ids has {part.size} entries, schedules have "
                f"{tile_ids.shape[0]}/{bitmaps.shape[0]}"
            )
        if part.size and (part.min() < 0 or part.max() >= S):
            raise ValueError(f"shard_ids {part} out of range for {S} shards")
    if combine not in ("psum_scatter", "psum"):
        raise ValueError(f"unknown combine {combine!r}")

    if mesh is None:
        # single-device emulation: shard loop in-program, f32 accumulate.
        # A subset flush runs ONLY the participants' kernels — that is
        # the per-shard scheduler's compute saving on the emulation path.
        fn = _emulated_fn(
            tuple(part.tolist()), combine_chunks, dynamic_switch, interpret
        )
        return fn(images, tile_ids, bitmaps)

    mesh_axis = dict(zip(mesh.axis_names, mesh.devices.shape)).get(axis_name)
    if mesh_axis != S:
        raise ValueError(
            f"mesh axis {axis_name!r} has size {mesh_axis}, need {S} shards"
        )
    if part.size != S or not np.array_equal(part, np.arange(S)):
        # shard_map needs one schedule per device: scatter the subset
        # into empty (-1 / zero) schedules — empty grids are masked
        # in-kernel, so non-participants produce exact-zero partials.
        # Device-side functional scatter: no host round-trip of the
        # just-built schedules on the per-shard flush hot path.
        idx = jnp.asarray(part, dtype=jnp.int32)
        tile_ids = jnp.full(
            (S,) + tuple(tile_ids.shape[1:]), -1, dtype=jnp.int32
        ).at[idx].set(tile_ids)
        bitmaps = jnp.zeros(
            (S,) + tuple(bitmaps.shape[1:]), dtype=bitmaps.dtype
        ).at[idx].set(bitmaps)

    if part.size == 1:
        # single-participant flush: the participant's partial IS the
        # result, so no collective runs at all — a per-shard flush
        # crosses zero interconnect on the mesh path too.
        fn = _mesh_single_fn(
            mesh, axis_name, combine_chunks, dynamic_switch, interpret
        )
        out = fn(images, tile_ids, bitmaps)
        return out[int(part[0])].astype(images.dtype)

    P = int(part.size)
    if P < S and S % P == 0:
        # multi-shard subset (owner-set / pool flush) whose size divides
        # the mesh: combine only among the participants via grouped psum
        # — interconnect scales with the owner-set size, not the mesh.
        # axis_index_groups must partition the axis into EQUAL sizes
        # (a TPU lowering requirement), so the non-participants are
        # chunked to the participant count and ring zeros among
        # themselves.  Subsets that do not divide the mesh fall through
        # to the full-axis combine below — non-participants contribute
        # exact-zero partials there, so numerics are identical and only
        # the ring width differs (the stats account the same rule).
        others = np.setdiff1d(np.arange(S), part)
        groups = (tuple(int(s) for s in np.sort(part)),) + tuple(
            tuple(int(s) for s in others[i : i + P])
            for i in range(0, others.size, P)
        )
        fn = _mesh_subset_fn(
            mesh, axis_name, combine_chunks, dynamic_switch, interpret,
            groups,
        )
        out = fn(images, tile_ids, bitmaps)
        return out[int(part[0])].astype(images.dtype)

    scatter = combine == "psum_scatter" and dim % S == 0
    fn = _mesh_fn(
        mesh, axis_name, combine_chunks, dynamic_switch, interpret, scatter
    )
    out = fn(images, tile_ids, bitmaps)
    # every shard returns the full combined batch; take shard 0's copy
    return out[0].astype(images.dtype)


def crossbar_reduce_tables(
    images: jax.Array,
    sbq,
    spans,
    *,
    mesh=None,
    axis_name: str = "model",
    combine: str = "psum_scatter",
    combine_chunks: int = 1,
    dynamic_switch: bool = True,
    interpret: bool | None = None,
) -> list[jax.Array]:
    """Multi-table entry: one fused sharded reduction, split per table.

    ``sbq`` is the fused :class:`~repro.core.reduction.
    ShardedBlockedQueries` (per-table compiles offset into the fused tile
    space, concatenated with ``concat_compiled_queries``), ``spans`` the
    per-table ``(row_start, batch)`` list that call returned.  A subset
    compile (``sbq.shards`` set) dispatches only the participating
    shards' kernels — the scheduler's independent per-shard flush path.

    Returns one ``(batch_t, dim)`` array per table, padding rows sliced.
    """
    out = crossbar_reduce_sharded(
        images, sbq.tile_ids, sbq.bitmaps,
        mesh=mesh, axis_name=axis_name, combine=combine,
        combine_chunks=combine_chunks, dynamic_switch=dynamic_switch,
        interpret=interpret, shard_ids=sbq.shards,
    )
    return [out[start : start + batch] for start, batch in spans]


def patch_shard_images(
    images: jax.Array,     # (S, capacity, tile_rows, dim) stacked shard images
    patch,                 # repro.dist.replan.PlanPatch (duck-typed)
    fused_image: np.ndarray,  # (num_tiles, tile_rows, dim) host master copy
) -> jax.Array:
    """DMAs ONLY a plan patch's moved tiles into the stacked shard images.

    The device-side half of online replanning (DESIGN.md §6): the host
    master image is the DMA source, and the update is one batched
    scatter of ``len(patch.dma)`` tiles — never a rebuild of the
    ``(S, capacity, tile_rows, dim)`` stack.  Slots freed by demotions
    keep their stale bytes; the plan stops addressing them, so they are
    unreachable (the padding-tile contract only ever covered slots the
    plan could address).

    When promotions outgrow the current capacity the stack is padded
    with zero tiles up to ``patch.new_capacity`` first — an allocation,
    but still no table-sized data movement (the pad is zeros and only
    the moved tiles are copied in).  A patch computed with slack
    age-out (``compute_plan_patch(..., shrink_slack=)``) may instead
    carry ``new_capacity`` *below* the current depth: the stack is
    sliced down, releasing the free tail long demotion streaks left
    behind — every slot the patched plan addresses stays below the new
    depth by construction.

    Tiered storage (DESIGN.md §9) rides the same scatter: a paging
    patch's ``fetch_dma`` triples copy the paged-in groups' tiles from
    the host master image into the slots its evictions (and earlier
    demotions) returned to the free-list.  Evicted slots themselves move
    no data — like demotion-freed slots they just stop being addressed,
    and the host master image stays authoritative for the cold tier.

    Args:
      images: the serving image stack (``ShardPlan.build_shard_images``
        output, possibly already patched and/or slack-padded).
      patch: the :class:`~repro.dist.replan.PlanPatch` being applied;
        only ``dma``, ``fetch_dma``, ``moved`` and ``new_capacity`` are
        read (``fetch_dma`` via getattr — pre-paging patches lack it).
      fused_image: the fused multi-table host image the plan indexes
        (``repro.dist.build_fused_image``).

    Returns:
      The patched image stack (a new array — jax functional update).
    """
    S, capacity = images.shape[0], images.shape[1]
    if patch.new_capacity > capacity:
        pad = jnp.zeros(
            (S, patch.new_capacity - capacity) + images.shape[2:], images.dtype
        )
        images = jnp.concatenate([images, pad], axis=1)
    elif patch.new_capacity < capacity:
        # slack age-out (DESIGN.md §6.2): every slot the patched plan
        # addresses is below the new depth (compaction relocated the
        # rest), so the slice drops only unaddressable bytes
        images = images[:, : patch.new_capacity]
    # promotions' new holders + paged-in tiles + compaction relocations,
    # one batched scatter from the host master image
    writes = list(patch.dma)
    writes += list(getattr(patch, "fetch_dma", ()) or ())
    writes += [(s, new, t) for s, t, _old, new in patch.moved]
    if not writes:
        return images
    shards = jnp.asarray([w[0] for w in writes], dtype=jnp.int32)
    slots = jnp.asarray([w[1] for w in writes], dtype=jnp.int32)
    tiles = np.asarray([w[2] for w in writes], dtype=np.int64)
    moved = jnp.asarray(np.asarray(fused_image)[tiles], dtype=images.dtype)
    return images.at[shards, slots].set(moved)


def combine_bytes_per_batch(
    out_rows: int, dim: int, num_shards: int, *, dtype_bytes: int = 4,
) -> int:
    """Cross-shard combine traffic of one batch, summed over shards.

    Ring accounting: a reduce-scatter (or all-gather) of an ``R × dim``
    f32 payload moves ``(S-1)/S × R × dim × 4`` bytes per shard; both
    combine modes cost two such passes (psum_scatter + all_gather, or a
    ring all-reduce), so the accounting is mode-independent.  Payloads
    are OUTPUT-sized — the whole point of combining partial sums instead
    of gathering tiles.
    """
    if num_shards <= 1:
        return 0
    per_shard = (num_shards - 1) / num_shards * out_rows * dim * dtype_bytes
    passes = 2  # reduce-scatter + all-gather, or all-reduce
    return int(passes * per_shard * num_shards)
