"""Sharded multi-table crossbar reduction over the ``model`` mesh axis.

The serving-scale entry point (DESIGN.md §4): each model shard holds its
slice of the fused multi-table crossbar image (``repro.dist.shard_plan``)
and runs the query-blocked Pallas kernel over its *own* tile schedule
(``repro.core.reduction.shard_block_queries``); the per-shard partial
sums are combined with a psum-scatter-style reduction.

Combine / DMA overlap: the block axis is split into ``combine_chunks``
contiguous chunks, each lowered as kernel-then-combine.  Chunk *c*'s
reduce-scatter has no data dependence on chunk *c+1*'s pallas_call, whose
grid is ``("parallel", "arbitrary")``, so XLA's async collectives overlap
chunk *c*'s ICI transfer with chunk *c+1*'s HBM→VMEM tile DMAs — the TPU
re-expression of "overlap the cross-shard combine with the next block's
tile fetches".

Two execution paths, numerically identical:

  * **emulation** (``mesh=None``) — a host loop over the shard axis with
    an f32 partial-sum accumulator; runs on a single device of any
    backend (tests, CPU benchmarks).
  * **shard_map** (``mesh=`` a mesh whose ``axis_name`` axis has size
    ``num_shards``) — each device runs its shard's kernel; partials
    combine with ``lax.psum_scatter`` over the embedding dim (payload is
    OUTPUT-sized, never table-sized) + ``all_gather``, or plain
    ``lax.psum`` when the dim does not divide.

This is inference-path machinery: no custom VJP (training through the
sharded image goes through the single-shard ``crossbar_reduce`` entries).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.kernels.crossbar_reduce import crossbar_reduce_pallas


def _shard_map():
    try:
        return jax.shard_map
    except AttributeError:  # jax < 0.5
        from jax.experimental.shard_map import shard_map

        return shard_map


def _chunk_bounds(nb: int, combine_chunks: int) -> list[tuple[int, int]]:
    """Contiguous, roughly equal block-axis chunks (static)."""
    chunks = max(1, min(combine_chunks, nb)) if nb else 1
    if nb == 0:
        return [(0, 0)]
    base, rem = divmod(nb, chunks)
    bounds, start = [], 0
    for c in range(chunks):
        end = start + base + (1 if c < rem else 0)
        bounds.append((start, end))
        start = end
    return bounds


def crossbar_reduce_sharded(
    images: jax.Array,    # (S, local_tiles, tile_rows, dim) stacked shard images
    tile_ids: jax.Array,  # (S, nb, max_tiles) int32 shard-local ids, -1 pad
    bitmaps: jax.Array,   # (S, nb, max_tiles, q_block, tile_rows)
    *,
    mesh=None,
    axis_name: str = "model",
    combine: str = "psum_scatter",
    combine_chunks: int = 1,
    dynamic_switch: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Shard-local query-blocked reduction + cross-shard combine.

    Args:
      images: per-shard local images from ``ShardPlan.build_shard_images``
        (trailing padding tiles zero).
      tile_ids / bitmaps: stacked shard-local blocked batch from
        ``shard_block_queries`` (every shard shares the block axis).
      mesh: run under shard_map on this mesh's ``axis_name`` axis (size
        must equal the shard count); ``None`` emulates on one device.
      combine: "psum_scatter" (reduce-scatter over the embedding dim +
        all-gather; falls back to psum when dim % shards != 0) or "psum".
      combine_chunks: block-axis chunks for combine/DMA overlap.

    Returns:
      ``(nb * q_block, dim)`` summed reduction in block-major query
      order — the same contract as ``crossbar_reduce_blocked``.
    """
    S, _, _, dim = images.shape
    if tile_ids.shape[0] != S or bitmaps.shape[0] != S:
        raise ValueError(
            f"shard axes disagree: images {images.shape[0]}, "
            f"tile_ids {tile_ids.shape[0]}, bitmaps {bitmaps.shape[0]}"
        )
    nb, q_block = bitmaps.shape[1], bitmaps.shape[3]
    if combine not in ("psum_scatter", "psum"):
        raise ValueError(f"unknown combine {combine!r}")
    bounds = _chunk_bounds(nb, combine_chunks)

    def shard_partial(img, ids, bms, c0, c1):
        return crossbar_reduce_pallas(
            img, ids[c0:c1], bms[c0:c1],
            dynamic_switch=dynamic_switch, interpret=interpret,
        ).astype(jnp.float32)                      # (cnb * q_block, dim)

    if mesh is None:
        # single-device emulation: shard loop in-program, f32 accumulate
        out = jnp.zeros((nb * q_block, dim), jnp.float32)
        for s in range(S):
            parts = [
                shard_partial(images[s], tile_ids[s], bitmaps[s], c0, c1)
                for c0, c1 in bounds
            ]
            out = out + jnp.concatenate(parts, axis=0)
        return out.astype(images.dtype)

    mesh_axis = dict(zip(mesh.axis_names, mesh.devices.shape)).get(axis_name)
    if mesh_axis != S:
        raise ValueError(
            f"mesh axis {axis_name!r} has size {mesh_axis}, need {S} shards"
        )
    scatter = combine == "psum_scatter" and dim % S == 0

    def local(img, ids, bms):
        img, ids, bms = img[0], ids[0], bms[0]
        outs = []
        for c0, c1 in bounds:
            part = shard_partial(img, ids, bms, c0, c1)
            # chunk c's combine is independent of chunk c+1's kernel →
            # XLA overlaps this collective with the next chunk's DMAs
            if scatter:
                part = lax.psum_scatter(
                    part, axis_name, scatter_dimension=1, tiled=True
                )
            else:
                part = lax.psum(part, axis_name)
            outs.append(part)
        out = jnp.concatenate(outs, axis=0)
        if scatter:
            out = lax.all_gather(out, axis_name, axis=1, tiled=True)
        return out[None]

    out = _shard_map()(
        local,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name)),
        out_specs=P(axis_name),
        # pallas_call has no replication rule; replication is re-established
        # explicitly by the psum/all_gather combine above
        check_rep=False,
    )(images, tile_ids, bitmaps)
    # every shard returns the full combined batch; take shard 0's copy
    return out[0].astype(images.dtype)


def crossbar_reduce_tables(
    images: jax.Array,
    sbq,
    spans,
    *,
    mesh=None,
    axis_name: str = "model",
    combine: str = "psum_scatter",
    combine_chunks: int = 1,
    dynamic_switch: bool = True,
    interpret: bool | None = None,
) -> list[jax.Array]:
    """Multi-table entry: one fused sharded reduction, split per table.

    ``sbq`` is the fused :class:`~repro.core.reduction.
    ShardedBlockedQueries` (per-table compiles offset into the fused tile
    space, concatenated with ``concat_compiled_queries``), ``spans`` the
    per-table ``(row_start, batch)`` list that call returned.

    Returns one ``(batch_t, dim)`` array per table, padding rows sliced.
    """
    out = crossbar_reduce_sharded(
        images, sbq.tile_ids, sbq.bitmaps,
        mesh=mesh, axis_name=axis_name, combine=combine,
        combine_chunks=combine_chunks, dynamic_switch=dynamic_switch,
        interpret=interpret,
    )
    return [out[start : start + batch] for start, batch in spans]


def patch_shard_images(
    images: jax.Array,     # (S, capacity, tile_rows, dim) stacked shard images
    patch,                 # repro.dist.replan.PlanPatch (duck-typed)
    fused_image: np.ndarray,  # (num_tiles, tile_rows, dim) host master copy
) -> jax.Array:
    """DMAs ONLY a plan patch's moved tiles into the stacked shard images.

    The device-side half of online replanning (DESIGN.md §6): the host
    master image is the DMA source, and the update is one batched
    scatter of ``len(patch.dma)`` tiles — never a rebuild of the
    ``(S, capacity, tile_rows, dim)`` stack.  Slots freed by demotions
    keep their stale bytes; the plan stops addressing them, so they are
    unreachable (the padding-tile contract only ever covered slots the
    plan could address).

    When promotions outgrow the current capacity the stack is padded
    with zero tiles up to ``patch.new_capacity`` first — an allocation,
    but still no table-sized data movement (the pad is zeros and only
    the moved tiles are copied in).

    Args:
      images: the serving image stack (``ShardPlan.build_shard_images``
        output, possibly already patched and/or slack-padded).
      patch: the :class:`~repro.dist.replan.PlanPatch` being applied;
        only ``dma`` and ``new_capacity`` are read.
      fused_image: the fused multi-table host image the plan indexes
        (``repro.dist.build_fused_image``).

    Returns:
      The patched image stack (a new array — jax functional update).
    """
    S, capacity = images.shape[0], images.shape[1]
    if patch.new_capacity > capacity:
        pad = jnp.zeros(
            (S, patch.new_capacity - capacity) + images.shape[2:], images.dtype
        )
        images = jnp.concatenate([images, pad], axis=1)
    if not patch.dma:
        return images
    shards = jnp.asarray([d[0] for d in patch.dma], dtype=jnp.int32)
    slots = jnp.asarray([d[1] for d in patch.dma], dtype=jnp.int32)
    tiles = np.asarray([d[2] for d in patch.dma], dtype=np.int64)
    moved = jnp.asarray(np.asarray(fused_image)[tiles], dtype=images.dtype)
    return images.at[shards, slots].set(moved)


def combine_bytes_per_batch(
    out_rows: int, dim: int, num_shards: int, *, dtype_bytes: int = 4,
) -> int:
    """Cross-shard combine traffic of one batch, summed over shards.

    Ring accounting: a reduce-scatter (or all-gather) of an ``R × dim``
    f32 payload moves ``(S-1)/S × R × dim × 4`` bytes per shard; both
    combine modes cost two such passes (psum_scatter + all_gather, or a
    ring all-reduce), so the accounting is mode-independent.  Payloads
    are OUTPUT-sized — the whole point of combining partial sums instead
    of gathering tiles.
    """
    if num_shards <= 1:
        return 0
    per_shard = (num_shards - 1) / num_shards * out_rows * dim * dtype_bytes
    passes = 2  # reduce-scatter + all-gather, or all-reduce
    return int(passes * per_shard * num_shards)
