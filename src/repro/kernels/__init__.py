"""Pallas TPU kernels for the ReCross hot path.

``crossbar_reduce`` — tiled one-hot MAC embedding reduction with the
dynamic READ/MAC switch (the paper's §III-B/§III-D datapath).
``crossbar_reduce_sharded`` — the multi-table serving entry: shard-local
query-blocked kernels over the ``model`` axis with a psum-scatter-style
cross-shard combine overlapped with the next block chunk's tile DMAs.
``embedding_bag`` — padded gather+sum (naive/nMARS baseline datapath and
single-hot LM token embedding).

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and run under
``interpret=True`` on CPU automatically.
"""

from repro.kernels.ops import (
    crossbar_reduce,
    crossbar_reduce_blocked,
    crossbar_reduce_blocked_ref,
    crossbar_reduce_ref,
    embedding_bag,
    embedding_bag_ref,
)
from repro.kernels.crossbar_reduce import crossbar_reduce_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.decode_attention import fused_decode_attention_pallas
from repro.kernels.ref import fused_decode_attention_ref
from repro.kernels.sharded import (
    combine_bytes_per_batch,
    crossbar_reduce_sharded,
    crossbar_reduce_tables,
    dispatch_cache_stats,
    patch_shard_images,
)

__all__ = [
    "crossbar_reduce", "crossbar_reduce_ref", "crossbar_reduce_pallas",
    "crossbar_reduce_blocked", "crossbar_reduce_blocked_ref",
    "crossbar_reduce_sharded", "crossbar_reduce_tables",
    "combine_bytes_per_batch", "dispatch_cache_stats", "patch_shard_images",
    "embedding_bag", "embedding_bag_ref", "embedding_bag_pallas",
    "fused_decode_attention_pallas", "fused_decode_attention_ref",
]
