"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM.

mLSTM: per-head matrix memory ``C ∈ R^{d×d}`` with exponential input gate
and forget gate, stabilized by the running max ``m`` (log-space gating).
Implemented as a ``lax.scan`` over time carrying ``(C, n, m)``; O(1)-state
decode falls out of the same step function — this is what makes
xlstm-125m eligible for the 500 k-token cell.

sLSTM: scalar-memory LSTM with exponential gating and per-head recurrent
weights, also a time scan carrying ``(c, n, h, m)``.

xlstm-125m alternates: layer i is sLSTM when ``(i % slstm_every) == 0``
(when slstm_every > 0), else mLSTM; both are preceded by RMSNorm and wrap
a residual.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init


# ------------------------------------------------------------- mLSTM ----

def init_mlstm(rng, d_model: int, num_heads: int, dtype) -> Params:
    hd = d_model // num_heads
    kq, kk, kv, ko, kg = jax.random.split(rng, 5)
    return {
        "wq": dense_init(kq, d_model, d_model, dtype),
        "wk": dense_init(kk, d_model, d_model, dtype),
        "wv": dense_init(kv, d_model, d_model, dtype),
        "wo": dense_init(ko, d_model, d_model, dtype, scale=0.5),
        # input & forget gate projections (scalar per head, f32 for stability)
        "wif": dense_init(kg, d_model, 2 * num_heads, jnp.float32),
        "b_i": jnp.zeros((num_heads,), jnp.float32),
        "b_f": jnp.full((num_heads,), 3.0, jnp.float32),  # forget-bias init
    }


def mlstm_scan(
    p: Params,
    x: jax.Array,          # (b, s, d_model)
    num_heads: int,
    *,
    init_state: tuple | None = None,
) -> Tuple[jax.Array, tuple]:
    """Returns (y (b,s,d), (C, n, m) final state)."""
    b, s, d = x.shape
    hd = d // num_heads
    scale = 1.0 / math.sqrt(hd)

    q = (x @ p["wq"]).reshape(b, s, num_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, num_heads, hd) * scale
    v = (x @ p["wv"]).reshape(b, s, num_heads, hd)
    gates = (x.astype(jnp.float32) @ p["wif"]).reshape(b, s, 2, num_heads)
    log_i = gates[:, :, 0] + p["b_i"]          # (b, s, H) pre-activation
    log_f = jax.nn.log_sigmoid(gates[:, :, 1] + p["b_f"])

    if init_state is None:
        C0 = jnp.zeros((b, num_heads, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, num_heads, hd), jnp.float32)
        m0 = jnp.full((b, num_heads), -1e30, jnp.float32)
    else:
        C0, n0, m0 = init_state

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, li, lf = inp  # (b,H,hd), (b,H,hd), (b,H,hd), (b,H), (b,H)
        m_new = jnp.maximum(lf + m, li)
        f_eff = jnp.exp(lf + m - m_new)[..., None]
        i_eff = jnp.exp(li - m_new)[..., None]
        C = C * f_eff[..., None] + i_eff[..., None] * (
            kt.astype(jnp.float32)[..., :, None] * vt.astype(jnp.float32)[..., None, :]
        )
        n = n * f_eff + i_eff * kt.astype(jnp.float32)
        num = jnp.einsum("bhd,bhde->bhe", qt.astype(jnp.float32), C)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qt.astype(jnp.float32), n))
        h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (C, n, m_new), h.astype(x.dtype)

    inputs = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        log_i.transpose(1, 0, 2),
        log_f.transpose(1, 0, 2),
    )
    state, hs = jax.lax.scan(step, (C0, n0, m0), inputs)
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d)
    return y @ p["wo"], state


def mlstm_chunked(
    p: Params,
    x: jax.Array,          # (b, s, d_model)
    num_heads: int,
    *,
    chunk: int = 256,
) -> Tuple[jax.Array, tuple]:
    """Chunkwise-parallel mLSTM — numerically identical to
    :func:`mlstm_scan` but O(s/chunk) sequential steps and O(chunk²)
    MXU-friendly intra-chunk work (the linear-attention duality).

    Log-space bookkeeping: with F_t = Σ lf (cumulative log forget) and
    g_t = li_t − F_t, the stabilizer is m_t = F_t + G_t, G_t = max g_{≤t};
    the carried matrix memory is C̃ = Σ exp(g − M) k vᵀ with M the carried
    max.  BPTT memory is per-chunk boundaries, not per-step — this is the
    memory-term fix recorded in EXPERIMENTS.md §Perf.
    """
    b, s, d = x.shape
    hd = d // num_heads
    scale = 1.0 / math.sqrt(hd)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    q = (x @ p["wq"]).reshape(b, sp, num_heads, hd).astype(jnp.float32)
    k = (x @ p["wk"]).reshape(b, sp, num_heads, hd).astype(jnp.float32) * scale
    v = (x @ p["wv"]).reshape(b, sp, num_heads, hd).astype(jnp.float32)
    gates = (x.astype(jnp.float32) @ p["wif"]).reshape(b, sp, 2, num_heads)
    log_i = gates[:, :, 0] + p["b_i"]                   # (b, sp, H)
    log_f = jax.nn.log_sigmoid(gates[:, :, 1] + p["b_f"])
    if pad:
        # padded steps: forget-gate 0 in log space, input gate -inf
        padmask = jnp.arange(sp) >= s
        log_i = jnp.where(padmask[None, :, None], -1e30, log_i)
        log_f = jnp.where(padmask[None, :, None], 0.0, log_f)

    cs = lambda a: a.reshape(b, nc, chunk, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))
    qc, kc, vc = cs(q), cs(k), cs(v)
    lic, lfc = cs(log_i), cs(log_f)

    def chunk_step(carry, inp):
        C, n, M, F = carry       # C:(b,H,hd,hd) n:(b,H,hd) M,F:(b,H)
        q_blk, k_blk, v_blk, li, lf = inp
        Floc = jnp.cumsum(lf, axis=1)                   # (b, t, H)
        Fg = F[:, None, :] + Floc                       # global F at each t
        g = li - Fg                                     # (b, t, H)
        Gloc = jax.lax.cummax(g, axis=1)
        G = jnp.maximum(M[:, None, :], Gloc)            # (b, t, H) running max
        # intra-chunk scores: w[t, t'] = exp(g_t' - G_t), causal
        wlog = g[:, None, :, :] - G[:, :, None, :]      # (b, t, t', H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]
        w = jnp.exp(jnp.where(causal, wlog, -1e30))
        # inter-chunk: exp(M - G_t)
        inter = jnp.exp(M[:, None, :] - G)              # (b, t, H)
        qk = jnp.einsum("bthd,buhd->btuh", q_blk, k_blk)    # (b, t, t', H)
        scores = w * qk
        num = (
            jnp.einsum("bthd,bhde->bthe", q_blk, C) * inter[..., None]
            + jnp.einsum("btuh,buhe->bthe", scores, v_blk)
        )
        den_vec = (
            jnp.einsum("bthd,bhd->bth", q_blk, n) * inter
            + scores.sum(axis=2)
        )
        m_t = Fg + G
        h = num / jnp.maximum(jnp.abs(den_vec), jnp.exp(-m_t))[..., None]
        # end-of-chunk state update
        M_new = G[:, -1]                                # (b, H)
        decay = jnp.exp(M - M_new)
        wk = jnp.exp(g - M_new[:, None, :])             # (b, t, H)
        C_new = C * decay[..., None, None] + jnp.einsum(
            "bth,bthd,bthe->bhde", wk, k_blk, v_blk
        )
        n_new = n * decay[..., None] + jnp.einsum("bth,bthd->bhd", wk, k_blk)
        F_new = F + Floc[:, -1]
        return (C_new, n_new, M_new, F_new), h

    C0 = jnp.zeros((b, num_heads, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, num_heads, hd), jnp.float32)
    M0 = jnp.full((b, num_heads), -1e30, jnp.float32)
    F0 = jnp.zeros((b, num_heads), jnp.float32)
    (C, n, M, F), hs = jax.lax.scan(chunk_step, (C0, n0, M0, F0), (qc, kc, vc, lic, lfc))
    y = hs.transpose(1, 0, 2, 3, 4).reshape(b, sp, d)[:, :s].astype(x.dtype)
    # sequential-compatible final state: m = F_end + M_end
    return y @ p["wo"], (C, n, F + M)


def mlstm_decode_step(p: Params, x: jax.Array, state: tuple, num_heads: int):
    """One-token step. x: (b, 1, d). Returns (y (b,1,d), new_state)."""
    y, new_state = mlstm_scan(p, x, num_heads, init_state=state)
    return y, new_state


# ------------------------------------------------------------- sLSTM ----

def init_slstm(rng, d_model: int, num_heads: int, dtype) -> Params:
    hd = d_model // num_heads
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        # input projections for [z, i, f, o]
        "w_in": dense_init(k1, d_model, 4 * d_model, dtype),
        # block-diagonal recurrent weights per head: (H, hd, 4*hd)
        "w_rec": (jax.random.truncated_normal(k2, -3, 3, (num_heads, hd, 4 * hd))
                  * (1.0 / math.sqrt(hd))).astype(jnp.float32),
        "bias": jnp.concatenate([
            jnp.zeros((2 * d_model,), jnp.float32),
            jnp.full((d_model,), 3.0, jnp.float32),   # forget bias
            jnp.zeros((d_model,), jnp.float32),
        ]),
        "wo": dense_init(k3, d_model, d_model, dtype, scale=0.5),
    }


def slstm_scan(
    p: Params,
    x: jax.Array,
    num_heads: int,
    *,
    init_state: tuple | None = None,
) -> Tuple[jax.Array, tuple]:
    b, s, d = x.shape
    hd = d // num_heads
    xin = (x @ p["w_in"]).astype(jnp.float32)  # (b, s, 4d)

    if init_state is None:
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.ones((b, d), jnp.float32)
        h0 = jnp.zeros((b, d), jnp.float32)
        m0 = jnp.zeros((b, d), jnp.float32)
    else:
        c0, n0, h0, m0 = init_state

    def step(carry, xt):
        c, n, h, m = carry
        hh = h.reshape(b, num_heads, hd)
        rec = jnp.einsum("bhd,hde->bhe", hh, p["w_rec"]).reshape(b, 4 * d)
        za, ia, fa, oa = jnp.split(xt + rec + p["bias"], 4, axis=-1)
        z = jnp.tanh(za)
        o = jax.nn.sigmoid(oa)
        lf = jax.nn.log_sigmoid(fa)
        m_new = jnp.maximum(lf + m, ia)
        i_eff = jnp.exp(ia - m_new)
        f_eff = jnp.exp(lf + m - m_new)
        c = f_eff * c + i_eff * z
        n = f_eff * n + i_eff
        h = o * (c / jnp.maximum(n, 1e-6))
        return (c, n, h, m_new), h

    state, hs = jax.lax.scan(step, (c0, n0, h0, m0), xin.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    return y @ p["wo"], state


def slstm_decode_step(p: Params, x: jax.Array, state: tuple, num_heads: int):
    y, new_state = slstm_scan(p, x, num_heads, init_state=state)
    return y, new_state
