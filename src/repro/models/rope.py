"""Rotary position embeddings: standard (Llama) and 2D/partial (ChatGLM).

``rope_2d=True`` (ChatGLM3) applies rotation to only the first half of
each head's dims, leaving the rest as-is — GLM's "RoPE 2d" per the
published config.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float, rot_dim: int | None = None):
    rot = rot_dim or head_dim
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv  # (rot/2,)


def apply_rope(
    x: jax.Array,              # (..., seq, heads, head_dim)
    positions: jax.Array,      # (..., seq)
    *,
    theta: float = 10_000.0,
    partial: bool = False,     # rotate only first half of head_dim (GLM)
) -> jax.Array:
    head_dim = x.shape[-1]
    rot_dim = head_dim // 2 if partial else head_dim
    inv = rope_frequencies(head_dim, theta, rot_dim)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., seq, rot/2)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]

    xr = x[..., :rot_dim].astype(jnp.float32)
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(xr.shape).astype(x.dtype)
    if partial:
        return jnp.concatenate([rotated, x[..., rot_dim:]], axis=-1)
    return rotated
