"""DLRM (Naumov et al.) with a ReCross-mapped embedding layer.

Bottom MLP over dense features → sparse embedding-bag reductions (one per
categorical table) → pairwise dot interaction → top MLP → CTR logit.

The embedding path is selectable:
  * ``"dense"``    — gather+sum on the logical table (oracle/CPU baseline),
  * ``"layout"``   — pure-jnp tiled MAC through the ReCross image,
  * ``"kernel"``   — the Pallas crossbar_reduce kernel (TPU hot path).

All three are numerically identical (tests assert it); the simulator
(repro.core.simulator) models what the ReRAM version of the same layout
would cost — together they reproduce the paper's experiments end to end.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mapping import CrossbarLayout
from repro.kernels import crossbar_reduce
from repro.core.reduction import reduce_via_layout
from repro.models.layers import Params, dense_init


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-recross"
    family: str = "recsys"
    num_tables: int = 1
    rows_per_table: int = 65_536
    embed_dim: int = 64
    dense_features: int = 13
    bottom_mlp: tuple = (512, 256, 64)
    top_mlp: tuple = (512, 256, 1)
    max_bag: int = 64             # padded lookups per table per sample
    # ReCross knobs
    group_size: int = 64
    embedding_path: str = "kernel"   # dense | layout | kernel
    dtype: str = "float32"

    @property
    def jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]


def init_dlrm(rng, cfg: DLRMConfig) -> Params:
    keys = jax.random.split(rng, 3 + cfg.num_tables)
    params: Params = {"tables": {}}
    for t in range(cfg.num_tables):
        params["tables"][f"t{t}"] = (
            jax.random.normal(keys[t], (cfg.rows_per_table, cfg.embed_dim)) * 0.01
        ).astype(cfg.jnp_dtype)

    def mlp_params(key, sizes, d_in):
        ps = []
        for i, d_out in enumerate(sizes):
            k = jax.random.fold_in(key, i)
            ps.append({
                "w": dense_init(k, d_in, d_out, cfg.jnp_dtype),
                "b": jnp.zeros((d_out,), cfg.jnp_dtype),
            })
            d_in = d_out
        return ps

    params["bottom"] = mlp_params(keys[-2], cfg.bottom_mlp, cfg.dense_features)
    n_emb = cfg.num_tables + 1
    n_pairs = n_emb * (n_emb - 1) // 2
    top_in = cfg.bottom_mlp[-1] + n_pairs
    params["top"] = mlp_params(keys[-1], cfg.top_mlp, top_in)
    return params


def _apply_mlp(ps, x, final_linear=False):
    for i, p in enumerate(ps):
        x = x @ p["w"] + p["b"]
        if not (final_linear and i == len(ps) - 1):
            x = jax.nn.relu(x)
    return x


def dlrm_forward(
    params: Params,
    cfg: DLRMConfig,
    dense: jax.Array,                    # (b, dense_features)
    sparse: Dict[str, Any],              # per-table query tensors (see below)
    *,
    layouts: Optional[Dict[str, CrossbarLayout]] = None,
    images: Optional[Dict[str, jax.Array]] = None,
) -> jax.Array:
    """Returns CTR logits (b,).

    ``sparse[f"t{i}"]`` is
      * ``indices`` (b, max_bag) int32 −1-padded          (dense path), or
      * ``(tile_ids, bitmaps)``                            (layout/kernel).
    """
    b = dense.shape[0]
    x_dense = _apply_mlp(params["bottom"], dense)

    embs: List[jax.Array] = [x_dense]
    for t in range(cfg.num_tables):
        key = f"t{t}"
        if cfg.embedding_path == "dense":
            idx = sparse[key]
            table = params["tables"][key]
            take = table[jnp.clip(idx, 0, table.shape[0] - 1)]
            e = (take * (idx >= 0)[..., None]).sum(axis=1)
        else:
            tile_ids, bitmaps = sparse[key]
            image = images[key]
            if cfg.embedding_path == "kernel":
                # image dim is padded to a 128 multiple by build_images
                e = crossbar_reduce(image, tile_ids, bitmaps)[:, : cfg.embed_dim]
            else:
                flat = image.reshape(-1, image.shape[-1])
                e = reduce_via_layout(
                    flat, tile_ids, bitmaps, tile_rows=image.shape[1]
                )[:, : cfg.embed_dim]
        embs.append(e.astype(x_dense.dtype))

    # pairwise dot-product interaction
    stack = jnp.stack(embs, axis=1)                       # (b, n_emb, d)
    inter = jnp.einsum("bnd,bmd->bnm", stack, stack)
    iu = jnp.triu_indices(stack.shape[1], k=1)
    pairs = inter[:, iu[0], iu[1]]                        # (b, n_pairs)

    top_in = jnp.concatenate([x_dense, pairs], axis=-1)
    return _apply_mlp(params["top"], top_in, final_linear=True)[:, 0]


def dlrm_loss(params, cfg, dense, sparse, labels, **kw):
    logits = dlrm_forward(params, cfg, dense, sparse, **kw)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def build_images(params: Params, cfg: DLRMConfig, layouts: Dict[str, CrossbarLayout]):
    """Materializes per-table crossbar images from current table params.

    The MXU lane width is 128, so the embedding dim is zero-padded up to a
    128 multiple for the kernel path (the forward slices it back off) —
    the TPU equivalent of the paper's column padding on 64-wide crossbars.
    """
    images = {}
    pad = (-cfg.embed_dim) % 128
    for key, layout in layouts.items():
        tbl = np.asarray(params["tables"][key], np.float32)
        img = layout.build_image(tbl).reshape(
            layout.num_tiles, layout.tile_rows, cfg.embed_dim
        )
        if pad:
            img = np.pad(img, ((0, 0), (0, 0), (0, pad)))
        images[key] = jnp.asarray(img, params["tables"][key].dtype)
    return images
