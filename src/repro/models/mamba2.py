"""Mamba2 (SSD) block — chunked state-space duality implementation.

The selective state-space recurrence

    h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t          (A scalar per head, SSD)
    y_t = C_t · h_t + D x_t

is computed with the SSD chunk decomposition: the sequence is split into
chunks of ``chunk`` steps; within a chunk the contribution is the masked
quadratic form (an attention-like einsum that maps onto the MXU), and a
``lax.scan`` over chunks carries the inter-chunk state ``(heads, p, N)``.
This is the standard train/prefill path; decode uses the O(1) recurrence
step (:func:`mamba_decode_step`).

Shapes follow Mamba2: ``d_inner = 2·d_model``, heads of head dim ``p``,
state size ``N = ssm_state``.  The depthwise causal conv is width 4.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init

CONV_W = 4


def init_mamba2(rng, d_model: int, ssm_state: int, dtype, *, head_dim: int = 64) -> Params:
    d_inner = 2 * d_model
    heads = d_inner // head_dim
    N = ssm_state
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    return {
        # fused input projection: [x, z, B, C, dt]
        "w_in": dense_init(k1, d_model, 2 * d_inner + 2 * N + heads, dtype),
        "conv": (jax.random.truncated_normal(k2, -3, 3, (CONV_W, d_inner)) * 0.2).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(jnp.float32),
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "w_out": dense_init(k3, d_inner, d_model, dtype, scale=0.5),
        "norm_scale": jnp.ones((d_inner,), dtype),
    }


def _split_proj(proj, d_inner, N, heads):
    x, z, B, C, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    return x, z, B, C, dt


def _causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv, width CONV_W. x: (b, s, d). state: (b, CONV_W-1, d)."""
    if state is None:
        pad = jnp.zeros((x.shape[0], CONV_W - 1, x.shape[-1]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(CONV_W))
    new_state = xp[:, -(CONV_W - 1) :]
    return out, new_state


def apply_mamba2(
    p: Params,
    u: jax.Array,                 # (b, s, d_model)
    *,
    ssm_state: int,
    head_dim: int = 64,
    chunk: int = 128,
) -> jax.Array:
    y, _ = mamba2_scan(p, u, ssm_state=ssm_state, head_dim=head_dim, chunk=chunk)
    return y


def mamba2_scan(
    p: Params,
    u: jax.Array,
    *,
    ssm_state: int,
    head_dim: int = 64,
    chunk: int = 128,
    init_state: jax.Array | None = None,
    conv_state: jax.Array | None = None,
) -> Tuple[jax.Array, tuple]:
    b, s, d_model = u.shape
    d_inner = 2 * d_model
    heads = d_inner // head_dim
    N = ssm_state

    proj = u @ p["w_in"]
    x, z, B, C, dt = _split_proj(proj, d_inner, N, heads)
    x, conv_out_state = _causal_conv(x, p["conv"], conv_state)
    x = jax.nn.silu(x)
    B = jax.nn.silu(B)   # (b, s, N) — shared across heads (Mamba2 multi-value)
    C = jax.nn.silu(C)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b, s, H)
    A = -jnp.exp(p["A_log"])                                     # (H,) negative

    xh = x.reshape(b, s, heads, head_dim)

    # pad sequence to chunk multiple
    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    xc = xh.reshape(b, nc, chunk, heads, head_dim)
    Bc = B.reshape(b, nc, chunk, N)
    Cc = C.reshape(b, nc, chunk, N)
    dtc = dt.reshape(b, nc, chunk, heads)

    # per-step decay a_t = exp(dt_t * A): (b, nc, chunk, H)
    log_a = dtc * A  # negative
    cum = jnp.cumsum(log_a, axis=2)  # within-chunk cumulative log decay

    def chunk_step(h, inputs):
        xck, Bck, Cck, dtk, logak, cumk = inputs
        # h: (b, H, p, N) carried state (in f32)
        # intra-chunk (quadratic, attention-like): L[t,t'] = exp(cum_t - cum_t') for t >= t'
        rel = cumk[:, :, None, :] - cumk[:, None, :, :]          # (b, t, t', H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]
        # mask BEFORE exp: exp of masked (positive, unbounded) entries would
        # overflow and poison gradients through the where (inf * 0 = NaN)
        L = jnp.exp(jnp.where(causal, rel, -1e30))
        # scores: (b, t, t', H) * C_t·B_t'
        cb = jnp.einsum("btn,bun->btu", Cck, Bck)                # (b, t, t')
        w = L * cb[..., None] * dtk[:, None, :, :]               # dt at source t'
        y_intra = jnp.einsum("btuh,buhp->bthp", w, xck.astype(jnp.float32))
        # contribution of carried state: y += C_t · (decay_t * h)
        decay_in = jnp.exp(cumk)                                 # (b, t, H)
        y_state = jnp.einsum("btn,bhpn->bthp", Cck, h) * decay_in[..., None]
        # update state: h' = decay_chunk * h + Σ_t decay_{end..t} dt_t B_t x_t
        total = jnp.exp(cumk[:, -1])                             # (b, H)
        tail = jnp.exp(cumk[:, -1][:, None, :] - cumk)           # (b, t, H)
        dBx = jnp.einsum(
            "bth,btn,bthp->bhpn", dtk * tail, Bck, xck.astype(jnp.float32)
        )
        h_new = h * total[:, :, None, None] + dBx
        return h_new, (y_intra + y_state).astype(u.dtype)

    if init_state is None:
        h0 = jnp.zeros((b, heads, head_dim, N), jnp.float32)
    else:
        h0 = init_state
    inputs = (
        xc.transpose(1, 0, 2, 3, 4),
        Bc.transpose(1, 0, 2, 3),
        Cc.transpose(1, 0, 2, 3),
        dtc.transpose(1, 0, 2, 3),
        log_a.reshape(b, nc, chunk, heads).transpose(1, 0, 2, 3),
        cum.reshape(b, nc, chunk, heads).transpose(1, 0, 2, 3),
    )
    h_last, ys = jax.lax.scan(chunk_step, h0, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, heads, head_dim)[:, :s]
    y = y + xh[:, :s] * p["D"][None, None, :, None].astype(u.dtype)
    y = y.reshape(b, s, d_inner)

    # gated RMSNorm (Mamba2)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)).astype(u.dtype)
    y = y * p["norm_scale"] * jax.nn.silu(z)
    return y @ p["w_out"], (h_last, conv_out_state)


def mamba2_decode_step(
    p: Params,
    u: jax.Array,                 # (b, 1, d_model)
    state: jax.Array,             # (b, H, p, N) f32
    conv_state: jax.Array,        # (b, CONV_W-1, d_inner)
    *,
    ssm_state: int,
    head_dim: int = 64,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """O(1) recurrence step. Returns (y (b,1,d), new_state, new_conv_state)."""
    b, _, d_model = u.shape
    d_inner = 2 * d_model
    heads = d_inner // head_dim
    N = ssm_state

    proj = u @ p["w_in"]
    x, z, B, C, dt = _split_proj(proj, d_inner, N, heads)
    x, conv_state = _causal_conv(x, p["conv"], conv_state)
    x = jax.nn.silu(x)[:, 0]                                  # (b, d_inner)
    B = jax.nn.silu(B)[:, 0]                                  # (b, N)
    C = jax.nn.silu(C)[:, 0]
    dt = jax.nn.softplus(dt.astype(jnp.float32)[:, 0] + p["dt_bias"])  # (b, H)
    A = -jnp.exp(p["A_log"])

    xh = x.reshape(b, heads, head_dim).astype(jnp.float32)
    decay = jnp.exp(dt * A)                                   # (b, H)
    state = state * decay[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, B, xh
    )
    y = jnp.einsum("bn,bhpn->bhp", C, state)                  # (b, H, p)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b, 1, d_inner).astype(u.dtype)

    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)).astype(u.dtype)
    y = y * p["norm_scale"] * jax.nn.silu(z)
    return y @ p["w_out"], state, conv_state
