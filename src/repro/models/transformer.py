"""Generic decoder LM covering all assigned families.

One ``init_lm`` / ``forward`` / ``decode_step`` triple handles:

  dense   — pre-norm GQA + (Sw/Ge)GLU MLP blocks, scanned over layers
  moe     — MLP replaced by top-k expert layer (sort-free dispatch)
  vlm     — superblocks of ``cross_attn_period`` self layers + 1 gated
            cross-attention layer over stub image embeddings
  audio   — musicgen: K codebook embeddings summed at input, K heads out
  ssm     — xLSTM: mLSTM blocks with periodic sLSTM (no FFN when d_ff=0)
  hybrid  — zamba2: Mamba2 backbone + ONE shared attention block applied
            every ``shared_attn_period`` layers (params shared across all
            applications — the Zamba trick)

All layer stacks are ``lax.scan``-ed over stacked param pytrees so the
lowered HLO is one block body regardless of depth (compile-time posture
for the 512-device dry-run, and faster compiles in production).

Activation sharding is annotated with logical axes via
:func:`repro.dist.sharding.maybe_shard` — a no-op outside a mesh context.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import maybe_shard
from repro.models import attention as attn
from repro.models import mamba2, xlstm
from repro.models.layers import (
    Params,
    apply_mlp,
    apply_norm,
    embed_init,
    dense_init,
    init_mlp,
    init_norm,
    stack_layers,
)
from repro.models.moe import apply_moe, init_moe


# ============================================================= init ======


def _init_block(rng, cfg: ModelConfig) -> Params:
    """One decoder block (dense/moe/audio families)."""
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    p: Params = {
        "norm_attn": init_norm(d, cfg.norm, cfg.jnp_dtype),
        "attn": attn.init_attention(
            k1, d, cfg.num_heads, cfg.kv_heads, hd, cfg.jnp_dtype, use_bias=cfg.use_bias
        ),
        "norm_mlp": init_norm(d, cfg.norm, cfg.jnp_dtype),
    }
    if cfg.moe:
        p["moe"] = init_moe(k2, d, cfg.d_ff, cfg.moe, cfg.act, cfg.jnp_dtype)
    elif cfg.d_ff:
        p["mlp"] = init_mlp(k2, d, cfg.d_ff, cfg.act, cfg.jnp_dtype, use_bias=cfg.use_bias)
    return p


def _init_xlstm_layers(rng, cfg: ModelConfig) -> Params:
    ks = jax.random.split(rng, cfg.num_layers)
    m_layers, s_layers = [], []
    for i in range(cfg.num_layers):
        block = {"norm": init_norm(cfg.d_model, cfg.norm, cfg.jnp_dtype)}
        if cfg.slstm_every and i % cfg.slstm_every == 0:
            block["cell"] = xlstm.init_slstm(ks[i], cfg.d_model, cfg.num_heads, cfg.jnp_dtype)
            s_layers.append(block)
        else:
            block["cell"] = xlstm.init_mlstm(ks[i], cfg.d_model, cfg.num_heads, cfg.jnp_dtype)
            m_layers.append(block)
    return {"slstm": stack_layers(s_layers), "mlstm": stack_layers(m_layers)}


def _init_zamba_layers(rng, cfg: ModelConfig) -> Params:
    period = cfg.shared_attn_period
    n_super = cfg.num_layers // period
    n_tail = cfg.num_layers - n_super * period
    ks = jax.random.split(rng, cfg.num_layers + 2)
    mk = lambda k: {
        "norm": init_norm(cfg.d_model, cfg.norm, cfg.jnp_dtype),
        "mamba": mamba2.init_mamba2(k, cfg.d_model, cfg.ssm_state, cfg.jnp_dtype),
    }
    body = stack_layers([mk(ks[i]) for i in range(n_super * period)])
    body = jax.tree.map(lambda x: x.reshape(n_super, period, *x.shape[1:]), body)
    tail = stack_layers([mk(ks[n_super * period + i]) for i in range(n_tail)]) if n_tail else None
    shared = {
        "norm": init_norm(cfg.d_model, cfg.norm, cfg.jnp_dtype),
        "attn": attn.init_attention(
            ks[-1], cfg.d_model, cfg.num_heads, cfg.kv_heads,
            cfg.resolved_head_dim, cfg.jnp_dtype,
        ),
    }
    out = {"super": body, "shared_attn": shared}
    if tail is not None:
        out["tail"] = tail
    return out


def _init_vlm_layers(rng, cfg: ModelConfig) -> Params:
    period = cfg.cross_attn_period
    n_super = cfg.num_layers // (period + 1)
    assert n_super * (period + 1) == cfg.num_layers, "vlm layers % (period+1) != 0"
    ks = jax.random.split(rng, cfg.num_layers + n_super)
    self_blocks = [
        _init_block(ks[i], cfg) for i in range(n_super * period)
    ]
    stacked = stack_layers(self_blocks)
    stacked = jax.tree.map(lambda x: x.reshape(n_super, period, *x.shape[1:]), stacked)
    cross = stack_layers([
        {
            "norm": init_norm(cfg.d_model, cfg.norm, cfg.jnp_dtype),
            "xattn": attn.init_cross_attention(
                ks[n_super * period + i], cfg.d_model, cfg.num_heads, cfg.kv_heads,
                cfg.resolved_head_dim, cfg.d_model, cfg.jnp_dtype,
            ),
            "norm_mlp": init_norm(cfg.d_model, cfg.norm, cfg.jnp_dtype),
            "mlp": init_mlp(ks[n_super * period + i], cfg.d_model, cfg.d_ff, cfg.act, cfg.jnp_dtype),
        }
        for i in range(n_super)
    ])
    return {"super": stacked, "cross": cross}


def init_lm(rng, cfg: ModelConfig) -> Params:
    """Initializes the full parameter pytree for any supported family."""
    k_emb, k_layers, k_head = jax.random.split(rng, 3)
    params: Params = {"final_norm": init_norm(cfg.d_model, cfg.norm, cfg.jnp_dtype)}

    V = cfg.padded_vocab  # padded so the vocab axis shards at any TP degree
    if cfg.family == "audio":
        for c in range(cfg.num_codebooks):
            kc = jax.random.fold_in(k_emb, c)
            params[f"embed_{c}"] = embed_init(kc, V, cfg.d_model, cfg.jnp_dtype)
            params[f"head_{c}"] = dense_init(
                jax.random.fold_in(k_head, c), cfg.d_model, V, cfg.jnp_dtype
            )
    else:
        params["embed"] = embed_init(k_emb, V, cfg.d_model, cfg.jnp_dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(k_head, cfg.d_model, V, cfg.jnp_dtype)

    if cfg.family == "ssm":
        params["layers"] = _init_xlstm_layers(k_layers, cfg)
    elif cfg.family == "hybrid":
        params["layers"] = _init_zamba_layers(k_layers, cfg)
    elif cfg.family == "vlm":
        params["layers"] = _init_vlm_layers(k_layers, cfg)
    else:  # dense | moe | audio
        ks = jax.random.split(k_layers, cfg.num_layers)
        params["layers"] = stack_layers([_init_block(k, cfg) for k in ks])
    return params


# ========================================================== forward ======


CHUNKED_ATTN_THRESHOLD = 4096  # seqs >= this use flash-style chunked attention


def _block_fwd(p: Params, x, cfg: ModelConfig, positions, *, window: int = 0):
    """Dense/moe/audio block. Returns (x, aux)."""
    s = x.shape[1]
    if s >= CHUNKED_ATTN_THRESHOLD:
        h = attn.chunked_self_attention(
            p["attn"], apply_norm(p["norm_attn"], x, cfg.norm),
            num_heads=cfg.num_heads, kv_heads=cfg.kv_heads,
            head_dim=cfg.resolved_head_dim, positions=positions,
            rope_theta=cfg.rope_theta, rope_partial=cfg.rope_2d, window=window,
        )
    else:
        h = attn.self_attention(
            p["attn"], apply_norm(p["norm_attn"], x, cfg.norm),
            num_heads=cfg.num_heads, kv_heads=cfg.kv_heads,
            head_dim=cfg.resolved_head_dim, positions=positions,
            rope_theta=cfg.rope_theta, rope_partial=cfg.rope_2d, window=window,
        )
    x = x + h
    x = maybe_shard(x, ("batch", "seq", "embed"))
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe:
        if cfg.moe_impl == "shardmap":
            from repro.models.moe import apply_moe_shardmap
            y, aux = apply_moe_shardmap(
                p["moe"], apply_norm(p["norm_mlp"], x, cfg.norm), cfg.moe, cfg.act
            )
        else:
            y, aux = apply_moe(
                p["moe"], apply_norm(p["norm_mlp"], x, cfg.norm), cfg.moe,
                cfg.act, num_groups=cfg.moe_groups,
            )
        x = x + y
    elif cfg.d_ff:
        x = x + apply_mlp(p["mlp"], apply_norm(p["norm_mlp"], x, cfg.norm), cfg.act)
    x = maybe_shard(x, ("batch", "seq", "embed"))
    return x, aux


def _scan_blocks(stacked: Params, x, cfg: ModelConfig, positions, *, remat=False):
    def body(carry, layer_p):
        h, aux = _block_fwd(layer_p, carry, cfg, positions)
        return h, aux

    if remat:
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, stacked)
    return x, auxs.sum()


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,                  # (b, s) or (b, K, s) for audio
    *,
    enc: Optional[jax.Array] = None,    # (b, t_img, d) vlm stub embeddings
    remat: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits, aux_loss)."""
    if cfg.family == "audio":
        x = sum(
            params[f"embed_{c}"][tokens[:, c]] for c in range(cfg.num_codebooks)
        )
        b, s = tokens.shape[0], tokens.shape[-1]
    else:
        x = params["embed"][tokens]
        b, s = tokens.shape
    x = maybe_shard(x, ("batch", "seq", "embed"))
    positions = jnp.arange(s)[None, :]
    aux = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe", "audio"):
        x, aux = _scan_blocks(params["layers"], x, cfg, positions, remat=remat)

    elif cfg.family == "vlm":
        assert enc is not None, "vlm needs image embeddings (stub frontend)"

        def superblock(carry, ps):
            self_p, cross_p = ps
            h, a = _scan_blocks(self_p, carry, cfg, positions, remat=remat)
            hn = apply_norm(cross_p["norm"], h, cfg.norm)
            h = h + attn.cross_attention(
                cross_p["xattn"], hn, enc, num_heads=cfg.num_heads,
                kv_heads=cfg.kv_heads, head_dim=cfg.resolved_head_dim,
            )
            h = h + apply_mlp(cross_p["mlp"], apply_norm(cross_p["norm_mlp"], h, cfg.norm), cfg.act)
            return h, a

        if remat:
            superblock = jax.checkpoint(superblock)
        x, auxs = jax.lax.scan(
            superblock, x, (params["layers"]["super"], params["layers"]["cross"])
        )
        aux = auxs.sum()

    elif cfg.family == "ssm":
        x = _xlstm_forward(params["layers"], x, cfg, remat=remat)

    elif cfg.family == "hybrid":
        x = _zamba_forward(params["layers"], x, cfg, positions, remat=remat)

    else:
        raise ValueError(f"unknown family {cfg.family}")

    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.family == "audio":
        logits = jnp.stack(
            [x @ params[f"head_{c}"] for c in range(cfg.num_codebooks)], axis=1
        )  # (b, K, s, V)
    else:
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = x @ head
        logits = maybe_shard(logits, ("batch", "seq", "vocab"))
    return logits, aux


MLSTM_CHUNK_THRESHOLD = 256  # seqs >= this use the chunkwise-parallel mLSTM


def _mlstm_apply(cell_p, x, num_heads):
    """Chunkwise-parallel mLSTM for long sequences (O(chunk) BPTT memory,
    MXU-friendly), exact sequential scan for short ones."""
    if x.shape[1] >= MLSTM_CHUNK_THRESHOLD:
        y, _ = xlstm.mlstm_chunked(cell_p, x, num_heads)
    else:
        y, _ = xlstm.mlstm_scan(cell_p, x, num_heads)
    return y


def _xlstm_forward(layers: Params, x, cfg: ModelConfig, *, remat: bool = False):
    """Alternating sLSTM / mLSTM blocks: sLSTM at i % slstm_every == 0."""
    period = cfg.slstm_every or cfg.num_layers + 1
    n_s = layers["slstm"]["norm"]["scale"].shape[0] if "slstm" in layers else 0
    n_m_per = period - 1

    m_stacked = layers["mlstm"]
    if n_s:
        m_stacked = jax.tree.map(
            lambda a: a.reshape(n_s, n_m_per, *a.shape[1:]), m_stacked
        )

        def superblock(carry, ps):
            s_p, m_p = ps
            h, _ = xlstm.slstm_scan(
                s_p["cell"], apply_norm(s_p["norm"], carry, cfg.norm), cfg.num_heads
            )
            carry = carry + h

            def mbody(c, mp):
                y = _mlstm_apply(
                    mp["cell"], apply_norm(mp["norm"], c, cfg.norm), cfg.num_heads
                )
                return c + y, None

            carry, _ = jax.lax.scan(mbody, carry, m_p)
            return carry, None

        if remat:
            superblock = jax.checkpoint(superblock)
        x, _ = jax.lax.scan(superblock, x, (layers["slstm"], m_stacked))
    else:
        def mbody(c, mp):
            y = _mlstm_apply(
                mp["cell"], apply_norm(mp["norm"], c, cfg.norm), cfg.num_heads
            )
            return c + y, None

        if remat:
            mbody = jax.checkpoint(mbody)
        x, _ = jax.lax.scan(mbody, x, m_stacked)
    return x


def _zamba_forward(
    layers: Params, x, cfg: ModelConfig, positions, *, window: int = 0,
    remat: bool = False,
):
    """Mamba2 backbone with ONE shared attention block every period layers."""
    shared = layers["shared_attn"]

    def mamba_block(c, mp):
        y = mamba2.apply_mamba2(
            mp["mamba"], apply_norm(mp["norm"], c, cfg.norm), ssm_state=cfg.ssm_state
        )
        return c + y, None

    s = x.shape[1]
    # long sequences: windowed + chunked shared attention (sub-quadratic)
    if s >= CHUNKED_ATTN_THRESHOLD:
        window = window or 4096
        attn_fn = functools.partial(attn.chunked_self_attention, window=window)
    else:
        attn_fn = functools.partial(attn.self_attention, window=window)

    def superblock(carry, ps):
        h, _ = jax.lax.scan(mamba_block, carry, ps)
        # shared attention (same params every application)
        a = attn_fn(
            shared["attn"], apply_norm(shared["norm"], h, cfg.norm),
            num_heads=cfg.num_heads, kv_heads=cfg.kv_heads,
            head_dim=cfg.resolved_head_dim, positions=positions,
            rope_theta=cfg.rope_theta,
        )
        return h + a, None

    if remat:
        superblock = jax.checkpoint(superblock)
    x, _ = jax.lax.scan(superblock, x, layers["super"])
    if "tail" in layers:
        x, _ = jax.lax.scan(mamba_block, x, layers["tail"])
    return x


# ============================================================= loss ======


def lm_loss(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    labels: jax.Array,
    *,
    enc: Optional[jax.Array] = None,
    remat: bool = False,
    aux_weight: float = 0.01,
) -> jax.Array:
    logits, aux = forward(params, cfg, tokens, enc=enc, remat=remat)
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask the padded vocab tail out of the softmax normalizer
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()
    return nll + aux_weight * aux
