from repro.models.transformer import forward, init_lm, lm_loss
from repro.models.dlrm import DLRMConfig, dlrm_forward, dlrm_loss, init_dlrm

__all__ = [
    "forward", "init_lm", "lm_loss",
    "DLRMConfig", "dlrm_forward", "dlrm_loss", "init_dlrm",
]
