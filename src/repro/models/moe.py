"""Mixture-of-Experts FFN with sort-free capacity dispatch.

Dispatch strategy (MaxText/T5X-style, memory-sane): for each token's
top-k choice, compute its *position within the expert's buffer* via a
cumulative-sum over the (tokens, experts) routing one-hot — an O(T·E)
intermediate, never the O(T·E·C) dispatch tensor.  Tokens are scattered
into a per-expert buffer ``(E, C, d)``, batch-matmul'd against stacked
expert weights (the einsum the ``model`` axis shards as expert
parallelism), and combined back with router weights.

Capacity ``C = ceil(T · top_k · cf / E)``; overflow tokens are dropped
(standard practice, cf=1.25 default) — drop fraction is returned for
monitoring and the aux load-balancing loss pushes the router away from
that regime.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import maybe_shard, maybe_shard_any
from repro.models.layers import Params, dense_init
from repro.configs.base import MoEConfig

# dispatch/combine buffers: shard capacity over data (token parallelism
# follows the batch), expert dim over model when it divides, else keep
# experts local and let the f-dim TP inside the einsum carry the model axis
_BUF_SHARDINGS = (
    ("experts", "expert_cap_dp", None),
    (None, "expert_cap_dp", None),
)
_HID_SHARDINGS = (
    ("experts", "expert_cap_dp", "mlp"),
    (None, "expert_cap_dp", "mlp"),
)


def init_moe(rng, d_model: int, d_ff: int, moe: MoEConfig, act: str, dtype) -> Params:
    kr, kg, kv, ko = jax.random.split(rng, 4)
    E = moe.num_experts
    p: Params = {
        "router": dense_init(kr, d_model, E, jnp.float32),  # router in f32
        "w_out": (jax.random.truncated_normal(ko, -3, 3, (E, d_ff, d_model)) * (0.5 / math.sqrt(d_ff))).astype(dtype),
        "w_val": (jax.random.truncated_normal(kv, -3, 3, (E, d_model, d_ff)) * (1.0 / math.sqrt(d_model))).astype(dtype),
    }
    if act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.truncated_normal(kg, -3, 3, (E, d_model, d_ff)) * (1.0 / math.sqrt(d_model))).astype(dtype)
    return p


def apply_moe(
    p: Params,
    x: jax.Array,          # (b, s, d)
    moe: MoEConfig,
    act: str = "swiglu",
    *,
    num_groups: int = 1,
    shard_buffers: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (b,s,d), aux_loss scalar).

    ``num_groups > 1`` enables GROUP-LOCAL dispatch (T5X/MaxText style):
    tokens are split into ``num_groups`` contiguous blocks, each with its
    own per-expert capacity ``C/num_groups`` and a block-local cumsum.
    When num_groups equals the data-parallel degree and the token axis is
    batch-sharded, every scatter stays shard-local — the cross-shard
    dispatch all-to-all disappears (§Perf granite/grok iterations).
    Dropping decisions become per-block instead of global (standard
    trade-off; same expected drop rate under a balanced router).
    """
    b, s, d = x.shape
    E, k = moe.num_experts, moe.top_k
    T = b * s
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32)) @ p["router"]         # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                  # (T, k)
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * Σ_e f_e · P_e
    dispatch_onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)  # (T, k, E)
    f = dispatch_onehot.sum(axis=(0, 1)) / (T * k)
    P = probs.mean(axis=0)
    aux = E * jnp.sum(f * P)

    G = num_groups if T % num_groups == 0 else 1
    Tg = T // G
    Cg = int(math.ceil(Tg * k * moe.capacity_factor / E))
    Cg = max(Cg, 8)
    C = G * Cg

    # position of each (token, choice) inside its expert's buffer —
    # cumsum runs WITHIN each token group; group g owns buffer rows
    # [g*Cg, (g+1)*Cg) so scatters never cross group (= shard) boundaries
    flat_e = top_e.reshape(G, Tg * k)                        # grouped choices
    choice_onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (G, Tg*k, E)
    pos_in_e = jnp.cumsum(choice_onehot, axis=1) * choice_onehot
    position = pos_in_e.sum(axis=-1) - 1                     # (G, Tg*k)
    keep = position < Cg
    position = jnp.where(keep, position, Cg - 1) + jnp.arange(G)[:, None] * Cg
    flat_e = flat_e.reshape(T * k)
    position = position.reshape(T * k)
    keep = keep.reshape(T * k)

    # scatter tokens into per-expert buffers
    buf = jnp.zeros((E, C, d), x.dtype)
    tok_idx = jnp.repeat(jnp.arange(T), k)
    pos_clip = position
    buf = buf.at[flat_e, pos_clip].add(
        xt[tok_idx] * keep[:, None].astype(x.dtype)
    )
    if shard_buffers:
        buf = maybe_shard_any(buf, _BUF_SHARDINGS)

    # expert FFN: batched matmul over the expert axis (EP shards this)
    if "w_gate" in p:
        gate_act = jax.nn.silu if act == "swiglu" else jax.nn.gelu
        h = gate_act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * jnp.einsum(
            "ecd,edf->ecf", buf, p["w_val"]
        )
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w_val"]))
    if shard_buffers:
        h = maybe_shard_any(h, _HID_SHARDINGS)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_out"])      # (E, C, d)
    if shard_buffers:
        out_buf = maybe_shard_any(out_buf, _BUF_SHARDINGS)

    # combine: gather each choice's result, weight, sum over k
    gathered = out_buf[flat_e, pos_clip] * keep[:, None].astype(x.dtype)  # (T*k, d)
    weighted = gathered * top_w.reshape(T * k, 1).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok_idx].add(weighted)
    return y.reshape(b, s, d), aux


def apply_moe_shardmap(
    p: Params,
    x: jax.Array,          # (b, s, d) batch-sharded over the dp axes
    moe: MoEConfig,
    act: str = "swiglu",
) -> Tuple[jax.Array, jax.Array]:
    """shard_map MoE: dispatch is SHARD-LOCAL over the data axes.

    GSPMD's auto-partitioning of the capacity scatter materializes the
    dispatch as buffer-sized all-reduces (measured: granite train_4k moves
    ~1.6 TiB/step of all-reduce, §Perf).  Here the token→expert scatter and
    the expert→token combine never leave the data shard: the region is
    *manual* over the dp axes and *auto* over "model", so the expert
    einsums keep their tensor-parallel sharding, and the FSDP-sharded
    expert weights are explicitly all-gathered once per call (the cheap
    direction: weights ≪ dispatch buffers).

    Falls back to :func:`apply_moe` outside a mesh context.
    """
    from repro.dist.sharding import _current
    from jax.sharding import PartitionSpec as P

    rules, mesh = _current()
    if mesh is None:
        return apply_moe(p, x, moe, act)
    dp = rules.get("batch", "data")
    dp_axes = dp if isinstance(dp, tuple) else (dp,)
    manual = frozenset(dp_axes)
    auto = frozenset(mesh.axis_names) - manual

    def local(x_loc, router, w_gate, w_val, w_out):
        # gather the FSDP (data-dim) shards of the expert weights
        w_gate = _ag(w_gate, dp_axes, axis=1)
        w_val = _ag(w_val, dp_axes, axis=1)
        w_out = _ag(w_out, dp_axes, axis=2)
        pl = {"router": router, "w_gate": w_gate, "w_val": w_val, "w_out": w_out}
        y_loc, aux = apply_moe(pl, x_loc, moe, act, shard_buffers=False)
        return y_loc, jax.lax.pmean(aux, dp_axes[-1])

    try:
        shard_map = jax.shard_map
        partial_kw = {"axis_names": manual}
    except AttributeError:
        # jax < 0.5 only has the experimental API (param spelled `auto`),
        # and its partial-auto regions hard-abort XLA-CPU's SPMD
        # partitioner when the manual body issues collectives
        # (spmd_partitioner.cc IsManualSubgroup check, verified on 0.4.37).
        # Fall back to the GSPMD auto path rather than risk a process
        # abort — slower (buffer-sized all-reduces) but correct.
        del auto
        return apply_moe(p, x, moe, act)

    in_specs = (
        P(dp, None, None),        # x: batch over dp
        P(),                      # router replicated
        P(None, dp, None),        # w_gate (E, d/fsdp, f)
        P(None, dp, None),        # w_val
        P(None, None, dp),        # w_out (E, f, d/fsdp)
    )
    out_specs = (P(dp, None, None), P())
    y, aux = shard_map(
        local, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **partial_kw,
    )(x, p["router"], p["w_gate"], p["w_val"], p["w_out"])
    return y, aux


def _ag(w, dp_axes, *, axis):
    # route through f32: the transpose of a bf16 all_gather is a bf16
    # reduce-scatter, which crashes XLA-CPU's AllReducePromotion pass
    # (hlo_instruction.cc "Invalid binary instruction opcode copy").
    # On TPU this cast is unnecessary; cost here is 2x gather payload.
    orig = w.dtype
    w = w.astype(jnp.float32)
    for a in dp_axes:
        w = jax.lax.all_gather(w, a, axis=axis, tiled=True)
    return w.astype(orig)


def moe_flops_per_token(d_model: int, d_ff: int, moe: MoEConfig, act: str) -> int:
    """Active FLOPs per token (for 6ND-style accounting)."""
    mats = 3 if act in ("swiglu", "geglu") else 2
    return 2 * mats * d_model * d_ff * moe.top_k
