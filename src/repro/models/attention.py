"""Attention: GQA self-attention (train/prefill/decode) and cross-attention.

Layout convention: activations ``(batch, seq, d_model)``; Q/K/V projected to
``(batch, seq, heads, head_dim)``.  GQA repeats KV groups logically via
einsum reshape — no materialized repeat_kv.

Decode path takes a KV cache ``(batch, max_seq, kv_heads, head_dim)`` per
layer and a write position; attention masks by cache validity, not
position comparison against materialized ranges, so the same code serves
32 k and 500 k caches.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import maybe_shard_any
from repro.models.layers import Params, dense_init
from repro.models.rope import apply_rope

# candidate shardings for the (b, kv_heads, g, s_q, s_k) score tensor:
# prefer head parallelism (kv heads, then q-groups).  When neither head
# count divides TP the scores stay batch-sharded — long sequences avoid
# the quadratic buffer entirely via chunked_self_attention instead.
_SCORE_SHARDINGS = (
    ("batch", "kv_heads", None, None, None),
    ("batch", None, "qgroups", None, None),
)


def init_attention(rng, d_model, num_heads, kv_heads, head_dim, dtype, *, use_bias=False) -> Params:
    kq, kk, kv, ko = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(kq, d_model, num_heads * head_dim, dtype),
        "wk": dense_init(kk, d_model, kv_heads * head_dim, dtype),
        "wv": dense_init(kv, d_model, kv_heads * head_dim, dtype),
        "wo": dense_init(ko, num_heads * head_dim, d_model, dtype, scale=0.5),
    }
    if use_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((kv_heads * head_dim,), dtype)
    return p


def _project(p, x, num_heads, kv_heads, head_dim):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (
        q.reshape(b, s, num_heads, head_dim),
        k.reshape(b, s, kv_heads, head_dim),
        v.reshape(b, s, kv_heads, head_dim),
    )


def _gqa_scores(q, k):
    """q: (b,s,H,d), k: (b,t,Hkv,d) → scores (b, Hkv, q_per_kv, s, t)."""
    b, s, H, d = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, s, kvh, H // kvh, d)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k)


def _gqa_out(attn, v):
    """attn: (b,Hkv,g,s,t), v: (b,t,Hkv,d) → (b,s,H*d)."""
    b, kvh, g, s, t = attn.shape
    out = jnp.einsum("bkgst,btkd->bskgd", attn, v)
    return out.reshape(b, s, kvh * g * v.shape[-1])


def self_attention(
    p: Params,
    x: jax.Array,                    # (b, s, d_model)
    *,
    num_heads: int,
    kv_heads: int,
    head_dim: int,
    positions: Optional[jax.Array] = None,
    rope_theta: float = 10_000.0,
    rope_partial: bool = False,
    causal: bool = True,
    window: int = 0,                 # >0 → sliding-window attention
) -> jax.Array:
    b, s, _ = x.shape
    q, k, v = _project(p, x, num_heads, kv_heads, head_dim)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q = apply_rope(q, positions, theta=rope_theta, partial=rope_partial)
    k = apply_rope(k, positions, theta=rope_theta, partial=rope_partial)

    scores = _gqa_scores(q, k).astype(jnp.float32) / math.sqrt(head_dim)
    scores = maybe_shard_any(scores, _SCORE_SHARDINGS)
    if causal:
        i = positions[:, None, None, :, None]  # query pos
        j = positions[:, None, None, None, :]  # key pos
        mask = j <= i
        if window:
            mask &= j > i - window
        scores = jnp.where(mask, scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    return _gqa_out(attn, v) @ p["wo"]


def decode_attention(
    p: Params,
    x: jax.Array,                    # (b, 1, d_model) — one new token
    k_cache: jax.Array,              # (b, max_seq, kv_heads, head_dim)
    v_cache: jax.Array,
    cache_len: jax.Array,            # scalar int32 — tokens already cached
    *,
    num_heads: int,
    kv_heads: int,
    head_dim: int,
    rope_theta: float = 10_000.0,
    rope_partial: bool = False,
):
    """One decode step: append KV at cache_len, attend over the valid prefix.

    Returns (out (b,1,d_model), new_k_cache, new_v_cache).
    """
    b = x.shape[0]
    pos = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    q, k, v = _project(p, x, num_heads, kv_heads, head_dim)
    q = apply_rope(q, pos, theta=rope_theta, partial=rope_partial)
    k = apply_rope(k, pos, theta=rope_theta, partial=rope_partial)

    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, cache_len, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, cache_len, 0, 0))

    scores = _gqa_scores(q, k_cache).astype(jnp.float32) / math.sqrt(head_dim)
    valid = (jnp.arange(k_cache.shape[1]) <= cache_len)[None, None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(attn, v_cache) @ p["wo"]
    return out, k_cache, v_cache


def chunked_self_attention(
    p: Params,
    x: jax.Array,                    # (b, s, d_model)
    *,
    num_heads: int,
    kv_heads: int,
    head_dim: int,
    positions: Optional[jax.Array] = None,
    rope_theta: float = 10_000.0,
    rope_partial: bool = False,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
    window: int = 0,
) -> jax.Array:
    """Flash-style causal attention: online softmax over key chunks.

    Never materializes the (s, s) score matrix — peak intermediate is
    (q_chunk, k_chunk) per head.  Numerically identical to
    :func:`self_attention` (same masking, f32 accumulation); used for
    long-sequence prefill (s >= ~8k) where the quadratic buffer would
    dominate HBM.
    """
    b, s, _ = x.shape
    assert s % q_chunk == 0 and s % k_chunk == 0, (s, q_chunk, k_chunk)
    q, k, v = _project(p, x, num_heads, kv_heads, head_dim)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q = apply_rope(q, positions, theta=rope_theta, partial=rope_partial)
    k = apply_rope(k, positions, theta=rope_theta, partial=rope_partial)
    scale = 1.0 / math.sqrt(head_dim)
    positions = jnp.broadcast_to(positions, (b, s))

    nq, nk = s // q_chunk, s // k_chunk
    kvh = kv_heads
    g = num_heads // kvh
    qc = q.reshape(b, nq, q_chunk, kvh, g, head_dim).astype(jnp.float32)
    kc = k.reshape(b, nk, k_chunk, kvh, head_dim).astype(jnp.float32)
    vc = v.reshape(b, nk, k_chunk, kvh, head_dim).astype(jnp.float32)
    qpos = positions.reshape(b, nq, q_chunk)
    kpos = positions.reshape(b, nk, k_chunk)

    def per_q_chunk(qi, q_blk, qp):
        # online softmax state: m (max), l (denominator), acc (numerator)
        m0 = jnp.full((b, q_chunk, kvh, g), -1e30, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, kvh, g), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, kvh, g, head_dim), jnp.float32)

        @jax.checkpoint
        def over_k(carry, inputs):
            # checkpointed: backward recomputes each (q,k) score block, so
            # residual memory stays O(q_chunk·k_chunk), flash-style
            m, l, acc = carry
            k_blk, v_blk, kp = inputs
            sc = jnp.einsum("bqkgd,btkd->bqkgt", q_blk, k_blk) * scale
            mask = kp[:, None, None, None, :] <= qp[:, :, None, None, None]
            if window:
                mask &= kp[:, None, None, None, :] > qp[:, :, None, None, None] - window
            sc = jnp.where(mask, sc, -1e30)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            correction = jnp.exp(m - m_new)
            w = jnp.exp(sc - m_new[..., None])
            l_new = l * correction + w.sum(axis=-1)
            acc_new = acc * correction[..., None] + jnp.einsum(
                "bqkgt,btkd->bqkgd", w, v_blk
            )
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            over_k, (m0, l0, a0),
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
             kpos.transpose(1, 0, 2)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # accumulate f32, store bf16: halves the stacked-output footprint
        return out.astype(x.dtype)  # (b, q_chunk, kvh, g, d)

    outs = jax.lax.map(
        lambda args: per_q_chunk(*args),
        (jnp.arange(nq), qc.transpose(1, 0, 2, 3, 4, 5), qpos.transpose(1, 0, 2)),
    )  # (nq, b, q_chunk, kvh, g, d) in x.dtype
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, num_heads * head_dim)
    return out @ p["wo"]


def decode_attention_readonly(
    p: Params,
    x: jax.Array,                    # (b, 1, d_model) — one new token
    k_cache: jax.Array,              # (b, max_seq, kv_heads, head_dim) READ-ONLY
    v_cache: jax.Array,
    cache_len: jax.Array,            # scalar int32
    *,
    num_heads: int,
    kv_heads: int,
    head_dim: int,
    rope_theta: float = 10_000.0,
    rope_partial: bool = False,
    kv_scale: Optional[tuple] = None,  # (k_scale, v_scale) (b, max_seq, kvh) for int8 caches
):
    """Decode WITHOUT writing the cache: attends over the valid prefix plus
    the new token's own K/V, and returns (out, k_new, v_new) so the caller
    batches all layers' cache writes into one scatter outside the layer
    scan.  Avoids the full-cache double buffer a scan-carried cache update
    costs (§Perf: decode memory iteration 1).  Numerically identical to
    :func:`decode_attention`.

    ``kv_scale`` enables int8 caches: entries are dequantized on read
    (§Perf decode iteration 2); k_new/v_new are returned unquantized.
    """
    b = x.shape[0]
    pos = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    q, k, v = _project(p, x, num_heads, kv_heads, head_dim)
    q = apply_rope(q, pos, theta=rope_theta, partial=rope_partial)
    k = apply_rope(k, pos, theta=rope_theta, partial=rope_partial)

    if kv_scale is not None:
        ks, vs = kv_scale
        kc = k_cache.astype(jnp.float32) * ks[..., None]
        vc = v_cache.astype(jnp.float32) * vs[..., None]
        kc, vc = kc.astype(x.dtype), vc.astype(x.dtype)
    else:
        kc, vc = k_cache, v_cache

    scores_c = _gqa_scores(q, kc).astype(jnp.float32) / math.sqrt(head_dim)
    valid = (jnp.arange(kc.shape[1]) < cache_len)[None, None, None, None, :]
    scores_c = jnp.where(valid, scores_c, -1e30)
    scores_n = _gqa_scores(q, k).astype(jnp.float32) / math.sqrt(head_dim)  # (b,kvh,g,1,1)

    m = jnp.maximum(scores_c.max(axis=-1, keepdims=True), scores_n)
    wc = jnp.exp(scores_c - m)
    wn = jnp.exp(scores_n - m)
    denom = wc.sum(axis=-1, keepdims=True) + wn
    out = (
        _gqa_out((wc / denom).astype(x.dtype), vc)
        + _gqa_out((wn / denom).astype(x.dtype), v)
    ) @ p["wo"]
    return out, k, v


def init_cross_attention(rng, d_model, num_heads, kv_heads, head_dim, enc_dim, dtype) -> Params:
    kq, kk, kv, ko = jax.random.split(rng, 4)
    return {
        "wq": dense_init(kq, d_model, num_heads * head_dim, dtype),
        "wk": dense_init(kk, enc_dim, kv_heads * head_dim, dtype),
        "wv": dense_init(kv, enc_dim, kv_heads * head_dim, dtype),
        "wo": dense_init(ko, num_heads * head_dim, d_model, dtype, scale=0.5),
        "gate": jnp.zeros((1,), dtype),  # zero-init tanh gate (Llama-vision style)
    }


def cross_attention(
    p: Params,
    x: jax.Array,          # (b, s, d_model)
    enc: jax.Array,        # (b, t, enc_dim) — image/patch embeddings
    *,
    num_heads: int,
    kv_heads: int,
    head_dim: int,
    q_chunk: int = 512,
) -> jax.Array:
    """Gated cross-attention; query dim is chunked so the (s × t_img)
    score buffer never exceeds (q_chunk × t_img) per head."""
    b, s, _ = x.shape
    t = enc.shape[1]
    q = (x @ p["wq"]).reshape(b, s, num_heads, head_dim)
    k = (enc @ p["wk"]).reshape(b, t, kv_heads, head_dim)
    v = (enc @ p["wv"]).reshape(b, t, kv_heads, head_dim)

    def block(q_blk):  # (b, qc, H, hd)
        scores = _gqa_scores(q_blk, k).astype(jnp.float32) / math.sqrt(head_dim)
        attn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        return _gqa_out(attn, v)  # (b, qc, H*hd)

    if s > q_chunk and s % q_chunk == 0:
        nq = s // q_chunk
        qs = q.reshape(b, nq, q_chunk, num_heads, head_dim).transpose(1, 0, 2, 3, 4)
        out = jax.lax.map(jax.checkpoint(block), qs)
        out = out.transpose(1, 0, 2, 3).reshape(b, s, num_heads * head_dim)
    else:
        out = block(q)
    out = out @ p["wo"]
    return jnp.tanh(p["gate"]) * out
