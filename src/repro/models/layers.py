"""Shared building blocks: norms, MLPs, initializers.

Parameters are plain pytrees (nested dicts of jnp arrays) — no framework
dependency.  Every block is a pair ``init_*(rng, ...) -> params`` /
``apply(params, x)`` of pure functions, so stacking + ``lax.scan`` over
layers and pjit sharding of the stacked pytree are trivial.

Initializers follow standard LM practice (trunc-normal fan-in for
projections, scaled residual-out init).
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------- inits --

def dense_init(rng, in_dim: int, out_dim: int, dtype, *, scale: float = 1.0):
    std = scale / math.sqrt(in_dim)
    return (jax.random.truncated_normal(rng, -3, 3, (in_dim, out_dim)) * std).astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype):
    return (jax.random.truncated_normal(rng, -3, 3, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------- norms --

def init_norm(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jax.Array, kind: str = "rmsnorm", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y.astype(x.dtype) * p["scale"]
    if "bias" in p:
        y = y + p["bias"]
    return y


# ----------------------------------------------------------------- mlps --

def init_mlp(rng, d_model: int, d_ff: int, act: str, dtype, *, use_bias=False) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    p: Params = {"out": dense_init(k2, d_ff, d_model, dtype, scale=0.5)}
    if act in ("swiglu", "geglu"):
        p["in_gate"] = dense_init(k1, d_model, d_ff, dtype)
        p["in_val"] = dense_init(k3, d_model, d_ff, dtype)
    else:
        p["in_val"] = dense_init(k1, d_model, d_ff, dtype)
    if use_bias:
        p["bias_out"] = jnp.zeros((d_model,), dtype)
    return p


def apply_mlp(p: Params, x: jax.Array, act: str = "swiglu") -> jax.Array:
    if act == "swiglu":
        h = jax.nn.silu(x @ p["in_gate"]) * (x @ p["in_val"])
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["in_gate"]) * (x @ p["in_val"])
    else:
        h = jax.nn.gelu(x @ p["in_val"])
    y = h @ p["out"]
    if "bias_out" in p:
        y = y + p["bias_out"]
    return y


# ------------------------------------------------------------- pytrees --

def stack_layers(layer_params: list) -> Params:
    """Stacks per-layer pytrees into leading-axis arrays for lax.scan."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layer_params)


def layer_slice(stacked: Params, i: int) -> Params:
    return jax.tree.map(lambda x: x[i], stacked)


def count_params(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def cast_floats(tree: Params, dtype) -> Params:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )
