"""Lock-discipline analysis for the serving engine (DESIGN.md §5).

The thread driver coordinates four locks — the blessed acquisition
order is

    ``_engine_lock`` → ``_results_lock`` → ``_stamp_lock`` →
    ``ProducerRegistry._lock``

(:data:`BLESSED_LOCK_ORDER`; outermost first — a thread holding a lock
may only acquire locks strictly later in the list, so every
acquisition path is a chain in one total order and deadlock-freedom is
a corollary).  Two complementary checkers enforce it:

**Static pass** (:func:`analyze_locks`): an AST walk over
``repro/serve/`` that

  * discovers each class's lock attributes (``self._x =
    threading.Lock()`` / ``RLock()``) and which classes its other
    attributes instantiate (so ``with self._registry._lock:`` and
    ``self._registry.stamp(...)`` resolve to ``ProducerRegistry``);
  * tracks the lexical ``with``-stack per method, recording every
    attribute access with the locks held around it and every
    lock-acquisition nesting edge — including edges reached through
    method calls (``self.m()`` / ``self._attr.m()``), closed over the
    call graph to a fixpoint;
  * reports **order violations** (a nesting edge that runs backwards
    against the blessed order, or any cycle among unordered locks),
    **non-reentrant re-acquisition** (a plain ``Lock`` taken while
    already held), and **mixed guarded/unguarded attributes** — a
    ``self._*`` attribute whose accesses are dominantly under one lock
    but also happen outside it (the unguarded-shared-write bug class).

  Conventions the pass understands: accesses inside ``__init__`` are
  construction-time (exempt); a method whose name ends in ``_locked``
  is a caller-holds-the-lock helper (its accesses count as guarded by
  its class's single lock); a line whose trailing comment contains
  ``unlocked:`` documents a deliberate lock-free access and is exempt
  (use it for append-only snapshot reads, with the reason after the
  colon).

**Runtime monitor** (:class:`LockMonitor` via :func:`monitor_server`):
wraps a live server's four locks so every real acquisition records the
locks the acquiring thread already holds.  The multiproducer stress
tests run under it and cross-check the observed edge set against the
static graph and the blessed order — the static pass over-approximates
(it cannot see which branches run), the monitor under-approximates (it
sees only exercised schedules), so agreement from both sides brackets
the truth.
"""

from __future__ import annotations

import ast
import dataclasses
import threading
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

#: The blessed acquisition order, outermost lock first (DESIGN.md §5).
#: A thread holding one of these may only acquire locks strictly later
#: in the tuple.
BLESSED_LOCK_ORDER: Tuple[str, ...] = (
    "ShardedEmbeddingServer._engine_lock",
    "ShardedEmbeddingServer._results_lock",
    "ShardedEmbeddingServer._stamp_lock",
    "ProducerRegistry._lock",
)

#: Suppression marker for deliberate lock-free accesses: any line whose
#: trailing comment contains this token is exempt from the mixed-access
#: report (document the reason after the colon).
UNLOCKED_MARKER = "unlocked:"


class LockOrderError(RuntimeError):
    """A runtime lock acquisition violated the blessed order."""


@dataclasses.dataclass(frozen=True)
class AttrAccess:
    """One ``self._*`` attribute access found by the static pass."""

    cls: str
    attr: str
    method: str
    path: str
    line: int
    locks: frozenset
    is_write: bool


@dataclasses.dataclass(frozen=True)
class OrderEdge:
    """One lock-nesting edge: ``held`` was held when ``acquired`` was
    taken (at ``path:line``, possibly through ``via`` method calls)."""

    held: str
    acquired: str
    path: str
    line: int
    via: str = ""


@dataclasses.dataclass
class MixedAccess:
    """An attribute guarded by ``lock`` at most sites but not all."""

    cls: str
    attr: str
    lock: str
    guarded: int
    unguarded_sites: List[Tuple[str, int, str]]  # (path, line, method)


@dataclasses.dataclass
class LockReport:
    """Everything the static pass extracted, plus derived findings."""

    locks: Dict[str, Set[str]]                  # class -> lock attrs
    rlocks: Set[str]                            # qualified reentrant locks
    edges: List[OrderEdge]
    accesses: List[AttrAccess]
    order_violations: List[str] = dataclasses.field(default_factory=list)
    cycles: List[List[str]] = dataclasses.field(default_factory=list)
    reentrancy_violations: List[str] = dataclasses.field(default_factory=list)
    mixed: List[MixedAccess] = dataclasses.field(default_factory=list)

    def findings(self) -> List[str]:
        """Flat human-readable finding list (empty = discipline holds)."""
        out = list(self.order_violations)
        for cyc in self.cycles:
            out.append(
                "lock-order cycle: " + " -> ".join(cyc + [cyc[0]])
            )
        out.extend(self.reentrancy_violations)
        for m in self.mixed:
            sites = ", ".join(
                f"{p}:{ln} ({meth})" for p, ln, meth in m.unguarded_sites
            )
            out.append(
                f"{m.cls}.{m.attr}: guarded by {m.lock} at {m.guarded} "
                f"site(s) but accessed without it at {sites}"
            )
        return out


def _lock_ctor(node: ast.AST) -> Optional[bool]:
    """``threading.Lock()`` → False, ``threading.RLock()`` → True."""
    if not isinstance(node, ast.Call):
        return None
    f = node.func
    name = None
    if isinstance(f, ast.Attribute):
        name = f.attr
    elif isinstance(f, ast.Name):
        name = f.id
    if name == "Lock":
        return False
    if name == "RLock":
        return True
    return None


def _self_attr(node: ast.AST) -> Optional[str]:
    """``self.X`` → ``"X"``, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


class _ClassScan(ast.NodeVisitor):
    """First pass over one class: lock attrs + attr → class bindings."""

    def __init__(self, known_classes: Set[str]):
        self.known = known_classes
        self.locks: Dict[str, bool] = {}        # attr -> is_rlock
        self.attr_class: Dict[str, str] = {}    # attr -> class name

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            attr = _self_attr(tgt)
            if attr is None:
                continue
            rlock = _lock_ctor(node.value)
            if rlock is not None:
                self.locks[attr] = rlock
                continue
            if isinstance(node.value, ast.Call):
                f = node.value.func
                cname = f.id if isinstance(f, ast.Name) else (
                    f.attr if isinstance(f, ast.Attribute) else None
                )
                if cname in self.known:
                    self.attr_class[attr] = cname
        self.generic_visit(node)


class _MethodWalk(ast.NodeVisitor):
    """Second pass over one method: with-stack, accesses, edges, calls."""

    def __init__(self, analyzer: "_Analyzer", cls: str, method: str,
                 base_locks: frozenset):
        self.an = analyzer
        self.cls = cls
        self.method = method
        self.held: List[str] = list(base_locks)
        self.acquired: Set[str] = set()          # locks taken directly
        self.calls: List[Tuple[Tuple[str, str], frozenset, int]] = []

    # ----- lock resolution ------------------------------------------------
    def _resolve_lock(self, expr: ast.AST) -> Optional[str]:
        """``self._x`` / ``self._attr._y`` → qualified lock name."""
        attr = _self_attr(expr)
        if attr is not None:
            if attr in self.an.class_locks.get(self.cls, {}):
                return f"{self.cls}.{attr}"
            return None
        if (isinstance(expr, ast.Attribute)
                and isinstance(expr.value, ast.Attribute)):
            base = _self_attr(expr.value)
            if base is not None:
                owner = self.an.attr_class.get((self.cls, base))
                if owner and expr.attr in self.an.class_locks.get(owner, {}):
                    return f"{owner}.{expr.attr}"
        return None

    # ----- with-stack -----------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        taken: List[str] = []
        for item in node.items:
            lock = self._resolve_lock(item.context_expr)
            if lock is None:
                self.visit(item.context_expr)
                continue
            self.an.record_acquire(
                lock, list(self.held), self.method, node.lineno
            )
            self.acquired.add(lock)
            self.held.append(lock)
            taken.append(lock)
        for stmt in node.body:
            self.visit(stmt)
        for lock in reversed(taken):
            self.held.remove(lock)

    # ----- calls ----------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute):
            base = _self_attr(f.value)
            if isinstance(f.value, ast.Name) and f.value.id == "self":
                self.calls.append((
                    (self.cls, f.attr), frozenset(self.held), node.lineno
                ))
            elif base is not None:
                owner = self.an.attr_class.get((self.cls, base))
                if owner is not None:
                    self.calls.append((
                        (owner, f.attr), frozenset(self.held), node.lineno
                    ))
        self.generic_visit(node)

    # ----- attribute accesses ---------------------------------------------
    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = _self_attr(node)
        if (attr is not None and attr.startswith("_")
                and attr not in self.an.class_locks.get(self.cls, {})):
            self.an.accesses.append(AttrAccess(
                cls=self.cls, attr=attr, method=self.method,
                path=self.an.current_path, line=node.lineno,
                locks=frozenset(self.held),
                is_write=isinstance(node.ctx, (ast.Store, ast.AugStore
                                               if hasattr(ast, "AugStore")
                                               else ast.Store)),
            ))
        self.generic_visit(node)


class _Analyzer:
    """Whole-package state shared by the per-method walks."""

    def __init__(self):
        self.class_locks: Dict[str, Dict[str, bool]] = {}
        self.attr_class: Dict[Tuple[str, str], str] = {}
        self.accesses: List[AttrAccess] = []
        self.edges: List[OrderEdge] = []
        self.direct_acquires: Dict[Tuple[str, str], Set[str]] = {}
        self.calls: Dict[
            Tuple[str, str], List[Tuple[Tuple[str, str], frozenset, int]]
        ] = {}
        self.method_paths: Dict[Tuple[str, str], str] = {}
        self.current_path = ""
        self.source_lines: Dict[str, List[str]] = {}

    def record_acquire(
        self, lock: str, held: List[str], method: str, line: int,
        via: str = "",
    ) -> None:
        for h in held:
            self.edges.append(OrderEdge(
                held=h, acquired=lock, path=self.current_path,
                line=line, via=via,
            ))

    # -------------------------------------------------------------- scan --
    def scan(self, sources: Dict[str, str]) -> None:
        trees: Dict[str, ast.Module] = {}
        for path, src in sources.items():
            trees[path] = ast.parse(src)
            self.source_lines[path] = src.splitlines()
        # pass 1: lock + attr-class discovery needs every class known
        known = {
            n.name
            for tree in trees.values()
            for n in ast.walk(tree) if isinstance(n, ast.ClassDef)
        }
        scans: Dict[str, _ClassScan] = {}
        for tree in trees.values():
            for n in tree.body:
                if not isinstance(n, ast.ClassDef):
                    continue
                sc = _ClassScan(known)
                sc.visit(n)
                scans[n.name] = sc
                if sc.locks:
                    self.class_locks[n.name] = sc.locks
        for cname, sc in scans.items():
            for attr, owner in sc.attr_class.items():
                if owner in self.class_locks:
                    self.attr_class[(cname, attr)] = owner
        # pass 2: per-method walks
        for path, tree in trees.items():
            self.current_path = path
            for n in tree.body:
                if not isinstance(n, ast.ClassDef):
                    continue
                for m in n.body:
                    if not isinstance(
                        m, (ast.FunctionDef, ast.AsyncFunctionDef)
                    ):
                        continue
                    base: frozenset = frozenset()
                    if m.name.endswith("_locked"):
                        # caller-holds-the-lock helper: guarded by the
                        # class's single lock (convention)
                        locks = self.class_locks.get(n.name, {})
                        if len(locks) == 1:
                            base = frozenset(
                                f"{n.name}.{a}" for a in locks
                            )
                    walk = _MethodWalk(self, n.name, m.name, base)
                    for stmt in m.body:
                        walk.visit(stmt)
                    self.direct_acquires[(n.name, m.name)] = walk.acquired
                    self.calls[(n.name, m.name)] = walk.calls
                    self.method_paths[(n.name, m.name)] = path

    # ----------------------------------------------------------- closure --
    def close_over_calls(self) -> None:
        """Fixpoint: locks a method may acquire transitively; then emit
        edges for calls made while holding locks."""
        closure: Dict[Tuple[str, str], Set[str]] = {
            k: set(v) for k, v in self.direct_acquires.items()
        }
        changed = True
        while changed:
            changed = False
            for caller, callees in self.calls.items():
                acc = closure.setdefault(caller, set())
                for callee, _held, _line in callees:
                    extra = closure.get(callee)
                    if extra and not extra <= acc:
                        acc |= extra
                        changed = True
        for caller, callees in self.calls.items():
            for callee, held, line in callees:
                if not held:
                    continue
                for lock in sorted(closure.get(callee, ())):
                    self.current_path = self.method_paths.get(caller, "")
                    self.record_acquire(
                        lock, [h for h in held], caller[1], line,
                        via=f"{callee[0]}.{callee[1]}",
                    )

    # ---------------------------------------------------------- findings --
    def derive(self, report: LockReport) -> None:
        order = {name: i for i, name in enumerate(BLESSED_LOCK_ORDER)}
        graph: Dict[str, Set[str]] = {}
        seen_edges: Set[Tuple[str, str]] = set()
        for e in report.edges:
            if e.held == e.acquired:
                if e.acquired not in report.rlocks:
                    report.reentrancy_violations.append(
                        f"{e.acquired} re-acquired while held at "
                        f"{e.path}:{e.line} ({e.via or e.acquired}) — "
                        f"plain Lock, this deadlocks"
                    )
                continue
            if (e.held, e.acquired) not in seen_edges:
                seen_edges.add((e.held, e.acquired))
                graph.setdefault(e.held, set()).add(e.acquired)
            if e.held in order and e.acquired in order:
                if order[e.held] >= order[e.acquired]:
                    via = f" via {e.via}" if e.via else ""
                    report.order_violations.append(
                        f"{e.acquired} acquired while holding {e.held} at "
                        f"{e.path}:{e.line}{via} — runs backwards against "
                        f"the blessed order "
                        f"{' -> '.join(BLESSED_LOCK_ORDER)}"
                    )
        report.cycles = _find_cycles(graph)
        report.mixed = _mixed_accesses(
            report.accesses, self.class_locks, self.source_lines
        )


def _find_cycles(graph: Dict[str, Set[str]]) -> List[List[str]]:
    """Simple-cycle enumeration (the graphs here have ≤ a dozen nodes)."""
    cycles: List[List[str]] = []
    seen_keys: Set[Tuple[str, ...]] = set()

    def dfs(start: str, node: str, path: List[str]) -> None:
        for nxt in sorted(graph.get(node, ())):
            if nxt == start:
                key = tuple(sorted(path))
                if key not in seen_keys:
                    seen_keys.add(key)
                    cycles.append(list(path))
            elif nxt not in path and nxt > start:
                # only expand nodes ordered after start: each cycle is
                # found exactly once, rooted at its smallest node
                dfs(start, nxt, path + [nxt])

    for start in sorted(graph):
        dfs(start, start, [start])
    return cycles


def _mixed_accesses(
    accesses: List[AttrAccess],
    class_locks: Dict[str, Dict[str, bool]],
    source_lines: Dict[str, List[str]],
) -> List[MixedAccess]:
    """Attributes dominantly guarded by one lock but not always.

    The dominant lock must guard at least two accesses AND a strict
    majority of all of them — attributes that are simply never locked
    (single-thread-by-design driver state) have no dominant lock and
    never report.  ``__init__`` accesses are construction-time; lines
    carrying the ``unlocked:`` marker are documented exemptions.
    """
    grouped: Dict[Tuple[str, str], List[AttrAccess]] = {}
    for a in accesses:
        if a.cls not in class_locks or a.method == "__init__":
            continue
        line = ""
        lines = source_lines.get(a.path)
        if lines and 0 < a.line <= len(lines):
            line = lines[a.line - 1]
        if UNLOCKED_MARKER in line:
            continue
        grouped.setdefault((a.cls, a.attr), []).append(a)
    out: List[MixedAccess] = []
    for (cls, attr), accs in sorted(grouped.items()):
        counts: Dict[str, int] = {}
        for a in accs:
            for lock in a.locks:
                counts[lock] = counts.get(lock, 0) + 1
        if not counts:
            continue
        lock = max(counts, key=lambda k: (counts[k], k))
        guarded = counts[lock]
        unguarded = [a for a in accs if lock not in a.locks]
        if guarded >= 2 and guarded > len(unguarded) and unguarded:
            out.append(MixedAccess(
                cls=cls, attr=attr, lock=lock, guarded=guarded,
                unguarded_sites=sorted(
                    (a.path, a.line, a.method) for a in unguarded
                ),
            ))
    return out


def _default_sources() -> Dict[str, str]:
    import repro.serve as serve_pkg

    root = Path(serve_pkg.__file__).parent
    return {
        f"repro/serve/{p.name}": p.read_text()
        for p in sorted(root.glob("*.py"))
    }


def analyze_locks(
    sources: Optional[Dict[str, str]] = None,
) -> LockReport:
    """Runs the static lock-discipline pass.

    Args:
      sources: ``{display path: source text}`` to analyze; ``None``
        analyzes the installed ``repro/serve`` package (the CLI gate's
        configuration).

    Returns:
      A :class:`LockReport`; ``report.findings()`` is empty when the
      discipline holds.
    """
    if sources is None:
        sources = _default_sources()
    an = _Analyzer()
    an.scan(sources)
    an.close_over_calls()
    report = LockReport(
        locks={c: set(l) for c, l in an.class_locks.items()},
        rlocks={
            f"{c}.{a}"
            for c, locks in an.class_locks.items()
            for a, rl in locks.items() if rl
        },
        edges=an.edges,
        accesses=an.accesses,
    )
    an.derive(report)
    return report


# --------------------------------------------------------------- runtime --


class OrderGraph:
    """Thread-safe record of runtime lock-acquisition edges."""

    def __init__(self):
        self._mu = threading.Lock()
        self.edges: Dict[Tuple[str, str], int] = {}
        self._tls = threading.local()

    def held(self) -> List[str]:
        """Locks the calling thread currently holds (monitor names)."""
        return list(getattr(self._tls, "stack", ()))

    def _record(self, name: str) -> None:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = []
            self._tls.stack = stack
        if name not in stack:
            with self._mu:
                for h in stack:
                    key = (h, name)
                    self.edges[key] = self.edges.get(key, 0) + 1
        stack.append(name)

    def _release(self, name: str) -> None:
        stack = getattr(self._tls, "stack", [])
        if name in stack:
            # remove the innermost occurrence (reentrant acquires push
            # one entry each)
            for i in range(len(stack) - 1, -1, -1):
                if stack[i] == name:
                    del stack[i]
                    break

    def edge_set(self) -> Set[Tuple[str, str]]:
        with self._mu:
            return set(self.edges)

    def check_blessed(
        self, order: Tuple[str, ...] = BLESSED_LOCK_ORDER
    ) -> List[str]:
        """Observed edges violating the blessed order (empty = clean)."""
        idx = {name: i for i, name in enumerate(order)}
        out = []
        for held, acquired in sorted(self.edge_set()):
            if held in idx and acquired in idx and idx[held] >= idx[acquired]:
                out.append(
                    f"{acquired} acquired while holding {held} "
                    f"({self.edges[(held, acquired)]}x)"
                )
        return out

    def cycles(self) -> List[List[str]]:
        graph: Dict[str, Set[str]] = {}
        for held, acquired in self.edge_set():
            if held != acquired:
                graph.setdefault(held, set()).add(acquired)
        return _find_cycles(graph)


class LockMonitor:
    """Drop-in wrapper for a ``Lock``/``RLock`` recording real
    acquisition orders into an :class:`OrderGraph`.

    Delegates ``acquire``/``release``/context-manager protocol to the
    wrapped lock; every acquisition by a thread already holding other
    monitored locks records a ``held → acquired`` edge.  Reentrant
    re-acquisition (RLocks) records no self-edge.  With
    ``enforce=True`` an acquisition that runs backwards against
    :data:`BLESSED_LOCK_ORDER` raises :class:`LockOrderError`
    immediately — deadlocks become deterministic test failures.
    """

    def __init__(self, name: str, lock, graph: OrderGraph,
                 *, enforce: bool = False):
        self.name = name
        self._lock = lock
        self._graph = graph
        self._enforce = enforce

    def _check(self) -> None:
        if not self._enforce:
            return
        idx = {n: i for i, n in enumerate(BLESSED_LOCK_ORDER)}
        mine = idx.get(self.name)
        if mine is None:
            return
        for held in self._graph.held():
            if held != self.name and idx.get(held, -1) >= mine:
                raise LockOrderError(
                    f"acquiring {self.name} while holding {held} runs "
                    f"backwards against the blessed order"
                )

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._check()
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            self._graph._record(self.name)
        return ok

    def release(self) -> None:
        self._lock.release()
        self._graph._release(self.name)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False

    def locked(self) -> bool:
        return self._lock.locked()


def monitor_server(server, *, enforce: bool = False) -> OrderGraph:
    """Wraps a live server's four locks with :class:`LockMonitor`\\ s.

    Returns the shared :class:`OrderGraph`; the stress tests drive
    traffic, then assert ``graph.check_blessed() == []`` and compare
    ``graph.edge_set()`` against the static pass.  The wrap is
    permanent for the server's lifetime (monitors are drop-in
    replacements, so serving behavior is unchanged).
    """
    graph = OrderGraph()
    server._engine_lock = LockMonitor(
        "ShardedEmbeddingServer._engine_lock", server._engine_lock, graph,
        enforce=enforce,
    )
    server._results_lock = LockMonitor(
        "ShardedEmbeddingServer._results_lock", server._results_lock, graph,
        enforce=enforce,
    )
    server._stamp_lock = LockMonitor(
        "ShardedEmbeddingServer._stamp_lock", server._stamp_lock, graph,
        enforce=enforce,
    )
    server._registry._lock = LockMonitor(
        "ProducerRegistry._lock", server._registry._lock, graph,
        enforce=enforce,
    )
    return graph
