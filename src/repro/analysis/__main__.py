"""CLI gate: ``python -m repro.analysis [--strict]``.

Runs the repo lint (:mod:`repro.analysis.lint`) and the static
lock-discipline pass (:mod:`repro.analysis.races`) and prints every
finding.  With ``--strict`` (the CI ``analysis`` job) any finding makes
the exit code 1; without it the report is informational and the exit
code is 0.  The runtime validators (:mod:`repro.analysis.invariants`)
are not run here — they live inside the serving stack behind
``RECROSS_VALIDATE=1``.
"""

from __future__ import annotations

import argparse
import sys

from repro.analysis.lint import run_lint
from repro.analysis.races import BLESSED_LOCK_ORDER, analyze_locks


def main(argv=None) -> int:
    """Runs lint + static lock pass; returns the process exit code."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="ReCross correctness tooling: repo lint + static "
                    "lock-discipline pass",
    )
    ap.add_argument(
        "--strict", action="store_true",
        help="exit 1 on any finding (the CI gate)",
    )
    ap.add_argument(
        "--root", default=None,
        help="repo root to lint (default: the installed tree)",
    )
    args = ap.parse_args(argv)

    lint_findings = run_lint(args.root)
    for f in lint_findings:
        print(f)

    report = analyze_locks()
    race_findings = report.findings()
    for msg in race_findings:
        print(f"[races] {msg}")

    n = len(lint_findings) + len(race_findings)
    locks = sum(len(v) for v in report.locks.values())
    edges = len({(e.held, e.acquired) for e in report.edges})
    print(
        f"repro.analysis: {n} finding(s) — lint={len(lint_findings)}, "
        f"races={len(race_findings)} ({locks} locks, {edges} distinct "
        f"acquisition edges, blessed order: "
        f"{' -> '.join(BLESSED_LOCK_ORDER)})"
    )
    if n and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
