"""Repo-specific lint rules for the ReCross tree (DESIGN.md §12).

Six AST rules encode conventions that ordinary linters cannot know:

``packed-key-guard``
    Any module that packs integer keys by multiply-add or shift into a
    ``key``/``gseq``-named variable must carry an overflow guard — a
    ``_check_*_capacity`` helper (PR 9) or an explicit ``1 << 63``
    capacity comparison.  Silent int64 wraparound in a packed key
    reorders merges without any exception.

``unseeded-random``
    No ``np.random.<fn>`` global-state draws and no stdlib
    ``random.<fn>`` module-level draws in ``src/`` or ``benchmarks/``
    — randomness must flow through ``np.random.default_rng(seed)`` (or
    ``random.Random(seed)``) so every run is replayable.

``oracle-coverage``
    Every ``_reference_*`` oracle defined in ``src/`` must be
    exercised by at least one file under ``tests/`` — an unreferenced
    oracle silently stops pinning the fast path.

``wall-clock``
    No ``time.time()``/``time.monotonic()`` in the deterministic
    merge/ordering modules (:data:`DETERMINISTIC_MODULES`).  Result
    ordering there is defined by packed sequence numbers, never by
    wall-clock reads (``scheduler.py``'s flush deadline is wall-clock
    *by design* and is not in the list).

``patch-mutation``
    ``PlanPatch`` fields are only mutated inside
    ``repro/dist/replan.py`` (``apply_plan_patch`` and the planners) —
    anywhere else, a staged patch is immutable until its barrier.

``docstring-coverage``
    Every public class, function, and public-class method in
    ``repro/serve`` and ``repro/dist`` carries a docstring.

Run via ``python -m repro.analysis`` (add ``--strict`` to exit
nonzero on findings — the CI gate).
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

#: Modules whose merge/ordering behavior must be wall-clock free.
DETERMINISTIC_MODULES = (
    "repro/serve/decode.py",
    "repro/serve/producers.py",
    "repro/serve/drift.py",
    "repro/serve/tiers.py",
    "repro/dist/replan.py",
    "repro/dist/shard_plan.py",
)

#: The only module allowed to mutate ``PlanPatch`` fields.
PATCH_MUTATION_MODULE = "repro/dist/replan.py"

#: Packages whose public API must be fully docstringed.
DOCSTRING_PACKAGES = ("repro/serve", "repro/dist")

_MUTATORS = {"append", "extend", "insert", "pop", "clear", "remove", "sort"}
_SEEDED_NP = {"default_rng", "Generator", "SeedSequence", "BitGenerator",
              "Philox", "PCG64"}
_SEEDED_STDLIB = {"Random", "SystemRandom"}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint finding at ``path:line``."""

    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _repo_root() -> Path:
    import repro

    return Path(repro.__file__).resolve().parents[2]


def _py_files(base: Path) -> List[Path]:
    return sorted(p for p in base.rglob("*.py") if p.is_file())


def _rel(path: Path, root: Path) -> str:
    try:
        return path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def _is_key_name(name: str) -> bool:
    low = name.lower()
    return "key" in low or "gseq" in low


def _has_mult(node: ast.AST) -> bool:
    return any(
        isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mult)
        for n in ast.walk(node)
    )


def _packs_key(node: ast.Assign) -> bool:
    """``key = a * b + c`` / ``key = (x << s) | y`` style packing."""
    names = [t.id for t in node.targets if isinstance(t, ast.Name)]
    if not any(_is_key_name(n) for n in names):
        return False
    v = node.value
    if isinstance(v, ast.BinOp) and isinstance(v.op, (ast.Add, ast.BitOr)):
        if _has_mult(v.left) or any(
            isinstance(n, ast.BinOp) and isinstance(n.op, ast.LShift)
            for n in ast.walk(v)
        ):
            return True
    return False


def _module_has_capacity_guard(tree: ast.Module) -> bool:
    for n in ast.walk(tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if n.name.startswith("_check_") and n.name.endswith("_capacity"):
                return True
        if isinstance(n, ast.Call):
            f = n.func
            name = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else ""
            )
            if name.startswith("_check_") and name.endswith("_capacity"):
                return True
        if (isinstance(n, ast.BinOp) and isinstance(n.op, ast.LShift)
                and isinstance(n.left, ast.Constant) and n.left.value == 1
                and isinstance(n.right, ast.Constant)
                and n.right.value == 63):
            return True
    return False


def _numpy_aliases(tree: ast.Module) -> Set[str]:
    out = set()
    for n in ast.walk(tree):
        if isinstance(n, ast.Import):
            for a in n.names:
                if a.name == "numpy":
                    out.add(a.asname or "numpy")
    return out


def _stdlib_random_imported(tree: ast.Module) -> bool:
    return any(
        isinstance(n, ast.Import) and any(a.name == "random" for a in n.names)
        for n in ast.walk(tree)
    )


def _check_module(
    rel: str, tree: ast.Module, findings: List[Finding], *,
    in_src: bool,
) -> None:
    np_aliases = _numpy_aliases(tree)
    has_stdlib_random = _stdlib_random_imported(tree)
    pack_sites: List[Tuple[int, str]] = []

    for node in ast.walk(tree):
        # -- packed-key-guard: collect packing sites -----------------------
        if isinstance(node, ast.Assign) and _packs_key(node):
            tgt = next(
                t.id for t in node.targets if isinstance(t, ast.Name)
            )
            pack_sites.append((node.lineno, tgt))

        # -- unseeded-random ----------------------------------------------
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            f = node.func
            # np.random.<fn>(...)
            if (isinstance(f.value, ast.Attribute)
                    and f.value.attr == "random"
                    and isinstance(f.value.value, ast.Name)
                    and f.value.value.id in np_aliases
                    and f.attr not in _SEEDED_NP):
                findings.append(Finding(
                    "unseeded-random", rel, node.lineno,
                    f"np.random.{f.attr}() draws from global state — "
                    f"use np.random.default_rng(seed)",
                ))
            # random.<fn>(...)
            elif (has_stdlib_random
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "random"
                    and f.attr not in _SEEDED_STDLIB):
                findings.append(Finding(
                    "unseeded-random", rel, node.lineno,
                    f"random.{f.attr}() draws from global state — "
                    f"use random.Random(seed)",
                ))

        # -- wall-clock ----------------------------------------------------
        if (rel.endswith(DETERMINISTIC_MODULES)
                and isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "time"
                and node.func.attr in ("time", "monotonic")):
            findings.append(Finding(
                "wall-clock", rel, node.lineno,
                f"time.{node.func.attr}() in a deterministic "
                f"merge/ordering module — ordering must come from packed "
                f"sequence numbers, not the clock",
            ))

        # -- patch-mutation ------------------------------------------------
        if in_src and not rel.endswith(PATCH_MUTATION_MODULE):
            tgt = None
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                tgts = (node.targets if isinstance(node, ast.Assign)
                        else [node.target])
                for t in tgts:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and _is_patch_name(t.value.id)):
                        tgt = (t.value.id, t.attr, node.lineno)
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _MUTATORS
                    and isinstance(node.func.value, ast.Attribute)
                    and isinstance(node.func.value.value, ast.Name)
                    and _is_patch_name(node.func.value.value.id)):
                tgt = (node.func.value.value.id,
                       f"{node.func.value.attr}.{node.func.attr}",
                       node.lineno)
            if tgt is not None:
                findings.append(Finding(
                    "patch-mutation", rel, tgt[2],
                    f"mutates {tgt[0]}.{tgt[1]} outside "
                    f"{PATCH_MUTATION_MODULE} — a staged PlanPatch is "
                    f"immutable until apply_plan_patch at the barrier",
                ))

    if pack_sites and not _module_has_capacity_guard(tree):
        for line, tgt in pack_sites:
            findings.append(Finding(
                "packed-key-guard", rel, line,
                f"packed-key arithmetic into {tgt!r} but the module has "
                f"no _check_*_capacity guard or 1 << 63 capacity check — "
                f"int64 wraparound would silently reorder merges",
            ))


def _is_patch_name(name: str) -> bool:
    return name == "patch" or name.endswith("_patch")


def _check_docstrings(
    rel: str, tree: ast.Module, findings: List[Finding]
) -> None:
    def need(node, qual: str) -> None:
        if not ast.get_docstring(node):
            kind = "class" if isinstance(node, ast.ClassDef) else "def"
            findings.append(Finding(
                "docstring-coverage", rel, node.lineno,
                f"public {kind} {qual} has no docstring",
            ))

    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not node.name.startswith("_"):
                need(node, node.name)
        elif isinstance(node, ast.ClassDef) and not node.name.startswith("_"):
            need(node, node.name)
            for m in node.body:
                if (isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and not m.name.startswith("_")):
                    need(m, f"{node.name}.{m.name}")


def run_lint(root: Optional[Path] = None) -> List[Finding]:
    """Runs every lint rule over a repo tree.

    Args:
      root: repo root containing ``src/`` (and optionally
        ``benchmarks/`` and ``tests/``); ``None`` locates the installed
        tree.

    Returns:
      All findings, sorted by path then line (empty = clean).
    """
    root = Path(root) if root is not None else _repo_root()
    src = root / "src" if (root / "src").is_dir() else root
    findings: List[Finding] = []
    oracle_defs: Dict[str, Tuple[str, int]] = {}

    for base, in_src in ((src, True), (root / "benchmarks", False)):
        if not base.is_dir():
            continue
        for path in _py_files(base):
            rel = _rel(path, root)
            try:
                tree = ast.parse(path.read_text())
            except SyntaxError as exc:
                findings.append(Finding(
                    "parse-error", rel, exc.lineno or 0, str(exc.msg)
                ))
                continue
            _check_module(rel, tree, findings, in_src=in_src)
            if in_src:
                if rel.startswith(
                    tuple(f"src/{p}" for p in DOCSTRING_PACKAGES)
                ) or rel.startswith(DOCSTRING_PACKAGES):
                    _check_docstrings(rel, tree, findings)
                for node in ast.walk(tree):
                    if (isinstance(node,
                                   (ast.FunctionDef, ast.AsyncFunctionDef))
                            and node.name.startswith("_reference_")):
                        oracle_defs.setdefault(
                            node.name, (rel, node.lineno)
                        )

    tests_dir = root / "tests"
    if oracle_defs and tests_dir.is_dir():
        test_text = "\n".join(
            p.read_text() for p in _py_files(tests_dir)
        )
        for name, (rel, line) in sorted(oracle_defs.items()):
            if name not in test_text:
                findings.append(Finding(
                    "oracle-coverage", rel, line,
                    f"{name} is not referenced by any file under tests/ — "
                    f"the oracle no longer pins the fast path",
                ))

    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))
