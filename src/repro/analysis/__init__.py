"""Correctness tooling for the ReCross serving stack (DESIGN.md §12).

Three passes keep the invariants that PRs 3–9 layered into the serving
stack machine-checked instead of enforced-by-example:

* :mod:`repro.analysis.invariants` — runtime validators for the
  documented §5/§6/§9 structural rules (per-shard slot uniqueness,
  frozen ``group_copies``/tile space, residency↔tier consistency,
  evict/fetch disjointness, packed-key capacity).  Opt-in via the
  ``RECROSS_VALIDATE=1`` environment variable; wired into plan build,
  patch apply-barriers and drain quiescence (default-on in the test
  suite through ``conftest.py``).
* :mod:`repro.analysis.races` — a static AST pass over ``repro/serve``
  that extracts which locks guard which ``self._*`` attributes,
  reports attributes touched both inside and outside their dominant
  lock and any lock-acquisition-order violation against the blessed
  order (DESIGN.md §5), plus :class:`~repro.analysis.races.LockMonitor`
  — a runtime wrapper recording *real* acquisition orders under the
  multiproducer stress tests to cross-check the static graph.
* :mod:`repro.analysis.lint` — repo-specific AST lint rules (packed-key
  arithmetic must route through the PR-9 guard helpers, no unseeded
  randomness in ``src``/``benchmarks``, every ``_reference_*`` oracle
  referenced by a test, no wall-clock reads in deterministic
  merge/ordering paths, ``PlanPatch`` mutated only via
  ``apply_plan_patch``, public ``serve``/``dist`` docstring coverage).

CLI gate: ``python -m repro.analysis --strict`` runs the lint and the
static lock pass and exits nonzero on any finding (the CI ``analysis``
job).
"""

from repro.analysis.invariants import (
    InvariantViolation,
    validate_patch,
    validate_plan,
    validate_server_state,
    validation_enabled,
)
from repro.analysis.lint import Finding, run_lint
from repro.analysis.races import (
    LockMonitor,
    LockOrderError,
    analyze_locks,
    monitor_server,
)

__all__ = [
    "InvariantViolation",
    "validate_plan",
    "validate_patch",
    "validate_server_state",
    "validation_enabled",
    "Finding",
    "run_lint",
    "analyze_locks",
    "LockMonitor",
    "LockOrderError",
    "monitor_server",
]
