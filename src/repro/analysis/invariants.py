"""Runtime validators for the plan/patch/server structural invariants.

The DESIGN.md §5/§6/§9 rules the serving stack's bit-identity tests pin
only by *outcome* are checked here *structurally*:

  * :func:`validate_plan` — per-shard slot uniqueness, hole/free-slot
    accounting (``local_num_tiles`` = allocated slots, holes allowed),
    the frozen fused tile space (``group_copies`` cumsum layout),
    replicated/sharded/COLD residency consistency, and the fixed
    hot-tier capacity bound.
  * :func:`validate_patch` — a :class:`~repro.dist.replan.PlanPatch`
    checked against the pre-apply plan: class-move preconditions,
    evict/fetch disjointness, DMA/freed-slot accounting (every freed
    slot is exactly a demotion's non-owner slot or an eviction's), and
    a full slot-collision simulation of the apply.
  * :func:`validate_server_state` — a quiesced
    :class:`~repro.serve.sharded.ShardedEmbeddingServer`: residency
    snapshot vs the live plan, host-tier presence of COLD rows,
    drift-tracker dirty-mark accounting, and every packed-key encoding
    (producer ``gseq``, wordline ent keys) within int64 capacity.

All three raise :class:`InvariantViolation` (an ``AssertionError``
subclass) with a message naming the first violated invariant.

Opt-in wiring (``RECROSS_VALIDATE=1``, see :func:`validation_enabled`):
``plan_shards`` validates every fresh plan, ``apply_plan_patch``
validates the patch before and the plan after every apply-barrier, and
``drain()`` validates the whole server at full quiescence.  The test
suite defaults the flag on through ``conftest.py``; benches leave it
off so committed BENCH numbers are never validator-skewed.
"""

from __future__ import annotations

import os
from typing import Dict, List, Tuple

import numpy as np

from repro.dist.shard_plan import COLD, ShardPlan


class InvariantViolation(AssertionError):
    """A documented structural invariant (DESIGN.md §5/§6/§9) failed."""


def validation_enabled() -> bool:
    """True when ``RECROSS_VALIDATE`` requests runtime validation.

    Any value other than unset/empty/``"0"`` enables it (the tests'
    ``conftest.py`` sets ``1``; benches leave it unset).
    """
    return os.environ.get("RECROSS_VALIDATE", "0") not in ("", "0")


def _fail(msg: str) -> None:
    raise InvariantViolation(msg)


def _tile_group(plan: ShardPlan) -> np.ndarray:
    return np.repeat(
        np.arange(plan.num_groups, dtype=np.int64), plan.group_copies
    )


def validate_plan(plan: ShardPlan) -> None:
    """Checks every structural invariant of a :class:`ShardPlan`.

    Raises:
      InvariantViolation: naming the first violated rule — shape
        mismatches, out-of-range placements, a mutated fused tile
        space, residency/holder inconsistency, duplicate local slots,
        miscounted ``local_num_tiles`` or a busted capacity bound.
    """
    G, T, S = plan.num_groups, plan.num_tiles, plan.num_shards
    if S < 1:
        _fail(f"plan has num_shards={S} (must be >= 1)")
    if plan.shard_of_group.shape != (G,):
        _fail(
            f"shard_of_group has shape {plan.shard_of_group.shape}, "
            f"expected ({G},)"
        )
    if plan.replicated_group.shape != (G,):
        _fail(
            f"replicated_group has shape {plan.replicated_group.shape}, "
            f"expected ({G},)"
        )
    if plan.shard_of_tile.shape != (T,):
        _fail(
            f"shard_of_tile has shape {plan.shard_of_tile.shape}, "
            f"expected ({T},)"
        )
    if plan.local_tile_of.shape != (S, T):
        _fail(
            f"local_tile_of has shape {plan.local_tile_of.shape}, "
            f"expected ({S}, {T})"
        )
    if plan.local_num_tiles.shape != (S,):
        _fail(
            f"local_num_tiles has shape {plan.local_num_tiles.shape}, "
            f"expected ({S},)"
        )
    if plan.group_load.shape != (G,):
        _fail(
            f"group_load has shape {plan.group_load.shape}, expected ({G},)"
        )
    if not np.all(np.isfinite(plan.group_load)):
        _fail("group_load contains non-finite values")
    if np.any(plan.group_load < 0):
        _fail("group_load contains negative values")

    sog = plan.shard_of_group
    bad = np.nonzero((sog < COLD) | (sog >= S))[0]
    if bad.size:
        _fail(
            f"group {int(bad[0])}: shard_of_group={int(sog[bad[0]])} is "
            f"not a shard id, -1 (replicated) or {COLD} (cold)"
        )
    mism = np.nonzero(plan.replicated_group != (sog == -1))[0]
    if mism.size:
        g = int(mism[0])
        _fail(
            f"group {g}: replicated_group={bool(plan.replicated_group[g])} "
            f"inconsistent with shard_of_group={int(sog[g])}"
        )

    # fused tile space: contiguous cumsum-of-copies layout, frozen —
    # a patch that mutated group_copies (or a tile space whose total
    # no longer matches) is the silent-corruption class §6.2 forbids
    if plan.group_copies is not None:
        copies = plan.group_copies
        if copies.shape != (G,):
            _fail(
                f"group_copies has shape {copies.shape}, expected ({G},)"
            )
        if np.any(copies < 1):
            g = int(np.nonzero(copies < 1)[0][0])
            _fail(f"group {g}: group_copies={int(copies[g])} (must be >= 1)")
        total = int(copies.sum())
        if total != T:
            _fail(
                f"group_copies sums to {total} but the fused tile space "
                f"has {T} tiles — the frozen tile space was mutated"
            )
        tg = _tile_group(plan)
        mism = np.nonzero(plan.shard_of_tile != sog[tg])[0]
        if mism.size:
            t = int(mism[0])
            _fail(
                f"tile {t} (group {int(tg[t])}): shard_of_tile="
                f"{int(plan.shard_of_tile[t])} != shard_of_group="
                f"{int(sog[tg[t]])} — tiles must travel with their group"
            )
    else:
        bad = np.nonzero(
            (plan.shard_of_tile < COLD) | (plan.shard_of_tile >= S)
        )[0]
        if bad.size:
            _fail(
                f"tile {int(bad[0])}: shard_of_tile="
                f"{int(plan.shard_of_tile[bad[0]])} out of range"
            )

    # residency/holders: replicated tiles held everywhere, sharded-once
    # tiles held exactly by their owner, COLD tiles held nowhere (the
    # §9 "cold rows absent from the shard images" half; host-tier
    # presence is the server-state check)
    held = plan.local_tile_of >= 0
    sot = plan.shard_of_tile
    expect = (sot == -1)[None, :] | (
        sot[None, :] == np.arange(S, dtype=sot.dtype)[:, None]
    )
    mism = np.nonzero(held != expect)
    if mism[0].size:
        s, t = int(mism[0][0]), int(mism[1][0])
        owner = int(sot[t])
        kind = (
            "replicated" if owner == -1
            else "cold (host-only)" if owner == COLD
            else f"owned by shard {owner}"
        )
        verb = "does not hold" if expect[s, t] else "holds"
        _fail(
            f"shard {s} {verb} tile {t}, which is {kind} "
            f"(local_tile_of={int(plan.local_tile_of[s, t])})"
        )

    # per-shard slot uniqueness + hole accounting: allocated slots are
    # unique non-negative ints (holes between them are fine — freed
    # slots stop being addressed), local_num_tiles counts exactly the
    # allocated slots, and under a fixed hot tier every slot stays
    # inside the capacity budget
    for s in range(S):
        slots = plan.local_tile_of[s][held[s]]
        uniq, counts = np.unique(slots, return_counts=True)
        if np.any(counts > 1):
            dup = int(uniq[np.argmax(counts > 1)])
            tiles = np.nonzero(held[s] & (plan.local_tile_of[s] == dup))[0]
            _fail(
                f"shard {s}: local slot {dup} assigned to "
                f"{int(counts[counts > 1][0])} tiles "
                f"{tiles.tolist()} — slot uniqueness violated"
            )
        if int(plan.local_num_tiles[s]) != slots.size:
            _fail(
                f"shard {s}: local_num_tiles={int(plan.local_num_tiles[s])} "
                f"but {slots.size} slots are allocated"
            )
        if plan.capacity_tiles is not None and slots.size:
            top = int(slots.max())
            if top >= plan.capacity_tiles:
                _fail(
                    f"shard {s}: slot {top} outside the fixed hot-tier "
                    f"capacity {plan.capacity_tiles}"
                )


def _patch_tiles(plan: ShardPlan, g: int, base: np.ndarray) -> range:
    return range(int(base[g]), int(base[g] + plan.group_copies[g]))


def validate_patch(plan: ShardPlan, patch) -> None:
    """Checks a :class:`~repro.dist.replan.PlanPatch` against the
    pre-apply ``plan``.

    Verifies class-move preconditions (promote from sharded-once
    resident, demote from replicated, evict from sharded-once resident,
    fetch from cold), evict/fetch disjointness, the DMA and freed-slot
    accounting (``len(dma) == Σ_promoted copies·(S-1)``, freed slots
    are exactly the demotions' non-owner slots plus the evictions'
    slots), and a full slot-collision simulation of the apply: no two
    incoming tiles land in one slot, no incoming tile lands in a
    still-occupied slot, every touched slot stays under
    ``new_capacity`` (and under the fixed hot-tier budget when the
    plan has one).

    Raises:
      InvariantViolation: naming the first violated rule.
    """
    G, S = plan.num_groups, plan.num_shards
    load = np.asarray(patch.drifted_load)
    if load.shape != (G,):
        _fail(
            f"patch drifted_load has shape {load.shape}, plan has "
            f"{G} groups"
        )
    if plan.group_copies is None:
        _fail("patch against a plan without group_copies (hand-built plan)")
    base = np.zeros(G, dtype=np.int64)
    np.cumsum(plan.group_copies[:-1], out=base[1:])
    copies = plan.group_copies

    promoted = list(patch.promoted)
    demote_of: Dict[int, int] = {}
    for g, o in patch.demoted:
        if g in demote_of:
            _fail(f"patch demotes group {g} twice")
        demote_of[int(g)] = int(o)
    fetch_of: Dict[int, int] = {}
    for g, s in patch.fetched:
        if g in fetch_of:
            _fail(f"patch fetches group {g} twice")
        fetch_of[int(g)] = int(s)
    evicted = [int(g) for g in patch.evicted]

    for name, ids in (("promoted", promoted), ("evicted", evicted)):
        if len(set(ids)) != len(ids):
            _fail(f"patch {name} list contains duplicate group ids")
    for name, ids in (
        ("promoted", promoted), ("demoted", list(demote_of)),
        ("fetched", list(fetch_of)), ("evicted", evicted),
    ):
        for g in ids:
            if not (0 <= g < G):
                _fail(f"patch {name} group {g} out of range [0, {G})")

    pset, eset, fset = set(promoted), set(evicted), set(fetch_of)
    if pset & set(demote_of):
        g = sorted(pset & set(demote_of))[0]
        _fail(f"patch both promotes and demotes group {g}")
    if eset & fset:
        g = sorted(eset & fset)[0]
        _fail(
            f"patch both evicts and fetches group {g} — evict/fetch "
            f"disjointness violated"
        )
    if pset & eset:
        g = sorted(pset & eset)[0]
        _fail(f"patch both promotes and evicts group {g}")
    if pset & fset:
        g = sorted(pset & fset)[0]
        _fail(f"patch both promotes and fetches group {g} (fetch lands "
              f"sharded-once; promotion is a later patch)")

    # class-move preconditions against the pre-apply plan
    for g in promoted:
        if plan.replicated_group[g]:
            _fail(f"patch promotes group {g} which is already replicated")
        if plan.shard_of_group[g] == COLD:
            _fail(f"patch promotes group {g} which is cold (fetch first)")
    for g, o in demote_of.items():
        if not plan.replicated_group[g]:
            _fail(f"patch demotes group {g} which is not replicated")
        if not (0 <= o < S):
            _fail(f"patch demotes group {g} to shard {o} out of range")
    for g in evicted:
        # a group may be demoted and evicted in ONE patch (demotion
        # lands it sharded-once, eviction then pages it out)
        if plan.replicated_group[g] and g not in demote_of:
            _fail(
                f"patch evicts group {g} which is not sharded-once "
                f"resident (replicated)"
            )
        if plan.shard_of_group[g] == COLD:
            _fail(
                f"patch evicts group {g} which is not sharded-once "
                f"resident (already cold)"
            )
    for g, s in fetch_of.items():
        if plan.shard_of_group[g] != COLD:
            _fail(f"patch fetches group {g} which is already resident")
        if not (0 <= s < S):
            _fail(f"patch fetches group {g} to shard {s} out of range")

    # DMA / freed accounting (DESIGN.md §6.1/§9)
    want = sum(int(copies[g]) * (S - 1) for g in promoted)
    if len(patch.dma) != want:
        _fail(
            f"patch carries {len(patch.dma)} promotion DMAs, promotions "
            f"require {want} (Σ copies · (S-1))"
        )
    want = sum(int(copies[g]) for g in fetch_of)
    if len(patch.fetch_dma) != want:
        _fail(
            f"patch carries {len(patch.fetch_dma)} fetch DMAs, fetches "
            f"require {want} (Σ copies)"
        )
    want = sum(int(copies[g]) for g in evicted)
    if int(patch.evicted_tiles) != want:
        _fail(
            f"patch evicted_tiles={int(patch.evicted_tiles)}, evictions "
            f"free {want} slots (Σ copies)"
        )

    tg = _tile_group(plan)
    for s, slot, t in patch.dma:
        if not (0 <= t < plan.num_tiles):
            _fail(f"patch DMA tile {t} out of range")
        if int(tg[t]) not in pset:
            _fail(
                f"patch DMA targets tile {t} of group {int(tg[t])} which "
                f"is not promoted"
            )
    for s, slot, t in patch.fetch_dma:
        if not (0 <= t < plan.num_tiles):
            _fail(f"patch fetch DMA tile {t} out of range")
        if int(tg[t]) not in fset:
            _fail(
                f"patch fetch DMA targets tile {t} of group {int(tg[t])} "
                f"which is not fetched"
            )

    # freed slots must be EXACTLY the demotions' non-owner slots plus
    # the evictions' owner slots (owner after a same-patch demotion)
    expect_freed: Dict[Tuple[int, int], int] = {}
    for g, o in demote_of.items():
        for t in _patch_tiles(plan, g, base):
            for s in range(S):
                if s == o:
                    continue
                slot = int(plan.local_tile_of[s, t])
                if slot < 0:
                    _fail(
                        f"patch demotes group {g} but shard {s} does not "
                        f"hold tile {t}"
                    )
                expect_freed[(s, slot)] = t
    for g in evicted:
        o = demote_of.get(g, int(plan.shard_of_group[g]))
        for t in _patch_tiles(plan, g, base):
            slot = int(plan.local_tile_of[o, t])
            if slot < 0:
                _fail(
                    f"patch evicts group {g} but shard {o} does not hold "
                    f"tile {t}"
                )
            expect_freed[(o, slot)] = t
    got_freed = [(int(s), int(slot)) for s, slot in patch.freed]
    if len(set(got_freed)) != len(got_freed):
        _fail("patch freed list contains duplicate (shard, slot) entries")
    if set(got_freed) != set(expect_freed):
        extra = set(got_freed) - set(expect_freed)
        missing = set(expect_freed) - set(got_freed)
        _fail(
            f"patch freed slots do not match the demotions+evictions: "
            f"unexpected {sorted(extra)[:4]}, missing {sorted(missing)[:4]}"
        )

    # slot-collision simulation of the apply: freed → moved → DMAs
    occ: List[Dict[int, int]] = []
    tile_slot: List[Dict[int, int]] = []
    for s in range(S):
        resident = np.nonzero(plan.local_tile_of[s] >= 0)[0]
        occ.append({
            int(plan.local_tile_of[s, t]): int(t) for t in resident
        })
        tile_slot.append({
            int(t): int(plan.local_tile_of[s, t]) for t in resident
        })
    for (s, slot), t in expect_freed.items():
        del occ[s][slot]
        del tile_slot[s][t]
    for s, t, old, new in patch.moved:
        if tile_slot[s].get(int(t)) != int(old):
            _fail(
                f"patch relocation of tile {t} on shard {s}: expected "
                f"slot {old}, plan has {tile_slot[s].get(int(t))}"
            )
        if int(new) in occ[s]:
            _fail(
                f"patch relocation of tile {t} on shard {s} lands in "
                f"slot {new} still holding tile {occ[s][int(new)]}"
            )
        del occ[s][int(old)]
        occ[s][int(new)] = int(t)
        tile_slot[s][int(t)] = int(new)
    for s, slot, t in list(patch.dma) + list(patch.fetch_dma):
        s, slot, t = int(s), int(slot), int(t)
        if not (0 <= s < S):
            _fail(f"patch DMA shard {s} out of range")
        if slot in occ[s]:
            _fail(
                f"patch DMA of tile {t} to shard {s} slot {slot} collides "
                f"with tile {occ[s][slot]}"
            )
        if t in tile_slot[s]:
            _fail(
                f"patch DMAs tile {t} to shard {s} which already holds it "
                f"at slot {tile_slot[s][t]}"
            )
        if slot >= int(patch.new_capacity):
            _fail(
                f"patch DMA of tile {t} to shard {s} slot {slot} outside "
                f"new_capacity {int(patch.new_capacity)}"
            )
        occ[s][slot] = t
        tile_slot[s][t] = slot
    if plan.capacity_tiles is not None:
        if int(patch.new_capacity) > int(plan.capacity_tiles):
            _fail(
                f"patch new_capacity={int(patch.new_capacity)} exceeds the "
                f"fixed hot-tier capacity {int(plan.capacity_tiles)}"
            )
        for s in range(S):
            if len(occ[s]) > int(plan.capacity_tiles):
                _fail(
                    f"shard {s} would hold {len(occ[s])} tiles after the "
                    f"patch, over the hot-tier capacity "
                    f"{int(plan.capacity_tiles)}"
                )


def validate_server_state(server, *, quiesced: bool = False) -> None:
    """Checks a :class:`~repro.serve.sharded.ShardedEmbeddingServer`.

    Structural rules that must hold at any patch barrier: the live
    plan validates, the device image stack fits the plan (and equals
    the fixed capacity under tiering), the residency snapshot matches
    the plan's resident mask, COLD rows are present in the host tier
    (fused master + logical host tables cover every table), the drift
    tracker's arrays are consistently shaped with boolean dirty marks,
    and every packed-key encoding still fits int64 — producer ``gseq``
    spaces (the overflowed-``gseq`` corruption class) and the wordline
    ent keys at the server's batch size.

    With ``quiesced=True`` (the drain-time wiring) additionally checks
    full quiescence: empty in-flight pipeline, scheduler, host queue
    and completed-results stash.

    Only the producer registry's own lock is taken (stamp → registry
    is the blessed order's last edge, so calling under the drain's
    stamp lock is safe); everything else is read directly — the caller
    owns the barrier.

    Raises:
      InvariantViolation: naming the first violated rule.
    """
    plan = server.plan
    validate_plan(plan)

    depth = int(server.shard_images.shape[1])
    if server.shard_images.shape[0] != plan.num_shards:
        _fail(
            f"shard image stack has {server.shard_images.shape[0]} shards, "
            f"plan has {plan.num_shards}"
        )
    if depth < plan.max_local_tiles:
        _fail(
            f"shard image depth {depth} < plan.max_local_tiles "
            f"{plan.max_local_tiles} — allocated slots fall off the image"
        )
    if server._capacity_tiles is not None:
        if depth != int(server._capacity_tiles):
            _fail(
                f"tiered image depth {depth} != fixed capacity "
                f"{int(server._capacity_tiles)}"
            )
        if plan.capacity_tiles != server._capacity_tiles:
            _fail(
                f"plan.capacity_tiles={plan.capacity_tiles} != server "
                f"capacity {server._capacity_tiles}"
            )

    # host tier: every COLD row must be servable host-side — the fused
    # master image covers the whole tile space and the logical tables
    # cover every served name at the row counts submit() validates
    if server._fused.shape[0] != plan.num_tiles:
        _fail(
            f"host master image has {server._fused.shape[0]} tiles, plan "
            f"has {plan.num_tiles}"
        )
    for name in server.names:
        tab = server._host_tables.get(name)
        if tab is None:
            _fail(f"host tier missing logical table {name!r}")
        if int(tab.shape[0]) != server._num_rows[name]:
            _fail(
                f"host table {name!r} has {int(tab.shape[0])} rows, "
                f"submit() validates against {server._num_rows[name]}"
            )

    # residency snapshot (§9): refreshed only at barriers, must equal
    # the live plan's resident mask at every barrier
    if server._residency is not None:
        snap = server._residency._resident
        if not np.array_equal(snap, plan.resident_group):
            g = int(np.nonzero(snap != plan.resident_group)[0][0])
            _fail(
                f"residency snapshot disagrees with the plan at group "
                f"{g}: snapshot={bool(snap[g])}, "
                f"plan resident={bool(plan.resident_group[g])} — "
                f"refresh happened off-barrier?"
            )

    # drift tracker: consistently shaped, boolean dirty marks, finite
    # non-negative decayed estimate (dirty-mark accounting feeds the
    # scale-invariant candidates= path, DESIGN.md §11)
    tracker = server.tracker
    if tracker is not None:
        if tracker.decayed.shape != (plan.num_groups,):
            _fail(
                f"drift tracker decayed load has shape "
                f"{tracker.decayed.shape}, plan has {plan.num_groups} groups"
            )
        if tracker._dirty.shape != (plan.num_groups,):
            _fail(
                f"drift tracker dirty marks have shape "
                f"{tracker._dirty.shape}, plan has {plan.num_groups} groups"
            )
        if tracker._dirty.dtype != np.bool_:
            _fail(
                f"drift tracker dirty marks have dtype "
                f"{tracker._dirty.dtype}, expected bool"
            )
        if not np.all(np.isfinite(tracker.decayed)):
            _fail("drift tracker decayed load contains non-finite values")
        if np.any(tracker.decayed < 0):
            _fail("drift tracker decayed load contains negative values")
        if tracker.observed_queries < 0 or tracker.observations < 0:
            _fail("drift tracker observation counters went negative")

    # packed-key capacity: producer gseq spaces (§10) — the NEXT stamp
    # of every registered space must still fit int64, and registration
    # must fit the stride
    reg = server._registry
    with reg._lock:
        labels = list(reg._label)
        spaces = [dict(space) for space in reg._next]
    if len(labels) > reg.stride:
        _fail(
            f"{len(labels)} producer spaces registered at stride "
            f"{reg.stride} — pids alias"
        )
    for pid, space in enumerate(spaces):
        for table, local in space.items():
            if local < 0:
                _fail(
                    f"producer space {labels[pid]!r} table {table!r}: "
                    f"negative local seq {local}"
                )
            if local * reg.stride + pid > (1 << 63) - 1:
                _fail(
                    f"producer space {labels[pid]!r} table {table!r}: "
                    f"next local seq {local} at stride {reg.stride} "
                    f"overflows the packed gseq capacity"
                )

    # wordline ent keys (§11): (qid · num_tiles + ent_tile) · tile_rows
    # + slot must fit int64 at the server's flush batch size
    for name, layout in zip(server.names, server.layouts):
        span = (
            int(server.batch_size) * int(layout.num_tiles)
            * int(layout.tile_rows)
        )
        if span > (1 << 63) - 1:
            _fail(
                f"table {name!r}: wordline ent keys overflow int64 at "
                f"batch {server.batch_size} × {layout.num_tiles} tiles × "
                f"{layout.tile_rows} rows"
            )

    # completed-results stash: chunk shapes agree and no pending gseq
    # is duplicated (a duplicate would tear the drain merge)
    with server._results_lock if not quiesced else _NullContext():
        completed = {
            name: list(chunks) for name, chunks in server._completed.items()
        }
    for name, chunks in completed.items():
        if not chunks:
            continue
        seqs = np.concatenate([np.asarray(c[0]) for c in chunks])
        for cseqs, crows in chunks:
            if np.asarray(cseqs).shape[0] != np.asarray(crows).shape[0]:
                _fail(
                    f"completed stash for {name!r}: {len(cseqs)} seqs vs "
                    f"{len(crows)} rows in one chunk"
                )
        uniq = np.unique(seqs)
        if uniq.size != seqs.size:
            _fail(
                f"completed stash for {name!r} holds duplicate sequence "
                f"ids — the drain merge would tear"
            )

    # buffered-count accounting (global mode)
    buffered = sum(len(q) for q in server._buffer.values())
    if buffered != server._buffered:
        _fail(
            f"_buffered={server._buffered} but the buffer holds "
            f"{buffered} queries"
        )

    if quiesced:
        if server._in_flight:
            _fail(
                f"quiesced server still has {len(server._in_flight)} "
                f"in-flight flushes"
            )
        if server.scheduler is not None and server.scheduler.pending_total():
            _fail(
                f"quiesced server still has "
                f"{server.scheduler.pending_total()} scheduled queries"
            )
        if server._host_queue is not None and len(server._host_queue):
            _fail(
                f"quiesced server still has {len(server._host_queue)} "
                f"host-queued queries"
            )
        if any(completed.values()):
            _fail("quiesced server still stashes completed results")


class _NullContext:
    """No-op lock stand-in for callers that already hold the lock."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False
