"""Deterministic, shard-aware input pipelines.

Production posture: every host derives its own shard of every batch from
(seed, step, host_index) alone — no coordinator, no state to checkpoint
beyond the step counter, and any replacement host can resume mid-run
(the fault-tolerance story depends on this).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Sequence

import numpy as np

from repro.data.synthetic import zipf_queries


@dataclasses.dataclass
class QueryBatcher:
    """Streams fixed-size DLRM query batches, shardable by host.

    Batch for step ``s`` on host ``h`` is derived from seed ``(seed, s, h)``
    so restart/elastic-rescale replays identically.
    """

    num_rows: int
    batch_size: int
    mean_bag: float
    seed: int = 0
    host_index: int = 0
    num_hosts: int = 1
    zipf_a: float = 1.2

    def batch(self, step: int) -> List[np.ndarray]:
        local = self.batch_size // self.num_hosts
        return zipf_queries(
            self.num_rows,
            local,
            self.mean_bag,
            zipf_a=self.zipf_a,
            seed=hash((self.seed, step, self.host_index)) % (2**31),
        )

    def __iter__(self) -> Iterator[List[np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass
class TokenBatcher:
    """Streams (tokens, labels) LM batches of synthetic text-like data.

    Token stream is a Zipf-over-vocab Markov-ish sequence: cheap, seeded,
    shardable, and enough structure that a few hundred training steps show
    a falling loss (used by the end-to-end example).
    """

    vocab_size: int
    batch_size: int
    seq_len: int
    seed: int = 0
    host_index: int = 0
    num_hosts: int = 1

    def batch(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        local = max(1, self.batch_size // self.num_hosts)
        rng = np.random.default_rng(hash((self.seed, step, self.host_index)) % (2**31))
        # Zipf unigram + local repetition structure (learnable bigrams)
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        p = ranks**-1.1
        p /= p.sum()
        base = rng.choice(self.vocab_size, size=(local, self.seq_len + 1), p=p)
        # inject deterministic bigram structure: x[t+1] = (x[t]*7+3) % V on 1/3 of positions
        mask = rng.random((local, self.seq_len)) < 0.34
        nxt = (base[:, :-1] * 7 + 3) % self.vocab_size
        base[:, 1:] = np.where(mask, nxt, base[:, 1:])
        return base[:, :-1].astype(np.int32), base[:, 1:].astype(np.int32)

    def __iter__(self) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
