from repro.data.synthetic import (
    SyntheticWorkload,
    WORKLOADS,
    make_workload,
    zipf_queries,
)
from repro.data.pipeline import QueryBatcher, TokenBatcher

__all__ = [
    "SyntheticWorkload", "WORKLOADS", "make_workload", "zipf_queries",
    "QueryBatcher", "TokenBatcher",
]
