from repro.data.synthetic import (
    SyntheticWorkload,
    WORKLOADS,
    make_workload,
    scale_trace,
    zipf_queries,
)
from repro.data.pipeline import QueryBatcher, TokenBatcher

__all__ = [
    "SyntheticWorkload", "WORKLOADS", "make_workload", "scale_trace",
    "zipf_queries", "QueryBatcher", "TokenBatcher",
]
