"""Synthetic Amazon-Review-like lookup workloads.

The paper evaluates on five Amazon Review categories whose defining
statistics are (Table I): number of embeddings 26 k – 963 k, mean bag
length ("Avg. Lat" — average lookups per query) 41 – 96, with power-law
access frequency and power-law co-occurrence (Fig. 2/4).

The dataset itself cannot ship here, so :func:`make_workload` synthesizes
traces with exactly those statistics: Zipf-distributed item popularity,
cluster-structured co-occurrence (items belong to soft "interest
clusters"; a query samples mostly within a cluster, which produces the
heavy-tailed co-occurrence the grouping algorithm exploits), and matched
table size / bag length per paper workload.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticWorkload:
    """Statistics-matched stand-in for one paper workload."""

    name: str
    num_rows: int          # "# of Embedding" (Table I)
    mean_bag: float        # "Avg. Lat" — mean lookups per query
    zipf_a: float = 1.2    # popularity exponent
    num_clusters: int = 0  # 0 → auto (~rows/256)
    in_cluster_p: float = 0.85  # probability a lookup stays in the query's cluster


# Paper Table I workloads. Row counts are scaled down 20x by default in
# make_workload(scale=...) so unit tests stay fast; benchmarks can run
# scale=1.0 for the full sizes.
WORKLOADS = {
    "software": SyntheticWorkload("software", 26_815, 41.32),
    "office_products": SyntheticWorkload("office_products", 315_644, 64.088),
    "electronics": SyntheticWorkload("electronics", 786_868, 55.746),
    "automotive": SyntheticWorkload("automotive", 932_019, 42.26),
    "sports": SyntheticWorkload("sports", 962_876, 96.019),
}


def zipf_popularity(num_rows: int, a: float, rng: np.random.Generator) -> np.ndarray:
    """Normalized Zipf pmf over rows, with a random rank permutation so
    hot ids are scattered across the id space (itemID order is NOT
    popularity order — this is what makes the naive mapping bad)."""
    ranks = np.arange(1, num_rows + 1, dtype=np.float64)
    p = ranks ** (-a)
    p /= p.sum()
    perm = rng.permutation(num_rows)
    out = np.empty(num_rows)
    out[perm] = p
    return out


def zipf_queries(
    num_rows: int,
    num_queries: int,
    mean_bag: float,
    *,
    zipf_a: float = 1.2,
    num_clusters: int | None = None,
    in_cluster_p: float = 0.85,
    basket_repeat_p: float = 0.65,
    num_baskets: int | None = None,
    seed: int = 0,
) -> List[np.ndarray]:
    """Generates a power-law, cluster-correlated query trace.

    Two levels of structure match real co-purchase data:

    * **Template baskets** — real sessions repeat item combinations (the
      structure MERCI's memoization and ReCross's grouping both exploit):
      with probability ``basket_repeat_p`` a query re-uses a popular
      template basket (Zipf-ranked) with a small perturbation.
    * **Interest clusters** — fresh queries pick a cluster by popularity,
      then draw ``k ~ 1 + Poisson(mean_bag - 1)`` lookups, each from the
      cluster w.p. ``in_cluster_p`` (by in-cluster popularity) else from
      the global Zipf.
    """
    rng = np.random.default_rng(seed)
    pop = zipf_popularity(num_rows, zipf_a, rng)
    if not num_clusters:
        num_clusters = max(8, num_rows // 256)

    # cluster assignment: contiguous popularity-rank chunks permuted into
    # id space (so clusters group items of mixed popularity)
    cluster_of = rng.integers(0, num_clusters, size=num_rows)
    cluster_rows: List[np.ndarray] = [
        np.where(cluster_of == c)[0] for c in range(num_clusters)
    ]
    cluster_pop = np.array([pop[r].sum() if len(r) else 0.0 for r in cluster_rows])
    cluster_pop /= cluster_pop.sum()

    def fresh_query() -> np.ndarray:
        c = rng.choice(num_clusters, p=cluster_pop)
        rows_c = cluster_rows[c]
        k = 1 + rng.poisson(max(mean_bag - 1.0, 0.0))
        picks = []
        if len(rows_c):
            pc = pop[rows_c] / pop[rows_c].sum()
            n_in = rng.binomial(k, in_cluster_p)
            if n_in:
                picks.append(rng.choice(rows_c, size=n_in, p=pc))
            k -= n_in
        if k:
            picks.append(rng.choice(num_rows, size=k, p=pop))
        q = np.unique(np.concatenate(picks)) if picks else np.array([0])
        return q.astype(np.int64)

    # template baskets, themselves Zipf-popular
    nb = num_baskets or max(16, num_queries // 8)
    baskets = [fresh_query() for _ in range(nb)]
    b_ranks = np.arange(1, nb + 1, dtype=np.float64) ** (-1.1)
    b_pop = b_ranks / b_ranks.sum()

    queries: List[np.ndarray] = []
    for _ in range(num_queries):
        if rng.random() < basket_repeat_p:
            q = baskets[int(rng.choice(nb, p=b_pop))]
            if rng.random() < 0.3 and len(q) > 2:  # small perturbation
                drop = rng.integers(0, len(q))
                q = np.delete(q, drop)
            queries.append(q.astype(np.int64))
        else:
            queries.append(fresh_query())
    return queries


def make_workload(
    name: str,
    *,
    num_queries: int = 2048,
    scale: float = 0.05,
    seed: int = 0,
) -> tuple[SyntheticWorkload, int, List[np.ndarray]]:
    """Returns (workload, num_rows_scaled, queries) for a paper workload.

    ``scale`` shrinks the table (and proportionally the bag length, floored
    at 8) so tests stay fast; scale=1.0 reproduces Table I sizes.
    """
    wl = WORKLOADS[name]
    rows = max(1024, int(wl.num_rows * scale))
    bag = max(8.0, wl.mean_bag * min(1.0, scale * 4 + 0.75))
    qs = zipf_queries(
        rows, num_queries, bag, zipf_a=wl.zipf_a,
        num_clusters=wl.num_clusters or None, in_cluster_p=wl.in_cluster_p,
        seed=seed,
    )
    return wl, rows, qs
