"""Synthetic Amazon-Review-like lookup workloads.

The paper evaluates on five Amazon Review categories whose defining
statistics are (Table I): number of embeddings 26 k – 963 k, mean bag
length ("Avg. Lat" — average lookups per query) 41 – 96, with power-law
access frequency and power-law co-occurrence (Fig. 2/4).

The dataset itself cannot ship here, so :func:`make_workload` synthesizes
traces with exactly those statistics: Zipf-distributed item popularity,
cluster-structured co-occurrence (items belong to soft "interest
clusters"; a query samples mostly within a cluster, which produces the
heavy-tailed co-occurrence the grouping algorithm exploits), and matched
table size / bag length per paper workload.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticWorkload:
    """Statistics-matched stand-in for one paper workload."""

    name: str
    num_rows: int          # "# of Embedding" (Table I)
    mean_bag: float        # "Avg. Lat" — mean lookups per query
    zipf_a: float = 1.2    # popularity exponent
    num_clusters: int = 0  # 0 → auto (~rows/256)
    in_cluster_p: float = 0.85  # probability a lookup stays in the query's cluster


# Paper Table I workloads. Row counts are scaled down 20x by default in
# make_workload(scale=...) so unit tests stay fast; benchmarks can run
# scale=1.0 for the full sizes.
WORKLOADS = {
    "software": SyntheticWorkload("software", 26_815, 41.32),
    "office_products": SyntheticWorkload("office_products", 315_644, 64.088),
    "electronics": SyntheticWorkload("electronics", 786_868, 55.746),
    "automotive": SyntheticWorkload("automotive", 932_019, 42.26),
    "sports": SyntheticWorkload("sports", 962_876, 96.019),
}


def zipf_popularity(num_rows: int, a: float, rng: np.random.Generator) -> np.ndarray:
    """Normalized Zipf pmf over rows, with a random rank permutation so
    hot ids are scattered across the id space (itemID order is NOT
    popularity order — this is what makes the naive mapping bad)."""
    ranks = np.arange(1, num_rows + 1, dtype=np.float64)
    p = ranks ** (-a)
    p /= p.sum()
    perm = rng.permutation(num_rows)
    out = np.empty(num_rows)
    out[perm] = p
    return out


def zipf_queries(
    num_rows: int,
    num_queries: int,
    mean_bag: float,
    *,
    zipf_a: float = 1.2,
    num_clusters: int | None = None,
    in_cluster_p: float = 0.85,
    basket_repeat_p: float = 0.65,
    num_baskets: int | None = None,
    seed: int = 0,
) -> List[np.ndarray]:
    """Generates a power-law, cluster-correlated query trace.

    Two levels of structure match real co-purchase data:

    * **Template baskets** — real sessions repeat item combinations (the
      structure MERCI's memoization and ReCross's grouping both exploit):
      with probability ``basket_repeat_p`` a query re-uses a popular
      template basket (Zipf-ranked) with a small perturbation.
    * **Interest clusters** — fresh queries pick a cluster by popularity,
      then draw ``k ~ 1 + Poisson(mean_bag - 1)`` lookups, each from the
      cluster w.p. ``in_cluster_p`` (by in-cluster popularity) else from
      the global Zipf.
    """
    rng = np.random.default_rng(seed)
    pop = zipf_popularity(num_rows, zipf_a, rng)
    if not num_clusters:
        num_clusters = max(8, num_rows // 256)

    # cluster assignment: contiguous popularity-rank chunks permuted into
    # id space (so clusters group items of mixed popularity)
    cluster_of = rng.integers(0, num_clusters, size=num_rows)
    cluster_rows: List[np.ndarray] = [
        np.where(cluster_of == c)[0] for c in range(num_clusters)
    ]
    cluster_pop = np.array([pop[r].sum() if len(r) else 0.0 for r in cluster_rows])
    cluster_pop /= cluster_pop.sum()

    def fresh_query() -> np.ndarray:
        c = rng.choice(num_clusters, p=cluster_pop)
        rows_c = cluster_rows[c]
        k = 1 + rng.poisson(max(mean_bag - 1.0, 0.0))
        picks = []
        if len(rows_c):
            pc = pop[rows_c] / pop[rows_c].sum()
            n_in = rng.binomial(k, in_cluster_p)
            if n_in:
                picks.append(rng.choice(rows_c, size=n_in, p=pc))
            k -= n_in
        if k:
            picks.append(rng.choice(num_rows, size=k, p=pop))
        q = np.unique(np.concatenate(picks)) if picks else np.array([0])
        return q.astype(np.int64)

    # template baskets, themselves Zipf-popular
    nb = num_baskets or max(16, num_queries // 8)
    baskets = [fresh_query() for _ in range(nb)]
    b_ranks = np.arange(1, nb + 1, dtype=np.float64) ** (-1.1)
    b_pop = b_ranks / b_ranks.sum()

    queries: List[np.ndarray] = []
    for _ in range(num_queries):
        if rng.random() < basket_repeat_p:
            q = baskets[int(rng.choice(nb, p=b_pop))]
            if rng.random() < 0.3 and len(q) > 2:  # small perturbation
                drop = rng.integers(0, len(q))
                q = np.delete(q, drop)
            queries.append(q.astype(np.int64))
        else:
            queries.append(fresh_query())
    return queries


def make_workload(
    name: str,
    *,
    num_queries: int = 2048,
    scale: float = 0.05,
    seed: int = 0,
) -> tuple[SyntheticWorkload, int, List[np.ndarray]]:
    """Returns (workload, num_rows_scaled, queries) for a paper workload.

    ``scale`` shrinks the table (and proportionally the bag length, floored
    at 8) so tests stay fast; scale=1.0 reproduces Table I sizes.
    """
    wl = WORKLOADS[name]
    rows = max(1024, int(wl.num_rows * scale))
    bag = max(8.0, wl.mean_bag * min(1.0, scale * 4 + 0.75))
    qs = zipf_queries(
        rows, num_queries, bag, zipf_a=wl.zipf_a,
        num_clusters=wl.num_clusters or None, in_cluster_p=wl.in_cluster_p,
        seed=seed,
    )
    return wl, rows, qs


def scale_trace(
    num_rows: int,
    num_queries: int,
    mean_bag: float,
    *,
    num_templates: int | None = None,
    zipf_a: float = 1.05,
    num_clusters: int | None = None,
    in_cluster_p: float = 0.85,
    template_zipf: float = 1.1,
    seed: int = 0,
) -> List[np.ndarray]:
    """Fully vectorized lookup trace for plan-build scale benches.

    :func:`zipf_queries` draws every fresh basket with an
    ``rng.choice(num_rows, p=pop)`` — O(num_rows) PER BASKET, unusable
    beyond ~100k rows.  This generator keeps the same two-level
    structure (Zipf-popular template baskets over Zipf-popular interest
    clusters) but samples everything in flat array passes, so a 10M-row
    / 1M-query trace builds in seconds:

    * rows are ranked by global Zipf popularity and bucketed into
      clusters; within a cluster, popularity order is inherited,
    * every template picks a cluster by cluster popularity, draws
      ``1 + Poisson(mean_bag - 1)`` lookups, each in-cluster w.p.
      ``in_cluster_p`` (by inverse-CDF Zipf rank over the cluster) else
      global, then dedups — one packed sort over the whole flat draw,
    * the query stream samples template ids from a Zipf over templates;
      queries share the template arrays by reference, so the trace
      costs O(num_templates * mean_bag + num_queries) memory.

    Identical queries ARE the point: the co-occurrence build collapses
    them to (pattern, multiplicity) before pair enumeration, which is
    what bounds the 10M-row build.
    """
    if num_rows < 1 or num_queries < 0:
        raise ValueError("num_rows must be >= 1 and num_queries >= 0")
    rng = np.random.default_rng(seed)
    nt = num_templates or max(64, num_rows // 64)
    if not num_clusters:
        num_clusters = max(8, num_rows // 256)
    C = int(num_clusters)

    # global popularity ordering: porder[r] = row with popularity rank r
    pop = zipf_popularity(num_rows, zipf_a, rng)
    porder = np.argsort(-pop, kind="stable").astype(np.int64)

    # cluster bucketing, rows within a cluster kept in popularity order:
    # sort rows by (cluster, popularity rank)
    cluster_of = rng.integers(0, C, size=num_rows)
    prank = np.empty(num_rows, dtype=np.int64)
    prank[porder] = np.arange(num_rows, dtype=np.int64)
    by_cluster = np.lexsort((prank, cluster_of))
    cl_sorted = cluster_of[by_cluster]
    cl_start = np.searchsorted(cl_sorted, np.arange(C + 1))
    cl_size = np.diff(cl_start)

    def zipf_ranks(m: np.ndarray, u: np.ndarray, a: float) -> np.ndarray:
        """Inverse-CDF Zipf(a) rank in [0, m) per draw (continuous
        approximation; exact enough for a synthetic workload)."""
        m = np.maximum(m.astype(np.float64), 1.0)
        if abs(a - 1.0) < 1e-9:
            r = np.power(m, u) - 1.0
        else:
            r = np.power((np.power(m, 1.0 - a) - 1.0) * u + 1.0, 1.0 / (1.0 - a)) - 1.0
        return np.minimum(r.astype(np.int64), (m - 1).astype(np.int64))

    # template cluster choices, Zipf-weighted by cluster popularity mass
    cl_mass = np.zeros(C)
    np.add.at(cl_mass, cl_sorted, pop[by_cluster])
    cl_rank = np.argsort(-cl_mass, kind="stable")
    tpl_c = cl_rank[zipf_ranks(np.full(nt, C), rng.random(nt), template_zipf)]
    tpl_c = tpl_c[cl_size[tpl_c] > 0]
    nt = tpl_c.size

    # flat item draws for all templates at once
    lens = 1 + rng.poisson(max(mean_bag - 1.0, 0.0), size=nt)
    tid = np.repeat(np.arange(nt, dtype=np.int64), lens)
    total = int(lens.sum())
    c_of_draw = tpl_c[tid]
    u = rng.random(total)
    in_c = rng.random(total) < in_cluster_p
    rows_flat = np.empty(total, dtype=np.int64)
    # in-cluster: Zipf rank within the draw's cluster bucket
    r_in = zipf_ranks(cl_size[c_of_draw[in_c]], u[in_c], zipf_a)
    rows_flat[in_c] = by_cluster[cl_start[c_of_draw[in_c]] + r_in]
    # global: Zipf rank over the whole table
    out_c = ~in_c
    rows_flat[out_c] = porder[
        zipf_ranks(np.full(int(out_c.sum()), num_rows), u[out_c], zipf_a)
    ]

    # per-template dedup in ONE packed sort: (template, row) ascending,
    # then drop adjacent duplicates within a template
    if total and num_rows > ((1 << 63) - 1) // max(total, 1):
        raise ValueError(
            f"scale_trace pack overflow: {nt} templates x {num_rows} rows"
        )
    key = tid * np.int64(num_rows) + rows_flat
    key = np.sort(key)
    keep = np.empty(total, dtype=bool)
    keep[0] = True
    np.not_equal(key[1:], key[:-1], out=keep[1:])
    key = key[keep]
    tid_d = key // num_rows
    rows_d = key - tid_d * num_rows
    tlens = np.bincount(tid_d, minlength=nt)
    ends = np.cumsum(tlens)
    starts = ends - tlens
    templates = [rows_d[s:e] for s, e in zip(starts.tolist(), ends.tolist())]
    templates = [t for t in templates if t.size]

    # query stream: Zipf-popular template picks, shared by reference
    pick = zipf_ranks(
        np.full(num_queries, len(templates)), rng.random(num_queries), template_zipf
    )
    return [templates[i] for i in pick.tolist()]
