"""Per-stage progress for long plan builds (``RECROSS_PLAN_PROGRESS``).

A 10M-row plan build runs for tens of seconds per stage; with nothing on
the terminal it is indistinguishable from a hang.  When the
``RECROSS_PLAN_PROGRESS`` env var is set (any non-empty value), the
long-running stages — co-occurrence blocks, the grouping seed walk,
shard placement — emit throttled one-line reports to stderr:

    [plan] grouping  3276800/10000000 rows  32.8%  812.3k rows/s

The emitter is deliberately dumb: callers own the unit ("rows",
"pairs", "groups"), ticks are throttled by wall time so a tick per
CSR block or per seed chunk costs one time() call, and the whole thing
is a no-op object when the env var is unset so hot loops pay a single
attribute check.  Benches surface the same per-stage wall time and
rows/s through their JSON spreads; this knob is for interactive runs.
"""

from __future__ import annotations

import os
import sys
import time

PROGRESS_ENV = "RECROSS_PLAN_PROGRESS"

#: minimum seconds between emitted lines
_INTERVAL_S = 0.5


def plan_progress_enabled() -> bool:
    """True when ``RECROSS_PLAN_PROGRESS`` is set non-empty."""
    return bool(os.environ.get(PROGRESS_ENV))


class StageProgress:
    """Throttled progress reporter for one pipeline stage.

    Args:
      stage: short stage label (``"grouping"``, ``"cooc"``...).
      total: total work units, or 0 when unknown (rate-only lines).
      unit: unit label for the report lines.
      enabled: overrides the env check (benches force-enable).
    """

    def __init__(
        self,
        stage: str,
        total: int = 0,
        unit: str = "rows",
        enabled: bool | None = None,
    ):
        self.enabled = plan_progress_enabled() if enabled is None else bool(enabled)
        self.stage = stage
        self.total = int(total)
        self.unit = unit
        self._t0 = time.perf_counter()
        self._last = self._t0

    def tick(self, done: int) -> None:
        """Report ``done`` units complete (throttled; safe to call often)."""
        if not self.enabled:
            return
        now = time.perf_counter()
        if now - self._last < _INTERVAL_S:
            return
        self._last = now
        self._emit(done, now)

    def finish(self, done: int) -> float:
        """Final report; returns the stage wall time in seconds."""
        now = time.perf_counter()
        if self.enabled:
            self._emit(done, now, final=True)
        return now - self._t0

    def _emit(self, done: int, now: float, final: bool = False) -> None:
        dt = max(now - self._t0, 1e-9)
        rate = done / dt
        pct = f"  {100.0 * done / self.total:5.1f}%" if self.total else ""
        tail = "  done" if final else ""
        print(
            f"[plan] {self.stage:<10s} {done}/{self.total or '?'} "
            f"{self.unit}{pct}  {rate / 1e3:.1f}k {self.unit}/s"
            f"  {dt:.1f}s{tail}",
            file=sys.stderr,
            flush=True,
        )
