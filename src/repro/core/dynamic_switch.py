"""Energy-aware dynamic switching (ReCross §III-D).

The dynamic-switch ADC decides per crossbar activation, from a popcount of
the wordline bitmap, whether to run the cheap READ path (one active row —
no MAC needed, low-resolution conversion) or the full MAC path.

Here that decision is expressed three ways, all sharing one predicate:

  * :func:`popcount` / :func:`select_mode` — the host/NumPy oracle used by
    the simulator and benchmarks;
  * :func:`jnp_select_mode` — the jittable JAX form used by the model-level
    reduction path;
  * the same predicate is inlined in the Pallas kernel
    (:mod:`repro.kernels.crossbar_reduce`) where it picks a row-copy
    datapath instead of a one-hot MXU matmul.

The energy trade-off is *runtime* information: the decision threshold can
be generalized beyond popcount==1 via :func:`energy_breakeven_rows`, which
computes when a sequence of READs stops being cheaper than one MAC (with
the paper's constants the breakeven is at 2 rows, i.e. the paper's
popcount==1 rule is exactly the energy-optimal threshold).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.energy import ReRAMCostModel, DEFAULT_RERAM

READ_MODE = 0
MAC_MODE = 1


def popcount(bitmap: np.ndarray) -> np.ndarray:
    """Number of activated wordlines per tile. bitmap: (..., tile_rows)."""
    return np.asarray(bitmap, dtype=np.int32).sum(axis=-1)


def select_mode(counts: np.ndarray, *, threshold: int = 1) -> np.ndarray:
    """READ_MODE where popcount <= threshold (and > 0), else MAC_MODE.

    counts == 0 tiles are not activated at all; they are reported as
    READ_MODE but charged nothing by the simulator.
    """
    counts = np.asarray(counts)
    return np.where(counts > threshold, MAC_MODE, READ_MODE).astype(np.int8)


def jnp_select_mode(counts: jnp.ndarray, *, threshold: int = 1) -> jnp.ndarray:
    """JAX twin of :func:`select_mode` (jit/vmap-safe)."""
    return jnp.where(counts > threshold, MAC_MODE, READ_MODE).astype(jnp.int8)


def energy_breakeven_rows(model: ReRAMCostModel = DEFAULT_RERAM) -> int:
    """Smallest row count for which one MAC beats serialized READs on energy.

    The dynamic switch takes the READ path while
    ``rows * E_read < E_mac(rows)``.  The paper switches at popcount==1;
    with the flash-ADC energy model the actual energy breakeven is *higher*
    (≈9 rows: one full 6-bit conversion costs ~8.6× a 3-bit read) — i.e.
    an extended "multi-read" policy (serialize 2..breakeven-1 rows through
    the low-res path) saves further energy at a latency cost.  This
    beyond-paper observation is evaluated in benchmarks and §Perf.
    """
    for rows in range(1, model.rows + 1):
        _, e_mac = model.crossbar_mac_event(rows)
        _, e_read = model.crossbar_read_event()
        if rows * e_read >= e_mac:
            return rows
    return model.rows + 1


def mode_statistics(counts: np.ndarray, *, threshold: int = 1) -> dict:
    """Activation-mix stats (paper Fig. 6): share of single-row activations."""
    counts = np.asarray(counts)
    active = counts[counts > 0]
    if active.size == 0:
        return {"activations": 0, "read_fraction": 0.0, "mac_fraction": 0.0,
                "mean_active_rows": 0.0}
    read = int((active <= threshold).sum())
    return {
        "activations": int(active.size),
        "read_fraction": read / active.size,
        "mac_fraction": 1.0 - read / active.size,
        "mean_active_rows": float(active.mean()),
    }
