"""Embedding-to-crossbar mapping (ReCross §III-A step 3-4 output).

Combines a :class:`~repro.core.grouping.Grouping` with a
:class:`~repro.core.replication.ReplicationPlan` into a concrete physical
layout: which tile (crossbar) holds which rows, where the replicas live,
and the permuted/padded table image that is written to device memory
before inference — the exact analogue of "the embedding table is preloaded
into ReRAM based on this optimized mapping".

The layout is consumed by
  * :mod:`repro.core.reduction`   — JAX lookup/reduction through the layout,
  * :mod:`repro.kernels`          — the Pallas tile kernel,
  * :mod:`repro.core.simulator`   — the ReRAM cost simulator,
  * :mod:`repro.dist.sharding`    — cross-shard replication of hot tiles.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.grouping import Grouping
from repro.core.replication import ReplicationPlan


@dataclasses.dataclass
class CrossbarLayout:
    """Physical layout of an embedding table over tiles.

    Logical groups ``0..G-1`` map to physical tiles; group ``g`` owns
    ``copies[g]`` physical tiles.  Rows keep their slot within every copy.

    Attributes:
      group_of / slot_of: ``(num_rows,)`` — logical placement of each row.
      copies: ``(G,)`` — physical copies per group.
      tile_base: ``(G,)`` — first physical tile id of each group; the
        copies of group g are tiles ``tile_base[g] .. tile_base[g]+copies[g]-1``.
      tile_rows: rows per tile (group_size, possibly padded).
      num_rows / dim: logical table shape.
    """

    group_of: np.ndarray
    slot_of: np.ndarray
    copies: np.ndarray
    tile_base: np.ndarray
    tile_rows: int
    num_rows: int
    dim: int

    @property
    def num_groups(self) -> int:
        return int(self.copies.shape[0])

    @property
    def num_tiles(self) -> int:
        return int(self.copies.sum())

    @property
    def padded_rows(self) -> int:
        return self.num_tiles * self.tile_rows

    # ---- index plumbing ---------------------------------------------------

    def physical_row(self, row: int, replica: int = 0) -> int:
        """Physical row index of logical ``row`` in its ``replica``-th copy."""
        g = int(self.group_of[row])
        r = replica % int(self.copies[g])
        tile = int(self.tile_base[g]) + r
        return tile * self.tile_rows + int(self.slot_of[row])

    def gather_index_map(self, replica_of_row: np.ndarray | None = None) -> np.ndarray:
        """(num_rows,) logical→physical row map (replica 0 unless given)."""
        g = self.group_of
        base = self.tile_base[g]
        if replica_of_row is not None:
            base = base + (replica_of_row % self.copies[g])
        return (base * self.tile_rows + self.slot_of).astype(np.int32)

    def build_image(self, table: np.ndarray) -> np.ndarray:
        """Materializes the padded, permuted, replicated device image.

        Returns ``(num_tiles * tile_rows, dim)`` — replica tiles hold
        identical data; padding slots are zero (so a stray access
        contributes nothing to a sum, mirroring an unprogrammed ReRAM
        cell at high resistance).  One vectorized scatter: every
        (row, replica) pair's physical row index is computed with
        repeat/cumsum arithmetic, then assigned in a single fancy index.
        """
        if table.shape != (self.num_rows, self.dim):
            raise ValueError(f"table shape {table.shape} != ({self.num_rows},{self.dim})")
        image = np.zeros((self.padded_rows, self.dim), dtype=table.dtype)
        from repro.core.cooccurrence import segment_ranks

        per_row_copies = self.copies[self.group_of].astype(np.int64)
        src = np.repeat(np.arange(self.num_rows, dtype=np.int64), per_row_copies)
        replica = segment_ranks(per_row_copies)
        tile = self.tile_base[self.group_of[src]].astype(np.int64) + replica
        image[tile * self.tile_rows + self.slot_of[src]] = table[src]
        return image

    def tile_of_groups(self) -> np.ndarray:
        """(num_tiles,) group id owning each physical tile."""
        return np.repeat(
            np.arange(self.num_groups, dtype=np.int32), self.copies
        )


def build_layout(
    grouping: Grouping,
    plan: ReplicationPlan,
    dim: int,
    *,
    tile_rows: int | None = None,
) -> CrossbarLayout:
    """Fuses grouping + replication into a physical layout."""
    copies = np.asarray(plan.copies, dtype=np.int32)
    if len(copies) != grouping.num_groups:
        raise ValueError("plan covers a different number of groups")
    tile_base = np.zeros(grouping.num_groups, dtype=np.int64)
    np.cumsum(copies[:-1], out=tile_base[1:])
    return CrossbarLayout(
        group_of=grouping.group_of.copy(),
        slot_of=grouping.slot_of.copy(),
        copies=copies,
        tile_base=tile_base,
        tile_rows=tile_rows or grouping.group_size,
        num_rows=len(grouping.group_of),
        dim=dim,
    )


@dataclasses.dataclass
class ActivationSet:
    """Sparse compiled form of a query batch against a layout.

    The CSR-style contract every consumer (simulator, query compiler,
    dense bitmap scatter) builds on: one entry per *activation* (a
    (query, tile) pair with ≥1 active wordline), sorted by (query, tile),
    plus the flat (query, tile, slot) wordline entries behind them.

    Attributes:
      act_qid / act_tile / act_rows: ``(A,)`` — per-activation query id,
        physical tile id, and popcount, lexicographically sorted by
        (query, tile) (the order ``np.nonzero`` yields on the dense form).
      ent_qid / ent_tile / ent_slot: ``(E,)`` — deduplicated activated
        wordline entries, sorted by (query, tile, slot).
      batch / num_tiles / tile_rows: dense-form dimensions.
    """

    act_qid: np.ndarray
    act_tile: np.ndarray
    act_rows: np.ndarray
    ent_qid: np.ndarray
    ent_tile: np.ndarray
    ent_slot: np.ndarray
    batch: int
    num_tiles: int
    tile_rows: int

    @property
    def num_activations(self) -> int:
        return int(self.act_qid.shape[0])

    def per_query_tiles(self) -> np.ndarray:
        """(batch,) distinct tiles activated by each query."""
        return np.bincount(self.act_qid, minlength=self.batch).astype(np.int64)

    def max_tiles_per_query(self) -> int:
        per_q = self.per_query_tiles()
        return int(per_q.max()) if per_q.size else 0


def _check_ent_key_capacity(layout: CrossbarLayout, batch: int) -> None:
    """Wordline entries pack as ``(qid * num_tiles + tile) * tile_rows +
    slot`` — the product must fit int64 or keys silently alias.  Raised
    before any entry allocation; ``block_queries`` shrinks the packed
    ``qid`` range, which is how huge batches stay under the limit."""
    span = batch * layout.num_tiles * layout.tile_rows
    if span >= 1 << 63:
        raise ValueError(
            f"entry keys would overflow int64: batch={batch} x "
            f"num_tiles={layout.num_tiles} x tile_rows={layout.tile_rows} "
            f">= 2^63; compile with block_queries to bound the packed range"
        )


def compile_activations(
    layout: CrossbarLayout,
    queries: Sequence[Sequence[int]],
    *,
    balance_replicas: bool = True,
    replica_block: int = 1,
    block_queries: int | None = None,
) -> ActivationSet:
    """Query batch → sparse activation set, fully vectorized.

    For each query, rows are bucketed by group; each touched group
    contributes one activated tile (one of its replicas, chosen
    round-robin per group when ``balance_replicas`` — the scheduler's
    replica-balancing step).  The round-robin state is reproduced
    without any Python loop: the replica of the r-th query touching a
    group (in batch order) is ``r % copies[g]``, computed by ranking the
    unique (query, group) touches within each group.

    ``replica_block > 1`` coarsens the round robin to blocks of that many
    consecutive queries: all queries of a block touching a group share one
    replica (the r-th *block* gets ``r % copies[g]``).  Use this when
    compiling for the query-blocked kernel — per-query balancing would
    spread a block's queries over replica tiles of identical data,
    inflating the block's tile union and defeating the DMA amortization.
    Numerics are unaffected either way (replicas hold identical rows).

    ``block_queries`` compiles the batch in chunks of that many
    consecutive queries so the peak intermediate (flattened ids, packed
    touch/entry keys) is O(chunk), not O(batch) — the per-group
    round-robin offset is carried across chunks, so the output is
    bit-identical to the one-shot compile for every chunk size.  Chunk
    boundaries are rounded up to ``replica_block`` multiples so a
    coarsened round-robin unit never straddles a chunk.
    """
    if replica_block < 1:
        raise ValueError("replica_block must be >= 1")
    if block_queries is not None and block_queries < 1:
        raise ValueError("block_queries must be >= 1")
    from repro.core.cooccurrence import flatten_ragged

    arrays = [np.asarray(q, dtype=np.int64).ravel() for q in queries]
    batch = len(arrays)
    empty = np.empty(0, np.int64)
    if block_queries is None or block_queries >= batch:
        _check_ent_key_capacity(layout, max(batch, 1))
        flat, lens, _ = flatten_ragged(arrays)
        if flat.size == 0:
            return ActivationSet(
                act_qid=empty, act_tile=empty, act_rows=empty,
                ent_qid=empty, ent_tile=empty, ent_slot=empty,
                batch=batch, num_tiles=layout.num_tiles,
                tile_rows=layout.tile_rows,
            )
        rr = _rr_state(layout, balance_replicas)
        parts = [_compile_chunk(layout, flat, lens, balance_replicas,
                                replica_block, rr, 0)]
    else:
        # round the chunk up to a replica_block multiple so coarsened
        # round-robin units (replica_block consecutive queries) are whole
        step = -(-block_queries // replica_block) * replica_block
        _check_ent_key_capacity(layout, step)
        rr = _rr_state(layout, balance_replicas)
        parts = []
        for q0 in range(0, batch, step):
            chunk = arrays[q0:q0 + step]
            flat, lens, _ = flatten_ragged(chunk)
            if flat.size == 0:
                continue
            parts.append(_compile_chunk(layout, flat, lens, balance_replicas,
                                        replica_block, rr, q0))
    if not parts:
        return ActivationSet(
            act_qid=empty, act_tile=empty, act_rows=empty,
            ent_qid=empty, ent_tile=empty, ent_slot=empty,
            batch=batch, num_tiles=layout.num_tiles, tile_rows=layout.tile_rows,
        )
    cat = [np.concatenate([p[k] for p in parts]) for k in range(6)]
    return ActivationSet(
        act_qid=cat[0], act_tile=cat[1], act_rows=cat[2],
        ent_qid=cat[3], ent_tile=cat[4], ent_slot=cat[5],
        batch=batch, num_tiles=layout.num_tiles, tile_rows=layout.tile_rows,
    )


def _rr_state(layout: CrossbarLayout, balance_replicas: bool) -> np.ndarray | None:
    """Per-group round-robin touch counters carried across query chunks."""
    if not balance_replicas:
        return None
    return np.zeros(layout.num_groups, dtype=np.int64)


def _compile_chunk(
    layout: CrossbarLayout,
    flat: np.ndarray,
    lens: np.ndarray,
    balance_replicas: bool,
    replica_block: int,
    rr: np.ndarray | None,
    qid_base: int,
) -> tuple[np.ndarray, ...]:
    """Compiles one consecutive query chunk; updates ``rr`` in place.

    ``qid_base`` must be a ``replica_block`` multiple.  Returns the six
    activation/entry arrays with global query ids; within-chunk order is
    (query, tile[, slot]) ascending, so chunks concatenate into the
    globally sorted order the one-shot compile produces.
    """
    from repro.core.cooccurrence import segment_ranks

    chunk_batch = int(lens.size)
    qid = np.repeat(np.arange(chunk_batch, dtype=np.int64), lens)
    group = layout.group_of[flat].astype(np.int64)
    slot = layout.slot_of[flat].astype(np.int64)

    # one tile choice per unique (query, group) touch
    num_groups = np.int64(layout.num_groups)
    touch_key = qid * num_groups + group
    uniq_touch, inv = np.unique(touch_key, return_inverse=True)
    t_qid = uniq_touch // num_groups
    t_group = uniq_touch % num_groups
    if balance_replicas:
        # round-robin unit: a (query, group) touch, or a (block, group)
        # touch when replica_block > 1; qid_base is a replica_block
        # multiple, so local block ids coincide with global ones shifted.
        if replica_block > 1:
            ukey = (t_qid // replica_block) * num_groups + t_group
            units, uinv = np.unique(ukey, return_inverse=True)
            u_group = units % num_groups
        else:
            uinv = None
            u_group = t_group
        # rank of each unit within its group, in batch order: unit keys are
        # sorted by (unit, group), so a stable sort by group preserves batch
        # order inside each group segment — run-local rank is the round robin.
        order = np.argsort(u_group, kind="stable")
        g_sorted = u_group[order]
        run_lengths = np.bincount(
            g_sorted, minlength=layout.num_groups
        ).astype(np.int64)
        rank = np.empty(g_sorted.size, dtype=np.int64)
        rank[order] = segment_ranks(run_lengths)
        rank += rr[u_group]  # carry from earlier chunks
        replica = rank % layout.copies[u_group].astype(np.int64)
        rr += run_lengths
        if uinv is not None:
            replica = replica[uinv]
    else:
        replica = np.zeros(t_qid.size, dtype=np.int64)
    t_tile = layout.tile_base[t_group].astype(np.int64) + replica

    # deduplicated (query, tile, slot) wordline entries
    ent_tile = t_tile[inv]
    tile_rows = np.int64(layout.tile_rows)
    ent_key = (qid * np.int64(layout.num_tiles) + ent_tile) * tile_rows + slot
    ent_uniq = np.unique(ent_key)
    e_slot = ent_uniq % tile_rows
    e_qt = ent_uniq // tile_rows
    e_tile = e_qt % layout.num_tiles
    e_qid = e_qt // layout.num_tiles

    # popcount per activation: ent entries grouped by (qid, tile); the
    # unique (qid, tile) keys come out sorted — matching np.nonzero order
    act_key, act_rows = np.unique(e_qt, return_counts=True)
    base = np.int64(qid_base)
    return (
        (act_key // layout.num_tiles).astype(np.int64) + base,
        (act_key % layout.num_tiles).astype(np.int64),
        act_rows.astype(np.int64),
        e_qid.astype(np.int64) + base,
        e_tile.astype(np.int64),
        e_slot.astype(np.int64),
    )


def query_tile_bitmaps(
    layout: CrossbarLayout,
    queries: Sequence[Sequence[int]],
    *,
    balance_replicas: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Compiles a query batch into dense per-tile wordline bitmaps.

    Vectorized scatter from :func:`compile_activations`.  Prefer the
    sparse :class:`ActivationSet` for large batches — the dense tensor is
    ``batch × num_tiles × tile_rows`` and exists for the kernel-compile
    and diagnostics paths.

    Returns:
      bitmaps: ``(batch, num_tiles, tile_rows)`` uint8 — activation image.
      counts:  ``(batch, num_tiles)`` int32 — popcount per tile (input to
        the dynamic switch).
    """
    acts = compile_activations(layout, queries, balance_replicas=balance_replicas)
    bitmaps = np.zeros((acts.batch, layout.num_tiles, layout.tile_rows), dtype=np.uint8)
    bitmaps[acts.ent_qid, acts.ent_tile, acts.ent_slot] = 1
    counts = np.zeros((acts.batch, layout.num_tiles), dtype=np.int32)
    counts[acts.act_qid, acts.act_tile] = acts.act_rows
    return bitmaps, counts


def _reference_query_tile_bitmaps(
    layout: CrossbarLayout,
    queries: Sequence[Sequence[int]],
    *,
    balance_replicas: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Original per-row Python loop (equivalence oracle for the tests)."""
    batch = len(queries)
    bitmaps = np.zeros((batch, layout.num_tiles, layout.tile_rows), dtype=np.uint8)
    rr = np.zeros(layout.num_groups, dtype=np.int64)  # per-group round robin
    for q_idx, q in enumerate(queries):
        per_group: dict[int, list[int]] = {}
        for row in q:
            per_group.setdefault(int(layout.group_of[row]), []).append(int(row))
        for g, rows in per_group.items():
            if balance_replicas:
                replica = int(rr[g] % layout.copies[g])
                rr[g] += 1
            else:
                replica = 0
            tile = int(layout.tile_base[g]) + replica
            for row in rows:
                bitmaps[q_idx, tile, int(layout.slot_of[row])] = 1
    counts = bitmaps.sum(axis=-1).astype(np.int32)
    return bitmaps, counts
