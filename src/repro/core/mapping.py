"""Embedding-to-crossbar mapping (ReCross §III-A step 3-4 output).

Combines a :class:`~repro.core.grouping.Grouping` with a
:class:`~repro.core.replication.ReplicationPlan` into a concrete physical
layout: which tile (crossbar) holds which rows, where the replicas live,
and the permuted/padded table image that is written to device memory
before inference — the exact analogue of "the embedding table is preloaded
into ReRAM based on this optimized mapping".

The layout is consumed by
  * :mod:`repro.core.reduction`   — JAX lookup/reduction through the layout,
  * :mod:`repro.kernels`          — the Pallas tile kernel,
  * :mod:`repro.core.simulator`   — the ReRAM cost simulator,
  * :mod:`repro.dist.sharding`    — cross-shard replication of hot tiles.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.grouping import Grouping
from repro.core.replication import ReplicationPlan


@dataclasses.dataclass
class CrossbarLayout:
    """Physical layout of an embedding table over tiles.

    Logical groups ``0..G-1`` map to physical tiles; group ``g`` owns
    ``copies[g]`` physical tiles.  Rows keep their slot within every copy.

    Attributes:
      group_of / slot_of: ``(num_rows,)`` — logical placement of each row.
      copies: ``(G,)`` — physical copies per group.
      tile_base: ``(G,)`` — first physical tile id of each group; the
        copies of group g are tiles ``tile_base[g] .. tile_base[g]+copies[g]-1``.
      tile_rows: rows per tile (group_size, possibly padded).
      num_rows / dim: logical table shape.
    """

    group_of: np.ndarray
    slot_of: np.ndarray
    copies: np.ndarray
    tile_base: np.ndarray
    tile_rows: int
    num_rows: int
    dim: int

    @property
    def num_groups(self) -> int:
        return int(self.copies.shape[0])

    @property
    def num_tiles(self) -> int:
        return int(self.copies.sum())

    @property
    def padded_rows(self) -> int:
        return self.num_tiles * self.tile_rows

    # ---- index plumbing ---------------------------------------------------

    def physical_row(self, row: int, replica: int = 0) -> int:
        """Physical row index of logical ``row`` in its ``replica``-th copy."""
        g = int(self.group_of[row])
        r = replica % int(self.copies[g])
        tile = int(self.tile_base[g]) + r
        return tile * self.tile_rows + int(self.slot_of[row])

    def gather_index_map(self, replica_of_row: np.ndarray | None = None) -> np.ndarray:
        """(num_rows,) logical→physical row map (replica 0 unless given)."""
        g = self.group_of
        base = self.tile_base[g]
        if replica_of_row is not None:
            base = base + (replica_of_row % self.copies[g])
        return (base * self.tile_rows + self.slot_of).astype(np.int32)

    def build_image(self, table: np.ndarray) -> np.ndarray:
        """Materializes the padded, permuted, replicated device image.

        Returns ``(num_tiles * tile_rows, dim)`` — replica tiles hold
        identical data; padding slots are zero (so a stray access
        contributes nothing to a sum, mirroring an unprogrammed ReRAM
        cell at high resistance).
        """
        if table.shape != (self.num_rows, self.dim):
            raise ValueError(f"table shape {table.shape} != ({self.num_rows},{self.dim})")
        image = np.zeros((self.padded_rows, self.dim), dtype=table.dtype)
        for g in range(self.num_groups):
            rows = np.where(self.group_of == g)[0]
            slots = self.slot_of[rows]
            for c in range(int(self.copies[g])):
                tile = int(self.tile_base[g]) + c
                image[tile * self.tile_rows + slots] = table[rows]
        return image

    def tile_of_groups(self) -> np.ndarray:
        """(num_tiles,) group id owning each physical tile."""
        out = np.empty(self.num_tiles, dtype=np.int32)
        for g in range(self.num_groups):
            out[self.tile_base[g] : self.tile_base[g] + self.copies[g]] = g
        return out


def build_layout(
    grouping: Grouping,
    plan: ReplicationPlan,
    dim: int,
    *,
    tile_rows: int | None = None,
) -> CrossbarLayout:
    """Fuses grouping + replication into a physical layout."""
    copies = np.asarray(plan.copies, dtype=np.int32)
    if len(copies) != grouping.num_groups:
        raise ValueError("plan covers a different number of groups")
    tile_base = np.zeros(grouping.num_groups, dtype=np.int64)
    np.cumsum(copies[:-1], out=tile_base[1:])
    return CrossbarLayout(
        group_of=grouping.group_of.copy(),
        slot_of=grouping.slot_of.copy(),
        copies=copies,
        tile_base=tile_base,
        tile_rows=tile_rows or grouping.group_size,
        num_rows=len(grouping.group_of),
        dim=dim,
    )


def query_tile_bitmaps(
    layout: CrossbarLayout,
    queries: Sequence[Sequence[int]],
    *,
    balance_replicas: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Compiles a query batch into per-tile wordline bitmaps.

    For each query, rows are bucketed by group; each touched group
    contributes one activated tile (one of its replicas, chosen
    round-robin per group when ``balance_replicas`` — the scheduler's
    replica-balancing step) with a ``tile_rows`` bitmap of activated
    wordlines.

    Returns:
      bitmaps: ``(batch, num_tiles, tile_rows)`` uint8 — activation image.
      counts:  ``(batch, num_tiles)`` int32 — popcount per tile (input to
        the dynamic switch).
    """
    batch = len(queries)
    bitmaps = np.zeros((batch, layout.num_tiles, layout.tile_rows), dtype=np.uint8)
    rr = np.zeros(layout.num_groups, dtype=np.int64)  # per-group round robin
    for q_idx, q in enumerate(queries):
        per_group: dict[int, list[int]] = {}
        for row in q:
            per_group.setdefault(int(layout.group_of[row]), []).append(int(row))
        for g, rows in per_group.items():
            if balance_replicas:
                replica = int(rr[g] % layout.copies[g])
                rr[g] += 1
            else:
                replica = 0
            tile = int(layout.tile_base[g]) + replica
            for row in rows:
                bitmaps[q_idx, tile, int(layout.slot_of[row])] = 1
    counts = bitmaps.sum(axis=-1).astype(np.int32)
    return bitmaps, counts
