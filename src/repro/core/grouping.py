"""Correlation-aware embedding grouping (ReCross §III-B, Algorithm 1).

Greedily partitions embedding rows into groups of ``group_size`` (the
crossbar height, 64 in the paper) such that rows that co-occur in queries
land in the same group.  A query then activates few groups (crossbars /
VMEM tiles) instead of scattering across many.

The implementation follows Algorithm 1 line-for-line, with three
production-grade refinements that do not change the algorithm's semantics:

  * the candidate list is a lazy max-heap keyed by co-occurrence weight
    *into the current group* (Algorithm 1 recomputes the max by a linear
    scan; the heap makes the whole pass O(E log E) instead of O(V·E));
    neighbor expansion reads the graph's CSR slices directly
    (:meth:`CoOccurrenceGraph.neighbor_arrays`), no per-row dicts,
  * candidate weights live in a flat array indexed by row id (bulk
    scatter-add per pick) and each pick pushes ONE heap entry — the
    whole neighbor batch, pre-sorted by ``(-weight, id)`` with NumPy and
    advanced lazily on pop.  Most pushed candidates are never popped
    (a 64-row group consumes 64 picks out of thousands of candidate
    updates), so the batch heap turns ~E per-edge ``heappush`` calls
    into ~V batch pushes,
  * rows with no ungrouped neighbours left fall back to frequency order,
    which is what "foreach embedding in sorted(embeddingList)" yields
    anyway once candidateList is empty.

``_reference_correlation_aware_grouping`` retains the original dict+
per-edge-push loop as the equivalence oracle; the batch-heap pass is
bit-identical (pop order is the same total order on ``(-weight, id)``,
see the invariant note on :func:`correlation_aware_grouping`).

**Epoch-blocked formulation (DESIGN.md §11).** At 10M rows the scalar
pop loop is the plan-build wall: every pick costs one heap pop plus one
CSR push, ~15 interpreter-bound microseconds each.  ``epoch > 1``
switches to a blocked pass that amortises that overhead over whole
rounds: each round bulk-extracts up to ``epoch`` picks from the heap's
top batches — the validated prefix of each batch that outranks the
true second-best head is consumed in ONE vectorized compare — then
pushes the merged CSR neighbourhoods of every pick in the round as a
single scatter-add (``np.subtract.at`` on the packed accumulator) and
one pre-sorted batch.  ``epoch=1`` reproduces the scalar pop-push
interleaving exactly (bit-identical to the oracle, pinned in tests);
``epoch>1`` trades pick-by-pick weight accumulation inside a round for
throughput, with the grouping-quality bound (total intra-group
co-occurrence mass >= 99% of the oracle's, :func:`grouping_quality`)
pinned in tests and recorded by ``benchmarks/pipeline_bench.py``.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.cooccurrence import CoOccurrenceGraph
from repro.core.progress import StageProgress


@dataclasses.dataclass
class Grouping:
    """Result of the grouping pass.

    Attributes:
      groups: list of groups; each group is a list of row ids,
        ``len(group) <= group_size`` (only the last group may be short).
      group_of: ``(num_rows,)`` int32 — group index of each row.
      slot_of: ``(num_rows,)`` int32 — slot (wordline) of each row inside
        its group.
      group_size: the crossbar height used.
    """

    groups: List[List[int]]
    group_of: np.ndarray
    slot_of: np.ndarray
    group_size: int

    @property
    def num_groups(self) -> int:
        return len(self.groups)

    def group_freq(self, freq: np.ndarray) -> np.ndarray:
        """Aggregate access frequency per group (input to Eq. 1 replication)."""
        out = np.zeros(self.num_groups, dtype=np.int64)
        np.add.at(out, self.group_of, freq)
        return out


def _check_heap_key_capacity(graph: CoOccurrenceGraph, shift: int) -> None:
    """Loud overflow guard on the packed grouping heap keys.

    A candidate's packed key is ``j - weight_into[j] << shift`` where
    ``weight_into[j]`` accumulates edge weights into the current group
    — bounded by the total edge-weight mass of the graph.  If that
    bound shifted up cannot fit int64 alongside the id, a >= 2^20-row /
    heavy-history table would silently alias (weight bits bleeding into
    id bits); fail loudly instead.
    """
    total_w = int(graph.weights.sum()) if graph.weights.size else 0
    if (total_w << shift) + graph.num_rows >= 1 << 63:
        raise ValueError(
            "grouping heap keys overflow int64: "
            f"num_rows={graph.num_rows} (id shift {shift}) with total "
            f"co-occurrence mass {total_w} cannot pack into one key; "
            "shard the lookup history or scale weights down"
        )


def correlation_aware_grouping(
    graph: CoOccurrenceGraph, group_size: int, *, epoch: int = 1
) -> Grouping:
    """Algorithm 1: correlation-aware embedding grouping.

    Args:
      graph: co-occurrence graph from the lookup history.
      group_size: rows per group (= crossbar height / tile rows).
      epoch: picks extracted per bulk round.  ``1`` (default) is the
        scalar batch-heap pass, bit-identical to the retained oracle.
        ``>1`` runs the epoch-blocked pass (module docstring): up to
        ``epoch`` picks are admitted per round before their merged
        neighbourhoods are pushed, trading exact pick-by-pick weight
        accumulation for vectorized throughput under the pinned
        >= 99% intra-group co-occurrence mass bound.

    Returns:
      A :class:`Grouping` covering every row exactly once.
    """
    if group_size < 1:
        raise ValueError("group_size must be >= 1")
    if epoch < 1:
        raise ValueError("epoch must be >= 1")
    n = graph.num_rows
    _check_heap_key_capacity(graph, max(n.bit_length(), 1))
    if epoch > 1:
        groups, cold = _epoch_blocked_pass(graph, group_size, epoch)
        groups = _repack_short_groups(groups, group_size, extra_loose=cold)
        return _grouping_from_groups(groups, n, group_size, check_cover=True)
    grouped = np.zeros(n, dtype=bool)  # groupedIndices
    groups: List[List[int]] = []

    order = graph.nodes_by_frequency()  # sorted(embeddingList)

    # Candidate priorities pack into ONE int64 PER ROW ID:
    # packed[j] = j - weight_into[j] * SCALE (weight 0 → packed[j] = j).
    # Ascending key order is (weight descending, id ascending) — exactly
    # the (-weight, id) pop order of the per-edge heap — so a batch is a
    # single sort, heap comparisons touch plain ints, and a candidate's
    # currency check is ONE int compare (packed[j] == key) instead of a
    # weight decode.  The accumulate "ComputeWeight(embedding,
    # currentEmbedding) over the merged list" is a single fused
    # gather-subtract per pick: packed[nbr] -= weight*SCALE; reset
    # between seeds by restoring only the touched ids to their identity.
    SHIFT = max(n.bit_length(), 1)
    SCALE = 1 << SHIFT
    MASK = SCALE - 1
    packed = np.arange(n, dtype=np.int64)
    # weights pre-scaled once so the push path skips the per-pick mul
    wscale = graph.weights.astype(np.int64) * SCALE
    # bytearray mirror of `grouped` for O(50ns) scalar reads in the pop
    # loop (numpy bool scalars cost ~3x more); the numpy array serves the
    # vectorized bulk staleness check.
    grouped_b = bytearray(n)
    indptr = graph.indptr.tolist()
    indices = graph.indices
    heappush, heappop, heapreplace = (
        heapq.heappush, heapq.heappop, heapq.heapreplace
    )

    for seed in order.tolist():
        if grouped_b[seed]:  # line 3-5: skip already grouped
            continue
        current: List[int] = [seed]
        grouped_b[seed] = 1
        grouped[seed] = True

        # candidateList as a lazy max-heap of sorted neighbor BATCHES.
        # Each entry is (key, seq, cursor, keys): the head of a sorted
        # packed-key batch plus the array to advance through on pop.
        # `seq` is a unique tiebreaker so heapq never compares the array
        # payloads; entries with equal keys are the same candidate at the
        # same weight, so their relative order cannot change the pick
        # sequence.  Pop order over distinct (weight, id) is the same
        # total order the per-edge heap yields — bit-identical groups.
        heap: List[tuple] = []
        touched: List[np.ndarray] = []
        seq = 0

        row = seed
        while len(current) < group_size:
            # ---- push_neighbors(row): one batch heap entry per pick.
            # (The reference loop also pushes after its final pick; that
            # batch is never popped, so skipping it here cannot change
            # the pick sequence — weights are per-seed scoped.) ----
            lo, hi = indptr[row], indptr[row + 1]
            if hi > lo:
                nbr_ids = indices[lo:hi]
                live = ~grouped[nbr_ids]
                ids = nbr_ids[live]
                if ids.size:
                    # CSR neighbor ids are unique within a row, so the
                    # fused gather-subtract is exact; pre-scaled weights
                    # and the packed accumulator make the re-push ONE
                    # arithmetic op on top of the liveness mask
                    pk = packed[ids] - wscale[lo:hi][live]
                    packed[ids] = pk
                    touched.append(ids)
                    if pk.size > 1:
                        pk.sort()          # fresh array → sort in place
                    heappush(heap, (int(pk[0]), seq, 0, pk))
                    seq += 1

            # ---- pop the max-weight candidate (lazy deletion of stale
            # entries): the heap head is the globally best *pushed*
            # (weight, id); skip it unless it still matches the
            # candidate's current weight.  The whole prefix of the top
            # batch that outranks the second-best head can be validated
            # in BULK: weights only grow and grouped only flips on
            # within a seed, so a stale entry is stale forever — skipped
            # entries never need revisiting, and equal keys across
            # batches are the same (weight, id), so consuming ties out
            # of the head first cannot change the pick sequence. ----
            best = None
            stale_s, stale_run = -1, 0
            while heap:
                key, s, k, keys = heap[0]
                # decode key = j - w*SCALE: SCALE is a power of two, so
                # j = key mod SCALE falls out of a mask; currency is one
                # int compare against the packed accumulator
                j = key & MASK
                if not grouped_b[j] and packed[j] == key:
                    # valid head: the common case stays a scalar pop
                    k += 1
                    if k < keys.size:
                        heapreplace(heap, (int(keys[k]), s, k, keys))
                    else:
                        heappop(heap)
                    best = j
                    break
                # stale head.  Staleness is permanent within a seed
                # (weights only grow, grouped only flips on), so a long
                # stale RUN inside one batch can be skipped in bulk:
                # after 8 consecutive stale pops of the same batch,
                # validate vectorized the whole prefix that outranks
                # the true second-best head (the smaller of the root's
                # children).  Equal keys across batches are the same
                # (weight, id), so consuming ties out of the head first
                # cannot change the pick sequence; the streak gate
                # keeps the scalar pop the only cost everywhere else.
                stale_run = stale_run + 1 if s == stale_s else 1
                stale_s = s
                k += 1
                nk = k
                if stale_run >= 8 and keys.size - k > 16:
                    if len(heap) > 2:
                        limit = (heap[1][0] if heap[1][0] < heap[2][0]
                                 else heap[2][0])
                    elif len(heap) > 1:
                        limit = heap[1][0]
                    else:
                        limit = None
                    hi_k = (
                        int(np.searchsorted(keys, limit, side="right"))
                        if limit is not None else keys.size
                    )
                    if hi_k > k:
                        seg = keys[k:hi_k]
                        j_arr = seg & MASK
                        ok = np.nonzero(
                            ~grouped[j_arr] & (packed[j_arr] == seg)
                        )[0]
                        if ok.size:
                            d = int(ok[0])
                            best = int(j_arr[d])
                            nk = k + d + 1
                        else:
                            nk = hi_k
                if nk < keys.size:
                    heapreplace(heap, (int(keys[nk]), s, nk, keys))
                else:
                    heappop(heap)
                if best is not None:
                    break
            if best is None:
                break  # no correlated candidates left: group stays short
            current.append(best)
            grouped_b[best] = 1
            grouped[best] = True
            row = best  # line 17: merge neighbours of the pick

        groups.append(current)
        if touched:
            # weights are per-seed scoped: restore identity packing
            cat = np.concatenate(touched)
            packed[cat] = cat

    # Compact short groups: Algorithm 1 leaves the trailing group short;
    # greedy filling can also produce mid-stream short groups when a
    # connected component is exhausted. Pack those rows together so that
    # only the final group may be short (keeps the crossbar image dense).
    groups = _repack_short_groups(groups, group_size)
    return _grouping_from_groups(groups, n, group_size, check_cover=True)


def _slice_positions(starts: np.ndarray, ends: np.ndarray) -> Optional[np.ndarray]:
    """Concatenated index positions covering ``[starts[i], ends[i])``.

    The vectorized multi-slice gather: one cumsum builds the positions
    of every CSR slice of a round's picks without a Python-level loop
    over picks.  Returns ``None`` when every slice is empty.
    """
    lens = ends - starts
    nz = lens > 0
    if not nz.any():
        return None
    s, e, l = starts[nz], ends[nz], lens[nz]
    offs = np.cumsum(l)
    delta = np.ones(int(offs[-1]), dtype=np.int64)
    delta[0] = s[0]
    if l.size > 1:
        delta[offs[:-1]] = s[1:] - e[:-1] + 1
    return np.cumsum(delta)


def _epoch_blocked_pass(
    graph: CoOccurrenceGraph, group_size: int, epoch: int
) -> tuple[List[List[int]], np.ndarray]:
    """Epoch-blocked grouping rounds (module docstring; DESIGN.md §11).

    Per round: extract up to the round budget of valid picks from the
    heap, then scatter-subtract the merged CSR neighbourhoods of ALL of
    the round's picks into the packed accumulator in one pass
    (``np.subtract.at`` handles duplicate neighbour ids across picks)
    and push them as one pre-sorted batch.  The round budget ramps
    geometrically (1, 2, 4, ... ``epoch``): the first picks define the
    group's core, where pick-by-pick weight accumulation matters most;
    tail fill tolerates blocking.  Extraction is hybrid: a valid head
    whose following ``budget`` keys all outrank the true second-best
    batch head (the smaller of the root's children — the epoch
    boundary) is consumed as one vectorized prefix validation; thin
    prefixes fall back to the scalar pop, and stale runs reuse the
    scalar pass's streak-gated bulk sweep.  Stale entries are stale
    forever within a seed (weights only grow, grouped only flips on),
    so skipped prefixes never need revisiting — the same lazy-deletion
    invariant as the scalar pass.  With ``epoch=1`` every round takes
    exactly one pick before its push and the pass is bit-identical to
    the oracle (pinned in tests).

    Memory: no ``indptr.tolist()`` / ``order.tolist()`` materialisation
    — the seed walk filters frequency-order chunks against ``grouped``
    so a 10M-row table never builds a 10M-element Python list.
    """
    n = graph.num_rows
    SHIFT = max(n.bit_length(), 1)
    SCALE = 1 << SHIFT
    MASK = np.int64(SCALE - 1)
    MASKI = SCALE - 1
    packed = np.arange(n, dtype=np.int64)
    wscale = graph.weights.astype(np.int64) * SCALE
    grouped = np.zeros(n, dtype=bool)
    grouped_b = bytearray(n)
    indptr = graph.indptr.astype(np.int64, copy=False)
    indices = graph.indices
    order = graph.nodes_by_frequency()
    heappush, heappop, heapreplace = (
        heapq.heappush, heapq.heappop, heapq.heapreplace
    )
    deg = np.diff(indptr)
    groups: List[List[int]] = []
    cold: List[np.ndarray] = []
    progress = StageProgress("grouping", n)
    done = 0
    SEED_CHUNK = 1 << 16

    for base in range(0, n, SEED_CHUNK):
        chunk = order[base : base + SEED_CHUNK]
        chunk = chunk[~grouped[chunk]]
        # ---- bulk cold tail: rows with NO co-occurrence edges can
        # never be candidates nor gain weight — the scalar walk would
        # make each a singleton group.  Collect them vectorized (in
        # frequency order) and let the repack chunk them, instead of
        # paying per-seed Python overhead for the (at scale, dominant)
        # edgeless majority.
        zmask = deg[chunk] == 0
        if zmask.any():
            zrows = chunk[zmask]
            grouped[zrows] = True
            cold.append(zrows)
            done += int(zrows.size)
            chunk = chunk[~zmask]
        for seed in chunk.tolist():
            if grouped_b[seed]:
                continue
            current: List[int] = [seed]
            grouped_b[seed] = 1
            grouped[seed] = True
            heap: List[tuple] = []
            touched: List[np.ndarray] = []
            seq = 0
            ramp = 1
            pend1 = seed                       # scalar pending pick
            pend_arrs: List[np.ndarray] = []   # multi-pick rounds

            while len(current) < group_size:
                # ---- merged push of the last round's picks.  The
                # single-pick round keeps the scalar pass's direct CSR
                # slice; multi-pick rounds gather every pick's slice in
                # one cumsum (multi-slice gather) and scatter-subtract
                # once.  Duplicate ids across picks ride along as extra
                # batch entries at the same (post-accumulate) key — the
                # first pop groups the id, the rest fail the check.
                if pend1 >= 0:
                    lo, hi = int(indptr[pend1]), int(indptr[pend1 + 1])
                    pend1 = -1
                    if hi > lo:
                        nbr = indices[lo:hi]
                        live = ~grouped[nbr]
                        ids = nbr[live]
                        if ids.size:
                            pk = packed[ids] - wscale[lo:hi][live]
                            packed[ids] = pk
                            touched.append(ids)
                            if pk.size > 1:
                                pk.sort()
                            heappush(heap, (int(pk[0]), seq, 0, pk))
                            seq += 1
                elif pend_arrs:
                    parr = (pend_arrs[0] if len(pend_arrs) == 1
                            else np.concatenate(pend_arrs))
                    pend_arrs = []
                    pos = _slice_positions(indptr[parr], indptr[parr + 1])
                    if pos is not None:
                        ids = indices[pos]
                        live = ~grouped[ids]
                        ids = ids[live]
                        if ids.size:
                            np.subtract.at(packed, ids, wscale[pos[live]])
                            touched.append(ids)
                            pk = packed[ids]
                            if pk.size > 1:
                                pk.sort()
                            heappush(heap, (int(pk[0]), seq, 0, pk))
                            seq += 1

                # round budget: geometric ramp capped by `epoch` and by
                # the space left in the group
                budget = min(ramp, epoch, group_size - len(current))
                ramp += ramp
                picks_s: List[int] = []
                stale_s, stale_run = -1, 0
                while budget > 0 and heap:
                    key, s, k, keys = heap[0]
                    j = key & MASKI
                    if not grouped_b[j] and packed[j] == key:
                        # valid head.  Rich-prefix probe: if the next
                        # `budget` keys of this batch all outrank the
                        # second-best head, one vectorized validation
                        # admits the whole run.
                        if budget > 1 and keys.size - k > 1:
                            if len(heap) > 2:
                                limit = (heap[1][0] if heap[1][0] < heap[2][0]
                                         else heap[2][0])
                            elif len(heap) > 1:
                                limit = heap[1][0]
                            else:
                                limit = None
                            probe = min(k + budget, keys.size) - 1
                            if limit is None or keys[probe] <= limit:
                                hi_k = (
                                    int(np.searchsorted(keys, limit, side="right"))
                                    if limit is not None else keys.size
                                )
                                seg = keys[k:hi_k]
                                j_arr = seg & MASK
                                ok = np.nonzero(
                                    ~grouped[j_arr] & (packed[j_arr] == seg)
                                )[0]
                                if ok.size > 1:
                                    # duplicate ids carry EQUAL keys,
                                    # adjacent in the sorted prefix — one
                                    # validation must not admit a row twice
                                    vk = seg[ok]
                                    ok = ok[np.concatenate(
                                        ([True], vk[1:] != vk[:-1])
                                    )]
                                take = ok[:budget]
                                picks = j_arr[take]
                                grouped[picks] = True
                                pl = picks.tolist()
                                for p in pl:
                                    grouped_b[p] = 1
                                current.extend(pl)
                                pend_arrs.append(picks)
                                budget -= int(take.size)
                                nk = k + int(take[-1]) + 1
                                if nk < keys.size:
                                    heapreplace(heap, (int(keys[nk]), s, nk, keys))
                                else:
                                    heappop(heap)
                                continue
                        # thin prefix: scalar take of the head
                        k += 1
                        if k < keys.size:
                            heapreplace(heap, (int(keys[k]), s, k, keys))
                        else:
                            heappop(heap)
                        grouped_b[j] = 1
                        grouped[j] = True
                        current.append(j)
                        picks_s.append(j)
                        budget -= 1
                        continue
                    # stale head: scalar advance + the scalar pass's
                    # streak-gated bulk sweep (staleness is permanent)
                    stale_run = stale_run + 1 if s == stale_s else 1
                    stale_s = s
                    k += 1
                    nk = k
                    if stale_run >= 8 and keys.size - k > 16:
                        if len(heap) > 2:
                            limit = (heap[1][0] if heap[1][0] < heap[2][0]
                                     else heap[2][0])
                        elif len(heap) > 1:
                            limit = heap[1][0]
                        else:
                            limit = None
                        hi_k = (
                            int(np.searchsorted(keys, limit, side="right"))
                            if limit is not None else keys.size
                        )
                        if hi_k > k:
                            seg = keys[k:hi_k]
                            j_arr = seg & MASK
                            ok = np.nonzero(
                                ~grouped[j_arr] & (packed[j_arr] == seg)
                            )[0]
                            # position at the first still-valid entry
                            nk = k + int(ok[0]) if ok.size else hi_k
                    if nk < keys.size:
                        heapreplace(heap, (int(keys[nk]), s, nk, keys))
                    else:
                        heappop(heap)

                if picks_s:
                    if not pend_arrs and len(picks_s) == 1:
                        pend1 = picks_s[0]
                    else:
                        pend_arrs.append(np.asarray(picks_s, dtype=np.int64))
                elif not pend_arrs:
                    break  # candidates exhausted: group stays short

            groups.append(current)
            done += len(current)
            if touched:
                cat = np.concatenate(touched)
                packed[cat] = cat
        progress.tick(done)
    progress.finish(done)
    cold_arr = np.concatenate(cold) if cold else _EMPTY_I64
    return groups, cold_arr



_EMPTY_I64 = np.empty(0, dtype=np.int64)


def grouping_quality(graph: CoOccurrenceGraph, grouping: Grouping) -> int:
    """Total intra-group co-occurrence mass of a grouping.

    Sum of edge weights whose endpoints share a group — the objective
    Algorithm 1 greedily maximises.  The epoch-blocked pass ships with
    ``grouping_quality(epoch_pass) >= 0.99 * grouping_quality(oracle)``
    pinned in tests and recorded in BENCH_pipeline.json.
    """
    if graph.indices.size == 0:
        return 0
    rows = np.repeat(
        np.arange(graph.num_rows, dtype=np.int64), np.diff(graph.indptr)
    )
    same = grouping.group_of[rows] == grouping.group_of[graph.indices]
    return int(graph.weights[same].sum())


def _reference_correlation_aware_grouping(
    graph: CoOccurrenceGraph, group_size: int
) -> Grouping:
    """Original dict-backed per-edge-push loop (equivalence oracle)."""
    if group_size < 1:
        raise ValueError("group_size must be >= 1")
    n = graph.num_rows
    grouped = np.zeros(n, dtype=bool)
    groups: List[List[int]] = []
    order = graph.nodes_by_frequency()

    for seed in order:
        seed = int(seed)
        if grouped[seed]:
            continue
        current: List[int] = [seed]
        grouped[seed] = True
        weight_into: Dict[int, int] = {}
        heap: List[tuple] = []

        def push_neighbors(row: int) -> None:
            nbr_ids, nbr_w = graph.neighbor_arrays(row)
            if nbr_ids.size == 0:
                return
            live = ~grouped[nbr_ids]
            for j, w in zip(nbr_ids[live].tolist(), nbr_w[live].tolist()):
                new_w = weight_into.get(j, 0) + w
                weight_into[j] = new_w
                heapq.heappush(heap, (-new_w, j))

        push_neighbors(seed)

        while len(current) < group_size:
            best = None
            while heap:
                negw, j = heapq.heappop(heap)
                if grouped[j] or weight_into.get(j, 0) != -negw:
                    continue
                best = j
                break
            if best is None:
                break
            current.append(best)
            grouped[best] = True
            weight_into.pop(best, None)
            push_neighbors(best)

        groups.append(current)

    groups = _repack_short_groups(groups, group_size)
    group_of = np.full(n, -1, dtype=np.int32)
    slot_of = np.full(n, -1, dtype=np.int32)
    for g, rows in enumerate(groups):
        for s, r in enumerate(rows):
            group_of[r] = g
            slot_of[r] = s
    assert (group_of >= 0).all(), "every row must be grouped"
    return Grouping(groups=groups, group_of=group_of, slot_of=slot_of, group_size=group_size)


def frequency_grouping(graph: CoOccurrenceGraph, group_size: int) -> Grouping:
    """Baseline [33]: group rows purely by descending access frequency.

    Fully vectorized (the 10M-row replan bench builds its layout here):
    row ``order[i]`` lands in group ``i // group_size`` slot
    ``i % group_size`` — two scatters instead of a per-row loop.
    """
    order = graph.nodes_by_frequency()
    n = graph.num_rows
    rank = np.arange(n, dtype=np.int64)
    group_of = np.empty(n, dtype=np.int32)
    slot_of = np.empty(n, dtype=np.int32)
    group_of[order] = (rank // group_size).astype(np.int32)
    slot_of[order] = (rank % group_size).astype(np.int32)
    olist = order.tolist()
    groups = [olist[i : i + group_size] for i in range(0, n, group_size)]
    return Grouping(groups=groups, group_of=group_of, slot_of=slot_of, group_size=group_size)


def naive_grouping(num_rows: int, group_size: int) -> Grouping:
    """Baseline "naïve": map rows to crossbars by original itemID order."""
    groups = [
        list(range(i, min(i + group_size, num_rows)))
        for i in range(0, num_rows, group_size)
    ]
    ids = np.arange(num_rows, dtype=np.int64)
    group_of = (ids // group_size).astype(np.int32)
    slot_of = (ids % group_size).astype(np.int32)
    return Grouping(groups=groups, group_of=group_of, slot_of=slot_of, group_size=group_size)


def _grouping_from_groups(
    groups: List[List[int]],
    num_rows: int,
    group_size: int,
    check_cover: bool = False,
) -> Grouping:
    """Builds the ``group_of`` / ``slot_of`` scatters from a group list.

    Vectorized: one concatenate over the group lists + two scatters —
    the per-row Python loop was itself seconds at 10M rows.
    """
    lens = np.fromiter((len(g) for g in groups), dtype=np.int64, count=len(groups))
    total = int(lens.sum())
    group_of = np.full(num_rows, -1, dtype=np.int32)
    slot_of = np.full(num_rows, -1, dtype=np.int32)
    if total:
        rows = np.concatenate([np.asarray(g, dtype=np.int64) for g in groups])
        gid = np.repeat(np.arange(len(groups), dtype=np.int64), lens)
        starts = np.concatenate(([0], np.cumsum(lens)[:-1]))
        slot = np.arange(total, dtype=np.int64) - np.repeat(starts, lens)
        group_of[rows] = gid.astype(np.int32)
        slot_of[rows] = slot.astype(np.int32)
    if check_cover:
        assert (group_of >= 0).all(), "every row must be grouped"
    return Grouping(groups=groups, group_of=group_of, slot_of=slot_of, group_size=group_size)


def _repack_short_groups(
    groups: List[List[int]],
    group_size: int,
    extra_loose: Optional[np.ndarray] = None,
) -> List[List[int]]:
    """Merges short groups into full ones without splitting full groups.

    ``extra_loose`` appends additional ungrouped rows (the epoch pass's
    bulk-collected zero-degree cold tail, in frequency order) to the
    loose pool before chunking — equivalent to those rows having formed
    singleton groups at the end of the walk.
    """
    full = [g for g in groups if len(g) == group_size]
    loose: List[int] = [r for g in groups if len(g) < group_size for r in g]
    if extra_loose is not None and extra_loose.size:
        loose.extend(extra_loose.tolist())
    for i in range(0, len(loose), group_size):
        full.append(loose[i : i + group_size])
    return full


def activations_per_query(
    grouping: Grouping, queries: Sequence[Sequence[int]]
) -> np.ndarray:
    """Distinct groups (crossbars) activated by each query (paper Fig. 9 metric).

    Vectorized: one unique over packed (query, group) keys for the whole
    batch instead of a Python set per query.
    """
    from repro.core.cooccurrence import flatten_ragged

    flat, lens, nq = flatten_ragged(queries)
    if flat.size == 0:
        return np.zeros(nq, dtype=np.int64)
    qid = np.repeat(np.arange(nq, dtype=np.int64), lens)
    ngroups = np.int64(grouping.num_groups)
    touched = np.unique(qid * ngroups + grouping.group_of[flat].astype(np.int64))
    return np.bincount(touched // ngroups, minlength=nq).astype(np.int64)
