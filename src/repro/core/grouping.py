"""Correlation-aware embedding grouping (ReCross §III-B, Algorithm 1).

Greedily partitions embedding rows into groups of ``group_size`` (the
crossbar height, 64 in the paper) such that rows that co-occur in queries
land in the same group.  A query then activates few groups (crossbars /
VMEM tiles) instead of scattering across many.

The implementation follows Algorithm 1 line-for-line, with three
production-grade refinements that do not change the algorithm's semantics:

  * the candidate list is a lazy max-heap keyed by co-occurrence weight
    *into the current group* (Algorithm 1 recomputes the max by a linear
    scan; the heap makes the whole pass O(E log E) instead of O(V·E));
    neighbor expansion reads the graph's CSR slices directly
    (:meth:`CoOccurrenceGraph.neighbor_arrays`), no per-row dicts,
  * candidate weights live in a flat array indexed by row id (bulk
    scatter-add per pick) and each pick pushes ONE heap entry — the
    whole neighbor batch, pre-sorted by ``(-weight, id)`` with NumPy and
    advanced lazily on pop.  Most pushed candidates are never popped
    (a 64-row group consumes 64 picks out of thousands of candidate
    updates), so the batch heap turns ~E per-edge ``heappush`` calls
    into ~V batch pushes,
  * rows with no ungrouped neighbours left fall back to frequency order,
    which is what "foreach embedding in sorted(embeddingList)" yields
    anyway once candidateList is empty.

``_reference_correlation_aware_grouping`` retains the original dict+
per-edge-push loop as the equivalence oracle; the batch-heap pass is
bit-identical (pop order is the same total order on ``(-weight, id)``,
see the invariant note on :func:`correlation_aware_grouping`).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Sequence

import numpy as np

from repro.core.cooccurrence import CoOccurrenceGraph


@dataclasses.dataclass
class Grouping:
    """Result of the grouping pass.

    Attributes:
      groups: list of groups; each group is a list of row ids,
        ``len(group) <= group_size`` (only the last group may be short).
      group_of: ``(num_rows,)`` int32 — group index of each row.
      slot_of: ``(num_rows,)`` int32 — slot (wordline) of each row inside
        its group.
      group_size: the crossbar height used.
    """

    groups: List[List[int]]
    group_of: np.ndarray
    slot_of: np.ndarray
    group_size: int

    @property
    def num_groups(self) -> int:
        return len(self.groups)

    def group_freq(self, freq: np.ndarray) -> np.ndarray:
        """Aggregate access frequency per group (input to Eq. 1 replication)."""
        out = np.zeros(self.num_groups, dtype=np.int64)
        np.add.at(out, self.group_of, freq)
        return out


def correlation_aware_grouping(
    graph: CoOccurrenceGraph, group_size: int
) -> Grouping:
    """Algorithm 1: correlation-aware embedding grouping.

    Args:
      graph: co-occurrence graph from the lookup history.
      group_size: rows per group (= crossbar height / tile rows).

    Returns:
      A :class:`Grouping` covering every row exactly once.
    """
    if group_size < 1:
        raise ValueError("group_size must be >= 1")
    n = graph.num_rows
    grouped = np.zeros(n, dtype=bool)  # groupedIndices
    groups: List[List[int]] = []

    order = graph.nodes_by_frequency()  # sorted(embeddingList)

    # Candidate priorities pack into ONE int64 PER ROW ID:
    # packed[j] = j - weight_into[j] * SCALE (weight 0 → packed[j] = j).
    # Ascending key order is (weight descending, id ascending) — exactly
    # the (-weight, id) pop order of the per-edge heap — so a batch is a
    # single sort, heap comparisons touch plain ints, and a candidate's
    # currency check is ONE int compare (packed[j] == key) instead of a
    # weight decode.  The accumulate "ComputeWeight(embedding,
    # currentEmbedding) over the merged list" is a single fused
    # gather-subtract per pick: packed[nbr] -= weight*SCALE; reset
    # between seeds by restoring only the touched ids to their identity.
    SHIFT = max(n.bit_length(), 1)
    SCALE = 1 << SHIFT
    MASK = SCALE - 1
    packed = np.arange(n, dtype=np.int64)
    # weights pre-scaled once so the push path skips the per-pick mul
    wscale = graph.weights.astype(np.int64) * SCALE
    # bytearray mirror of `grouped` for O(50ns) scalar reads in the pop
    # loop (numpy bool scalars cost ~3x more); the numpy array serves the
    # vectorized bulk staleness check.
    grouped_b = bytearray(n)
    indptr = graph.indptr.tolist()
    indices = graph.indices
    heappush, heappop, heapreplace = (
        heapq.heappush, heapq.heappop, heapq.heapreplace
    )

    for seed in order.tolist():
        if grouped_b[seed]:  # line 3-5: skip already grouped
            continue
        current: List[int] = [seed]
        grouped_b[seed] = 1
        grouped[seed] = True

        # candidateList as a lazy max-heap of sorted neighbor BATCHES.
        # Each entry is (key, seq, cursor, keys): the head of a sorted
        # packed-key batch plus the array to advance through on pop.
        # `seq` is a unique tiebreaker so heapq never compares the array
        # payloads; entries with equal keys are the same candidate at the
        # same weight, so their relative order cannot change the pick
        # sequence.  Pop order over distinct (weight, id) is the same
        # total order the per-edge heap yields — bit-identical groups.
        heap: List[tuple] = []
        touched: List[np.ndarray] = []
        seq = 0

        row = seed
        while len(current) < group_size:
            # ---- push_neighbors(row): one batch heap entry per pick.
            # (The reference loop also pushes after its final pick; that
            # batch is never popped, so skipping it here cannot change
            # the pick sequence — weights are per-seed scoped.) ----
            lo, hi = indptr[row], indptr[row + 1]
            if hi > lo:
                nbr_ids = indices[lo:hi]
                live = ~grouped[nbr_ids]
                ids = nbr_ids[live]
                if ids.size:
                    # CSR neighbor ids are unique within a row, so the
                    # fused gather-subtract is exact; pre-scaled weights
                    # and the packed accumulator make the re-push ONE
                    # arithmetic op on top of the liveness mask
                    pk = packed[ids] - wscale[lo:hi][live]
                    packed[ids] = pk
                    touched.append(ids)
                    if pk.size > 1:
                        pk.sort()          # fresh array → sort in place
                    heappush(heap, (int(pk[0]), seq, 0, pk))
                    seq += 1

            # ---- pop the max-weight candidate (lazy deletion of stale
            # entries): the heap head is the globally best *pushed*
            # (weight, id); skip it unless it still matches the
            # candidate's current weight.  The whole prefix of the top
            # batch that outranks the second-best head can be validated
            # in BULK: weights only grow and grouped only flips on
            # within a seed, so a stale entry is stale forever — skipped
            # entries never need revisiting, and equal keys across
            # batches are the same (weight, id), so consuming ties out
            # of the head first cannot change the pick sequence. ----
            best = None
            stale_s, stale_run = -1, 0
            while heap:
                key, s, k, keys = heap[0]
                # decode key = j - w*SCALE: SCALE is a power of two, so
                # j = key mod SCALE falls out of a mask; currency is one
                # int compare against the packed accumulator
                j = key & MASK
                if not grouped_b[j] and packed[j] == key:
                    # valid head: the common case stays a scalar pop
                    k += 1
                    if k < keys.size:
                        heapreplace(heap, (int(keys[k]), s, k, keys))
                    else:
                        heappop(heap)
                    best = j
                    break
                # stale head.  Staleness is permanent within a seed
                # (weights only grow, grouped only flips on), so a long
                # stale RUN inside one batch can be skipped in bulk:
                # after 8 consecutive stale pops of the same batch,
                # validate vectorized the whole prefix that outranks
                # the true second-best head (the smaller of the root's
                # children).  Equal keys across batches are the same
                # (weight, id), so consuming ties out of the head first
                # cannot change the pick sequence; the streak gate
                # keeps the scalar pop the only cost everywhere else.
                stale_run = stale_run + 1 if s == stale_s else 1
                stale_s = s
                k += 1
                nk = k
                if stale_run >= 8 and keys.size - k > 16:
                    if len(heap) > 2:
                        limit = (heap[1][0] if heap[1][0] < heap[2][0]
                                 else heap[2][0])
                    elif len(heap) > 1:
                        limit = heap[1][0]
                    else:
                        limit = None
                    hi_k = (
                        int(np.searchsorted(keys, limit, side="right"))
                        if limit is not None else keys.size
                    )
                    if hi_k > k:
                        seg = keys[k:hi_k]
                        j_arr = seg & MASK
                        ok = np.nonzero(
                            ~grouped[j_arr] & (packed[j_arr] == seg)
                        )[0]
                        if ok.size:
                            d = int(ok[0])
                            best = int(j_arr[d])
                            nk = k + d + 1
                        else:
                            nk = hi_k
                if nk < keys.size:
                    heapreplace(heap, (int(keys[nk]), s, nk, keys))
                else:
                    heappop(heap)
                if best is not None:
                    break
            if best is None:
                break  # no correlated candidates left: group stays short
            current.append(best)
            grouped_b[best] = 1
            grouped[best] = True
            row = best  # line 17: merge neighbours of the pick

        groups.append(current)
        if touched:
            # weights are per-seed scoped: restore identity packing
            cat = np.concatenate(touched)
            packed[cat] = cat

    # Compact short groups: Algorithm 1 leaves the trailing group short;
    # greedy filling can also produce mid-stream short groups when a
    # connected component is exhausted. Pack those rows together so that
    # only the final group may be short (keeps the crossbar image dense).
    groups = _repack_short_groups(groups, group_size)

    group_of = np.full(n, -1, dtype=np.int32)
    slot_of = np.full(n, -1, dtype=np.int32)
    for g, rows in enumerate(groups):
        for s, r in enumerate(rows):
            group_of[r] = g
            slot_of[r] = s
    assert (group_of >= 0).all(), "every row must be grouped"
    return Grouping(groups=groups, group_of=group_of, slot_of=slot_of, group_size=group_size)


def _reference_correlation_aware_grouping(
    graph: CoOccurrenceGraph, group_size: int
) -> Grouping:
    """Original dict-backed per-edge-push loop (equivalence oracle)."""
    if group_size < 1:
        raise ValueError("group_size must be >= 1")
    n = graph.num_rows
    grouped = np.zeros(n, dtype=bool)
    groups: List[List[int]] = []
    order = graph.nodes_by_frequency()

    for seed in order:
        seed = int(seed)
        if grouped[seed]:
            continue
        current: List[int] = [seed]
        grouped[seed] = True
        weight_into: Dict[int, int] = {}
        heap: List[tuple] = []

        def push_neighbors(row: int) -> None:
            nbr_ids, nbr_w = graph.neighbor_arrays(row)
            if nbr_ids.size == 0:
                return
            live = ~grouped[nbr_ids]
            for j, w in zip(nbr_ids[live].tolist(), nbr_w[live].tolist()):
                new_w = weight_into.get(j, 0) + w
                weight_into[j] = new_w
                heapq.heappush(heap, (-new_w, j))

        push_neighbors(seed)

        while len(current) < group_size:
            best = None
            while heap:
                negw, j = heapq.heappop(heap)
                if grouped[j] or weight_into.get(j, 0) != -negw:
                    continue
                best = j
                break
            if best is None:
                break
            current.append(best)
            grouped[best] = True
            weight_into.pop(best, None)
            push_neighbors(best)

        groups.append(current)

    groups = _repack_short_groups(groups, group_size)
    group_of = np.full(n, -1, dtype=np.int32)
    slot_of = np.full(n, -1, dtype=np.int32)
    for g, rows in enumerate(groups):
        for s, r in enumerate(rows):
            group_of[r] = g
            slot_of[r] = s
    assert (group_of >= 0).all(), "every row must be grouped"
    return Grouping(groups=groups, group_of=group_of, slot_of=slot_of, group_size=group_size)


def frequency_grouping(graph: CoOccurrenceGraph, group_size: int) -> Grouping:
    """Baseline [33]: group rows purely by descending access frequency."""
    order = [int(i) for i in graph.nodes_by_frequency()]
    groups = [order[i : i + group_size] for i in range(0, len(order), group_size)]
    return _grouping_from_groups(groups, graph.num_rows, group_size)


def naive_grouping(num_rows: int, group_size: int) -> Grouping:
    """Baseline "naïve": map rows to crossbars by original itemID order."""
    groups = [
        list(range(i, min(i + group_size, num_rows)))
        for i in range(0, num_rows, group_size)
    ]
    return _grouping_from_groups(groups, num_rows, group_size)


def _grouping_from_groups(
    groups: List[List[int]], num_rows: int, group_size: int
) -> Grouping:
    group_of = np.full(num_rows, -1, dtype=np.int32)
    slot_of = np.full(num_rows, -1, dtype=np.int32)
    for g, rows in enumerate(groups):
        for s, r in enumerate(rows):
            group_of[r] = g
            slot_of[r] = s
    return Grouping(groups=groups, group_of=group_of, slot_of=slot_of, group_size=group_size)


def _repack_short_groups(
    groups: List[List[int]], group_size: int
) -> List[List[int]]:
    """Merges short groups into full ones without splitting full groups."""
    full = [g for g in groups if len(g) == group_size]
    loose: List[int] = [r for g in groups if len(g) < group_size for r in g]
    for i in range(0, len(loose), group_size):
        full.append(loose[i : i + group_size])
    return full


def activations_per_query(
    grouping: Grouping, queries: Sequence[Sequence[int]]
) -> np.ndarray:
    """Distinct groups (crossbars) activated by each query (paper Fig. 9 metric).

    Vectorized: one unique over packed (query, group) keys for the whole
    batch instead of a Python set per query.
    """
    from repro.core.cooccurrence import flatten_ragged

    flat, lens, nq = flatten_ragged(queries)
    if flat.size == 0:
        return np.zeros(nq, dtype=np.int64)
    qid = np.repeat(np.arange(nq, dtype=np.int64), lens)
    ngroups = np.int64(grouping.num_groups)
    touched = np.unique(qid * ngroups + grouping.group_of[flat].astype(np.int64))
    return np.bincount(touched // ngroups, minlength=nq).astype(np.int64)
