"""Baseline pipelines the paper compares against, as one-call helpers.

Each helper takes (graph or num_rows, queries, batch context) and returns
a (layout, SimReport) pair, so benchmarks and tests compare apples to
apples:

  * ``naive``      — itemID-order mapping, no replication, static ADC.
  * ``frequency``  — frequency-sorted mapping [33], no replication, static ADC.
  * ``nmars``      — nMARS [24]: naive mapping, parallel lookup + sequential
                     aggregation, static ADC.
  * ``recross``    — full ReCross: correlation grouping + Eq.-1 replication
                     + dynamic switching.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

from repro.core.cooccurrence import CoOccurrenceGraph, build_cooccurrence
from repro.core.grouping import (
    correlation_aware_grouping,
    frequency_grouping,
    naive_grouping,
)
from repro.core.mapping import CrossbarLayout, build_layout
from repro.core.replication import plan_replication
from repro.core.simulator import SimReport, simulate_batch, simulate_nmars_baseline
from repro.core.energy import ReRAMCostModel, DEFAULT_RERAM


def recross_pipeline(
    graph: CoOccurrenceGraph,
    queries: Sequence[Sequence[int]],
    *,
    group_size: int = 64,
    dim: int = 64,
    batch_size: int | None = None,
    area_budget_ratio: float | None = None,
    model: ReRAMCostModel = DEFAULT_RERAM,
    replication_scheme: str = "log",
    dynamic_switching: bool = True,
) -> Tuple[CrossbarLayout, SimReport]:
    grouping = correlation_aware_grouping(graph, group_size)
    plan = plan_replication(
        grouping,
        graph.freq,
        batch_size or len(queries),
        area_budget_ratio=area_budget_ratio,
        scheme=replication_scheme,
    )
    layout = build_layout(grouping, plan, dim)
    report = simulate_batch(
        layout, queries, model=model, dynamic_switching=dynamic_switching
    )
    return layout, report


def naive_pipeline(
    num_rows: int,
    queries: Sequence[Sequence[int]],
    *,
    group_size: int = 64,
    dim: int = 64,
    model: ReRAMCostModel = DEFAULT_RERAM,
) -> Tuple[CrossbarLayout, SimReport]:
    grouping = naive_grouping(num_rows, group_size)
    plan = plan_replication(grouping, np.zeros(num_rows), 1, scheme="none")
    layout = build_layout(grouping, plan, dim)
    report = simulate_batch(
        layout, queries, model=model, dynamic_switching=False, balance_replicas=False
    )
    return layout, report


def frequency_pipeline(
    graph: CoOccurrenceGraph,
    queries: Sequence[Sequence[int]],
    *,
    group_size: int = 64,
    dim: int = 64,
    model: ReRAMCostModel = DEFAULT_RERAM,
) -> Tuple[CrossbarLayout, SimReport]:
    grouping = frequency_grouping(graph, group_size)
    plan = plan_replication(grouping, graph.freq, 1, scheme="none")
    layout = build_layout(grouping, plan, dim)
    report = simulate_batch(
        layout, queries, model=model, dynamic_switching=False, balance_replicas=False
    )
    return layout, report


def nmars_pipeline(
    num_rows: int,
    queries: Sequence[Sequence[int]],
    *,
    group_size: int = 64,
    dim: int = 64,
    model: ReRAMCostModel = DEFAULT_RERAM,
) -> Tuple[CrossbarLayout, SimReport]:
    grouping = naive_grouping(num_rows, group_size)
    plan = plan_replication(grouping, np.zeros(num_rows), 1, scheme="none")
    layout = build_layout(grouping, plan, dim)
    report = simulate_nmars_baseline(layout, queries, model=model)
    return layout, report
