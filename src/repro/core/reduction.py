"""JAX embedding reduction through a ReCross layout.

The *numerical* side of ReCross: given the permuted/replicated device image
produced by :meth:`CrossbarLayout.build_image`, perform the embedding-bag
reduction for a batch of queries.  Three executable paths, all producing
identical values:

  * :func:`reduce_dense_oracle` — direct gather+sum on the *logical* table
    (ground truth; layout-independent).
  * :func:`reduce_via_layout`   — pure-jnp tiled one-hot MAC through the
    physical image with dynamic READ/MAC switching expressed as
    ``jnp.where`` (the reference the Pallas kernel is tested against).
  * :mod:`repro.kernels.ops.crossbar_reduce` — the Pallas TPU kernel.

Queries arrive in the framework's *compiled query format* (a fixed-shape
representation so everything jits):

  ``tile_ids``  (batch, max_tiles)            int32, -1 padded
  ``bitmaps``   (batch, max_tiles, tile_rows) activation masks (0/1)

produced by :func:`compile_queries` from the ragged host-side form.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.mapping import CrossbarLayout


@dataclasses.dataclass
class CompiledQueries:
    """Fixed-shape query batch (device-ready)."""

    tile_ids: jax.Array   # (batch, max_tiles) int32, -1 = padding
    bitmaps: jax.Array    # (batch, max_tiles, tile_rows) same dtype as table
    max_tiles: int

    @property
    def batch(self) -> int:
        return self.tile_ids.shape[0]


@dataclasses.dataclass
class BlockedQueries:
    """Query-blocked compiled batch (device-ready, DESIGN.md §3).

    ``q_block`` consecutive queries share one tile schedule (the union of
    their per-query tile lists, deduplicated): one tile DMA serves the
    whole block, and the kernel's MAC is a ``(q_block, tile_rows)``
    matmul.  The batch is padded up to a q_block multiple; kernel output
    rows beyond :attr:`batch` are padding and should be sliced off.
    """

    tile_ids: jax.Array   # (nb, max_tiles) int32, -1 = padding — per block
    bitmaps: jax.Array    # (nb, max_tiles, q_block, tile_rows)
    q_block: int
    batch: int            # original (unpadded) query count

    @property
    def num_blocks(self) -> int:
        return self.tile_ids.shape[0]

    @property
    def max_tiles(self) -> int:
        return self.tile_ids.shape[1]


def compile_queries(
    layout: CrossbarLayout,
    queries: Sequence[Sequence[int]],
    *,
    max_tiles: int | None = None,
    dtype=jnp.float32,
    balance_replicas: bool = True,
    replica_block: int = 1,
) -> CompiledQueries:
    """Ragged host queries → fixed-shape device arrays.

    ``max_tiles`` defaults to the batch's maximum tiles-per-query, rounded
    up to a multiple of 8 for sublane friendliness.  Built directly from
    the sparse :class:`~repro.core.mapping.ActivationSet` with two
    scatters — the dense ``(batch, num_tiles, tile_rows)`` intermediate is
    never materialized.  Pass ``replica_block=q_block`` when the result
    feeds :func:`block_compiled_queries` so replica choice is shared
    inside each block (see :func:`~repro.core.mapping.compile_activations`).
    """
    from repro.core.mapping import compile_activations

    acts = compile_activations(
        layout, queries,
        balance_replicas=balance_replicas, replica_block=replica_block,
    )
    batch = acts.batch
    per_q = acts.per_query_tiles()
    width = int(per_q.max()) if per_q.size else 1
    max_tiles = _padded_width(width, max_tiles, "query")

    from repro.core.cooccurrence import segment_ranks

    tile_ids = np.full((batch, max_tiles), -1, dtype=np.int32)
    bitmaps = np.zeros((batch, max_tiles, layout.tile_rows), dtype=np.float32)
    # slot position of each activation within its query (activations are
    # (query, tile)-sorted, so the run-local rank is the position)
    pos = segment_ranks(per_q)
    tile_ids[acts.act_qid, pos] = acts.act_tile
    # wordline entries inherit their activation's slot position
    ent_pos = np.repeat(pos, acts.act_rows)
    bitmaps[acts.ent_qid, ent_pos, acts.ent_slot] = 1.0
    return CompiledQueries(
        tile_ids=jnp.asarray(tile_ids),
        bitmaps=jnp.asarray(bitmaps, dtype=dtype),
        max_tiles=max_tiles,
    )


def _pad_to_blocks(
    ids: np.ndarray, bms: np.ndarray, q_block: int
) -> tuple[np.ndarray, np.ndarray, int]:
    """Zero/-1-pads a flat compiled batch up to a q_block multiple.

    Shared by every block compiler (flat and sharded) so the padding
    rule — and therefore output row alignment — can never diverge.
    """
    batch, s_flat = ids.shape
    tile_rows = bms.shape[-1]
    nb = -(-batch // q_block) if batch else 0
    pad = nb * q_block - batch
    if pad:
        ids = np.concatenate([ids, np.full((pad, s_flat), -1, ids.dtype)])
        bms = np.concatenate([bms, np.zeros((pad, s_flat, tile_rows), bms.dtype)])
    return ids, bms, nb


def _check_block_key_capacity(n_outer: int, n_inner: int, what: str) -> None:
    """Packed block keys ``outer * n_inner + inner`` must fit in int64.

    Only reachable with absurd block counts, but wraparound here would
    silently merge unrelated (block, tile) pairs instead of raising.
    """
    if n_outer and n_inner and n_outer > ((1 << 63) - 1) // n_inner:
        raise OverflowError(
            f"{what}: {n_outer} x {n_inner} packed keys overflow int64"
        )


def _padded_width(width: int, max_tiles: int | None, what: str) -> int:
    """Union width → tile-axis allocation: sublane-friendly multiple of 8.

    One definition for every block compiler — the per-shard-grid ≤
    flat-grid invariant relies on both rounding widths identically.
    """
    if max_tiles is None:
        max_tiles = max(8, int(np.ceil(width / 8)) * 8)
    if width > max_tiles:
        raise ValueError(f"{what} touches {width} tiles > max_tiles={max_tiles}")
    return max_tiles


def block_compiled_queries(
    cq: CompiledQueries,
    q_block: int,
    *,
    max_tiles: int | None = None,
) -> BlockedQueries:
    """Flat compiled batch → query-blocked layout for the blocked kernel.

    Each block of ``q_block`` consecutive queries gets the deduplicated
    union of its members' tile lists.  With a correlation-aware layout the
    members share hot tiles, so the union width stays close to a single
    query's — that is what shrinks the kernel grid by ~``q_block``×.
    Ragged batches are zero-padded up to a block multiple.

    Compile ``cq`` with ``replica_block=q_block`` so replicated hot groups
    resolve to one tile per block instead of one per query — per-query
    round robin would put identical replica tiles in the same union.
    """
    if q_block < 1:
        raise ValueError("q_block must be >= 1")
    ids, bms, nb = _pad_to_blocks(
        np.asarray(cq.tile_ids), np.asarray(cq.bitmaps), q_block
    )
    batch = cq.tile_ids.shape[0]
    tile_rows = bms.shape[-1]

    vq, vs = np.nonzero(ids >= 0)
    vt = ids[vq, vs].astype(np.int64)
    vblk = vq // q_block
    num_tiles = int(vt.max()) + 1 if vt.size else 1
    _check_block_key_capacity(max(nb, 1), num_tiles, "block_compiled_queries")
    key = vblk * np.int64(num_tiles) + vt
    uniq = np.unique(key)
    ub = (uniq // num_tiles).astype(np.int64)
    ut = (uniq % num_tiles).astype(np.int64)
    per_blk = np.bincount(ub, minlength=max(nb, 1))
    width = int(per_blk.max()) if uniq.size else 0
    max_tiles = _padded_width(width, max_tiles, "block")

    from repro.core.cooccurrence import segment_ranks

    blocked_ids = np.full((max(nb, 1), max_tiles), -1, dtype=np.int32)
    pos_u = segment_ranks(per_blk)
    blocked_ids[ub, pos_u] = ut
    blocked_bms = np.zeros(
        (max(nb, 1), max_tiles, q_block, tile_rows), dtype=np.asarray(bms).dtype
    )
    pos_entry = pos_u[np.searchsorted(uniq, key)]
    blocked_bms[vblk, pos_entry, vq % q_block] = bms[vq, vs]
    return BlockedQueries(
        tile_ids=jnp.asarray(blocked_ids),
        bitmaps=jnp.asarray(blocked_bms),
        q_block=q_block,
        batch=batch,
    )


@dataclasses.dataclass
class ShardedBlockedQueries:
    """Per-shard query-blocked batch for the sharded kernel (DESIGN.md §4).

    The stacked form of ``num_shards`` shard-local :class:`BlockedQueries`:
    every shard sees the same block axis (so cross-shard partial sums
    align row-for-row) but its own tile schedule — shard-local tile ids,
    shard-local tile unions.  ``max_tiles`` is the widest per-(shard,
    block) union over the whole batch, so each shard's grid is
    ``(nb, max_tiles)`` with ``max_tiles`` bounded by the busiest shard,
    never by the global union.

    An activation (query, tile) is owned by exactly one shard: the tile's
    owner for sharded-once tiles, ``block % num_shards`` for tiles
    replicated on every shard (hot-group work round-robins over blocks).
    Summing the shards' kernel outputs therefore reproduces the
    single-device blocked reduction exactly once per activation.
    """

    tile_ids: jax.Array   # (P, nb, max_tiles) int32 shard-LOCAL ids, -1 pad
    bitmaps: jax.Array    # (P, nb, max_tiles, q_block, tile_rows)
    q_block: int
    batch: int            # original (unpadded) query count
    shard_widths: np.ndarray  # (P,) widest per-shard block union, pre-pad
    shards: np.ndarray | None = None  # (P,) global shard ids of the stack
    # (None = all shards in order, the full-flush compile)

    @property
    def num_shards(self) -> int:
        return self.tile_ids.shape[0]

    @property
    def shard_ids(self) -> np.ndarray:
        """Global shard id of each stacked schedule (DESIGN.md §7).

        A full-flush compile stacks every shard in order; a subset flush
        (``participants=`` to :func:`shard_block_queries`) stacks only
        the participating shards, and the kernel dispatch needs to know
        which image slices they index.
        """
        if self.shards is not None:
            return self.shards
        return np.arange(self.num_shards, dtype=np.int64)

    @property
    def num_blocks(self) -> int:
        return self.tile_ids.shape[1]

    @property
    def max_tiles(self) -> int:
        return self.tile_ids.shape[2]

    def grid_cells_per_shard(self) -> int:
        """Kernel grid cells each shard runs (= nb × padded max_tiles)."""
        return self.num_blocks * self.max_tiles


def shard_block_queries(
    cq: CompiledQueries,
    plan,
    q_block: int,
    *,
    max_tiles: int | None = None,
    participants: Sequence[int] | None = None,
) -> ShardedBlockedQueries:
    """Flat compiled batch → per-shard blocked layout for ``plan``.

    ``plan`` is a :class:`repro.dist.shard_plan.ShardPlan` (duck-typed:
    only ``num_shards`` / ``shard_of_tile`` / ``local_tile_of`` /
    ``max_local_tiles`` are read, keeping ``repro.core`` free of a
    ``repro.dist`` import).  ``cq.tile_ids`` must be in the plan's fused
    tile space — offset per-table compiles with
    :func:`offset_compiled_queries` first.

    Compile ``cq`` with ``replica_block=q_block``, exactly as for
    :func:`block_compiled_queries`; replicas of a sharded group live on
    the same shard, so block-granular replica choice stays shard-local.

    ``participants`` restricts the compile to a shard subset (DESIGN.md
    §7): the stacked schedules cover only those shards (in the given
    order — :attr:`ShardedBlockedQueries.shards` records the mapping),
    and replicated-everywhere tiles round-robin over the *participants*
    instead of all shards, so a home's batch — one shard's, or an
    owner-set home's exact owner subset — compiles without
    recompiling — or waiting for — the fused global batch.  Every
    sharded-once tile the batch activates must be owned by a
    participant; a query routed to the wrong subset raises.
    """
    if q_block < 1:
        raise ValueError("q_block must be >= 1")
    S = int(plan.num_shards)
    if participants is None:
        parts = np.arange(S, dtype=np.int64)
        shards_field = None
    else:
        parts = np.asarray(list(participants), dtype=np.int64)
        if parts.size == 0 or parts.size != np.unique(parts).size:
            raise ValueError(f"participants must be non-empty unique ids, got {parts}")
        if parts.min() < 0 or parts.max() >= S:
            raise ValueError(f"participants {parts} out of range for {S} shards")
        shards_field = parts
    P = int(parts.size)
    ids, bms, nb = _pad_to_blocks(
        np.asarray(cq.tile_ids), np.asarray(cq.bitmaps), q_block
    )
    batch = cq.tile_ids.shape[0]
    tile_rows = bms.shape[-1]
    nb_safe = max(nb, 1)

    vq, vs = np.nonzero(ids >= 0)
    vt = ids[vq, vs].astype(np.int64)
    vblk = vq // q_block
    shard_of_tile = np.asarray(plan.shard_of_tile)
    own = shard_of_tile[vt].astype(np.int64)
    # cold (host-tier) tiles are held by NO shard — a capacity-bounded
    # plan serves them via the host gather+sum path, and the server's
    # residency router must divert such queries before compile.  -2 is
    # repro.dist.shard_plan.COLD (literal here: repro.core stays free of
    # a repro.dist import).
    if (own == -2).any():
        raise ValueError(
            "batch activates cold (host-tier) tiles; cold queries must "
            "take the host gather+sum path, not the crossbar kernels"
        )
    # replicated-everywhere tiles: block-level round robin over the
    # participating shards (degrades to "the one flushing shard owns
    # everything" for a single-shard flush)
    own = np.where(own < 0, parts[vblk % P], own)
    # global shard id → stack position
    part_pos = np.full(S, -1, dtype=np.int64)
    part_pos[parts] = np.arange(P, dtype=np.int64)
    pos_own = part_pos[own]
    if pos_own.size and pos_own.min() < 0:
        missing = np.unique(own[pos_own < 0]).tolist()
        raise ValueError(
            f"batch activates tiles owned by non-participating shards "
            f"{missing}; participants={parts.tolist()}"
        )
    lt = np.asarray(plan.local_tile_of)[own, vt].astype(np.int64)
    if lt.size and lt.min() < 0:
        raise ValueError("plan does not hold an activated tile on its owner")

    Lmax = max(int(plan.max_local_tiles), 1)
    _check_block_key_capacity(P * nb_safe, Lmax, "shard_block_queries")
    key = (pos_own * nb_safe + vblk) * Lmax + lt
    uniq = np.unique(key)
    usb = uniq // Lmax
    ult = (uniq % Lmax).astype(np.int64)
    us = (usb // nb_safe).astype(np.int64)
    ub = (usb % nb_safe).astype(np.int64)
    per_sb = np.bincount(usb, minlength=P * nb_safe)
    width = int(per_sb.max()) if uniq.size else 0
    max_tiles = _padded_width(width, max_tiles, "shard block")

    from repro.core.cooccurrence import segment_ranks

    blocked_ids = np.full((P, nb_safe, max_tiles), -1, dtype=np.int32)
    pos_u = segment_ranks(per_sb)
    blocked_ids[us, ub, pos_u] = ult
    blocked_bms = np.zeros(
        (P, nb_safe, max_tiles, q_block, tile_rows), dtype=bms.dtype
    )
    pos_entry = pos_u[np.searchsorted(uniq, key)]
    blocked_bms[pos_own, vblk, pos_entry, vq % q_block] = bms[vq, vs]
    widths = per_sb.reshape(P, nb_safe).max(axis=1) if uniq.size else np.zeros(P, np.int64)
    return ShardedBlockedQueries(
        tile_ids=jnp.asarray(blocked_ids),
        bitmaps=jnp.asarray(blocked_bms),
        q_block=q_block,
        batch=batch,
        shard_widths=widths.astype(np.int64),
        shards=shards_field,
    )


class BlockUnionTracker:
    """Incremental block-union fill accounting for one pending stream.

    The flush scheduler (DESIGN.md §7) needs to know, as queries
    accumulate on a flush home — one shard, or a frozen owner set of
    shards — how large that home's kernel grid would be
    if it flushed *now* — without compiling anything.  With
    ``replica_block=q_block`` every block resolves each activated group
    to exactly one replica tile, so a block's union width equals the
    number of distinct groups its members touch; this tracker maintains
    exactly that, one ``set`` union per in-progress block:

      * :attr:`fill` — Σ union widths over all pending blocks (the raw
        tile-DMA count of a flush-now);
      * :meth:`grid_cells` — ``nb × padded max width``, the same
        sublane-padded accounting as :func:`shard_block_queries`.

    ``add`` takes the query's distinct activated *group* ids (host-side
    routing already computes them); O(groups-per-query) per call.
    """

    def __init__(self, q_block: int):
        if q_block < 1:
            raise ValueError("q_block must be >= 1")
        self.q_block = q_block
        self.reset()

    def reset(self) -> None:
        self._n = 0
        self._filled = 0          # Σ union widths of completed blocks
        self._max_width = 0
        self._block: set = set()  # current partial block's union

    def add(self, groups) -> None:
        """Appends one query (its distinct activated group ids)."""
        if self._n and self._n % self.q_block == 0:
            self._filled += len(self._block)
            self._max_width = max(self._max_width, len(self._block))
            self._block = set()
        self._block.update(int(g) for g in groups)
        self._n += 1

    @property
    def pending(self) -> int:
        """Queries added since the last reset."""
        return self._n

    @property
    def fill(self) -> int:
        """Σ block-union widths of the pending stream (tile DMA count)."""
        return self._filled + len(self._block)

    def grid_cells(self) -> int:
        """Kernel grid cells of a flush-now (nb × sublane-padded width)."""
        if self._n == 0:
            return 0
        nb = -(-self._n // self.q_block)
        width = max(self._max_width, len(self._block))
        return nb * _padded_width(width, None, "pending block")


def fused_group_loads(
    cq: CompiledQueries, tile_group: np.ndarray, num_groups: int
) -> np.ndarray:
    """Per-fused-group active-row counts of a compiled batch.

    The serve-time observation feeding drift tracking (DESIGN.md §6):
    instead of re-walking the ragged host queries, the load is read off
    the batch that was compiled for the kernel anyway.  Each valid
    (query, tile) slot contributes its wordline popcount to the tile's
    group, so a query touching *k* rows of a group counts *k* — the same
    per-row semantics as ``CoOccurrenceGraph.freq`` aggregated by
    ``Grouping.group_freq``, which is what the shard plan's
    ``group_load`` was built from.  Replica choice does not matter: all
    replicas of a group map to the same group id.

    Args:
      cq: a compiled batch in the *fused* tile space (post
        :func:`offset_compiled_queries` / :func:`concat_compiled_queries`).
      tile_group: ``(num_tiles,)`` fused tile id → fused group id
        (``repeat(arange(G), group_copies)``).
      num_groups: fused group count G.

    Returns:
      ``(G,)`` float64 active-row counts.
    """
    ids = np.asarray(cq.tile_ids)
    valid = ids >= 0
    if not valid.any():
        return np.zeros(num_groups, dtype=np.float64)
    groups = np.asarray(tile_group)[ids[valid].astype(np.int64)]
    rows = np.asarray(cq.bitmaps)[valid].sum(axis=-1)
    return np.bincount(
        groups, weights=rows.astype(np.float64), minlength=num_groups
    ).astype(np.float64)


def offset_compiled_queries(cq: CompiledQueries, tile_offset: int) -> CompiledQueries:
    """Rebases a per-table compile into the fused multi-table tile space."""
    ids = np.asarray(cq.tile_ids)
    return CompiledQueries(
        tile_ids=jnp.asarray(np.where(ids >= 0, ids + tile_offset, ids)),
        bitmaps=cq.bitmaps,
        max_tiles=cq.max_tiles,
    )


def concat_compiled_queries(
    cqs: Sequence[CompiledQueries], q_block: int
) -> tuple[CompiledQueries, list[tuple[int, int]]]:
    """Stacks per-table compiled batches for one fused kernel invocation.

    Each table's batch is padded up to a ``q_block`` multiple (so blocks
    never span tables) and all are padded to a common tile width, then
    concatenated on the query axis.

    Returns:
      (fused CompiledQueries, per-table ``(row_start, batch)`` spans into
      the fused — and therefore into the kernel output — row space).
    """
    if q_block < 1:
        raise ValueError("q_block must be >= 1")
    if not cqs:
        raise ValueError("need at least one compiled batch")
    width = max(cq.max_tiles for cq in cqs)
    ids_parts, bms_parts, spans = [], [], []
    row = 0
    for cq in cqs:
        ids = np.asarray(cq.tile_ids)
        bms = np.asarray(cq.bitmaps)
        batch, s_flat = ids.shape
        rows = -(-batch // q_block) * q_block if batch else 0
        tile_rows = bms.shape[-1]
        pid = np.full((rows, width), -1, dtype=ids.dtype)
        pbm = np.zeros((rows, width, tile_rows), dtype=bms.dtype)
        pid[:batch, :s_flat] = ids
        pbm[:batch, :s_flat] = bms
        ids_parts.append(pid)
        bms_parts.append(pbm)
        spans.append((row, batch))
        row += rows
    fused = CompiledQueries(
        tile_ids=jnp.asarray(np.concatenate(ids_parts)),
        bitmaps=jnp.asarray(np.concatenate(bms_parts)),
        max_tiles=width,
    )
    return fused, spans


def reduce_dense_oracle(
    table: jax.Array, queries: Sequence[Sequence[int]]
) -> jax.Array:
    """Ground-truth gather+sum on the logical table (host-ragged input)."""
    out = []
    for q in queries:
        ids = jnp.asarray(sorted(set(int(i) for i in q)), dtype=jnp.int32)
        out.append(table[ids].sum(axis=0) if len(q) else jnp.zeros(table.shape[-1], table.dtype))
    return jnp.stack(out)


@partial(jax.jit, static_argnames=("tile_rows", "dynamic_switch"))
def reduce_via_layout(
    image: jax.Array,      # (num_tiles * tile_rows, dim) physical image
    tile_ids: jax.Array,   # (batch, max_tiles)
    bitmaps: jax.Array,    # (batch, max_tiles, tile_rows)
    *,
    tile_rows: int,
    dynamic_switch: bool = True,
) -> jax.Array:
    """Pure-jnp tiled one-hot MAC through the physical image.

    Per (query, slot): fetch the tile, then either
      * READ path  (popcount==1): select the single active row, or
      * MAC path: ``bitmap @ tile`` (one-hot MXU matmul).
    Padding slots (tile_id == -1) have all-zero bitmaps and contribute 0.
    """
    num_tiles = image.shape[0] // tile_rows
    dim = image.shape[-1]
    tiles3 = image.reshape(num_tiles, tile_rows, dim)

    def per_query(tids, bms):
        def per_slot(tid, bm):
            tile = tiles3[jnp.clip(tid, 0, num_tiles - 1)]  # (tile_rows, dim)
            mac = bm @ tile  # (dim,)
            if dynamic_switch:
                count = bm.sum()
                # READ path: arg-select the active row without a matmul.
                row = jnp.argmax(bm)
                read = tile[row] * (count > 0)
                out = jnp.where(count <= 1, read, mac)
            else:
                out = mac
            return out * (tid >= 0)

        return jax.vmap(per_slot)(tids, bms).sum(axis=0)

    return jax.vmap(per_query)(tile_ids, bitmaps)


def reduction_flops(bitmaps: np.ndarray, dim: int, dynamic_switch: bool) -> int:
    """FLOPs of the layout reduction (for benchmark reporting)."""
    counts = np.asarray(bitmaps).sum(axis=-1)
    tiles_active = counts > 0
    if dynamic_switch:
        mac_tiles = counts > 1
    else:
        mac_tiles = tiles_active
    tile_rows = np.asarray(bitmaps).shape[-1]
    # MAC tile: 2*tile_rows*dim; READ tile: dim (copy, counted as 0 FLOP)
    return int(mac_tiles.sum()) * 2 * tile_rows * dim
