"""JAX embedding reduction through a ReCross layout.

The *numerical* side of ReCross: given the permuted/replicated device image
produced by :meth:`CrossbarLayout.build_image`, perform the embedding-bag
reduction for a batch of queries.  Three executable paths, all producing
identical values:

  * :func:`reduce_dense_oracle` — direct gather+sum on the *logical* table
    (ground truth; layout-independent).
  * :func:`reduce_via_layout`   — pure-jnp tiled one-hot MAC through the
    physical image with dynamic READ/MAC switching expressed as
    ``jnp.where`` (the reference the Pallas kernel is tested against).
  * :mod:`repro.kernels.ops.crossbar_reduce` — the Pallas TPU kernel.

Queries arrive in the framework's *compiled query format* (a fixed-shape
representation so everything jits):

  ``tile_ids``  (batch, max_tiles)            int32, -1 padded
  ``bitmaps``   (batch, max_tiles, tile_rows) activation masks (0/1)

produced by :func:`compile_queries` from the ragged host-side form.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.mapping import CrossbarLayout


@dataclasses.dataclass
class CompiledQueries:
    """Fixed-shape query batch (device-ready)."""

    tile_ids: jax.Array   # (batch, max_tiles) int32, -1 = padding
    bitmaps: jax.Array    # (batch, max_tiles, tile_rows) same dtype as table
    max_tiles: int

    @property
    def batch(self) -> int:
        return self.tile_ids.shape[0]


def compile_queries(
    layout: CrossbarLayout,
    queries: Sequence[Sequence[int]],
    *,
    max_tiles: int | None = None,
    dtype=jnp.float32,
    balance_replicas: bool = True,
) -> CompiledQueries:
    """Ragged host queries → fixed-shape device arrays.

    ``max_tiles`` defaults to the batch's maximum tiles-per-query, rounded
    up to a multiple of 8 for sublane friendliness.
    """
    from repro.core.mapping import query_tile_bitmaps

    bm, counts = query_tile_bitmaps(layout, queries, balance_replicas=balance_replicas)
    batch = bm.shape[0]
    per_q = [np.nonzero(counts[i])[0] for i in range(batch)]
    width = max((len(p) for p in per_q), default=1)
    if max_tiles is None:
        max_tiles = max(8, int(np.ceil(width / 8)) * 8)
    if width > max_tiles:
        raise ValueError(f"query touches {width} tiles > max_tiles={max_tiles}")

    tile_ids = np.full((batch, max_tiles), -1, dtype=np.int32)
    bitmaps = np.zeros((batch, max_tiles, layout.tile_rows), dtype=np.float32)
    for i, tiles in enumerate(per_q):
        tile_ids[i, : len(tiles)] = tiles
        bitmaps[i, : len(tiles)] = bm[i, tiles]
    return CompiledQueries(
        tile_ids=jnp.asarray(tile_ids),
        bitmaps=jnp.asarray(bitmaps, dtype=dtype),
        max_tiles=max_tiles,
    )


def reduce_dense_oracle(
    table: jax.Array, queries: Sequence[Sequence[int]]
) -> jax.Array:
    """Ground-truth gather+sum on the logical table (host-ragged input)."""
    out = []
    for q in queries:
        ids = jnp.asarray(sorted(set(int(i) for i in q)), dtype=jnp.int32)
        out.append(table[ids].sum(axis=0) if len(q) else jnp.zeros(table.shape[-1], table.dtype))
    return jnp.stack(out)


@partial(jax.jit, static_argnames=("tile_rows", "dynamic_switch"))
def reduce_via_layout(
    image: jax.Array,      # (num_tiles * tile_rows, dim) physical image
    tile_ids: jax.Array,   # (batch, max_tiles)
    bitmaps: jax.Array,    # (batch, max_tiles, tile_rows)
    *,
    tile_rows: int,
    dynamic_switch: bool = True,
) -> jax.Array:
    """Pure-jnp tiled one-hot MAC through the physical image.

    Per (query, slot): fetch the tile, then either
      * READ path  (popcount==1): select the single active row, or
      * MAC path: ``bitmap @ tile`` (one-hot MXU matmul).
    Padding slots (tile_id == -1) have all-zero bitmaps and contribute 0.
    """
    num_tiles = image.shape[0] // tile_rows
    dim = image.shape[-1]
    tiles3 = image.reshape(num_tiles, tile_rows, dim)

    def per_query(tids, bms):
        def per_slot(tid, bm):
            tile = tiles3[jnp.clip(tid, 0, num_tiles - 1)]  # (tile_rows, dim)
            mac = bm @ tile  # (dim,)
            if dynamic_switch:
                count = bm.sum()
                # READ path: arg-select the active row without a matmul.
                row = jnp.argmax(bm)
                read = tile[row] * (count > 0)
                out = jnp.where(count <= 1, read, mac)
            else:
                out = mac
            return out * (tid >= 0)

        return jax.vmap(per_slot)(tids, bms).sum(axis=0)

    return jax.vmap(per_query)(tile_ids, bitmaps)


def reduction_flops(bitmaps: np.ndarray, dim: int, dynamic_switch: bool) -> int:
    """FLOPs of the layout reduction (for benchmark reporting)."""
    counts = np.asarray(bitmaps).sum(axis=-1)
    tiles_active = counts > 0
    if dynamic_switch:
        mac_tiles = counts > 1
    else:
        mac_tiles = tiles_active
    tile_rows = np.asarray(bitmaps).shape[-1]
    # MAC tile: 2*tile_rows*dim; READ tile: dim (copy, counted as 0 FLOP)
    return int(mac_tiles.sum()) * 2 * tile_rows * dim
