"""Latency / energy cost models.

Two models live here:

1. :class:`ReRAMCostModel` — a NeuroSIM-flavoured analytic model of the
   paper's hardware (22 nm, 64×64 crossbar, 2-bit cells, 6-bit flash ADC,
   dynamic-switch ADC with popcount).  It reproduces the *relative*
   numbers of the paper's figures (speedup / energy-efficiency ratios);
   absolute constants are taken from the NeuroSIM / ISAAC / flash-ADC
   literature the paper cites and are documented per field.

2. :class:`TPUCostModel` — roofline constants for the TPU v5e target used
   by the dry-run analysis (§Roofline): 197 TFLOP/s bf16, 819 GB/s HBM,
   ~50 GB/s/link ICI.

The simulator (:mod:`repro.core.simulator`) charges events against the
ReRAM model; the launcher's roofline pass charges compiled HLO against the
TPU model.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ReRAMCostModel:
    """Analytic ReRAM crossbar cost model (paper Table I hardware).

    Latency unit: nanoseconds.  Energy unit: picojoules.

    Field provenance:
      * crossbar 64x64, 2-bit cells, 6-bit ADC, 256x256 tile, 512b bus —
        paper Table I.
      * MAC read pulse ~10 ns and array read energy — ISAAC [20] /
        NeuroSIM [27] 22nm-class numbers.
      * flash ADC: 2^n - 1 comparators; energy scales ~2^n — paper §III-D
        and Razavi [30].  6-bit MAC mode uses 63 comparators; READ mode
        uses 3-bit effective resolution (7 comparators, the paper reports
        "utilizing only 3 bits instead of the full 6-bit resolution").
      * popcount circuit: monolithic-3D CIM popcount [32]; tiny vs ADC.
    """

    rows: int = 64
    cols: int = 64
    bits_per_cell: int = 2
    adc_bits: int = 6
    read_adc_bits: int = 3

    # -- latency (ns) --
    mac_latency_ns: float = 10.0       # one full-array MAC incl. ADC conversion
    read_latency_ns: float = 5.0       # single-wordline read, low-res ADC path
    adc_latency_ns: float = 1.0        # flash ADC conversion (parallel, fast)
    popcount_latency_ns: float = 0.3   # [32]
    bus_cycle_ns: float = 1.0          # 512b global bus transfer per tile result
    dram_fetch_ns: float = 100.0       # host-side row fetch (CPU baseline path)

    # -- energy (pJ) --
    cell_mac_energy_pj: float = 0.0002   # per cell per MAC (22nm ReRAM)
    cell_read_energy_pj: float = 0.0001  # per cell per read
    comparator_energy_pj: float = 0.04   # per comparator per conversion
    popcount_energy_pj: float = 0.05     # per activation decision [32]
    wordline_driver_energy_pj: float = 0.01  # per driven wordline
    bus_energy_pj: float = 0.8           # per 512b transfer
    dram_fetch_energy_pj: float = 2000.0  # per 64B DRAM row fetch (CPU path)

    # ---- derived per-event costs ----------------------------------------

    @property
    def comparators_mac(self) -> int:
        return (1 << self.adc_bits) - 1  # 63

    @property
    def comparators_read(self) -> int:
        return (1 << self.read_adc_bits) - 1  # 7

    def adc_energy(self, mac_mode: bool) -> float:
        """Energy of one column conversion in MAC vs READ mode (pJ)."""
        n = self.comparators_mac if mac_mode else self.comparators_read
        return n * self.comparator_energy_pj

    def crossbar_mac_event(self, active_rows: int) -> tuple[float, float]:
        """(latency_ns, energy_pj) of one crossbar MAC activation.

        All ``cols`` columns convert; ``active_rows`` wordlines are driven;
        every cell on an active wordline dissipates MAC energy.
        """
        lat = self.mac_latency_ns + self.adc_latency_ns + self.popcount_latency_ns
        energy = (
            active_rows * self.cols * self.cell_mac_energy_pj
            + active_rows * self.wordline_driver_energy_pj
            + self.cols * self.adc_energy(mac_mode=True)
            + self.popcount_energy_pj
            + self.bus_energy_pj
        )
        return lat, energy

    def crossbar_read_event(self) -> tuple[float, float]:
        """(latency_ns, energy_pj) of one single-row READ activation."""
        lat = self.read_latency_ns + self.adc_latency_ns + self.popcount_latency_ns
        energy = (
            self.cols * self.cell_read_energy_pj
            + self.wordline_driver_energy_pj
            + self.cols * self.adc_energy(mac_mode=False)
            + self.popcount_energy_pj
            + self.bus_energy_pj
        )
        return lat, energy

    def crossbar_static_mac_event(self, active_rows) -> tuple[float, float]:
        """MAC event *without* dynamic switching (nMARS / naive ADС path).

        Always pays the full 6-bit conversion even for one active row, and
        no popcount circuit exists.  ``active_rows`` may be an int or an
        int array (the vectorized simulator charges whole batches at once;
        all event formulas are affine in the row count).
        """
        lat = self.mac_latency_ns + self.adc_latency_ns
        floor_rows = np.maximum(active_rows, 1)
        energy = (
            floor_rows * self.cols * self.cell_mac_energy_pj
            + floor_rows * self.wordline_driver_energy_pj
            + self.cols * self.adc_energy(mac_mode=True)
            + self.bus_energy_pj
        )
        return lat, energy

    def cpu_reduction_event(self, rows: int) -> tuple[float, float]:
        """Host CPU gathers `rows` rows from DRAM and sums them (baseline Fig. 11)."""
        lat = rows * self.dram_fetch_ns
        energy = rows * self.dram_fetch_energy_pj
        return lat, energy


@dataclasses.dataclass(frozen=True)
class TPUCostModel:
    """Roofline constants for TPU v5e (per chip), used by §Roofline."""

    peak_flops: float = 197e12          # bf16 FLOP/s
    hbm_bandwidth: float = 819e9        # B/s
    ici_bandwidth: float = 50e9         # B/s per link
    hbm_bytes: float = 16e9             # HBM capacity
    vmem_bytes: float = 128 * 1024 * 1024  # ~128 MiB VMEM (v5e ~128MB? conservative)

    def compute_time(self, flops: float, chips: int) -> float:
        return flops / (chips * self.peak_flops)

    def memory_time(self, bytes_: float, chips: int) -> float:
        return bytes_ / (chips * self.hbm_bandwidth)

    def collective_time(self, bytes_: float, chips: int) -> float:
        return bytes_ / (chips * self.ici_bandwidth)


DEFAULT_RERAM = ReRAMCostModel()
DEFAULT_TPU = TPUCostModel()
