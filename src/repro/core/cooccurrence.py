"""Co-occurrence statistics for embedding lookups (ReCross §III-A steps 1-2).

The offline phase of ReCross starts from a *lookup history*: a sequence of
queries, each query being the set of embedding-row ids that one inference
pulls from one table (a multi-hot ``SparseLengthsSum`` bag in DLRM terms).

From the history we build

  * ``freq[i]``      — access frequency of row *i* (power-law in practice),
  * a *co-occurrence list* — for every unordered pair ``(i, j)`` that appears
    together in at least one query, the number of queries containing both,

and from the list a *co-occurrence graph* where nodes are rows and edge
weights are co-access counts.  The graph is the input to the
correlation-aware grouping of :mod:`repro.core.grouping`.

Everything here is plain NumPy on the host: this is offline preprocessing,
exactly as in the paper (the ReRAM image is computed once, then written to
the crossbars before inference).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

Query = Sequence[int]


@dataclasses.dataclass
class CoOccurrenceGraph:
    """Sparse undirected co-occurrence graph.

    Attributes:
      num_rows: total number of embedding rows (nodes), including rows that
        never appear in the history (isolated nodes).
      freq: ``(num_rows,)`` int64 — per-row access frequency.
      adjacency: ``adjacency[i]`` is a dict ``{j: weight}`` of co-access
        counts.  Symmetric: ``j in adjacency[i]`` iff ``i in adjacency[j]``.
      num_queries: number of queries in the history.
    """

    num_rows: int
    freq: np.ndarray
    adjacency: List[Dict[int, int]]
    num_queries: int

    # ---- basic graph API used by the grouping algorithm -----------------

    def neighbors(self, i: int) -> Dict[int, int]:
        return self.adjacency[i]

    def weight(self, i: int, j: int) -> int:
        return self.adjacency[i].get(j, 0)

    def degree(self, i: int) -> int:
        return len(self.adjacency[i])

    @property
    def total_freq(self) -> int:
        return int(self.freq.sum())

    def nodes_by_frequency(self) -> np.ndarray:
        """Row ids sorted by descending access frequency (stable)."""
        # stable sort so equal-frequency rows keep id order (determinism)
        return np.argsort(-self.freq, kind="stable")

    def edge_count(self) -> int:
        return sum(len(a) for a in self.adjacency) // 2

    # ---- distribution diagnostics (paper Fig. 2 / Fig. 4) ---------------

    def correlation_counts(self) -> np.ndarray:
        """Number of correlated embeddings per row (paper Fig. 2)."""
        return np.array([len(a) for a in self.adjacency], dtype=np.int64)

    def powerlaw_alpha(self) -> float:
        """Crude MLE of the power-law exponent of the frequency distribution.

        Used only for reporting (the paper repeatedly observes power-law
        behaviour); not used by any algorithm.
        """
        f = self.freq[self.freq > 0].astype(np.float64)
        if f.size < 2:
            return float("nan")
        fmin = f.min()
        return 1.0 + f.size / np.log(f / fmin + 1e-12).sum()


def build_cooccurrence(
    queries: Iterable[Query],
    num_rows: int,
    *,
    max_pairs_per_query: int | None = None,
) -> CoOccurrenceGraph:
    """Builds frequency + co-occurrence graph from a lookup history.

    Args:
      queries: iterable of queries; each query is a sequence of row ids
        (duplicates within a query are collapsed — co-occurrence is a set
        property, matching the paper's "accessed together" definition).
      num_rows: table height.
      max_pairs_per_query: optional cap on the pairs enumerated per query
        (queries are O(k^2) in pairs; DLRM bags are small, k ≲ 100, so the
        default unbounded enumeration is what the paper does).

    Returns:
      A :class:`CoOccurrenceGraph`.
    """
    freq = np.zeros(num_rows, dtype=np.int64)
    pair_counts: collections.Counter = collections.Counter()
    num_queries = 0

    for q in queries:
        ids = sorted(set(int(i) for i in q))
        if not ids:
            continue
        num_queries += 1
        for i in ids:
            if not 0 <= i < num_rows:
                raise ValueError(f"row id {i} out of range [0, {num_rows})")
            freq[i] += 1
        pairs = ((ids[a], ids[b]) for a in range(len(ids)) for b in range(a + 1, len(ids)))
        if max_pairs_per_query is not None:
            pairs = _take(pairs, max_pairs_per_query)
        pair_counts.update(pairs)

    adjacency: List[Dict[int, int]] = [dict() for _ in range(num_rows)]
    for (i, j), w in pair_counts.items():
        adjacency[i][j] = w
        adjacency[j][i] = w

    return CoOccurrenceGraph(
        num_rows=num_rows, freq=freq, adjacency=adjacency, num_queries=num_queries
    )


def _take(it, n):
    for k, x in enumerate(it):
        if k >= n:
            return
        yield x


def merge_graphs(a: CoOccurrenceGraph, b: CoOccurrenceGraph) -> CoOccurrenceGraph:
    """Merges two histories (e.g. shards of a distributed trace collection).

    This is what a production deployment does: every serving replica logs
    its own lookup histogram, and the offline phase folds them together.
    """
    if a.num_rows != b.num_rows:
        raise ValueError("graphs cover different tables")
    adjacency: List[Dict[int, int]] = [dict(d) for d in a.adjacency]
    for i, nbrs in enumerate(b.adjacency):
        for j, w in nbrs.items():
            adjacency[i][j] = adjacency[i].get(j, 0) + w
    return CoOccurrenceGraph(
        num_rows=a.num_rows,
        freq=a.freq + b.freq,
        adjacency=adjacency,
        num_queries=a.num_queries + b.num_queries,
    )
