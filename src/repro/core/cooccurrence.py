"""Co-occurrence statistics for embedding lookups (ReCross §III-A steps 1-2).

The offline phase of ReCross starts from a *lookup history*: a sequence of
queries, each query being the set of embedding-row ids that one inference
pulls from one table (a multi-hot ``SparseLengthsSum`` bag in DLRM terms).

From the history we build

  * ``freq[i]``      — access frequency of row *i* (power-law in practice),
  * a *co-occurrence graph* — for every unordered pair ``(i, j)`` that
    appears together in at least one query, the number of queries
    containing both,

stored CSR-style (``indptr`` / ``indices`` / ``weights``), symmetric, with
neighbor lists sorted by id.  The graph is the input to the
correlation-aware grouping of :mod:`repro.core.grouping`, which walks the
CSR arrays directly.

Everything here is vectorized NumPy on the host: pair enumeration packs
every (i, j) pair of every query into one int64 key array and counts them
with a single ``np.unique`` — no Python-level loop over queries or pairs —
so Criteo-scale histories (100k+ queries) compile in seconds.  This is
offline preprocessing, exactly as in the paper (the ReRAM image is
computed once, then written to the crossbars before inference).
``_reference_build_cooccurrence`` keeps the original dict-of-Counters loop
as the equivalence oracle for the property tests.

At 10M-row scale the all-at-once pair enumeration is the memory wall:
the flat pair list is O(sum of k² over distinct patterns), which dwarfs
the unique-edge output.  ``build_cooccurrence(..., block_pairs=...)``
caps the enumerated intermediate: distinct patterns are walked in chunks
whose pair budget is at most ``block_pairs`` (always at least one
pattern), each chunk is counted into a sorted (packed key, weight) run,
and runs are consolidated with an LSM-style geometric merge so the
accumulated state never exceeds O(unique edges) while each merge only
touches runs of comparable size.  Integer weight addition is associative
and the final key order is the same ascending packed order, so the
blocked build is bit-identical to the unblocked one for every block
size ≥ 1 pattern.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from repro.core.progress import StageProgress

Query = Sequence[int]


@dataclasses.dataclass
class CoOccurrenceGraph:
    """Sparse undirected co-occurrence graph in CSR form.

    Attributes:
      num_rows: total number of embedding rows (nodes), including rows that
        never appear in the history (isolated nodes).
      freq: ``(num_rows,)`` int64 — per-row access frequency.
      indptr: ``(num_rows + 1,)`` int64 — CSR row pointers.
      indices: ``(nnz,)`` int64 — neighbor ids, ascending within each row.
        Symmetric: edge (i, j) is stored in both row i and row j.
      weights: ``(nnz,)`` int64 — co-access counts aligned with indices.
      num_queries: number of (non-empty) queries in the history.
    """

    num_rows: int
    freq: np.ndarray
    indptr: np.ndarray
    indices: np.ndarray
    weights: np.ndarray
    num_queries: int

    # ---- basic graph API used by the grouping algorithm -----------------

    def neighbors(self, i: int) -> Dict[int, int]:
        """``{j: weight}`` view of row i (materialized; prefer
        :meth:`neighbor_arrays` in hot loops)."""
        lo, hi = self.indptr[i], self.indptr[i + 1]
        return dict(zip(self.indices[lo:hi].tolist(), self.weights[lo:hi].tolist()))

    def neighbor_arrays(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        """(ids, weights) CSR slices of row i — zero-copy."""
        lo, hi = self.indptr[i], self.indptr[i + 1]
        return self.indices[lo:hi], self.weights[lo:hi]

    def weight(self, i: int, j: int) -> int:
        lo, hi = self.indptr[i], self.indptr[i + 1]
        k = lo + np.searchsorted(self.indices[lo:hi], j)
        if k < hi and self.indices[k] == j:
            return int(self.weights[k])
        return 0

    def degree(self, i: int) -> int:
        return int(self.indptr[i + 1] - self.indptr[i])

    @property
    def total_freq(self) -> int:
        return int(self.freq.sum())

    def nodes_by_frequency(self) -> np.ndarray:
        """Row ids sorted by descending access frequency (stable)."""
        # stable sort so equal-frequency rows keep id order (determinism)
        return np.argsort(-self.freq, kind="stable")

    def edge_count(self) -> int:
        return int(self.indices.shape[0]) // 2

    # ---- distribution diagnostics (paper Fig. 2 / Fig. 4) ---------------

    def correlation_counts(self) -> np.ndarray:
        """Number of correlated embeddings per row (paper Fig. 2)."""
        return np.diff(self.indptr).astype(np.int64)

    def powerlaw_alpha(self) -> float:
        """Crude MLE of the power-law exponent of the frequency distribution.

        Used only for reporting (the paper repeatedly observes power-law
        behaviour); not used by any algorithm.
        """
        f = self.freq[self.freq > 0].astype(np.float64)
        if f.size < 2:
            return float("nan")
        fmin = f.min()
        return 1.0 + f.size / np.log(f / fmin + 1e-12).sum()

    # ---- construction ----------------------------------------------------

    @classmethod
    def from_pair_counts(
        cls,
        num_rows: int,
        pair_i: np.ndarray,
        pair_j: np.ndarray,
        pair_w: np.ndarray,
        freq: np.ndarray,
        num_queries: int,
    ) -> "CoOccurrenceGraph":
        """Builds the symmetric CSR arrays from unique (i < j, weight) edges.

        Scatter construction, no sort of the doubled edge list: row r's
        ascending neighbor list is exactly its j-major-ordered incoming
        edges (all ids < r) followed by its i-major-ordered outgoing edges
        (all ids > r), so both halves are placed by segment-rank
        arithmetic; only the (j, i) ordering of the upper triangle needs
        one argsort of E entries (half the edge list).
        """
        pair_i = np.asarray(pair_i, dtype=np.int64)
        pair_j = np.asarray(pair_j, dtype=np.int64)
        pair_w = np.asarray(pair_w, dtype=np.int64)
        n_edges = pair_i.size
        freq = np.asarray(freq, dtype=np.int64)
        if n_edges == 0:
            return cls(
                num_rows=num_rows, freq=freq,
                indptr=np.zeros(num_rows + 1, np.int64),
                indices=np.empty(0, np.int64), weights=np.empty(0, np.int64),
                num_queries=num_queries,
            )
        if (pair_i >= pair_j).any():
            raise ValueError("edges must be upper-triangle (i < j)")
        key = pair_i * np.int64(num_rows) + pair_j
        if np.any(key[1:] <= key[:-1]):  # callers usually pass (i, j)-sorted
            order = np.argsort(key)
            pair_i, pair_j, pair_w = pair_i[order], pair_j[order], pair_w[order]

        deg_out = np.bincount(pair_i, minlength=num_rows).astype(np.int64)
        deg_in = np.bincount(pair_j, minlength=num_rows).astype(np.int64)
        indptr = np.zeros(num_rows + 1, dtype=np.int64)
        np.cumsum(deg_out + deg_in, out=indptr[1:])

        pos_out = indptr[pair_i] + deg_in[pair_i] + segment_ranks(deg_out)

        order_in = np.argsort(pair_j * np.int64(num_rows) + pair_i)
        bj, bi, bw = pair_j[order_in], pair_i[order_in], pair_w[order_in]
        pos_in = indptr[bj] + segment_ranks(deg_in)

        indices = np.empty(2 * n_edges, dtype=np.int64)
        weights = np.empty(2 * n_edges, dtype=np.int64)
        indices[pos_out] = pair_j
        weights[pos_out] = pair_w
        indices[pos_in] = bi
        weights[pos_in] = bw
        return cls(
            num_rows=num_rows, freq=freq, indptr=indptr,
            indices=indices, weights=weights, num_queries=num_queries,
        )

    def unique_edges(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(i, j, w) arrays of the upper-triangle (i < j) edge list."""
        src = np.repeat(np.arange(self.num_rows, dtype=np.int64), np.diff(self.indptr))
        upper = src < self.indices
        return src[upper], self.indices[upper], self.weights[upper]


def flatten_ragged(queries: Iterable[Query]) -> Tuple[np.ndarray, np.ndarray, int]:
    """Flattens a ragged id history into ``(flat_ids, lengths, num_queries)``.

    Keeps zero-length queries (their length is 0) so callers that index by
    batch position — the query compiler, the per-query diagnostics — keep
    their alignment.  The one flatten idiom shared by the whole offline
    pipeline.
    """
    arrays = [np.asarray(q, dtype=np.int64).ravel() for q in queries]
    nq = len(arrays)
    lengths = np.fromiter((a.size for a in arrays), np.int64, nq)
    if nq == 0 or int(lengths.sum()) == 0:
        return np.empty(0, np.int64), lengths, nq
    flat = np.concatenate([a for a in arrays if a.size])
    return flat, lengths, nq


def segment_ranks(lengths: np.ndarray) -> np.ndarray:
    """``0..len-1`` within each run of a lengths array, concatenated.

    The rank-within-segment companion of :func:`flatten_ragged`; the one
    place the ``arange - repeat(cumsum - lengths)`` index arithmetic
    lives.  Zero-length segments contribute nothing.
    """
    lengths = np.asarray(lengths, dtype=np.int64)
    starts = np.cumsum(lengths) - lengths
    return (
        np.arange(int(lengths.sum()), dtype=np.int64)
        - np.repeat(starts, lengths)
    )


def _dedup_within_queries(
    queries: Iterable[Query], num_rows: int
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Flattens a ragged history into per-query sorted+deduped id runs.

    Returns (rows, query_lengths, num_queries) where ``rows`` concatenates
    each non-empty query's unique ids in ascending order (empty queries
    are dropped; ``num_queries`` counts the non-empty ones).
    """
    flat, lengths, _ = flatten_ragged(queries)
    lengths = lengths[lengths > 0]
    nq = int(lengths.size)
    if nq == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64), 0
    bad = (flat < 0) | (flat >= num_rows)
    if bad.any():
        i = int(flat[bad][0])
        raise ValueError(f"row id {i} out of range [0, {num_rows})")
    qid = np.repeat(np.arange(nq, dtype=np.int64), lengths)
    # pack (qid, row) into one key so a value-only np.sort replaces the
    # far slower lexsort; nq * num_rows stays well under 2^63 for any
    # realistic table/history (guarded just in case)
    if nq * num_rows < 2**62:
        key = np.sort(qid * np.int64(num_rows) + flat)
        keep = np.ones(key.size, dtype=bool)
        keep[1:] = key[1:] != key[:-1]
        key = key[keep]
        rows, qid = key % num_rows, key // num_rows
    else:  # pragma: no cover - overflow guard
        order = np.lexsort((flat, qid))
        flat, qid = flat[order], qid[order]
        keep = np.ones(flat.size, dtype=bool)
        keep[1:] = (flat[1:] != flat[:-1]) | (qid[1:] != qid[:-1])
        rows, qid = flat[keep], qid[keep]
    return rows, np.bincount(qid, minlength=nq).astype(np.int64), nq


def _enumerate_pairs(
    rows: np.ndarray,
    lengths: np.ndarray,
    max_pairs_per_query: int | None,
) -> Tuple[np.ndarray, np.ndarray]:
    """All (left, right) index pairs within each query run, vectorized.

    Pair order within a query matches the reference double loop: left
    position ascending, then right position ascending — which is what
    makes ``max_pairs_per_query`` truncation agree with the loop version.
    """
    n = rows.size
    local = segment_ranks(lengths)
    rep = np.repeat(lengths, lengths) - 1 - local    # left-appearances per elem
    if int(rep.sum()) == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    left = np.repeat(np.arange(n, dtype=np.int64), rep)
    right = segment_ranks(rep) + left + 1
    if max_pairs_per_query is not None:
        ppq = lengths * (lengths - 1) // 2
        m = segment_ranks(ppq) < max_pairs_per_query
        left, right = left[m], right[m]
    return left, right


def _dedup_identical_queries(
    rows: np.ndarray, lengths: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Collapses byte-identical queries into (rows, lengths, multiplicity).

    Recommendation traces repeat template baskets heavily; counting each
    distinct query once and weighting its pairs by multiplicity is exact
    (a pair's count is the number of queries containing it) and shrinks
    the O(k²) pair enumeration by the repeat factor.  Queries are grouped
    by length (equal queries must have equal length), each group is
    deduplicated with one ``np.unique(axis=0)`` over its (n, L) id matrix.
    """
    nq = lengths.size
    starts = np.cumsum(lengths) - lengths
    out_rows, out_lens, out_mult = [], [], []
    for length in np.unique(lengths):
        sel = np.where(lengths == length)[0]
        if length == 0:
            continue
        mat = rows[starts[sel][:, None] + np.arange(length)]
        uniq, mult = np.unique(mat, axis=0, return_counts=True)
        out_rows.append(uniq.ravel())
        out_lens.append(np.full(uniq.shape[0], length, dtype=np.int64))
        out_mult.append(mult.astype(np.int64))
    if not out_rows:
        return rows[:0], lengths[:0], lengths[:0]
    return (
        np.concatenate(out_rows),
        np.concatenate(out_lens),
        np.concatenate(out_mult),
    )


def _check_pair_key_capacity(num_rows: int) -> None:
    """Packed pair keys are ``i * num_rows + j`` — both < num_rows, so the
    encoding needs ``num_rows**2 < 2**63``.  Raised *before* any pair
    allocation so a too-tall table fails loudly and instantly."""
    if num_rows > 3_037_000_499:  # isqrt(2^63): packed keys would wrap
        raise NotImplementedError(
            f"num_rows={num_rows} exceeds int64 pair-key packing "
            f"(limit 3_037_000_499 rows)"
        )


def build_cooccurrence(
    queries: Iterable[Query],
    num_rows: int,
    *,
    max_pairs_per_query: int | None = None,
    block_pairs: int | None = None,
) -> CoOccurrenceGraph:
    """Builds frequency + co-occurrence graph from a lookup history.

    Fully vectorized: the history is flattened once, ids are deduped per
    query with one lexsort, byte-identical queries are collapsed to
    (pattern, multiplicity), and every pair of every distinct pattern is
    counted by ``np.unique`` over packed ``i * num_rows + j`` int64 keys
    with multiplicity weights.

    Args:
      queries: iterable of queries; each query is a sequence of row ids
        (duplicates within a query are collapsed — co-occurrence is a set
        property, matching the paper's "accessed together" definition).
      num_rows: table height.
      max_pairs_per_query: optional cap on the pairs enumerated per query
        (queries are O(k^2) in pairs; DLRM bags are small, k ≲ 100, so the
        default unbounded enumeration is what the paper does).  The first
        pairs in (left, right) position order are kept, matching the
        reference implementation's truncation.
      block_pairs: cap on the number of pairs enumerated at once.  None
        enumerates every pair of every pattern in one flat array (fastest
        when it fits); an integer walks the patterns in chunks of at most
        ``block_pairs`` pairs (at least one pattern per chunk) so the
        peak intermediate is O(block_pairs), not O(total pairs).  The
        result is bit-identical for every value.

    Returns:
      A :class:`CoOccurrenceGraph`.
    """
    _check_pair_key_capacity(num_rows)
    if block_pairs is not None and block_pairs < 1:
        raise ValueError("block_pairs must be >= 1")
    rows, lengths, nq = _dedup_within_queries(queries, num_rows)
    rows, lengths, mult = _dedup_identical_queries(rows, lengths)
    freq = np.bincount(
        rows, weights=np.repeat(mult, lengths).astype(np.float64),
        minlength=num_rows,
    ).astype(np.int64)
    ppq = lengths * (lengths - 1) // 2
    if max_pairs_per_query is not None:
        ppq = np.minimum(ppq, max_pairs_per_query)
    total_pairs = int(ppq.sum())
    if total_pairs == 0:
        e = np.empty(0, np.int64)
        return CoOccurrenceGraph.from_pair_counts(num_rows, e, e, e, freq, nq)
    if block_pairs is None or block_pairs >= total_pairs:
        left, right = _enumerate_pairs(rows, lengths, max_pairs_per_query)
        pair_w = np.repeat(mult, ppq)
        keys = rows[left] * np.int64(num_rows) + rows[right]
        uk, w = _count_packed_keys(keys, pair_w, num_rows)
    else:
        uk, w = _blocked_pair_counts(
            rows, lengths, mult, ppq, num_rows, max_pairs_per_query, block_pairs
        )
    return CoOccurrenceGraph.from_pair_counts(
        num_rows, uk // num_rows, uk % num_rows, w, freq, nq
    )


def _merge_key_runs(
    a: Tuple[np.ndarray, np.ndarray], b: Tuple[np.ndarray, np.ndarray]
) -> Tuple[np.ndarray, np.ndarray]:
    """Merges two sorted-unique (keys, weights) runs, summing weights."""
    k = np.concatenate([a[0], b[0]])
    w = np.concatenate([a[1], b[1]])
    order = np.argsort(k, kind="stable")  # two sorted runs: mergesort is O(n)
    k, w = k[order], w[order]
    starts = np.ones(k.size, dtype=bool)
    starts[1:] = k[1:] != k[:-1]
    idx = np.flatnonzero(starts)
    return k[idx], np.add.reduceat(w, idx)


def _blocked_pair_counts(
    rows: np.ndarray,
    lengths: np.ndarray,
    mult: np.ndarray,
    ppq: np.ndarray,
    num_rows: int,
    max_pairs_per_query: int | None,
    block_pairs: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Pair counting with an O(block_pairs) enumerated intermediate.

    Walks the distinct patterns in chunks whose summed pair budget stays
    ≤ ``block_pairs`` (always ≥ 1 pattern so a bag wider than the block
    still makes progress), counts each chunk into a sorted-unique
    (packed key, weight) run, and consolidates runs with a geometric
    merge stack: a run is folded into its neighbor whenever the neighbor
    is less than twice its size, so every edge participates in
    O(log #chunks) merges and the resident runs total O(unique edges).
    """
    row_starts = np.cumsum(lengths) - lengths
    cum = np.cumsum(ppq)
    total = int(cum[-1])
    progress = StageProgress("cooc", total, unit="pairs")
    runs: List[Tuple[np.ndarray, np.ndarray]] = []
    p0 = 0
    num_patterns = int(lengths.size)
    nr = np.int64(num_rows)
    while p0 < num_patterns:
        base = int(cum[p0 - 1]) if p0 else 0
        p1 = max(int(np.searchsorted(cum, base + block_pairs, side="right")), p0 + 1)
        r0 = int(row_starts[p0])
        r1 = int(row_starts[p1 - 1] + lengths[p1 - 1])
        left, right = _enumerate_pairs(
            rows[r0:r1], lengths[p0:p1], max_pairs_per_query
        )
        if left.size:
            pair_w = np.repeat(mult[p0:p1], ppq[p0:p1])
            keys = rows[r0:r1][left] * nr + rows[r0:r1][right]
            runs.append(_count_packed_keys(keys, pair_w, num_rows))
            while len(runs) >= 2 and runs[-2][0].size < 2 * runs[-1][0].size:
                runs[-2:] = [_merge_key_runs(runs[-2], runs[-1])]
        progress.tick(int(cum[p1 - 1]))
        p0 = p1
    progress.finish(total)
    while len(runs) >= 2:
        runs[-2:] = [_merge_key_runs(runs[-2], runs[-1])]
    if not runs:  # pragma: no cover - total_pairs > 0 guarantees a run
        return np.empty(0, np.int64), np.empty(0, np.int64)
    return runs[0]


def _count_packed_keys(
    keys: np.ndarray, weights: np.ndarray, num_rows: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Sums ``weights`` per unique packed pair key; returns sorted
    (keys, weights).

    Hot path packs the weight into the key's low bits so one value-only
    ``np.sort`` + ``np.add.reduceat`` replaces argsort/unique indirection
    (≈3× faster on multi-million-pair histories).  Falls back to
    ``np.unique`` when the combined key would not fit 63 bits.
    """
    w_max = int(weights.max())
    shift = 62 - (num_rows * num_rows).bit_length()
    if shift > 0 and w_max < (1 << shift):
        packed = np.sort((keys << shift) | weights)
        high = packed >> shift
        starts = np.ones(high.size, dtype=bool)
        starts[1:] = high[1:] != high[:-1]
        starts_idx = np.flatnonzero(starts)
        w = np.add.reduceat(packed & ((np.int64(1) << shift) - 1), starts_idx)
        uk = high[starts_idx]
    else:  # pragma: no cover - enormous-multiplicity guard
        uk, inv = np.unique(keys, return_inverse=True)
        w = np.bincount(inv, weights=weights.astype(np.float64)).astype(np.int64)
    return uk, w.astype(np.int64)


def _count_weighted_keys(
    keys: np.ndarray, weights: np.ndarray, num_rows: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(i, j, weight) form of :func:`_count_packed_keys` (legacy callers)."""
    uk, w = _count_packed_keys(keys, weights, num_rows)
    return uk // num_rows, uk % num_rows, w


def _reference_build_cooccurrence(
    queries: Iterable[Query],
    num_rows: int,
    *,
    max_pairs_per_query: int | None = None,
) -> CoOccurrenceGraph:
    """Original pair-by-pair loop implementation (equivalence oracle)."""
    freq = np.zeros(num_rows, dtype=np.int64)
    pair_counts: collections.Counter = collections.Counter()
    num_queries = 0

    for q in queries:
        ids = sorted(set(int(i) for i in q))
        if not ids:
            continue
        num_queries += 1
        for i in ids:
            if not 0 <= i < num_rows:
                raise ValueError(f"row id {i} out of range [0, {num_rows})")
            freq[i] += 1
        pairs = ((ids[a], ids[b]) for a in range(len(ids)) for b in range(a + 1, len(ids)))
        if max_pairs_per_query is not None:
            pairs = _take(pairs, max_pairs_per_query)
        pair_counts.update(pairs)

    if pair_counts:
        items = np.array([(i, j, w) for (i, j), w in pair_counts.items()], dtype=np.int64)
        pi, pj, w = items[:, 0], items[:, 1], items[:, 2]
    else:
        pi = pj = w = np.empty(0, np.int64)
    return CoOccurrenceGraph.from_pair_counts(num_rows, pi, pj, w, freq, num_queries)


def _take(it, n):
    for k, x in enumerate(it):
        if k >= n:
            return
        yield x


def merge_graphs(a: CoOccurrenceGraph, b: CoOccurrenceGraph) -> CoOccurrenceGraph:
    """Merges two histories (e.g. shards of a distributed trace collection).

    This is what a production deployment does: every serving replica logs
    its own lookup histogram, and the offline phase folds them together.
    Pure array concatenation + one ``np.unique`` — no Python loop.
    """
    if a.num_rows != b.num_rows:
        raise ValueError("graphs cover different tables")
    ai, aj, aw = a.unique_edges()
    bi, bj, bw = b.unique_edges()
    keys = np.concatenate([ai, bi]) * np.int64(a.num_rows) + np.concatenate([aj, bj])
    w = np.concatenate([aw, bw])
    uk, inv = np.unique(keys, return_inverse=True)
    mw = np.bincount(inv, weights=w.astype(np.float64)).astype(np.int64)
    return CoOccurrenceGraph.from_pair_counts(
        a.num_rows, uk // a.num_rows, uk % a.num_rows, mw,
        a.freq + b.freq, a.num_queries + b.num_queries,
    )
