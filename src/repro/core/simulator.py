"""Cycle-level crossbar scheduler / cost simulator.

Replays a query batch against a :class:`~repro.core.mapping.CrossbarLayout`
and charges every crossbar activation to the
:class:`~repro.core.energy.ReRAMCostModel`.  This is the NeuroSIM-role
component: it produces the paper's evaluation metrics —

  * completion time of the batch (with inter-query contention: a tile can
    serve one activation at a time; replicas serve in parallel — the
    §III-C stall-cycle story),
  * total energy,
  * crossbar-activation counts (Fig. 9),
  * READ/MAC mode mix (Fig. 6),

for ReCross and for the baselines (naïve mapping, frequency-based mapping
[33], nMARS-style static-ADC reduction [24], CPU gather-sum).

The batch replay is fully vectorized: queries are compiled once into the
sparse :class:`~repro.core.mapping.ActivationSet`, per-activation
latencies/energies come from the (affine) cost-model formulas evaluated on
whole arrays, and tile busy time / total energy are charged with
``np.ufunc.at`` scatters in the same (query, tile) order the original
Python loop used — so the accumulated floats are bit-identical to the loop
(kept as :func:`_reference_simulate_batch` for the equivalence tests) and
100k-query histories replay in milliseconds instead of minutes.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.energy import ReRAMCostModel, DEFAULT_RERAM
from repro.core.mapping import (
    CrossbarLayout,
    compile_activations,
    _reference_query_tile_bitmaps,
)


@dataclasses.dataclass
class SimReport:
    """Batch-level simulation result."""

    completion_time_ns: float
    energy_pj: float
    activations: int
    read_activations: int
    mac_activations: int
    stall_ns: float
    per_query_tiles: np.ndarray      # (batch,) tiles activated by each query
    mean_active_rows: float

    @property
    def read_fraction(self) -> float:
        return self.read_activations / max(self.activations, 1)

    def speedup_over(self, other: "SimReport") -> float:
        return other.completion_time_ns / max(self.completion_time_ns, 1e-12)

    def energy_efficiency_over(self, other: "SimReport") -> float:
        return other.energy_pj / max(self.energy_pj, 1e-12)


def _activation_costs(
    rows: np.ndarray,
    model: ReRAMCostModel,
    dynamic_switching: bool,
    switch_threshold: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(latency_ns, energy_pj, read_mask) per activation, vectorized.

    The cost-model event methods are affine in ``active_rows``, so calling
    them on int64 arrays reproduces the scalar per-event arithmetic
    exactly (same IEEE operations elementwise as the reference loop).
    """
    rows = np.asarray(rows, dtype=np.int64)
    if dynamic_switching:
        read_mask = rows <= switch_threshold
        lat_read, e_read = model.crossbar_read_event()
        lat_mac, e_mac = model.crossbar_mac_event(rows)
        lat = np.where(read_mask, lat_read * rows, lat_mac)
        energy = np.where(read_mask, e_read * rows, e_mac)
    else:
        read_mask = np.zeros(rows.shape, dtype=bool)
        lat, energy = model.crossbar_static_mac_event(rows)
        lat = np.broadcast_to(np.float64(lat), rows.shape)
    return lat, energy, read_mask


def simulate_batch(
    layout: CrossbarLayout,
    queries: Sequence[Sequence[int]],
    *,
    model: ReRAMCostModel = DEFAULT_RERAM,
    dynamic_switching: bool = True,
    balance_replicas: bool = True,
    switch_threshold: int = 1,
) -> SimReport:
    """Simulates one batch of embedding-reduction queries.

    Timing model: all queries of a batch are issued simultaneously
    (batch-level inference).  Each activated tile serves its queue of
    activations serially; distinct tiles (including replicas of the same
    group) operate in parallel.  Batch completion time is the max over
    tiles of the tile's busy time — queue imbalance therefore shows up as
    stalls, which is exactly what Eq.-1 replication attacks.
    """
    acts = compile_activations(layout, queries, balance_replicas=balance_replicas)
    num_tiles = layout.num_tiles
    rows = acts.act_rows
    activations = acts.num_activations

    lat, energy_per_act, read_mask = _activation_costs(
        rows, model, dynamic_switching, switch_threshold
    )

    tile_busy_ns = np.zeros(num_tiles, dtype=np.float64)
    # ufunc.at applies repeated indices sequentially in array order; the
    # activation list is (query, tile)-sorted — the same order the scalar
    # loop charged tiles in, so per-tile sums match it bit for bit.
    np.add.at(tile_busy_ns, acts.act_tile, lat)
    energy_acc = np.zeros(1, dtype=np.float64)
    np.add.at(energy_acc, np.zeros(activations, dtype=np.intp), energy_per_act)

    reads = int(read_mask.sum())
    completion = float(tile_busy_ns.max()) if activations else 0.0
    # stall = extra serialization beyond a perfectly balanced schedule
    ideal = float(tile_busy_ns.sum()) / max(num_tiles, 1)
    per_query_tiles = acts.per_query_tiles()

    return SimReport(
        completion_time_ns=completion,
        energy_pj=float(energy_acc[0]),
        activations=activations,
        read_activations=reads,
        mac_activations=activations - reads,
        stall_ns=max(completion - ideal, 0.0),
        per_query_tiles=per_query_tiles,
        mean_active_rows=int(rows.sum()) / max(activations, 1),
    )


def _reference_simulate_batch(
    layout: CrossbarLayout,
    queries: Sequence[Sequence[int]],
    *,
    model: ReRAMCostModel = DEFAULT_RERAM,
    dynamic_switching: bool = True,
    balance_replicas: bool = True,
    switch_threshold: int = 1,
) -> SimReport:
    """Original per-activation Python loop (equivalence oracle)."""
    bitmaps, counts = _reference_query_tile_bitmaps(
        layout, queries, balance_replicas=balance_replicas
    )
    batch, num_tiles = counts.shape

    tile_busy_ns = np.zeros(num_tiles, dtype=np.float64)
    energy = 0.0
    activations = 0
    reads = 0
    macs = 0
    active_rows_sum = 0

    q_idx, t_idx = np.nonzero(counts)
    for q, t in zip(q_idx, t_idx):
        rows = int(counts[q, t])
        activations += 1
        active_rows_sum += rows
        if dynamic_switching and rows <= switch_threshold:
            # READ mode: k activated rows are read out serially through the
            # low-resolution ADC path (k=1 in the paper; thresholds >1 are
            # the beyond-paper "multi-read" policy, see §Perf notes)
            lat, e = model.crossbar_read_event()
            lat, e = lat * rows, e * rows
            reads += 1
        elif dynamic_switching:
            lat, e = model.crossbar_mac_event(rows)
            macs += 1
        else:
            lat, e = model.crossbar_static_mac_event(rows)
            macs += 1
        tile_busy_ns[t] += lat
        energy += e

    completion = float(tile_busy_ns.max()) if activations else 0.0
    ideal = float(tile_busy_ns.sum()) / max(num_tiles, 1)
    per_query_tiles = (counts > 0).sum(axis=1).astype(np.int64)

    return SimReport(
        completion_time_ns=completion,
        energy_pj=energy,
        activations=activations,
        read_activations=reads,
        mac_activations=macs,
        stall_ns=max(completion - ideal, 0.0),
        per_query_tiles=per_query_tiles,
        mean_active_rows=active_rows_sum / max(activations, 1),
    )


def simulate_cpu_baseline(
    queries: Sequence[Sequence[int]],
    *,
    model: ReRAMCostModel = DEFAULT_RERAM,
    parallel_lanes: int = 8,
) -> SimReport:
    """CPU gather-sum baseline (Fig. 11): DRAM row fetches + host adds.

    ``parallel_lanes`` models the memory-level parallelism of a desktop
    CPU's load queue; energy is charged per fetched row regardless.
    ``mean_active_rows`` reports the true mean unique rows fetched per
    query (the Fig. 11 comparison axis), not a placeholder.
    """
    per_query = np.fromiter(
        (len(set(int(r) for r in q)) for q in queries), np.int64, len(queries)
    )
    lane_busy = np.zeros(parallel_lanes, dtype=np.float64)
    energy = 0.0
    for rows in per_query:
        lat, e = model.cpu_reduction_event(int(rows))
        lane = int(np.argmin(lane_busy))
        lane_busy[lane] += lat
        energy += e
    total_rows = int(per_query.sum())
    return SimReport(
        completion_time_ns=float(lane_busy.max()),
        energy_pj=energy,
        activations=total_rows,
        read_activations=total_rows,
        mac_activations=0,
        stall_ns=0.0,
        per_query_tiles=per_query,
        mean_active_rows=float(per_query.mean()) if per_query.size else 0.0,
    )


def simulate_nmars_baseline(
    layout: CrossbarLayout,
    queries: Sequence[Sequence[int]],
    *,
    model: ReRAMCostModel = DEFAULT_RERAM,
    crossbars_per_adder: int = 8,
) -> SimReport:
    """nMARS-style [24] baseline: parallel in-memory lookup, then
    aggregation of per-crossbar partial sums over a hierarchical adder
    fabric (one adder lane per ``crossbars_per_adder`` crossbars, serial
    within a lane), static full-resolution ADC, no replication balancing."""
    rep = simulate_batch(
        layout,
        queries,
        model=model,
        dynamic_switching=False,
        balance_replicas=False,
    )
    lanes = max(layout.num_tiles // crossbars_per_adder, 1)
    transfers = float(rep.per_query_tiles.sum())
    agg_ns = transfers * model.bus_cycle_ns / lanes
    agg_pj = transfers * model.bus_energy_pj
    return dataclasses.replace(
        rep,
        completion_time_ns=rep.completion_time_ns + agg_ns,
        energy_pj=rep.energy_pj + agg_pj,
    )
