"""ReCross core: the paper's contribution as composable pieces.

Offline phase: cooccurrence → grouping (Alg. 1) → replication (Eq. 1) →
mapping.  Online phase: dynamic_switch + reduction (JAX) / kernels (Pallas)
+ simulator (ReRAM cost accounting).
"""

from repro.core.cooccurrence import CoOccurrenceGraph, build_cooccurrence, merge_graphs
from repro.core.grouping import (
    Grouping,
    activations_per_query,
    correlation_aware_grouping,
    frequency_grouping,
    naive_grouping,
)
from repro.core.replication import (
    ReplicationPlan,
    log_scaled_copies,
    plan_replication,
    shard_replication_sets,
)
from repro.core.mapping import (
    ActivationSet,
    CrossbarLayout,
    build_layout,
    compile_activations,
    query_tile_bitmaps,
)
from repro.core.dynamic_switch import (
    MAC_MODE,
    READ_MODE,
    energy_breakeven_rows,
    jnp_select_mode,
    mode_statistics,
    popcount,
    select_mode,
)
from repro.core.energy import DEFAULT_RERAM, DEFAULT_TPU, ReRAMCostModel, TPUCostModel
from repro.core.simulator import (
    SimReport,
    simulate_batch,
    simulate_cpu_baseline,
    simulate_nmars_baseline,
)
from repro.core.reduction import (
    BlockedQueries,
    BlockUnionTracker,
    CompiledQueries,
    ShardedBlockedQueries,
    block_compiled_queries,
    compile_queries,
    concat_compiled_queries,
    fused_group_loads,
    offset_compiled_queries,
    reduce_dense_oracle,
    reduce_via_layout,
    shard_block_queries,
)
from repro.core import baselines

__all__ = [
    "CoOccurrenceGraph", "build_cooccurrence", "merge_graphs",
    "Grouping", "correlation_aware_grouping", "frequency_grouping",
    "naive_grouping", "activations_per_query",
    "ReplicationPlan", "log_scaled_copies", "plan_replication",
    "shard_replication_sets",
    "ActivationSet", "CrossbarLayout", "build_layout",
    "compile_activations", "query_tile_bitmaps",
    "READ_MODE", "MAC_MODE", "popcount", "select_mode", "jnp_select_mode",
    "energy_breakeven_rows", "mode_statistics",
    "ReRAMCostModel", "TPUCostModel", "DEFAULT_RERAM", "DEFAULT_TPU",
    "SimReport", "simulate_batch", "simulate_cpu_baseline",
    "simulate_nmars_baseline",
    "BlockedQueries", "BlockUnionTracker", "CompiledQueries",
    "ShardedBlockedQueries",
    "block_compiled_queries", "compile_queries", "concat_compiled_queries",
    "fused_group_loads",
    "offset_compiled_queries", "reduce_dense_oracle", "reduce_via_layout",
    "shard_block_queries",
    "baselines",
]
