"""Access-aware crossbar allocation (ReCross §III-C, Eq. 1).

Even after correlation-aware grouping, group access frequency remains
power-law: a few hot crossbars serialize the queries of a batch while the
rest idle.  ReCross replicates hot groups, with a *log-scaled* copy count

    num_copies(g) = floor( log(freq_g) / log(freq_total) * log(batch) )

(Eq. 1).  Log scaling (a) tames the head of the power law so replication
does not explode area, and (b) still hands every moderately-hot group at
least one extra copy.

On TPU the same equation drives two placements:

  * **intra-shard replicas** — extra physical tiles inside one model shard,
    so concurrent queries of a batch hit different tiles (the paper's
    stall-cycle fix, consumed by :mod:`repro.core.simulator`);
  * **cross-shard replication** — groups whose copy count reaches the
    model-parallel degree are stored fully replicated instead of sharded,
    removing them from the all-to-all exchange of a distributed embedding
    lookup (consumed by :mod:`repro.dist.sharding`).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence

import numpy as np

from repro.core.grouping import Grouping


@dataclasses.dataclass
class ReplicationPlan:
    """Per-group replica counts and the area budget they consume.

    Attributes:
      copies: ``(num_groups,)`` int32 — number of *physical copies* of each
        group (>= 1; 1 means not replicated).
      duplication_ratio: extra area as a fraction of the unreplicated image
        (paper Fig. 10 sweeps 0/5/10/20 %).
      batch_size: the batch size Eq. 1 was evaluated with.
    """

    copies: np.ndarray
    duplication_ratio: float
    batch_size: int

    @property
    def num_groups(self) -> int:
        return int(self.copies.shape[0])

    @property
    def total_tiles(self) -> int:
        return int(self.copies.sum())

    def extra_tiles(self) -> int:
        return self.total_tiles - self.num_groups


def log_scaled_copies(
    group_freq: np.ndarray,
    batch_size: int,
    *,
    base_copies: int = 1,
    total: float | None = None,
) -> np.ndarray:
    """Eq. 1 of the paper, vectorized over groups.

    ``num_copies = floor(log(freq)/log(freq_total) * log(batch))`` *extra*
    copies on top of the mandatory one.  Groups with zero recorded accesses
    get the base copy only.

    ``total`` overrides the normalizing ``freq_total`` (default: the sum
    of ``group_freq``).  The online replanner passes the full segment's
    mass while evaluating Eq. 1 on only the drifted *subset* of groups —
    the copy count of group ``g`` depends on the rest of the table only
    through this total, so a subset evaluation with the full-table total
    is exact.
    """
    if batch_size < 1:
        raise ValueError("batch_size must be >= 1")
    freq = np.asarray(group_freq, dtype=np.float64)
    total = float(freq.sum()) if total is None else float(total)
    out = np.full(freq.shape, base_copies, dtype=np.int32)
    if total <= 1.0 or batch_size == 1:
        return out
    pos = freq >= 1.0
    scale = math.log(float(batch_size)) / math.log(float(total))
    extra = np.floor(np.log(np.maximum(freq, 1.0)) * scale).astype(np.int32)
    out[pos] += np.maximum(extra[pos], 0)
    return out


def linear_copies(group_freq: np.ndarray, batch_size: int) -> np.ndarray:
    """Baseline: naive frequency-proportional duplication (paper Fig. 5 left).

    Allocates copies proportional to raw frequency.  Under a power law this
    leaves "most crossbars unduplicated" while the head hoards copies —
    shown only as the ablation baseline.
    """
    freq = np.asarray(group_freq, dtype=np.float64)
    total = freq.sum()
    if total <= 0:
        return np.ones(freq.shape, dtype=np.int32)
    share = freq / total
    return (1 + np.floor(share * batch_size)).astype(np.int32)


def plan_replication(
    grouping: Grouping,
    freq: np.ndarray,
    batch_size: int,
    *,
    area_budget_ratio: float | None = None,
    scheme: str = "log",
) -> ReplicationPlan:
    """Builds the replication plan for a grouping.

    Args:
      grouping: output of the grouping pass.
      freq: per-row access frequency (graph.freq).
      batch_size: inference batch size (Eq. 1's ``batch``).
      area_budget_ratio: optional cap on extra area (paper Fig. 10's
        Dup-5%/10%/20%).  When set, extra copies are granted to the
        hottest groups first until the budget is exhausted.
      scheme: "log" (Eq. 1), "linear" (ablation baseline) or "none".

    Returns:
      A :class:`ReplicationPlan`.
    """
    gfreq = grouping.group_freq(np.asarray(freq))
    if scheme == "none":
        copies = np.ones(grouping.num_groups, dtype=np.int32)
    elif scheme == "log":
        copies = log_scaled_copies(gfreq, batch_size)
    elif scheme == "linear":
        copies = linear_copies(gfreq, batch_size)
    else:
        raise ValueError(f"unknown replication scheme {scheme!r}")

    if area_budget_ratio is not None:
        copies = _apply_area_budget(copies, gfreq, area_budget_ratio)

    ratio = float(copies.sum() - len(copies)) / max(len(copies), 1)
    return ReplicationPlan(copies=copies, duplication_ratio=ratio, batch_size=batch_size)


def _apply_area_budget(
    copies: np.ndarray, gfreq: np.ndarray, budget_ratio: float
) -> np.ndarray:
    """Clamps total extra copies to ``budget_ratio * num_groups``.

    Extra copies are granted in descending group-frequency order, one
    round-robin layer at a time, so the budget preferentially covers the
    hottest groups but never gives a group more than Eq. 1 asked for.
    """
    n = len(copies)
    budget = int(math.floor(budget_ratio * n))
    want_extra = np.maximum(copies - 1, 0)
    granted = np.zeros_like(want_extra)
    order = np.argsort(-gfreq, kind="stable")
    # layer-by-layer grant: first copy to all hot groups, then second, ...
    layer = 1
    while budget > 0 and (want_extra > granted).any():
        for g in order:
            if budget == 0:
                break
            if want_extra[g] >= layer and granted[g] < layer:
                granted[g] += 1
                budget -= 1
        layer += 1
    return (1 + granted).astype(np.int32)


def shard_replication_sets(
    plan: ReplicationPlan, model_parallelism: int
) -> np.ndarray:
    """Derives the cross-shard placement from a replication plan.

    Groups whose copy count is >= ``model_parallelism`` are flagged for
    full replication across model-parallel shards (they leave the
    all-to-all path entirely); the rest stay sharded.

    Returns:
      ``(num_groups,)`` bool — True where the group is replicated across
      shards.
    """
    return plan.copies >= max(model_parallelism, 2)
