"""Sharded, atomic, restartable checkpoints (no orbax dependency).

Layout on disk::

    <dir>/step_000123/
        manifest.json            # tree structure, shapes, dtypes, step
        host_000.npz             # this host's shard of every leaf
        ...
        COMMITTED                # written last — atomic-commit marker

Fault-tolerance contract:
  * save is crash-safe: a checkpoint without ``COMMITTED`` is ignored by
    :func:`latest_step` (a torn write never becomes the restore point);
  * each host writes only the leaf shards it owns (process-local npz), so
    saving scales with hosts and needs no coordinator;
  * restore re-shards onto the *current* mesh: leaves are re-assembled
    from host files and re-placed via ``jax.device_put`` with the target
    sharding — this is what makes elastic re-scaling (restore a 512-chip
    checkpoint on 256 chips) work;
  * async: ``save_async`` hands the host-transfer + write to a background
    thread and returns a handle; the train loop overlaps the next steps
    with the write and joins at the following save point.

In this single-process container every save is a single host file, but
the format and code paths are the multi-host ones.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p)
            for p in path
        )
        names.append(name)
        leaves.append(leaf)
    return names, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, host_index: int = 0) -> str:
    """Synchronous checkpoint save. Returns the committed directory."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)

    names, leaves, _ = _flatten_with_names(tree)
    arrays = {}
    meta = {"step": step, "leaves": []}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            arrays[name] = arr.view(np.uint16)
            dtype = "bfloat16"
        else:
            arrays[name] = arr
            dtype = str(arr.dtype)
        meta["leaves"].append({"name": name, "shape": list(arr.shape), "dtype": dtype})

    np.savez(os.path.join(tmp_dir, f"host_{host_index:03d}.npz"), **arrays)
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp_dir, "COMMITTED"), "w") as f:
        f.write(str(time.time()))
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    return step_dir


class AsyncSaveHandle:
    def __init__(self, thread: threading.Thread):
        self._thread = thread

    def wait(self):
        self._thread.join()

    @property
    def done(self) -> bool:
        return not self._thread.is_alive()


def save_async(ckpt_dir: str, step: int, tree: Any, *, host_index: int = 0) -> AsyncSaveHandle:
    """Snapshot to host memory now, write in the background."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    t = threading.Thread(
        target=save, args=(ckpt_dir, step, host_tree), kwargs={"host_index": host_index},
        daemon=True,
    )
    t.start()
    return AsyncSaveHandle(t)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "COMMITTED")):
                best = max(best or -1, int(d.split("_")[1]))
    return best


def restore(
    ckpt_dir: str,
    step: int,
    like: Any,
    *,
    shardings: Any = None,
) -> Any:
    """Restores into the structure of ``like``; re-shards if given shardings.

    ``like`` may contain arrays or ShapeDtypeStructs — only structure,
    shapes and dtypes are used.
    """
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        meta = json.load(f)
    dtype_of = {l["name"]: l["dtype"] for l in meta["leaves"]}

    stored: dict[str, np.ndarray] = {}
    for fname in sorted(os.listdir(step_dir)):
        if fname.startswith("host_") and fname.endswith(".npz"):
            with np.load(os.path.join(step_dir, fname)) as z:
                for k in z.files:
                    arr = z[k]
                    if dtype_of.get(k) == "bfloat16":
                        arr = arr.view(jnp.bfloat16)
                    stored[k] = arr

    names, leaves, treedef = _flatten_with_names(like)
    out = []
    shard_leaves = jax.tree.leaves(shardings) if shardings is not None else [None] * len(leaves)
    for name, leaf, shard in zip(names, leaves, shard_leaves):
        if name not in stored:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = stored[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != {leaf.shape}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr))
    return treedef.unflatten(out)
