from repro.train.optimizer import (
    AdamW,
    Adafactor,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
    make_schedule,
    wsd_schedule,
)
from repro.train.loop import TrainState, init_train_state, make_eval_step, make_train_step
from repro.train import checkpoint, compression, fault_tolerance

__all__ = [
    "AdamW", "Adafactor", "clip_by_global_norm", "cosine_schedule",
    "make_optimizer", "make_schedule", "wsd_schedule",
    "TrainState", "init_train_state", "make_eval_step", "make_train_step",
    "checkpoint", "compression", "fault_tolerance",
]
