"""Optimizers and LR schedules (no optax dependency — built on jax.tree).

* AdamW — fp32 moments, decoupled weight decay, global-norm clipping.
* Adafactor — factored second moment (PaLM-style), the default for ≥100 B
  configs so optimizer bytes/chip stay inside HBM (DESIGN.md §5).
* Schedules: cosine and WSD (warmup-stable-decay, MiniCPM's schedule).

Optimizer states are created with the same structure as params, so the
FSDP/ZeRO sharding rules in dist/sharding.py apply to them verbatim (the
launcher shards moments over the data axis).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# ------------------------------------------------------------ schedules --

def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def wsd_schedule(base_lr: float, warmup: int, stable: int, total: int) -> Callable:
    """Warmup-Stable-Decay (MiniCPM): flat plateau then sharp decay tail."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        decay_len = max(total - warmup - stable, 1)
        prog = jnp.clip((step - warmup - stable) / decay_len, 0.0, 1.0)
        decay = base_lr * (1.0 - prog) ** 2
        out = jnp.where(step < warmup, warm, base_lr)
        return jnp.where(step < warmup + stable, out, decay)
    return lr


def make_schedule(kind: str, base_lr: float, total: int, *, warmup: int = 0) -> Callable:
    warmup = warmup or max(total // 100, 10)
    if kind == "wsd":
        return wsd_schedule(base_lr, warmup, int(total * 0.8), total)
    return cosine_schedule(base_lr, warmup, total)


# ---------------------------------------------------------------- AdamW --

class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(self, grads, state: AdamWState, params) -> Tuple[Any, AdamWState]:
        grads = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        lr = self.schedule(step)
        b1, b2 = self.b1, self.b2

        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype), state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)),
            state.nu, grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * p.astype(m.dtype)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


# ------------------------------------------------------------ Adafactor --

class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any      # row second-moment factors (or full v for <2D leaves)
    vc: Any      # col factors (zeros for <2D leaves)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    """Factored AdaGrad-style optimizer (Shazeer & Stern), momentum-free.

    Second moment of an (r, c) matrix is stored as (r,) + (c,) factors —
    O(r+c) instead of O(r·c); >2-D leaves factor over the trailing two
    dims.  This is what makes the 314 B grok config's optimizer fit.
    """

    schedule: Callable
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def init(self, params) -> AdafactorState:
        def vr_init(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc_init(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)

        return AdafactorState(
            step=jnp.zeros((), jnp.int32),
            vr=jax.tree.map(vr_init, params),
            vc=jax.tree.map(vc_init, params),
        )

    def update(self, grads, state: AdafactorState, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-self.decay)
        lr = self.schedule(step)

        def upd(p, g, vr, vc):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            if p.ndim >= 2:
                vr = beta * vr + (1 - beta) * g2.mean(axis=-1)
                vc = beta * vc + (1 - beta) * g2.mean(axis=-2)
                denom = vr.mean(axis=-1, keepdims=True)[..., None]
                prec = (vr[..., None] / denom) * vc[..., None, :]
                u = g * jax.lax.rsqrt(prec + self.eps)
            else:
                vr = beta * vr + (1 - beta) * g2
                u = g * jax.lax.rsqrt(vr + self.eps)
                vc = vc
            # update clipping (RMS(u) <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + self.eps)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            newp = p.astype(jnp.float32) - lr * (u + self.weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), vr, vc

        flat_p, tree = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_vr = jax.tree.leaves(state.vr)
        flat_vc = jax.tree.leaves(state.vc)
        outs = [upd(p, g, vr, vc) for p, g, vr, vc in zip(flat_p, flat_g, flat_vr, flat_vc)]
        new_p = tree.unflatten([o[0] for o in outs])
        new_vr = tree.unflatten([o[1] for o in outs])
        new_vc = tree.unflatten([o[2] for o in outs])
        return new_p, AdafactorState(step=step, vr=new_vr, vc=new_vc)


# ---------------------------------------------------------------- utils --

def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)


def make_optimizer(kind: str, schedule: Callable, **kw):
    if kind == "adamw":
        return AdamW(schedule=schedule, **kw)
    if kind == "adafactor":
        return Adafactor(schedule=schedule, **kw)
    raise ValueError(f"unknown optimizer {kind!r}")
