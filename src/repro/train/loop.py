"""Train-step builders: value_and_grad + optimizer + microbatching.

``make_train_step`` returns the pure function the launcher pjits.  The
global batch is optionally split into microbatches accumulated with
``lax.scan`` (grad accumulation) — the standard memory lever when the
per-device activation footprint of train_4k exceeds HBM; remat of layer
bodies is the second lever (forwarded into the model).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import lm_loss
from repro.train.optimizer import AdamW, Adafactor


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def init_train_state(params, optimizer) -> TrainState:
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        step=jnp.zeros((), jnp.int32),
    )


def make_train_step(
    cfg: ModelConfig,
    optimizer,
    *,
    remat: bool = False,
    microbatches: int = 1,
    has_enc: bool = False,
    accum_dtype=jnp.float32,
) -> Callable:
    """Builds train_step(state, batch) -> (state, metrics).

    batch = {"tokens": ..., "labels": ...[, "enc": ...]}; the leading batch
    dim must be divisible by ``microbatches``.
    """

    def loss_fn(params, tokens, labels, enc):
        return lm_loss(params, cfg, tokens, labels, enc=enc, remat=remat)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, dict]:
        tokens, labels = batch["tokens"], batch["labels"]
        enc = batch.get("enc") if has_enc else None

        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, tokens, labels, enc)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:])

            mb = jax.tree.map(split, {"tokens": tokens, "labels": labels})
            enc_mb = split(enc) if enc is not None else None

            def acc(carry, idx_mb):
                loss_acc, grads_acc = carry
                tk, lb = idx_mb["tokens"], idx_mb["labels"]
                ec = idx_mb.get("enc")
                l, g = jax.value_and_grad(loss_fn)(state.params, tk, lb, ec)
                return (
                    loss_acc + l / microbatches,
                    jax.tree.map(
                        lambda a, b: a + (b / microbatches).astype(a.dtype),
                        grads_acc, g,
                    ),
                ), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), state.params)
            xs = dict(mb)
            if enc_mb is not None:
                xs["enc"] = enc_mb
            (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros((), jnp.float32), zeros), xs)

        new_params, new_opt = optimizer.update(grads, state.opt_state, state.params)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
        ))
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_eval_step(cfg: ModelConfig, *, has_enc: bool = False) -> Callable:
    def eval_step(params, batch):
        enc = batch.get("enc") if has_enc else None
        return lm_loss(params, cfg, batch["tokens"], batch["labels"], enc=enc)

    return eval_step
