"""Error-feedback gradient compression for the slow (cross-pod) axis.

At multi-pod scale the data-center interconnect between pods is an order
of magnitude slower than intra-pod ICI, so cross-pod gradient all-reduce
gets compressed: int8 quantization with per-leaf scale and *error
feedback* (the quantization residual is added back into the next step's
gradient), which keeps SGD convergence unbiased in practice.

The compressor is a pure function pair so it drops into the pjit'd train
step: ``compress`` before the pod-axis psum, ``decompress`` after;
the error-feedback buffer rides in the train state.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any   # residual pytree, same structure as grads (f32)


def init_compression(grads_like) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def compress(grads, state: CompressionState) -> Tuple[Any, Any, CompressionState]:
    """Returns (int8 payload, scales, new_state). Residual goes to state."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        err = gf - q.astype(jnp.float32) * scale
        return q, scale, err

    qs, scales, errs = [], [], []
    flat, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(state.error)
    for g, e in zip(flat, flat_e):
        q, s, err = one(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(err)
    return (
        tree.unflatten(qs),
        tree.unflatten(scales),
        CompressionState(error=tree.unflatten(errs)),
    )


def decompress(payload, scales):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, payload, scales
    )


def compressed_bytes(grads) -> int:
    """Bytes on the wire after compression (for the roofline's pod axis)."""
    return sum(g.size for g in jax.tree.leaves(grads))  # int8: 1 B/elem


def raw_bytes(grads) -> int:
    return sum(g.size * g.dtype.itemsize for g in jax.tree.leaves(grads))
