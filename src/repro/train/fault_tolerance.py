"""Fault-tolerance runtime: heartbeats, stragglers, elastic re-meshing.

What actually runs on a 1000-node fleet and what is represented here:

  * **Heartbeat monitor** — every host appends ``(host, step, t)`` records;
    the monitor flags hosts whose last beat is older than ``timeout``.
    In production the transport is the cluster scheduler / etcd; here it
    is an in-process store with the same interface, unit-tested against
    simulated failures.
  * **Straggler mitigation** — per-step duration tracking with a robust
    z-score; hosts slower than ``threshold × median`` over a window are
    flagged for eviction (the data pipeline's statelessness makes eviction
    cheap: survivors re-derive the failed host's shard from seed+step).
  * **Elastic re-mesh** — on membership change, :func:`plan_remesh`
    computes the new mesh shape (largest (data × model) grid that fits
    the survivors, model axis preserved) and the restore path re-shards
    the last committed checkpoint onto it (checkpoint.restore handles the
    re-placement).
  * **Restart loop** — :func:`run_with_restarts` wraps a step function,
    catches failures, restores the latest checkpoint, and resumes; used
    by the end-to-end example and tested with injected faults.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class HeartbeatMonitor:
    timeout_s: float = 60.0
    _last: Dict[int, Tuple[int, float]] = dataclasses.field(default_factory=dict)

    def beat(self, host: int, step: int, t: Optional[float] = None) -> None:
        self._last[host] = (step, t if t is not None else time.time())

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        return [h for h, (_, t) in self._last.items() if now - t > self.timeout_s]

    def membership(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        return sorted(h for h, (_, t) in self._last.items() if now - t <= self.timeout_s)


@dataclasses.dataclass
class StragglerDetector:
    window: int = 32
    threshold: float = 2.0
    _durations: Dict[int, List[float]] = dataclasses.field(default_factory=dict)

    def record(self, host: int, duration_s: float) -> None:
        self._durations.setdefault(host, []).append(duration_s)
        if len(self._durations[host]) > self.window:
            self._durations[host].pop(0)

    def stragglers(self) -> List[int]:
        if not self._durations:
            return []
        meds = {h: float(np.median(d)) for h, d in self._durations.items() if d}
        overall = float(np.median(list(meds.values())))
        if overall <= 0:
            return []
        return sorted(h for h, m in meds.items() if m > self.threshold * overall)


def plan_remesh(
    n_hosts: int,
    chips_per_host: int,
    *,
    model_parallelism: int,
    pods: int = 1,
) -> Tuple[int, ...]:
    """Largest (pods, data, model) grid on the surviving chips.

    The model axis is preserved (params were sharded for that TP degree);
    data parallelism absorbs the loss.  Raises if fewer chips than one
    model replica remain.
    """
    chips = n_hosts * chips_per_host
    per_pod = chips // pods
    data = per_pod // model_parallelism
    if data < 1:
        raise RuntimeError(
            f"cannot re-mesh: {chips} chips < model_parallelism {model_parallelism}"
        )
    if pods > 1:
        return (pods, data, model_parallelism)
    return (data, model_parallelism)


def run_with_restarts(
    step_fn: Callable[[int, object], object],
    init_state: object,
    num_steps: int,
    *,
    save_fn: Callable[[int, object], None],
    restore_fn: Callable[[], Tuple[int, object]],
    save_every: int = 10,
    max_restarts: int = 5,
) -> Tuple[object, Dict]:
    """Drives step_fn with checkpoint/restart on any exception.

    Returns (final_state, stats) where stats counts restarts and replayed
    steps — the integration test injects faults and asserts the final
    state matches an uninterrupted run (determinism contract).
    """
    stats = {"restarts": 0, "replayed_steps": 0}
    state = init_state
    step = 0
    restarts = 0
    while step < num_steps:
        try:
            state = step_fn(step, state)
            step += 1
            if step % save_every == 0 or step == num_steps:
                save_fn(step, state)
        except Exception:
            restarts += 1
            stats["restarts"] = restarts
            if restarts > max_restarts:
                raise
            restored_step, state = restore_fn()
            stats["replayed_steps"] += step - restored_step if step > restored_step else 0
            step = restored_step
    return state, stats
