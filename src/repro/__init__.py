"""repro — ReCross (ReRAM-crossbar embedding reduction) re-built as a
production JAX/Pallas framework for TPU.  See DESIGN.md."""

__version__ = "0.1.0"
