"""MusicGen-medium [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens.

4 parallel codebooks (vocab 2048 each) summed at input, 4 LM heads out.
The EnCodec frontend is a STUB: tokens arrive as (b, 4, s) int32.
"""

import dataclasses

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    num_codebooks=4,
    norm="layernorm",
    act="gelu",
    use_bias=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=64, num_heads=4, kv_heads=4,
        d_ff=128, vocab_size=64, num_codebooks=2, dtype="float32",
    )
