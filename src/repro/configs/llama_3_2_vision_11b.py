"""Llama-3.2-Vision-11B [hf:meta-llama; unverified] — cross-attn image layers.

40 layers = 8 superblocks of (4 self-attn + 1 gated cross-attn).  The
vision frontend is a STUB: ``input_specs()`` supplies precomputed patch
embeddings (b, num_image_tokens, d_model).
"""

import dataclasses

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    kv_heads=8,
    d_ff=14_336,
    vocab_size=128_256,
    cross_attn_period=4,      # 4 self layers per cross layer
    num_image_tokens=1601,    # 448px / 14 patches + cls, one tile
    rope_theta=5e5,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=5, d_model=64, num_heads=4, kv_heads=2,
        d_ff=192, vocab_size=256, cross_attn_period=4, num_image_tokens=16,
        dtype="float32",
    )
