"""MiniCPM-2B [arXiv:2404.06395; hf] — dense llama-like, WSD schedule."""

import dataclasses

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    schedule="wsd",          # MiniCPM's warmup-stable-decay schedule
    tie_embeddings=True,     # MiniCPM ties input/output embeddings
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=64, num_heads=4, kv_heads=4,
        d_ff=160, vocab_size=256, dtype="float32",
    )
