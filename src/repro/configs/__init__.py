from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    get_config,
    list_configs,
    supported_shapes,
)

__all__ = [
    "ARCH_IDS", "SHAPES", "ModelConfig", "MoEConfig", "ShapeConfig",
    "get_config", "list_configs", "supported_shapes",
]
