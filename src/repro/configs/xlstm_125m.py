"""xLSTM-125M [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks, no FFN.

Linear recurrence → sub-quadratic: runs the long_500k cell.
"""

import dataclasses

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    kv_heads=4,
    d_ff=0,                   # xLSTM blocks carry their own projections
    vocab_size=50_304,
    slstm_every=6,            # sLSTM at layers 0 and 6, mLSTM elsewhere
    subquadratic=True,
    norm="layernorm",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=4, d_model=64, num_heads=2, kv_heads=2,
        vocab_size=256, slstm_every=2, dtype="float32",
    )
