"""ChatGLM3-6B [arXiv:2406.12793; hf] — dense, GQA kv=2, RoPE-2d (partial)."""

import dataclasses

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    kv_heads=2,
    d_ff=13_696,
    vocab_size=65_024,
    rope_2d=True,            # GLM rotary on half the head dims
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=64, num_heads=4, kv_heads=2,
        d_ff=192, vocab_size=256, dtype="float32",
    )
