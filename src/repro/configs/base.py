"""Config schema: one dataclass describes every supported architecture.

Each ``src/repro/configs/<arch>.py`` exports

  * ``FULL``  — the exact published configuration (dry-run only; params are
    never materialized, only ``jax.eval_shape``-d),
  * ``smoke()`` — a reduced same-family config that trains one step on CPU,
  * the shared shape table (``SHAPES``) is defined here.

The registry (:func:`get_config`, :func:`list_configs`) is what
``--arch <id>`` resolves through in the launchers.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # capacity factor for dense dispatch (tokens per expert = tokens/E * cf)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description (decoder LM family unless noted)."""

    name: str
    family: str                  # dense | moe | ssm | vlm | hybrid | audio | recsys
    num_layers: int
    d_model: int
    num_heads: int
    kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0            # 0 → d_model // num_heads
    moe: Optional[MoEConfig] = None
    ssm_state: int = 0           # Mamba2 state dim (hybrid/ssm)
    rope_theta: float = 10_000.0
    rope_2d: bool = False        # ChatGLM-style: rotary on half the head dims
    use_bias: bool = False
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "swiglu"          # swiglu | geglu | gelu
    tie_embeddings: bool = False
    # vlm: cross-attention every `cross_attn_period` layers
    cross_attn_period: int = 0
    num_image_tokens: int = 0    # vlm stub frontend output length
    # audio: number of parallel codebooks (musicgen)
    num_codebooks: int = 0
    # hybrid (zamba): shared attention block applied every `shared_attn_period`
    shared_attn_period: int = 0
    # xlstm: ratio of sLSTM blocks (rest mLSTM); 12L xlstm-125m uses blocks at [3]...
    slstm_every: int = 0
    # sub-quadratic attention available (gates long_500k)
    subquadratic: bool = False
    # MoE dispatch groups (1 = global cumsum; = data-parallel degree for
    # shard-local dispatch, see models/moe.py)
    moe_groups: int = 1
    # "gspmd": auto-partitioned dispatch; "shardmap": manual shard-local
    # dispatch with explicit FSDP weight gathering (see apply_moe_shardmap)
    moe_impl: str = "gspmd"
    # training schedule
    schedule: str = "cosine"     # cosine | wsd
    dtype: str = "bfloat16"

    # -- derived -----------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.kv_heads, 1)

    @property
    def jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a 256 multiple so the vocab axis shards over
        any TP degree up to 256 (standard embedding padding; the loss masks
        the padded tail)."""
        return ((self.vocab_size + 255) // 256) * 256

    def param_count(self) -> int:
        """Approximate parameter count (reported, and used for 6ND)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * hd * self.num_heads + 2 * d * hd * self.kv_heads + hd * self.num_heads * d
        if self.family == "ssm":
            per_layer = 8 * d * d // 2  # xlstm-ish blocks
        elif self.family == "hybrid":
            dm = 2 * self.d_model
            per_layer = 2 * d * dm + dm * d  # mamba in/out proj (approx)
        else:
            per_layer = attn
        if self.moe:
            ff = 3 * d * self.d_ff * self.moe.num_experts + d * self.moe.num_experts
        elif self.d_ff and self.family != "hybrid":
            ff = 3 * d * self.d_ff
        else:
            ff = 0  # ssm/hybrid blocks carry their own projections (no FFN)
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.num_codebooks:
            emb = self.num_codebooks * V * d + self.num_codebooks * V * d
        return L * (per_layer + ff) + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        full_ff = 3 * d * self.d_ff * self.moe.num_experts
        act_ff = 3 * d * self.d_ff * self.moe.top_k
        return self.param_count() - L * (full_ff - act_ff)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "minicpm-2b",
    "stablelm-3b",
    "chatglm3-6b",
    "command-r-35b",
    "grok-1-314b",
    "granite-moe-3b-a800m",
    "xlstm-125m",
    "llama-3.2-vision-11b",
    "zamba2-7b",
    "musicgen-medium",
]

_MODULE_OF = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}
_MODULE_OF["dlrm-recross"] = "dlrm_recross"


def get_config(arch: str, *, smoke: bool = False):
    """Resolves ``--arch`` ids to (ModelConfig | DLRMConfig)."""
    if arch not in _MODULE_OF:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULE_OF)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_OF[arch]}")
    return mod.smoke() if smoke else mod.FULL


def list_configs() -> list[str]:
    return list(_MODULE_OF)


def supported_shapes(cfg: ModelConfig) -> list[str]:
    """Shape cells defined for this arch (long_500k only if sub-quadratic)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")
    return out
