"""Granite-MoE 3B-a800m [hf:ibm-granite; hf] — MoE 40 experts top-8."""

import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    kv_heads=8,
    d_ff=512,                 # per-expert FFN width
    vocab_size=49_155,
    moe=MoEConfig(num_experts=40, top_k=8),
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=64, num_heads=4, kv_heads=2,
        d_ff=64, vocab_size=256, moe=MoEConfig(num_experts=8, top_k=2),
        dtype="float32",
    )
