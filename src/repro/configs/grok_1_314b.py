"""Grok-1 314B [hf:xai-org/grok-1; unverified] — MoE 8 experts top-2, GQA kv=8."""

import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    kv_heads=8,
    d_ff=32_768,
    vocab_size=131_072,
    moe=MoEConfig(num_experts=8, top_k=2),
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=64, num_heads=4, kv_heads=2,
        d_ff=128, vocab_size=256, moe=MoEConfig(num_experts=4, top_k=2),
        dtype="float32",
    )
