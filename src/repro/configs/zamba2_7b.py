"""Zamba2-7B [arXiv:2411.15242; unverified] — Mamba2 + shared attention.

81 layers = 13 superblocks of 6 Mamba2 layers + 1 SHARED attention block
application (single param copy) + 3 tail Mamba2 layers.  Recurrent
backbone + windowed shared attention → sub-quadratic: runs long_500k.
"""

import dataclasses

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    kv_heads=32,
    d_ff=14_336,              # (unused by mamba blocks; kept for reporting)
    vocab_size=32_000,
    ssm_state=64,
    shared_attn_period=6,
    subquadratic=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=5, d_model=64, num_heads=4, kv_heads=4,
        d_ff=0, vocab_size=256, ssm_state=16, shared_attn_period=2,
        dtype="float32",
    )
