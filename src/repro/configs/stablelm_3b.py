"""StableLM-3B [hf:stabilityai/stablelm-2-1_6b family; unverified] — dense."""

import dataclasses

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    kv_heads=32,
    d_ff=6912,
    vocab_size=50_304,
    norm="layernorm",        # StableLM uses LayerNorm
    use_bias=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=64, num_heads=4, kv_heads=4,
        d_ff=192, vocab_size=256, dtype="float32",
    )
