"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01; unverified] — GQA, no-bias."""

import dataclasses

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    kv_heads=8,
    d_ff=22_528,
    vocab_size=256_000,
    use_bias=False,
    norm="layernorm",        # Cohere uses LayerNorm (no bias)
    rope_theta=8e6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=128, num_heads=8, kv_heads=2,
        d_ff=320, vocab_size=512, dtype="float32",
    )
