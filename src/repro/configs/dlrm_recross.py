"""DLRM with ReCross embedding reduction — the paper's own workload."""

import dataclasses

from repro.models.dlrm import DLRMConfig

FULL = DLRMConfig(
    name="dlrm-recross",
    num_tables=8,
    rows_per_table=932_019,     # automotive (paper Table I)
    embed_dim=64,
    dense_features=13,
    bottom_mlp=(512, 256, 64),
    top_mlp=(1024, 512, 1),
    max_bag=64,
    group_size=64,
)


def smoke() -> DLRMConfig:
    return dataclasses.replace(
        FULL, num_tables=2, rows_per_table=2048, embed_dim=128,
        bottom_mlp=(64, 128), top_mlp=(64, 1), max_bag=16,
        group_size=16,
    )
