"""Sharding rules: logical axes, spec derivation, sanitization, contexts.

Every tensor in the codebase is annotated with *logical* axis names
("batch", "mlp", "vocab", ...).  A rule table maps logical names to mesh
axes; specs derived from the table are *sanitized* against the actual
array shapes (an axis that does not divide evenly falls back to
replicated) so one rule table serves every arch × shape cell.

Three layers:

  * **rule tables** — :data:`LOGICAL_RULES_SINGLE_POD` (16×16 data×model)
    and :data:`LOGICAL_RULES_MULTI_POD` (2×16×16 pod×data×model; the batch
    axis spans both pod and data).
  * **activation constraints** — :func:`maybe_shard` /
    :func:`maybe_shard_any` apply ``with_sharding_constraint`` *only*
    inside an :func:`activation_sharding_ctx`; outside a context they are
    identity, so model code carries its sharding annotations everywhere
    (unit tests, single device, 512-chip dry-run) without branching.
  * **parameter specs** — :func:`param_specs_for` derives a PartitionSpec
    tree from parameter *names* (the stable contract of the model zoo:
    ``wq/wk/wv/in_gate/w_gate/w_val`` are in-projections sharded
    (fsdp, tp); ``wo/w_out/out/down`` are out-projections sharded
    (tp, fsdp); ``embed``/``lm_head`` shard the vocab over model; norms,
    biases, scalar gates and routers replicate).
"""

from __future__ import annotations

import contextlib
import math
import threading
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Rules = Dict[str, Any]  # logical axis name -> mesh axis | tuple | None

# ---------------------------------------------------------------- rules --

_COMMON_RULES: Rules = {
    # activations
    "batch": "data",
    "seq": None,
    "embed": None,          # residual stream stays unsharded within a shard
    "expert_cap_dp": "data",
    # tensor parallelism
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "qgroups": "model",
    "vocab": "model",
    # parameters
    "fsdp": "data",
    # axes that never shard on these meshes
    "experts": None,
    "stage": None,
}

LOGICAL_RULES_SINGLE_POD: Rules = dict(_COMMON_RULES)

LOGICAL_RULES_MULTI_POD: Rules = dict(
    _COMMON_RULES,
    batch=("pod", "data"),
    expert_cap_dp=("pod", "data"),
)


def logical_to_spec(axes: Sequence[Optional[str]], rules: Rules) -> P:
    """Translates a tuple of logical axis names into a PartitionSpec."""
    return P(*(rules.get(a) if a is not None else None for a in axes))


# ----------------------------------------------------------- sanitation --


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    # works for jax.sharding.Mesh and for test fakes carrying
    # .axis_names + .devices (an ndarray whose shape is the mesh shape)
    return dict(zip(mesh.axis_names, np.shape(mesh.devices)))


def sanitize_spec(spec: P, shape: Sequence[int], mesh) -> P:
    """Drops spec entries whose mesh-axis product does not divide the dim.

    Keeps the spec length (``P("model", None)`` sanitizes to
    ``P(None, None)``, not ``P()``), so specs stay positionally aligned
    with the array rank they were written for.  A part naming a mesh
    axis the mesh does not carry (e.g. ``("pod", "data")`` on a
    single-pod mesh) is dropped too — treating an unknown axis as size 1
    would let an invalid spec through to ``with_sharding_constraint``.
    """
    sizes = _mesh_axis_sizes(mesh)
    out = []
    for d, part in enumerate(spec):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        known = all(a in sizes for a in axes)
        n = math.prod(sizes.get(a, 1) for a in axes)
        ok = known and d < len(shape) and n > 0 and shape[d] % n == 0
        out.append(part if ok else None)
    return P(*out)


def sanitize_specs_tree(specs, avals, mesh):
    """Tree-maps :func:`sanitize_spec` over a (specs, avals) pair."""
    return jax.tree.map(
        lambda s, a: sanitize_spec(s, a.shape, mesh),
        specs,
        avals,
        is_leaf=lambda x: isinstance(x, P),
    )


# -------------------------------------------------- activation context --

_CTX = threading.local()


def _current() -> Tuple[Optional[Rules], Any]:
    """(rules, mesh) of the innermost activation context, (None, None) outside."""
    return getattr(_CTX, "state", (None, None))


@contextlib.contextmanager
def activation_sharding_ctx(mesh, rules: Rules):
    """Installs (mesh, rules) so :func:`maybe_shard` becomes active."""
    prev = _current()
    _CTX.state = (rules, mesh)
    try:
        yield
    finally:
        _CTX.state = prev


def maybe_shard(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Constrains ``x`` to the logical ``axes`` — identity outside a context."""
    rules, mesh = _current()
    if mesh is None:
        return x
    spec = sanitize_spec(logical_to_spec(axes, rules), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def maybe_shard_any(
    x: jax.Array, candidates: Iterable[Sequence[Optional[str]]]
) -> jax.Array:
    """First candidate whose spec survives sanitization intact wins.

    Candidates are tried in order; one whose every requested axis divides
    the shape is applied.  If none fully applies, ``x`` is returned
    unconstrained (the conservative fallback — never a wrong sharding).
    """
    rules, mesh = _current()
    if mesh is None:
        return x
    for axes in candidates:
        spec = logical_to_spec(axes, rules)
        san = sanitize_spec(spec, x.shape, mesh)
        if san == spec:
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, san))
    return x


# ------------------------------------------------------- parameter specs --

# name-pattern contract of the model zoo (exact leaf-name match):
#   in-projections  (..., d_in, d_out): fsdp on d_in, tp on d_out
#   out-projections (..., d_out, d_in): tp on d_out, fsdp on d_in
_IN_PROJ_NAMES = frozenset(
    {"wq", "wk", "wv", "wqkv", "qkv", "in_gate", "in", "up",
     "w_gate", "w_val", "w_in", "wi"}
)
_OUT_PROJ_NAMES = frozenset({"wo", "w_out", "out", "down"})


def _leaf_name(path) -> str:
    for p in reversed(path):
        key = getattr(p, "key", None)
        if key is not None:
            return str(key)
    return ""


def param_specs_for(params, rules: Rules, *, moe: bool = False):
    """PartitionSpec tree for a parameter tree, from leaf names alone.

    ``moe`` is accepted for call-site clarity; expert tensors are already
    covered by the name patterns (``w_gate``/``w_val``/``w_out`` with a
    leading expert dim that maps to the "experts" rule, None on these
    meshes) and routers replicate.
    """
    del moe  # name patterns cover the expert layout
    fsdp = rules.get("fsdp", "data")
    tp = rules.get("mlp", "model")
    vocab = rules.get("vocab", "model")

    def spec(path, leaf) -> P:
        name = _leaf_name(path)
        rank = len(leaf.shape)
        if rank < 2:
            return P()
        lead = [None] * (rank - 2)
        if name in _IN_PROJ_NAMES:
            return P(*lead, fsdp, tp)
        if name in _OUT_PROJ_NAMES:
            return P(*lead, tp, fsdp)
        if name == "embed":
            return P(*lead, vocab, fsdp)
        if name == "lm_head":
            return P(*lead, fsdp, vocab)
        return P()

    return jax.tree_util.tree_map_with_path(spec, params)
