"""Shard planner: partition the crossbar image over the ``model`` axis.

This is the placement half of the sharded serving datapath documented in
DESIGN.md §4.  A single device cannot hold the replicated crossbar image
for many DLRM tables at production scale, so the image must shard across
the model mesh axis *without* giving back the per-shard DMA amortization
of the query-blocked kernel.  The planner decides, per group (and per
table — multiple tables fuse into one tile id space):

  * **replicated-everywhere** — hot groups whose Eq.-1 log-scaled copy
    count ``floor(log(freq_g)/log(freq_total) · log(batch))`` reaches
    the shard count (:func:`repro.core.replication.
    shard_replication_sets`) are stored on *every* shard.  Their
    activations never cross shards; ownership round-robins over blocks
    so the hottest work spreads across the mesh.
  * **sharded-once** — every other group lives on exactly one shard
    (all of its intra-shard replica tiles move together, so replica
    balancing keeps working shard-locally).  Assignment is greedy
    frequency-balanced: descending group load, least-loaded shard
    first, ties to the lowest shard id — deterministic.

The plan's unit is the **fused tile space**: table *t*'s physical tiles
occupy ``[tile_offset[t], tile_offset[t] + num_tiles_t)``, so one shard
map, one stacked shard image, and one kernel invocation serve every
table at once.  Consumed by
:func:`repro.core.reduction.shard_block_queries` (per-shard block
compiler) and :mod:`repro.kernels.sharded` (the shard_map reduction).

Plans are not immutable at serve time: :mod:`repro.dist.replan` edits
the placement arrays *incrementally* when serve-time access frequencies
drift (DESIGN.md §6).  The fields a patch may touch and the fields that
stay frozen are spelled out there.

**Tiered storage** (DESIGN.md §9): when ``plan_shards`` is given a
``capacity_tiles`` budget, the shard images become a *hot tier* — a
capacity-bounded cache over the host-resident fused master image.  Only
the hottest groups (by load, greedy while the per-shard budget lasts)
are planned resident; the rest are **cold**: ``shard_of_group`` /
``shard_of_tile`` hold the :data:`COLD` sentinel (-2) and no shard
allocates a local slot.  Cold groups are served by the host gather+sum
fallback and can be paged in later by :mod:`repro.dist.replan`
fetch/evict patches.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.core.mapping import CrossbarLayout
from repro.core.progress import StageProgress
from repro.core.replication import (
    ReplicationPlan,
    log_scaled_copies,
    shard_replication_sets,
)

# ``shard_of_group`` / ``shard_of_tile`` sentinel for groups outside the
# hot tier (host-resident only).  Distinct from -1 (replicated on every
# shard): -1 tiles are held everywhere, COLD tiles are held nowhere.
COLD = -2


@dataclasses.dataclass
class TableSegment:
    """One table's slice of the fused group/tile id spaces."""

    name: str
    group_offset: int
    tile_offset: int
    num_groups: int
    num_tiles: int
    tile_rows: int

    @property
    def tile_end(self) -> int:
        """One past the segment's last fused tile id."""
        return self.tile_offset + self.num_tiles


@dataclasses.dataclass
class ShardPlan:
    """Placement of every fused group/tile onto ``num_shards`` shards.

    Attributes:
      num_shards: model-parallel degree the plan was built for.
      tables: per-table segments of the fused id spaces, in input order.
      replicated_group: ``(G,)`` bool — True where the group is stored on
        every shard (fused group ids).
      shard_of_group: ``(G,)`` int32 — owning shard, -1 for replicated,
        :data:`COLD` (-2) for groups outside the hot tier (host-only).
      shard_of_tile: ``(T,)`` int32 — owning shard per fused physical
        tile, -1 for replicated (consumed as the ownership rule by the
        block compiler), :data:`COLD` for host-only tiles.
      local_tile_of: ``(num_shards, T)`` int32 — fused tile id → local
        tile id on that shard, -1 where the shard does not hold the tile.
      local_num_tiles: ``(num_shards,)`` — tiles resident per shard
        (sharded-owned + replicated).
      group_load: ``(G,)`` float64 — the load metric the placement was
        balanced for.  After an online replan this is the drifted
        snapshot the patch was computed on.
      group_copies: ``(G,)`` int64 — intra-shard replica tiles per fused
        group (frozen: physical tiles never change at serve time).
        Group ``g``'s fused tiles are the contiguous range starting at
        ``cumsum(group_copies)[g-1]`` — the layout invariant
        :func:`plan_shards` pins.  Consumed by
        :func:`repro.dist.replan.compute_plan_patch`.
      capacity_tiles: per-shard hot-tier budget the plan was built under
        (None: unbounded — every group resident, no cold tier).
    """

    num_shards: int
    tables: List[TableSegment]
    replicated_group: np.ndarray
    shard_of_group: np.ndarray
    shard_of_tile: np.ndarray
    local_tile_of: np.ndarray
    local_num_tiles: np.ndarray
    group_load: np.ndarray
    group_copies: np.ndarray | None = None
    capacity_tiles: int | None = None

    @property
    def num_groups(self) -> int:
        """Fused group count ``G`` across all tables."""
        return int(self.replicated_group.shape[0])

    @property
    def num_tiles(self) -> int:
        """Fused physical tile count ``T`` across all tables."""
        return int(self.shard_of_tile.shape[0])

    @property
    def max_local_tiles(self) -> int:
        """Stacked per-shard image depth (highest local tile id + 1).

        For a fresh plan local numbering is dense, so this equals
        ``local_num_tiles.max()``; after incremental patches a shard's
        numbering may contain holes (freed slots), so the depth is the
        highest *allocated* slot, not the resident count.
        """
        if self.local_tile_of.size == 0:
            return 0
        return int(self.local_tile_of.max(initial=-1)) + 1

    @property
    def replicated_tiles(self) -> int:
        """Fused tiles stored on every shard."""
        return int((self.shard_of_tile == -1).sum())

    @property
    def resident_group(self) -> np.ndarray:
        """``(G,)`` bool — True where the group is in the hot tier
        (replicated or sharded-once); False for cold (host-only)."""
        return self.shard_of_group != COLD

    @property
    def cold_groups(self) -> np.ndarray:
        """Fused group ids outside the hot tier (host-resident only)."""
        return np.nonzero(self.shard_of_group == COLD)[0]

    @property
    def cold_tiles(self) -> int:
        """Fused tiles outside the hot tier (host-resident only)."""
        return int((self.shard_of_tile == COLD).sum())

    def shard_tiles(self, shard: int) -> np.ndarray:
        """Fused tile ids resident on ``shard``, in local-tile order."""
        resident = np.nonzero(self.local_tile_of[shard] >= 0)[0]
        order = np.argsort(self.local_tile_of[shard][resident], kind="stable")
        return resident[order].astype(np.int64)

    def build_shard_images(self, fused_image: np.ndarray) -> np.ndarray:
        """Stacks per-shard local images from the fused device image.

        Args:
          fused_image: ``(num_tiles, tile_rows, dim)`` — per-table images
            concatenated on the tile axis (see :func:`build_fused_image`).

        Returns:
          ``(num_shards, max_local_tiles, tile_rows, dim)`` — shard s's
          resident tiles at their local ids; unallocated slots (trailing
          padding, and holes left by replan demotions) are zero, so a
          stray access contributes nothing to a sum (the same contract
          as padding slots inside a tile).
        """
        if fused_image.shape[0] != self.num_tiles:
            raise ValueError(
                f"fused image has {fused_image.shape[0]} tiles, plan has "
                f"{self.num_tiles}"
            )
        tile_rows, dim = fused_image.shape[1], fused_image.shape[2]
        out = np.zeros(
            (self.num_shards, self.max_local_tiles, tile_rows, dim),
            dtype=fused_image.dtype,
        )
        for s in range(self.num_shards):
            tiles = self.shard_tiles(s)
            # scatter to the allocated slots, NOT 0..n-1: a patched
            # plan's local numbering may contain holes
            out[s, self.local_tile_of[s][tiles]] = fused_image[tiles]
        return out

    def memory_summary(self) -> dict:
        """Tile residency accounting (replication overhead of the plan)."""
        cold = self.cold_tiles
        sharded_tiles = self.num_tiles - self.replicated_tiles - cold
        stored = sharded_tiles + self.replicated_tiles * self.num_shards
        return {
            "num_tiles": self.num_tiles,
            "replicated_tiles": self.replicated_tiles,
            "cold_tiles": cold,
            "cold_groups": int((self.shard_of_group == COLD).sum()),
            "capacity_tiles": self.capacity_tiles,
            "resident_tile_fraction":
                (self.num_tiles - cold) / max(self.num_tiles, 1),
            "stored_tiles": stored,
            "storage_ratio": stored / max(self.num_tiles, 1),
            "local_num_tiles": self.local_num_tiles.tolist(),
            "max_local_tiles": self.max_local_tiles,
        }


def _fuse_segments(
    names: Sequence[str], layouts: Sequence[CrossbarLayout]
) -> List[TableSegment]:
    segs: List[TableSegment] = []
    g_off = t_off = 0
    tile_rows = layouts[0].tile_rows
    for name, layout in zip(names, layouts):
        if layout.tile_rows != tile_rows:
            raise ValueError(
                f"table {name!r} tile_rows={layout.tile_rows} != {tile_rows}; "
                "fused serving requires a uniform crossbar height"
            )
        segs.append(TableSegment(
            name=name, group_offset=g_off, tile_offset=t_off,
            num_groups=layout.num_groups, num_tiles=layout.num_tiles,
            tile_rows=tile_rows,
        ))
        g_off += layout.num_groups
        t_off += layout.num_tiles
    return segs


def plan_shards(
    layouts: Sequence[CrossbarLayout],
    plans: Sequence[ReplicationPlan],
    num_shards: int,
    *,
    names: Sequence[str] | None = None,
    group_freqs: Sequence[np.ndarray] | None = None,
    eq1_batch: int | None = None,
    capacity_tiles: int | None = None,
) -> ShardPlan:
    """Builds the shard placement for one or more tables.

    Args:
      layouts: per-table crossbar layouts (uniform ``tile_rows``).
      plans: per-table Eq.-1 replication plans (same order).  Besides the
        replicated-everywhere decision (see ``eq1_batch``), only the
        intra-shard replica *structure* (``copies`` per group) is read —
        physical tiles are frozen once the layout is built.
      names: optional table names for reporting (default ``t0..tN``).
      group_freqs: optional per-table per-group access frequencies used
        as the balancing load; falls back to Eq.-1 copy counts (which are
        log-frequency, so still hotness-ordered).
      eq1_batch: when set (requires ``group_freqs``), the
        replicated-everywhere set is *re-evaluated* from ``group_freqs``
        via Eq. 1's log-scaled copy count at this batch size instead of
        being read off the offline ``plans``.  This is the from-scratch
        reference for online replanning (DESIGN.md §6): passing the
        drifted frequencies here must produce a plan whose served
        outputs the incremental patch path reproduces bit-for-bit.  With
        ``group_freqs`` equal to the training-time group frequencies and
        ``eq1_batch`` equal to the plans' ``batch_size``, the replicated
        set is identical to the default path (assuming the ``log``
        scheme with no area budget).
      capacity_tiles: optional per-shard hot-tier budget (in tiles).
        When set, placement walks groups in descending load and admits
        them while the budget lasts: a replicated group needs
        ``copies[g]`` free slots on *every* shard (else it degrades to
        sharded-once), a sharded-once group needs ``copies[g]`` free on
        some shard (else it is left **cold**: host-resident only,
        served by the gather+sum fallback until a replan patch pages it
        in).  None (the default) keeps the uncapped all-resident
        behavior bit-for-bit.

    Returns:
      A :class:`ShardPlan` over the fused group/tile spaces.
    """
    if num_shards < 1:
        raise ValueError("num_shards must be >= 1")
    if capacity_tiles is not None and capacity_tiles < 1:
        raise ValueError("capacity_tiles must be >= 1 (or None for uncapped)")
    if len(layouts) != len(plans) or not layouts:
        raise ValueError("need one replication plan per layout (>= 1 table)")
    if eq1_batch is not None and group_freqs is None:
        raise ValueError("eq1_batch re-evaluates Eq. 1 and needs group_freqs")
    if names is None:
        names = [f"t{i}" for i in range(len(layouts))]
    segs = _fuse_segments(names, layouts)

    G = sum(s.num_groups for s in segs)
    T = sum(s.num_tiles for s in segs)
    replicated = np.zeros(G, dtype=bool)
    load = np.zeros(G, dtype=np.float64)
    copies = np.zeros(G, dtype=np.int64)
    for i, (seg, layout, plan) in enumerate(zip(segs, layouts, plans)):
        gs = slice(seg.group_offset, seg.group_offset + seg.num_groups)
        # Eq.-1 cross-shard rule: copy count >= shard count → replicate;
        # with eq1_batch the copy count is recomputed from the supplied
        # (possibly drifted) frequencies instead of the offline plan
        if eq1_batch is not None:
            replicated[gs] = log_scaled_copies(
                np.asarray(group_freqs[i], dtype=np.float64), eq1_batch
            ) >= max(num_shards, 2)
        else:
            replicated[gs] = shard_replication_sets(plan, num_shards)
        copies[gs] = layout.copies
        # the fused tile space assumes each group's replica tiles are
        # contiguous in fused-group order (what build_layout emits and
        # build_fused_image concatenates) — pin it rather than trust it
        expect_base = np.zeros(seg.num_groups, dtype=np.int64)
        np.cumsum(layout.copies[:-1], out=expect_base[1:])
        if not np.array_equal(layout.tile_base, expect_base):
            raise ValueError(
                f"table {seg.name!r}: tile_base is not the contiguous "
                "cumsum-of-copies layout the fused tile space requires"
            )
        if group_freqs is not None:
            load[gs] = np.asarray(group_freqs[i], dtype=np.float64)
        else:
            load[gs] = plan.copies.astype(np.float64)

    # greedy frequency-balanced assignment of the sharded groups, in
    # descending load order (ties: fused id order, stable).  Loaded
    # groups go to the least-loaded shard (ties: fewest resident tiles,
    # then lowest id).  The ZERO-load cold tail — which contributes no
    # serving load but most of the image bytes — balances on tile count
    # instead: adding load 0 never moves a load-argmin, so load-first
    # placement would pile the entire cold tail onto one shard and
    # forfeit the memory relief that is half the point of sharding.
    # Cold groups sort last, so they also repair tile imbalance the hot
    # phase left behind.
    #
    # Under a capacity budget the same descending-load walk doubles as
    # the hot-tier admission policy: the hottest groups are admitted
    # until the per-shard budget runs out, everything after goes COLD.
    # Replicated admission charges every shard's budget (uncapped
    # placement deliberately does NOT count replicated tiles in the
    # tie-break totals — that behavior is preserved bit-for-bit).
    # plain Python lists in the sequential walk: per-step numpy scalar
    # indexing/compare dominates at 10⁵+ groups, list ops are ~5× faster
    # and bit-identical (Python floats ARE IEEE doubles)
    shard_of_group = np.full(G, -1, dtype=np.int32)
    shard_load = [0.0] * num_shards
    shard_tiles = [0] * num_shards
    order = np.argsort(-load, kind="stable")
    shard_ids = range(num_shards)
    cap = capacity_tiles
    load_l = load.tolist()
    copies_l = copies.tolist()
    repl_l = replicated.tolist()
    progress = StageProgress("placement", G, unit="groups")
    for done, g in enumerate(order.tolist()):
        if done & 0x3FFF == 0:
            progress.tick(done)
        c = copies_l[g]
        if repl_l[g]:
            if cap is not None:
                if max(shard_tiles) + c <= cap:
                    shard_tiles = [t + c for t in shard_tiles]
                else:
                    # no room on every shard: degrade to sharded-once
                    # (still hot — it gets the next-best residency)
                    replicated[g] = False
                    repl_l[g] = False
            if repl_l[g]:
                continue
        if cap is None:
            fits = shard_ids
        else:
            fits = [i for i in shard_ids if shard_tiles[i] + c <= cap]
            if not fits:
                shard_of_group[g] = COLD
                continue
        lg = load_l[g]
        if lg > 0:
            s = min(fits, key=lambda i: (shard_load[i], shard_tiles[i], i))
        else:
            s = min(fits, key=lambda i: (shard_tiles[i], i))
        shard_of_group[g] = s
        shard_load[s] += lg
        shard_tiles[s] += c
    progress.finish(G)

    # per-tile placement: a group's replica tiles travel with the group
    tile_group = np.repeat(np.arange(G, dtype=np.int64), copies)
    shard_of_tile = shard_of_group[tile_group].astype(np.int32)

    # local tile numbering: resident tiles in ascending fused id order
    local_tile_of = np.full((num_shards, T), -1, dtype=np.int32)
    local_num_tiles = np.zeros(num_shards, dtype=np.int64)
    for s in range(num_shards):
        resident = np.nonzero((shard_of_tile == s) | (shard_of_tile == -1))[0]
        local_tile_of[s, resident] = np.arange(resident.size, dtype=np.int32)
        local_num_tiles[s] = resident.size

    plan = ShardPlan(
        num_shards=num_shards,
        tables=segs,
        replicated_group=replicated,
        shard_of_group=shard_of_group,
        shard_of_tile=shard_of_tile,
        local_tile_of=local_tile_of,
        local_num_tiles=local_num_tiles,
        group_load=load,
        group_copies=copies,
        capacity_tiles=capacity_tiles,
    )
    # opt-in structural validation (RECROSS_VALIDATE=1, DESIGN.md §12);
    # lazy import: analysis imports this module at its own top level
    from repro.analysis.invariants import validation_enabled

    if validation_enabled():
        from repro.analysis.invariants import validate_plan

        validate_plan(plan)
    return plan


def build_fused_image(
    layouts: Sequence[CrossbarLayout], tables: Sequence[np.ndarray]
) -> np.ndarray:
    """Builds the concatenated multi-table device image.

    Args:
      layouts: per-table crossbar layouts, in the same order (and with
        the same uniform ``dim``) as passed to :func:`plan_shards`.
      tables: per-table logical ``(rows, dim)`` arrays.

    Returns:
      ``(Σ num_tiles, tile_rows, dim)`` — each table's permuted,
      replicated image (:meth:`CrossbarLayout.build_image`) reshaped to
      tile-major and concatenated on the tile axis, so fused tile id
      ``tile_offset[t] + k`` indexes table ``t``'s physical tile ``k``.
      This is also the host-resident master copy online replanning DMAs
      moved tiles from (DESIGN.md §6).
    """
    if len(layouts) != len(tables) or not layouts:
        raise ValueError("need one table per layout (>= 1 table)")
    dim = layouts[0].dim
    parts = []
    for layout, table in zip(layouts, tables):
        if layout.dim != dim:
            raise ValueError("fused serving requires a uniform embedding dim")
        parts.append(
            layout.build_image(np.asarray(table))
            .reshape(layout.num_tiles, layout.tile_rows, dim)
        )
    return np.concatenate(parts, axis=0)
