"""Incremental shard-plan patching for serve-time frequency drift.

The plan-patch half of the online replanning datapath (DESIGN.md §6).
:func:`repro.dist.shard_plan.plan_shards` places groups from
training-time frequencies; at serve time the observed distribution
drifts (:mod:`repro.serve.drift` tracks it), and the paper's Eq.-1 wins
depend on the *currently hot* groups being the replicated ones.  Rather
than rebuilding the plan and re-DMA-ing the whole stacked shard image,
this module computes an **incremental patch** against the live plan:

  * **promote** — groups whose Eq.-1 log-scaled copy count on the
    drifted load now reaches the shard count move sharded-once →
    replicated-everywhere.  The owner keeps its tiles; every other
    shard receives a copy (``copies[g] × (S-1)`` tile DMAs).
  * **demote** — replicated groups that cooled below the threshold move
    to sharded-once on the shard that is least loaded under the drifted
    frequencies (greedy, descending load — the same rule as the fresh
    planner).  Every shard already holds the tiles, so demotion frees
    ``S-1`` slots and DMAs **nothing**.
  * everything else **stays put** (placement inertia): a sharded-once
    group that remains sharded-once keeps its owner even if a fresh
    greedy pass would have placed it elsewhere.  That is what bounds the
    patch at the moved groups' tiles instead of the whole image.

The patch edits only the plan's *placement* arrays (``replicated_group``
/ ``shard_of_group`` / ``shard_of_tile`` / ``local_tile_of`` /
``local_num_tiles`` / ``group_load``); the fused tile space, the table
segments and the intra-shard replica structure (``group_copies``) are
frozen.  Freed slots leave holes in a shard's local numbering — they are
never addressed again until a later promotion reuses them, exactly like
a retired ReRAM crossbar awaiting reprogramming — so
``ShardPlan.max_local_tiles`` tracks the highest allocated slot, not the
resident count.

Image application is :func:`repro.kernels.sharded.patch_shard_images`:
only the ``dma`` triples move tile data, never the full image.
``tests/test_replan.py`` pins patched-plan serving bit-identical to a
from-scratch ``plan_shards(..., eq1_batch=...)`` rebuild on the drifted
frequencies.

**Paging** (DESIGN.md §9): when the plan was built under a
``capacity_tiles`` hot-tier budget, passing a :class:`PagingPolicy`
extends the patch with **fetch** (cold group pages into the hot tier —
one master-image DMA per tile) and **evict** (a cooled resident group
pages out — its slots return to the free-list, no data moves: the host
master is authoritative).  A swap is hysteresis-gated — the incoming
group's load must exceed ``hysteresis ×`` the victim's — so a pair of
groups oscillating around equal load cannot thrash in and out every
barrier.  Under paging the capacity is FIXED: promotions that would
grow the image are deferred instead, and slack age-out is skipped.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.replication import log_scaled_copies
from repro.dist.shard_plan import COLD, ShardPlan


@dataclasses.dataclass(frozen=True)
class PagingPolicy:
    """Hot-tier paging knobs consumed by :func:`compute_plan_patch`.

    Attributes:
      capacity_tiles: the per-shard hot-tier budget (slots per shard
        image).  Fixed for the lifetime of the server — paging swaps
        within it, never grows it.
      hysteresis: a cold group may displace a resident victim only when
        ``load[in] > hysteresis · load[victim]``.  Values > 1 make the
        reverse swap immediately impossible (it would require
        ``load[victim] > hysteresis² · load[victim]``), which is the
        anti-thrash guarantee.
      max_fetch_tiles: optional cap on tiles paged IN per patch, to
        bound the DMA stall at one flush barrier (None: unbounded).
      min_fetch_load: a cold group pages in only when its decayed load
        exceeds this (0.0: any observed traffic qualifies).
    """

    capacity_tiles: int
    hysteresis: float = 1.5
    max_fetch_tiles: int | None = None
    min_fetch_load: float = 0.0


@dataclasses.dataclass
class PlanPatch:
    """One drift event's incremental edit of a :class:`ShardPlan`.

    Attributes:
      promoted: fused group ids moving sharded-once → replicated.
      demoted: ``(fused group id, new owner shard)`` pairs moving
        replicated → sharded-once.
      dma: ``(shard, local_slot, fused_tile)`` triples — the ONLY tile
        data movement the patch requires (new holders of promoted
        groups).  ``len(dma) == Σ_promoted copies[g] · (S-1)``.
      freed: ``(shard, local_slot)`` slots released by demotions; no
        data movement, the slot just stops being addressed.
      new_capacity: per-shard image depth required after the patch.
        Grows only when promotions exhaust the free slots + slack
        headroom; SHRINKS below the computed-against capacity only when
        slack age-out was requested (``shrink_slack=`` — long demotion
        streaks leave a free-slot tail that would otherwise persist at
        its high-water mark forever).
      moved: ``(shard, fused_tile, old_slot, new_slot)`` resident-tile
        relocations performed by slack age-out: tiles living above the
        shrunk depth compact down into freed holes so the slice loses
        only unaddressed slots.  Each relocation is one tile DMA from
        the host master image; empty unless ``shrink_slack`` was set.
      drifted_load: the ``(G,)`` fused-group load snapshot the patch was
        computed on; becomes the patched plan's ``group_load`` so the
        drift statistic re-anchors to the new placement.
      fetched: ``(fused group id, shard)`` pairs paging cold →
        sharded-once resident (tiered storage only).
      evicted: fused group ids paging sharded-once → cold; their slots
        land on ``freed`` (no data movement — the host master image is
        authoritative, so page-out is free).
      fetch_dma: ``(shard, local_slot, fused_tile)`` triples for the
        paged-in tiles — like ``dma`` but sourced by the paging path,
        kept separate so paged-tile/byte accounting is exact.
      evicted_tiles: Σ copies over ``evicted`` (slot-count the
        evictions return to the free-list).
      deferred: fused group ids whose Eq.-1 target said replicate but
        whose promotion was deferred by the fixed paging budget.  They
        stay sharded-once; callers tracking drift candidates must keep
        them live (their target status can outlast their drift mark).
    """

    promoted: List[int]
    demoted: List[Tuple[int, int]]
    dma: List[Tuple[int, int, int]]
    freed: List[Tuple[int, int]]
    new_capacity: int
    drifted_load: np.ndarray
    moved: List[Tuple[int, int, int, int]] = dataclasses.field(
        default_factory=list
    )
    fetched: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    evicted: List[int] = dataclasses.field(default_factory=list)
    fetch_dma: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list
    )
    evicted_tiles: int = 0
    deferred: List[int] = dataclasses.field(default_factory=list)

    @property
    def num_moved_groups(self) -> int:
        """Groups changing replication class (promoted + demoted)."""
        return len(self.promoted) + len(self.demoted)

    @property
    def num_paged_tiles(self) -> int:
        """Tiles paged across the host↔device boundary: fetches DMA
        data in; evictions only free slots but count as paging events."""
        return len(self.fetch_dma) + self.evicted_tiles

    @property
    def num_moved_tiles(self) -> int:
        """Tiles the patch DMAs for promotions — the acceptance metric
        vs a full rebuild (compaction DMAs are :attr:`num_relocated_tiles`)."""
        return len(self.dma)

    @property
    def num_relocated_tiles(self) -> int:
        """Tiles slack age-out compacts into lower slots (also DMAs)."""
        return len(self.moved)

    def is_noop(self) -> bool:
        """True when drift changed no replication class, no tile
        relocated AND nothing paged (rebase only) — the only patches
        safe to apply without the image update, since they touch no
        device state."""
        return not (self.promoted or self.demoted or self.moved
                    or self.fetched or self.evicted)

    def summary(self) -> dict:
        """Patch size counters for logs/reports."""
        return {
            "promoted_groups": len(self.promoted),
            "demoted_groups": len(self.demoted),
            "moved_tiles": self.num_moved_tiles,
            "relocated_tiles": self.num_relocated_tiles,
            "freed_slots": len(self.freed),
            "new_capacity": self.new_capacity,
            "fetched_groups": len(self.fetched),
            "evicted_groups": len(self.evicted),
            "fetched_tiles": len(self.fetch_dma),
            "evicted_tiles": self.evicted_tiles,
        }


def rescale_load_to_plan(
    load: np.ndarray, plan: ShardPlan, reference_totals
) -> np.ndarray:
    """Rescales each table segment of a load vector to a reference mass.

    Eq. 1's copy count ``1 + floor(log f_g / log f_total · log B)`` is
    **not scale-invariant**: shrinking every frequency by a common
    factor lowers ``log f_g / log f_total`` for every group.  A decayed
    serve-time estimate sits orders of magnitude below the training
    totals the offline plan was computed from, so feeding it to Eq. 1
    raw would systematically under-promote — hot-set rotations would
    demote cooled groups but rarely replicate the newly-hot ones.
    Rescaling each segment to its training-time total compares
    *distributions* at the calibrated magnitude instead.

    Args:
      load: ``(G,)`` fused-group load (e.g. ``DriftTracker.load()``).
      plan: the plan whose table segments define the scaling blocks.
      reference_totals: per-table reference mass, in segment order
        (the server captures ``Σ group_load`` per segment at build).

    Returns:
      A new ``(G,)`` float64 array; segments with zero observed or zero
      reference mass are left unscaled.
    """
    out = np.asarray(load, dtype=np.float64).copy()
    for seg, total in zip(plan.tables, reference_totals):
        gs = slice(seg.group_offset, seg.group_offset + seg.num_groups)
        mass = out[gs].sum()
        if mass > 0.0 and total > 0.0:
            out[gs] *= float(total) / mass
    return out


def _group_tile_base(plan: ShardPlan) -> np.ndarray:
    if plan.group_copies is None:
        raise ValueError(
            "plan has no group_copies — replanning needs a plan built by "
            "plan_shards (not a hand-constructed ShardPlan)"
        )
    base = np.zeros(plan.num_groups, dtype=np.int64)
    np.cumsum(plan.group_copies[:-1], out=base[1:])
    return base


def _eq1_targets(
    plan: ShardPlan,
    load: np.ndarray,
    eq1_batch: int,
    candidates: np.ndarray | None,
) -> np.ndarray:
    """(G,) bool — groups Eq. 1 says to replicate on the drifted load.

    With ``candidates`` only those groups (plus every currently
    replicated group, so demotion checks stay complete) are evaluated;
    everything else reports False.  Exact under the server's drift
    protocol: a group untouched since the last evaluation has a weakly
    *decreasing* rescaled load against a constant segment total, so a
    group that was not an Eq.-1 target then cannot have become one —
    see DESIGN.md §11.
    """
    S = plan.num_shards
    threshold = max(S, 2)
    target = np.zeros(plan.num_groups, dtype=bool)
    if candidates is None:
        for seg in plan.tables:
            gs = slice(seg.group_offset, seg.group_offset + seg.num_groups)
            target[gs] = log_scaled_copies(load[gs], eq1_batch) >= threshold
        return target
    cand = np.union1d(
        np.asarray(candidates, dtype=np.int64),
        np.nonzero(plan.replicated_group)[0],
    )
    if cand.size and (cand[0] < 0 or cand[-1] >= plan.num_groups):
        raise ValueError("candidate group id out of range")
    for seg in plan.tables:
        lo = seg.group_offset
        hi = lo + seg.num_groups
        cs = cand[np.searchsorted(cand, lo):np.searchsorted(cand, hi)]
        if cs.size:
            # subset evaluation at the full segment's normalizing mass
            target[cs] = log_scaled_copies(
                load[cs], eq1_batch, total=float(load[lo:hi].sum())
            ) >= threshold
    return target


def compute_plan_patch(
    plan: ShardPlan,
    drifted_load: np.ndarray,
    *,
    eq1_batch: int,
    capacity: int | None = None,
    shrink_slack: int | None = None,
    paging: PagingPolicy | None = None,
    candidates: np.ndarray | None = None,
) -> PlanPatch:
    """Diffs the live plan against Eq. 1 evaluated on the drifted load.

    Scale-invariant: the work is O(changed groups) plus vectorized
    NumPy over the slots the patch actually touches — per-shard slot
    occupancy is one int array scatter, free slots one ``flatnonzero``,
    and a patch that changes no replication class never materializes
    slot state at all.  At 10M rows (~10⁵ groups) a drift window's
    patch computes in milliseconds; the retained
    :func:`_reference_compute_plan_patch` oracle is the bit-exact
    specification the tests diff against.

    Args:
      plan: the currently-serving :class:`ShardPlan`.
      drifted_load: ``(G,)`` fused-group access load (e.g. the decayed
        estimate from :class:`repro.serve.drift.DriftTracker`).
      eq1_batch: Eq. 1's ``batch`` for the replicate-vs-shard threshold
        (the server passes its ``batch_size_for_eq1``).
      capacity: current per-shard image depth (slots a promotion may
        fill without growing the image); defaults to
        ``plan.max_local_tiles``.
      shrink_slack: when set, age out slack capacity — the patch's
        ``new_capacity`` drops to the highest slot any shard still
        allocates (post-patch) plus this many headroom slots, instead
        of staying at the high-water mark.  The server requests this
        after long demotion streaks so the slot free-list shrinks back
        instead of growing monotonically; never raises capacity above
        what the patch itself requires.  Ignored under ``paging``
        (tiered capacity is fixed).
      paging: a :class:`PagingPolicy` for capacity-bounded plans.  When
        set, the patch additionally pages cold groups in (``fetched`` /
        ``fetch_dma``) and cooled residents out (``evicted``) within
        the fixed ``paging.capacity_tiles`` budget, hysteresis-gated;
        promotions that would exceed the budget are deferred instead of
        growing the image.
      candidates: optional fused group ids whose replication class may
        have changed (the server passes
        :meth:`~repro.serve.drift.DriftTracker.drifted_groups`).  Eq. 1
        is then evaluated only on ``candidates ∪ replicated`` instead
        of all G groups, which is what makes the patch scale-invariant;
        exact whenever every group whose load *rose* since the last
        evaluation is included (see :func:`_eq1_targets`).  ``None``
        scans every group.

    Returns:
      A :class:`PlanPatch`.  Pure host-side computation — no device
      arrays are touched, so it can run while a flush executes on
      device (the double-buffered staging in
      :class:`repro.serve.sharded.ShardedEmbeddingServer`).
    """
    load = np.asarray(drifted_load, dtype=np.float64)
    if load.shape != (plan.num_groups,):
        raise ValueError(
            f"drifted load has shape {load.shape}, plan has "
            f"{plan.num_groups} groups"
        )
    S = plan.num_shards
    tile_base = _group_tile_base(plan)
    copies = plan.group_copies
    if paging is not None:
        capacity = int(paging.capacity_tiles)
    elif capacity is None:
        capacity = plan.max_local_tiles

    target = _eq1_targets(plan, load, eq1_batch, candidates)

    # cold (host-only) groups cannot jump straight to replicated: they
    # must page in first (sharded-once), and may promote a later patch
    promoted = np.nonzero(
        target & ~plan.replicated_group & plan.resident_group
    )[0]
    demote_ids = np.nonzero(~target & plan.replicated_group)[0]

    if (promoted.size == 0 and demote_ids.size == 0
            and paging is None and shrink_slack is None):
        # class-unchanged rebase: no slot state needed at all
        return PlanPatch(
            promoted=[], demoted=[], dma=[], freed=[],
            new_capacity=capacity, drifted_load=load.copy(),
        )

    # drifted load + resident-tile pressure of the placement that stays
    # put; promoted groups leave their owner's tally (their work
    # round-robins after the patch).  bincount accumulates in the same
    # element order np.add.at would, so the float sums are bit-equal.
    stays = plan.shard_of_group >= 0
    stays[promoted] = False
    owner_of_stays = plan.shard_of_group[stays].astype(np.int64)
    shard_load = np.bincount(
        owner_of_stays, weights=load[stays], minlength=S
    ).tolist()
    shard_tiles = np.bincount(
        owner_of_stays, weights=copies[stays].astype(np.float64), minlength=S
    ).astype(np.int64).tolist()

    # demotions: the fresh planner's rule restricted to the moved
    # groups — greedy descending drifted load; loaded groups to the
    # least-loaded shard (tile pressure breaks ties), but the typical
    # demoted group has COOLED to ~zero load, where frequency balance
    # says nothing: those place on the least-TILE-loaded shard, the
    # cold-tail memory balance that is half the point of sharding.
    demoted: List[Tuple[int, int]] = []
    shard_ids = range(S)
    order = demote_ids[np.argsort(-load[demote_ids], kind="stable")]
    for g in order.tolist():
        if load[g] > 0:
            s = int(min(shard_ids,
                        key=lambda i: (shard_load[i], shard_tiles[i], i)))
        else:
            s = int(min(shard_ids, key=lambda i: (shard_tiles[i], i)))
        demoted.append((g, s))
        shard_load[s] += load[g]
        shard_tiles[s] += int(copies[g])

    # slot bookkeeping, vectorized: per-shard occupancy (slot → fused
    # tile, -1 free) built with one nonzero + scatter instead of S
    # Python dicts; demotions free non-owner slots first, promotions
    # then fill the lowest free slot per shard (deterministic), growing
    # the capacity only when a shard has no free slot left
    width = max(capacity, plan.max_local_tiles)
    if promoted.size:
        width += int(copies[promoted].sum())
    occ = np.full((S, width), -1, dtype=np.int64)
    srows, tcols = np.nonzero(plan.local_tile_of >= 0)
    occ[srows, plan.local_tile_of[srows, tcols]] = tcols
    freed: List[Tuple[int, int]] = []
    for g, o in demoted:
        for t in range(int(tile_base[g]), int(tile_base[g] + copies[g])):
            for s in range(S):
                if s == o:
                    continue
                slot = int(plan.local_tile_of[s, t])
                if slot < 0:
                    raise ValueError(
                        f"replicated group {g}: shard {s} does not hold "
                        f"tile {t}"
                    )
                occ[s, slot] = -1
                freed.append((s, slot))
    free = [np.flatnonzero(occ[s, :capacity] < 0).tolist() for s in range(S)]
    grow = [capacity] * S
    dma: List[Tuple[int, int, int]] = []
    dma_index: dict = {}                   # (shard, slot) → index into dma
    kept_promoted: List[int] = []
    deferred: List[int] = []
    for g in promoted.tolist():
        owner = int(plan.shard_of_group[g])
        c = int(copies[g])
        if paging is not None and any(
            len(free[s]) < c for s in range(S) if s != owner
        ):
            # fixed hot-tier budget: a promotion that would grow the
            # image is deferred (the group stays sharded-once; Eq. 1
            # will re-target it once evictions open slots)
            deferred.append(g)
            continue
        kept_promoted.append(g)
        for t in range(int(tile_base[g]), int(tile_base[g] + c)):
            for s in range(S):
                if s == owner:
                    continue
                if free[s]:
                    slot = free[s].pop(0)
                else:
                    slot = grow[s]
                    grow[s] += 1
                occ[s, slot] = t
                dma_index[(s, slot)] = len(dma)
                dma.append((s, slot, t))
    promoted = np.asarray(kept_promoted, dtype=np.int64)

    # ---- paging (tiered storage, DESIGN.md §9): swap the drifted-hot
    # cold groups into the fixed budget, hysteresis-gated ---------------
    fetched: List[Tuple[int, int]] = []
    evicted: List[int] = []
    fetch_dma: List[Tuple[int, int, int]] = []
    evicted_tiles = 0
    if paging is not None:
        # post-patch owner map (promotions → -1, demotions → new owner)
        own = plan.shard_of_group.copy()
        for g, o in demoted:
            own[g] = o
        own[promoted] = -1
        # eviction candidates: sharded-once residents per shard,
        # coldest first (a group fetched THIS patch is not a candidate —
        # within-patch anti-thrash on top of the hysteresis gate).
        # lexsort (ids last ⇒ secondary key) matches the reference's
        # (load, gid) tuple sort per shard.
        res_ids = np.nonzero(own >= 0)[0]
        vorder = np.lexsort((res_ids, load[res_ids], own[res_ids]))
        v_ids = res_ids[vorder]
        v_shard = own[res_ids][vorder]
        vict_g = [v_ids[v_shard == s] for s in range(S)]
        vict_l = [load[v] for v in vict_g]
        vpos = [0] * S                      # consumed prefix per shard
        cold_ids = np.nonzero(own == COLD)[0]
        cold_ids = cold_ids[load[cold_ids] > paging.min_fetch_load]
        cold_order = cold_ids[np.argsort(-load[cold_ids], kind="stable")]
        for g in cold_order.tolist():
            c = int(copies[g])
            if (paging.max_fetch_tiles is not None
                    and len(fetch_dma) + c > paging.max_fetch_tiles):
                break
            fits = [s for s in range(S) if len(free[s]) >= c]
            if fits:
                s = min(fits, key=lambda i: (shard_load[i], shard_tiles[i], i))
            else:
                # pick the shard whose coldest victims free ≥ c slots at
                # the least evicted load, every victim hysteresis-gated
                best = None               # (victim load Σ, shard, victims)
                for cs in range(S):
                    have = len(free[cs])
                    picks: List[int] = []
                    vload = 0.0
                    pos = vpos[cs]
                    while have < c and pos < vict_g[cs].size:
                        lv = float(vict_l[cs][pos])
                        gv = int(vict_g[cs][pos])
                        if load[g] <= paging.hysteresis * lv:
                            break         # not hot enough to displace
                        picks.append(gv)
                        vload += lv
                        have += int(copies[gv])
                        pos += 1
                    if have >= c and (best is None or (vload, cs) < best[:2]):
                        best = (vload, cs, picks, pos)
                if best is None:
                    continue              # nothing evictable for this one
                _, s, picks, pos = best
                vpos[s] = pos
                for gv in picks:
                    o = int(own[gv])
                    for t in range(int(tile_base[gv]),
                                   int(tile_base[gv] + copies[gv])):
                        slot = int(plan.local_tile_of[o, t])
                        if slot < 0:
                            raise ValueError(
                                f"evicting group {gv}: shard {o} does not "
                                f"hold tile {t}"
                            )
                        occ[o, slot] = -1
                        bisect.insort(free[o], slot)
                        freed.append((o, slot))
                    evicted.append(gv)
                    evicted_tiles += int(copies[gv])
                    own[gv] = COLD
                    shard_load[o] -= float(load[gv])
                    shard_tiles[o] -= int(copies[gv])
            for t in range(int(tile_base[g]), int(tile_base[g] + c)):
                slot = free[s].pop(0)
                occ[s, slot] = t
                fetch_dma.append((s, slot, t))
            fetched.append((g, s))
            own[g] = s
            shard_load[s] += float(load[g])
            shard_tiles[s] += c

    new_capacity = max(grow)
    moved: List[Tuple[int, int, int, int]] = []
    if (shrink_slack is not None and paging is None
            and new_capacity <= capacity):
        # slack age-out: compact the stack down to the busiest shard's
        # resident count + requested headroom.  Tiles above the new
        # depth relocate into free holes below it (one master-image DMA
        # each); a promotion landing above it just retargets its DMA.
        # Only legal when nothing grew this patch.
        depth = min(
            capacity,
            int((occ >= 0).sum(axis=1).max()) + int(shrink_slack),
        )
        for s in range(S):
            over = (np.flatnonzero(occ[s, depth:] >= 0) + depth).tolist()
            free_low = np.flatnonzero(occ[s, :depth] < 0).tolist()
            for old in over:
                new = free_low.pop(0)
                t = int(occ[s, old])
                occ[s, old] = -1
                occ[s, new] = t
                idx = dma_index.pop((s, old), None)
                if idx is not None:
                    dma[idx] = (s, new, t)   # incoming tile, not resident
                    dma_index[(s, new)] = idx
                else:
                    moved.append((s, t, old, new))
        new_capacity = depth
    return PlanPatch(
        promoted=promoted.tolist(),
        demoted=demoted,
        dma=dma,
        freed=freed,
        new_capacity=new_capacity,
        drifted_load=load.copy(),
        moved=moved,
        fetched=fetched,
        evicted=evicted,
        fetch_dma=fetch_dma,
        evicted_tiles=evicted_tiles,
        deferred=deferred,
    )


def _reference_compute_plan_patch(
    plan: ShardPlan,
    drifted_load: np.ndarray,
    *,
    eq1_batch: int,
    capacity: int | None = None,
    shrink_slack: int | None = None,
    paging: PagingPolicy | None = None,
) -> PlanPatch:
    """Original dict-of-slots implementation (equivalence oracle).

    Semantically identical to :func:`compute_plan_patch` with
    ``candidates=None``, but builds per-shard ``{slot: tile}`` dicts and
    Python free-slot sets over the whole image — O(S·T) work per call
    regardless of how small the patch is.  Retained as the oracle the
    property tests diff the vectorized implementation against.
    """
    load = np.asarray(drifted_load, dtype=np.float64)
    if load.shape != (plan.num_groups,):
        raise ValueError(
            f"drifted load has shape {load.shape}, plan has "
            f"{plan.num_groups} groups"
        )
    S = plan.num_shards
    tile_base = _group_tile_base(plan)
    copies = plan.group_copies
    if paging is not None:
        capacity = int(paging.capacity_tiles)
    elif capacity is None:
        capacity = plan.max_local_tiles

    # target replicated set: Eq. 1 on the drifted load, per table segment
    # (Eq. 1 normalizes by the table's total frequency)
    target = np.zeros(plan.num_groups, dtype=bool)
    for seg in plan.tables:
        gs = slice(seg.group_offset, seg.group_offset + seg.num_groups)
        target[gs] = log_scaled_copies(load[gs], eq1_batch) >= max(S, 2)

    # cold (host-only) groups cannot jump straight to replicated: they
    # must page in first (sharded-once), and may promote a later patch
    promoted = np.nonzero(
        target & ~plan.replicated_group & plan.resident_group
    )[0]
    demote_ids = np.nonzero(~target & plan.replicated_group)[0]

    # drifted load + resident-tile pressure of the placement that stays
    # put; promoted groups leave their owner's tally (their work
    # round-robins after the patch)
    shard_load = np.zeros(S, dtype=np.float64)
    shard_tiles = np.zeros(S, dtype=np.int64)
    stays = plan.shard_of_group >= 0
    stays[promoted] = False
    np.add.at(shard_load, plan.shard_of_group[stays], load[stays])
    np.add.at(shard_tiles, plan.shard_of_group[stays], copies[stays])

    # demotions: the fresh planner's rule restricted to the moved
    # groups — greedy descending drifted load; loaded groups to the
    # least-loaded shard (tile pressure breaks ties), but the typical
    # demoted group has COOLED to ~zero load, where frequency balance
    # says nothing: those place on the least-TILE-loaded shard, the
    # cold-tail memory balance that is half the point of sharding.
    demoted: List[Tuple[int, int]] = []
    shard_ids = range(S)
    order = demote_ids[np.argsort(-load[demote_ids], kind="stable")]
    for g in order.tolist():
        if load[g] > 0:
            s = int(min(shard_ids,
                        key=lambda i: (shard_load[i], shard_tiles[i], i)))
        else:
            s = int(min(shard_ids, key=lambda i: (shard_tiles[i], i)))
        demoted.append((g, s))
        shard_load[s] += load[g]
        shard_tiles[s] += int(copies[g])

    # slot bookkeeping: demotions free non-owner slots first, promotions
    # then fill the lowest free slot per shard (deterministic), growing
    # the capacity only when a shard has no free slot left
    slot_tile: List[dict] = []
    for s in range(S):
        resident = np.nonzero(plan.local_tile_of[s] >= 0)[0]
        slot_tile.append({
            int(plan.local_tile_of[s, t]): int(t) for t in resident
        })
    freed: List[Tuple[int, int]] = []
    for g, o in demoted:
        for t in range(int(tile_base[g]), int(tile_base[g] + copies[g])):
            for s in range(S):
                if s == o:
                    continue
                slot = int(plan.local_tile_of[s, t])
                if slot < 0:
                    raise ValueError(
                        f"replicated group {g}: shard {s} does not hold "
                        f"tile {t}"
                    )
                del slot_tile[s][slot]
                freed.append((s, slot))
    free = [sorted(set(range(capacity)) - slot_tile[s].keys()) for s in range(S)]
    grow = [capacity] * S
    dma: List[Tuple[int, int, int]] = []
    dma_index: dict = {}                   # (shard, slot) → index into dma
    kept_promoted: List[int] = []
    deferred: List[int] = []
    for g in promoted.tolist():
        owner = int(plan.shard_of_group[g])
        c = int(copies[g])
        if paging is not None and any(
            len(free[s]) < c for s in range(S) if s != owner
        ):
            # fixed hot-tier budget: a promotion that would grow the
            # image is deferred (the group stays sharded-once; Eq. 1
            # will re-target it once evictions open slots)
            deferred.append(g)
            continue
        kept_promoted.append(g)
        for t in range(int(tile_base[g]), int(tile_base[g] + c)):
            for s in range(S):
                if s == owner:
                    continue
                if free[s]:
                    slot = free[s].pop(0)
                else:
                    slot = grow[s]
                    grow[s] += 1
                slot_tile[s][slot] = t
                dma_index[(s, slot)] = len(dma)
                dma.append((s, slot, t))
    promoted = np.asarray(kept_promoted, dtype=np.int64)

    # ---- paging (tiered storage, DESIGN.md §9): swap the drifted-hot
    # cold groups into the fixed budget, hysteresis-gated ---------------
    fetched: List[Tuple[int, int]] = []
    evicted: List[int] = []
    fetch_dma: List[Tuple[int, int, int]] = []
    evicted_tiles = 0
    if paging is not None:
        # post-patch owner map (promotions → -1, demotions → new owner)
        own = plan.shard_of_group.copy()
        for g, o in demoted:
            own[g] = o
        own[promoted] = -1
        # eviction candidates: sharded-once residents per shard,
        # coldest first (a group fetched THIS patch is not a candidate —
        # within-patch anti-thrash on top of the hysteresis gate)
        victims: List[List[Tuple[float, int]]] = [[] for _ in range(S)]
        for g in np.nonzero(own >= 0)[0].tolist():
            victims[int(own[g])].append((float(load[g]), g))
        for s in range(S):
            victims[s].sort()
        vpos = [0] * S                      # consumed prefix per shard
        cold_ids = np.nonzero(own == COLD)[0]
        cold_ids = cold_ids[load[cold_ids] > paging.min_fetch_load]
        cold_order = cold_ids[np.argsort(-load[cold_ids], kind="stable")]
        for g in cold_order.tolist():
            c = int(copies[g])
            if (paging.max_fetch_tiles is not None
                    and len(fetch_dma) + c > paging.max_fetch_tiles):
                break
            fits = [s for s in range(S) if len(free[s]) >= c]
            if fits:
                s = min(fits, key=lambda i: (shard_load[i], shard_tiles[i], i))
            else:
                # pick the shard whose coldest victims free ≥ c slots at
                # the least evicted load, every victim hysteresis-gated
                best = None               # (victim load Σ, shard, victims)
                for cs in range(S):
                    have = len(free[cs])
                    picks: List[int] = []
                    vload = 0.0
                    pos = vpos[cs]
                    while have < c and pos < len(victims[cs]):
                        lv, gv = victims[cs][pos]
                        if load[g] <= paging.hysteresis * lv:
                            break         # not hot enough to displace
                        picks.append(gv)
                        vload += lv
                        have += int(copies[gv])
                        pos += 1
                    if have >= c and (best is None or (vload, cs) < best[:2]):
                        best = (vload, cs, picks, pos)
                if best is None:
                    continue              # nothing evictable for this one
                _, s, picks, pos = best
                vpos[s] = pos
                for gv in picks:
                    o = int(own[gv])
                    for t in range(int(tile_base[gv]),
                                   int(tile_base[gv] + copies[gv])):
                        slot = int(plan.local_tile_of[o, t])
                        if slot < 0:
                            raise ValueError(
                                f"evicting group {gv}: shard {o} does not "
                                f"hold tile {t}"
                            )
                        del slot_tile[o][slot]
                        bisect.insort(free[o], slot)
                        freed.append((o, slot))
                    evicted.append(gv)
                    evicted_tiles += int(copies[gv])
                    own[gv] = COLD
                    shard_load[o] -= float(load[gv])
                    shard_tiles[o] -= int(copies[gv])
            for t in range(int(tile_base[g]), int(tile_base[g] + c)):
                slot = free[s].pop(0)
                slot_tile[s][slot] = t
                fetch_dma.append((s, slot, t))
            fetched.append((g, s))
            own[g] = s
            shard_load[s] += float(load[g])
            shard_tiles[s] += c

    new_capacity = max(grow)
    moved: List[Tuple[int, int, int, int]] = []
    if (shrink_slack is not None and paging is None
            and new_capacity <= capacity):
        # slack age-out: compact the stack down to the busiest shard's
        # resident count + requested headroom.  Tiles above the new
        # depth relocate into free holes below it (one master-image DMA
        # each); a promotion landing above it just retargets its DMA.
        # Only legal when nothing grew this patch.
        target = min(
            capacity, max(len(st) for st in slot_tile) + int(shrink_slack)
        )
        for s in range(S):
            over = sorted(slot for slot in slot_tile[s] if slot >= target)
            free_low = sorted(
                set(range(target)) - set(slot_tile[s])
            )
            for old in over:
                new = free_low.pop(0)
                t = slot_tile[s].pop(old)
                slot_tile[s][new] = t
                idx = dma_index.pop((s, old), None)
                if idx is not None:
                    dma[idx] = (s, new, t)   # incoming tile, not resident
                    dma_index[(s, new)] = idx
                else:
                    moved.append((s, t, old, new))
        new_capacity = target
    return PlanPatch(
        promoted=promoted.tolist(),
        demoted=demoted,
        dma=dma,
        freed=freed,
        new_capacity=new_capacity,
        drifted_load=load.copy(),
        moved=moved,
        fetched=fetched,
        evicted=evicted,
        fetch_dma=fetch_dma,
        evicted_tiles=evicted_tiles,
        deferred=deferred,
    )


def apply_plan_patch(plan: ShardPlan, patch: PlanPatch) -> ShardPlan:
    """Applies a patch to the placement arrays; returns a new plan.

    The input plan is not mutated (the server swaps plans atomically
    between flushes).  Only placement arrays change: the fused tile
    space, table segments and ``group_copies`` carry over by reference.
    """
    # opt-in structural validation at the apply barrier
    # (RECROSS_VALIDATE=1, DESIGN.md §12); lazy import: analysis
    # imports this module at its own top level
    from repro.analysis.invariants import validation_enabled

    if validation_enabled():
        from repro.analysis.invariants import validate_patch

        validate_patch(plan, patch)

    S = plan.num_shards
    tile_base = _group_tile_base(plan)
    copies = plan.group_copies
    replicated = plan.replicated_group.copy()
    shard_of_group = plan.shard_of_group.copy()
    shard_of_tile = plan.shard_of_tile.copy()
    local = plan.local_tile_of.copy()
    nloc = plan.local_num_tiles.copy()

    for g, o in patch.demoted:
        if not replicated[g]:
            raise ValueError(f"demoting group {g} which is not replicated")
        replicated[g] = False
        shard_of_group[g] = o
        for t in range(int(tile_base[g]), int(tile_base[g] + copies[g])):
            shard_of_tile[t] = o
            for s in range(S):
                if s != o and local[s, t] >= 0:
                    local[s, t] = -1
                    nloc[s] -= 1
    for g in patch.evicted:
        o = int(shard_of_group[g])
        if replicated[g] or o < 0:
            raise ValueError(
                f"evicting group {g} which is not sharded-once resident"
            )
        shard_of_group[g] = COLD
        for t in range(int(tile_base[g]), int(tile_base[g] + copies[g])):
            if local[o, t] < 0:
                raise ValueError(
                    f"evicting group {g}: shard {o} does not hold tile {t}"
                )
            shard_of_tile[t] = COLD
            local[o, t] = -1
            nloc[o] -= 1
    for g in patch.promoted:
        if replicated[g]:
            raise ValueError(f"promoting group {g} which is already replicated")
        if shard_of_group[g] == COLD:
            raise ValueError(f"promoting group {g} which is cold (fetch first)")
        replicated[g] = True
        shard_of_group[g] = -1
        ts = slice(int(tile_base[g]), int(tile_base[g] + copies[g]))
        shard_of_tile[ts] = -1
    for g, o in patch.fetched:
        if shard_of_group[g] != COLD:
            raise ValueError(f"fetching group {g} which is already resident")
        shard_of_group[g] = o
        ts = slice(int(tile_base[g]), int(tile_base[g] + copies[g]))
        shard_of_tile[ts] = o
    for s, slot, t in list(patch.dma) + list(patch.fetch_dma):
        if local[s, t] >= 0:
            raise ValueError(f"shard {s} already holds fused tile {t}")
        local[s, t] = slot
        nloc[s] += 1
    for s, t, old, new in patch.moved:
        if local[s, t] != old:
            raise ValueError(
                f"relocation of fused tile {t} on shard {s}: expected "
                f"slot {old}, plan has {local[s, t]}"
            )
        local[s, t] = new

    out = ShardPlan(
        num_shards=S,
        tables=plan.tables,
        replicated_group=replicated,
        shard_of_group=shard_of_group,
        shard_of_tile=shard_of_tile,
        local_tile_of=local,
        local_num_tiles=nloc,
        group_load=patch.drifted_load.copy(),
        group_copies=copies,
        capacity_tiles=plan.capacity_tiles,
    )
    if validation_enabled():
        from repro.analysis.invariants import validate_plan

        validate_plan(out)
    return out
