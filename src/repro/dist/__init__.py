"""Distribution layer: logical-axis sharding rules and pipeline parallelism.

``repro.dist.sharding`` owns the logical→mesh translation used by every
model, the activation-constraint helpers (no-ops outside a mesh context so
single-device tests run unchanged), and the name-pattern parameter-spec
derivation consumed by the dry-run and the elastic-restart path.

``repro.dist.pipeline_parallel`` owns the GPipe-style stage rotation used
by the pipeline-parallel example and its schedule math.

``repro.dist.shard_plan`` owns the crossbar shard planner: which groups
replicate across every model shard (Eq.-1 hot sets) vs live sharded-once,
over the fused multi-table tile space.

``repro.dist.replan`` owns the incremental plan patcher for serve-time
frequency drift: promote newly-hot groups into the replicated set,
demote cooled ones, DMA only the moved tiles (DESIGN.md §6).
"""

from repro.dist import sharding
from repro.dist import pipeline_parallel
from repro.dist.replan import (
    PagingPolicy,
    PlanPatch,
    apply_plan_patch,
    compute_plan_patch,
    rescale_load_to_plan,
)
from repro.dist.shard_plan import (
    COLD,
    ShardPlan,
    TableSegment,
    build_fused_image,
    plan_shards,
)

__all__ = [
    "sharding", "pipeline_parallel",
    "COLD", "ShardPlan", "TableSegment", "build_fused_image", "plan_shards",
    "PagingPolicy", "PlanPatch", "apply_plan_patch", "compute_plan_patch",
    "rescale_load_to_plan",
]
