"""GPipe-style pipeline parallelism over a "stage" mesh axis.

``pipelined_apply`` runs ``M`` microbatches through ``S`` stages with the
classic fill/drain rotation: at tick ``t`` stage ``s`` processes
microbatch ``t - s`` (when valid) and hands its activation to stage
``s + 1`` via ``ppermute``.  Completion takes ``M + S - 1`` ticks; the
fill/drain overhead is :func:`bubble_fraction`.

The whole rotation is a single ``shard_map`` + ``lax.scan`` region so the
per-stage weights never leave their shard and XLA overlaps the ppermute
with the next tick's compute.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.5 exports shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version shim
    from jax.experimental.shard_map import shard_map as _shard_map


def bubble_fraction(num_microbatches: int, num_stages: int) -> float:
    """Idle fraction of the ideal schedule: (S-1) / (M + S-1)."""
    if num_microbatches < 1 or num_stages < 1:
        raise ValueError("need at least one microbatch and one stage")
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


def pipelined_apply(
    w: jax.Array,                  # (S, ...) stacked per-stage params
    x: jax.Array,                  # (M, microbatch, d) microbatched input
    body: Callable[[jax.Array, jax.Array], jax.Array],
    mesh,
) -> jax.Array:
    """Applies ``body(w[s], ·)`` for s = 0..S-1 over every microbatch.

    Returns the (M, microbatch, d) outputs of the final stage; numerically
    identical to running all stages sequentially on one device.
    """
    num_stages = _mesh_stage_size(mesh)
    if w.shape[0] != num_stages:
        raise ValueError(
            f"w has {w.shape[0]} stages but mesh 'stage' axis is {num_stages}"
        )
    num_micro = x.shape[0]
    ticks = num_micro + num_stages - 1
    perm = [(i, (i + 1) % num_stages) for i in range(num_stages)]

    def local(w_loc, x_all):
        stage = lax.axis_index("stage")
        w_stage = w_loc[0]

        def tick(carry, t):
            inbuf, outputs = carry
            m = t - stage
            # stage 0 draws fresh microbatches; later stages consume the
            # activation rotated in from the previous stage last tick
            fresh = x_all[jnp.clip(t, 0, num_micro - 1)]
            h_in = jnp.where(stage == 0, fresh, inbuf)
            h_out = body(w_stage, h_in)
            nxt = lax.ppermute(h_out, "stage", perm)
            m_clip = jnp.clip(m, 0, num_micro - 1)
            valid = (m >= 0) & (m < num_micro)
            outputs = outputs.at[m_clip].set(
                jnp.where(valid, h_out, outputs[m_clip])
            )
            return (nxt, outputs), None

        init = (jnp.zeros_like(x_all[0]), jnp.zeros_like(x_all))
        (_, outputs), _ = lax.scan(tick, init, jnp.arange(ticks))
        # only the final stage's records are the pipeline output; psum
        # broadcasts them so the out_spec can be replicated
        mine = jnp.where(stage == num_stages - 1, outputs, jnp.zeros_like(outputs))
        return lax.psum(mine, "stage")

    return _shard_map(
        local,
        mesh=mesh,
        in_specs=(P("stage"), P()),
        out_specs=P(),
    )(w, x)


def _mesh_stage_size(mesh) -> int:
    import numpy as np

    sizes = dict(zip(mesh.axis_names, np.shape(mesh.devices)))
    if "stage" not in sizes:
        raise ValueError(f"mesh {mesh.axis_names} has no 'stage' axis")
    return int(sizes["stage"])
