"""Sharded multi-table serving launcher (real shard_map on host devices).

Forces the host platform to present enough devices, builds a
``(1, num_shards)`` (data, model) mesh, stands up a
:class:`~repro.serve.sharded.ShardedEmbeddingServer` over synthetic
Zipf-weighted tables, and drives a continuous stream of per-table
queries through the batched flush path.  Prints the per-shard grid
cells / combine bytes / wall time report.

Usage::

    PYTHONPATH=src python -m repro.launch.serve_sharded --shards 4 --tables 2
    PYTHONPATH=src python -m repro.launch.serve_sharded --emulate   # no mesh
    PYTHONPATH=src python -m repro.launch.serve_sharded --emulate --drift
    PYTHONPATH=src python -m repro.launch.serve_sharded --emulate \
        --flush-policy deadline --skew 3   # async per-shard pipelining
    PYTHONPATH=src python -m repro.launch.serve_sharded --shards 4 \
        --flush-policy owner-set --threaded   # owner-set homes + driver
                                              # thread (non-blocking submit)
    PYTHONPATH=src python -m repro.launch.serve_sharded --emulate \
        --flush-policy per-shard --threaded --producers 4
                                              # 4 concurrent producer
                                              # threads, per-producer
                                              # sequence spaces (§10)
    PYTHONPATH=src python -m repro.launch.serve_sharded --emulate \
        --flush-policy per-shard --threaded \
        --inject compile:2,device:1,poison:1,hang:1 \
        --inject-seed 0 --watchdog 2.0        # seeded chaos replay: the
                                              # engine heals (DESIGN.md §8)
    PYTHONPATH=src python -m repro.launch.serve_sharded --emulate \
        --flush-policy deadline --capacity-frac 0.25 --drift
                                              # tiered hot/cold storage:
                                              # device holds 1/4 of the
                                              # working set, drift pages
                                              # groups in/out (§9)

``--drift`` enables the drifting-workload replay (DESIGN.md §6): after
``--drift-at`` of the request stream, row ids are remapped through a
fixed permutation — the hot set rotates onto previously-cold rows — and
the server's online replanner (enabled with the ``--replan-*`` knobs)
incrementally promotes/demotes groups instead of rebuilding the plan.
The report then includes the replan counters (patches applied, tiles
DMA'd, residual drift).

The module is import-safe: args are parsed and ``XLA_FLAGS`` is set only
when run as ``__main__`` (the device-count flag must land before the
first jax import, so :func:`main` defers its jax-touching imports).
"""

from __future__ import annotations

import argparse
import json
import os


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--tables", type=int, default=2)
    ap.add_argument("--rows", type=int, default=2048)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--history", type=int, default=2048)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--batch-size", type=int, default=128)
    ap.add_argument("--q-block", type=int, default=8)
    ap.add_argument("--group-size", type=int, default=64)
    ap.add_argument("--mean-bag", type=float, default=12.0)
    ap.add_argument("--combine", choices=["psum_scatter", "psum"],
                    default="psum_scatter")
    ap.add_argument("--combine-chunks", type=int, default=2)
    ap.add_argument("--flush-policy",
                    choices=["global", "per-shard", "deadline", "owner-set"],
                    default="global",
                    help="global: synchronous fused flushes (PR-2 path); "
                         "per-shard/deadline: shards flush independently "
                         "as their block unions fill, host compile "
                         "pipelined against device execution; owner-set: "
                         "multi-owner queries additionally key their home "
                         "by the frozen owner set, so a 2-owner flush "
                         "compiles (and combines over) exactly 2 shards "
                         "(DESIGN.md §7)")
    ap.add_argument("--owner-set-max", type=int, default=None,
                    help="owner-set policy: sets larger than this pool "
                         "up instead of getting their own home (None: "
                         "every multi-owner set is keyed; 2-3 keeps the "
                         "high-value small-set homes and avoids "
                         "fragmenting near-mesh traffic)")
    ap.add_argument("--producers", type=int, default=1,
                    help="concurrent producer threads sharing the server "
                         "(DESIGN.md §10): the request stream splits "
                         "round-robin, each thread submits under its own "
                         "producer label (its own sequence space), and "
                         "the final drain merges the streams in the "
                         "deterministic (local_seq, producer_id) order. "
                         "> 1 requires an async --flush-policy; pair "
                         "with --threaded for the non-blocking front "
                         "door")
    ap.add_argument("--threaded", action="store_true",
                    help="run the async engine on a driver thread: "
                         "submit() only validates + enqueues (bounded "
                         "hand-off queue) and never blocks on a full "
                         "in-flight pipeline; submit-side p50/p95/p99 "
                         "land in the report (DESIGN.md §7.2)")
    ap.add_argument("--union-budget", type=int, default=None,
                    help="per-shard block-union fill that triggers an "
                         "independent flush (None: batch-size/deadline "
                         "triggers only)")
    ap.add_argument("--flush-deadline", type=int, default=None,
                    help="max submissions a pending query waits before a "
                         "forced flush (deadline policy; default 4x "
                         "batch-size)")
    ap.add_argument("--max-in-flight", type=int, default=2,
                    help="bound on dispatched-but-unretired async flushes")
    ap.add_argument("--skew", type=float, default=1.0,
                    help="per-table arrival skew: table i receives "
                         "weight skew^-i of the request stream (1.0 = "
                         "uniform); skewed arrivals are where per-shard "
                         "flushing beats the global policy")
    ap.add_argument("--emulate", action="store_true",
                    help="single-device shard loop instead of shard_map")
    ap.add_argument("--drift", action="store_true",
                    help="drifting-workload replay: rotate the hot set "
                         "mid-stream and replan online")
    ap.add_argument("--drift-at", type=float, default=0.5,
                    help="fraction of the stream after which rows remap")
    ap.add_argument("--drift-seed", type=int, default=7)
    ap.add_argument("--replan-threshold", type=float, default=0.2)
    ap.add_argument("--replan-half-life", type=float, default=4.0)
    ap.add_argument("--replan-min-queries", type=int, default=64)
    ap.add_argument("--slack-tiles", type=int, default=8,
                    help="per-shard zero-tile image headroom for promotions")
    ap.add_argument("--capacity-frac", type=float, default=None,
                    help="tiered storage (DESIGN.md §9): cap the per-shard "
                         "hot-tier image at this fraction of what an "
                         "uncapped plan would need — 0.25 means the device "
                         "holds a quarter of the working set; cold queries "
                         "serve via the host gather+sum path and drift-"
                         "driven paging swaps groups in/out at flush "
                         "barriers (None: untiered, everything resident)")
    ap.add_argument("--capacity-tiles", type=int, default=None,
                    help="absolute per-shard hot-tier budget in tiles "
                         "(alternative to --capacity-frac)")
    ap.add_argument("--tier-hysteresis", type=float, default=1.5,
                    help="load ratio a cold group must beat over its "
                         "eviction victim to page in (anti-thrash; >= 1)")
    ap.add_argument("--host-batch", type=int, default=None,
                    help="cold queries buffered before a host-path flush "
                         "(default: --batch-size)")
    ap.add_argument("--host-deadline", type=int, default=None,
                    help="max submissions a queued cold query waits before "
                         "a forced host flush (default: 4x host batch)")
    ap.add_argument("--inject", default=None, metavar="KIND:N[,KIND:N...]",
                    help="chaos replay (DESIGN.md §8): inject a seeded, "
                         "deterministic fault schedule, e.g. "
                         "'compile:2,device:1,poison:2,hang:1'.  Kinds: "
                         "compile (transient host-compile failure), "
                         "device (fault at dispatch), device-late (fault "
                         "at retire), hang (flush never reports ready — "
                         "pair with --watchdog), poison (a (table, seq) "
                         "query that fails every containing batch until "
                         "bisection quarantines it), patch (staged plan "
                         "patch fails to apply).  The self-healing "
                         "policy retries/bisects/degrades; the report's "
                         "'faults' section shows the ledger")
    ap.add_argument("--inject-seed", type=int, default=0,
                    help="fault-plan draw + retry-jitter seed (same seed "
                         "+ same replay = same faults: replayable chaos)")
    ap.add_argument("--inject-hang-s", type=float, default=None,
                    help="simulated hang duration for injected 'hang' "
                         "faults (default: forever — the watchdog's job)")
    ap.add_argument("--watchdog", type=float, default=None,
                    help="per-flush watchdog deadline in seconds: a "
                         "flush not ready by then is timed out and "
                         "served degraded via the inline host path "
                         "(None: no watchdog)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="in-place re-dispatch attempts per failed flush "
                         "before bisection/quarantine (0 + the other "
                         "defaults still bisects; see RetryPolicy)")
    return ap.parse_args(argv)


def build_fault_plan(args, table_names, requests):
    """``--inject 'compile:2,poison:1'`` → a seeded FaultPlan (None when
    no injection was requested)."""
    if not args.inject:
        return None
    from repro.serve.faults import FaultPlan

    counts = {}
    for part in args.inject.split(","):
        part = part.strip()
        if not part:
            continue
        kind, _, n = part.partition(":")
        counts[kind.strip()] = int(n) if n else 1
    per_table = max(1, requests // max(1, len(table_names)))
    producers = (
        tuple(f"p{i}" for i in range(args.producers))
        if args.producers > 1 else ()
    )
    return FaultPlan.random(
        args.inject_seed, counts,
        horizon=max(4, requests // max(1, args.batch_size)),
        tables=tuple(table_names),
        max_seq=max(1, per_table // max(1, args.producers)),
        hang_s=args.inject_hang_s,
        producers=producers,
    )


def main(args) -> None:
    # deferred: jax must initialize AFTER the XLA_FLAGS device forcing
    import numpy as np
    import jax

    from repro.data import zipf_queries
    from repro.serve.sharded import ShardedEmbeddingServer

    rng = np.random.default_rng(0)
    tables = {
        f"t{i}": rng.normal(size=(args.rows, args.dim)).astype(np.float32)
        for i in range(args.tables)
    }
    histories = {
        name: zipf_queries(args.rows, args.history, args.mean_bag, seed=i)
        for i, name in enumerate(tables)
    }

    mesh = None
    if not args.emulate:
        if len(jax.devices()) < args.shards:
            raise SystemExit(
                f"only {len(jax.devices())} devices visible, need {args.shards} "
                "(XLA_FLAGS forcing failed?)"
            )
        mesh = jax.make_mesh((1, args.shards), ("data", "model"))

    replan_cfg = None
    if args.drift:
        from repro.serve.drift import ReplanConfig

        replan_cfg = ReplanConfig(
            threshold=args.replan_threshold,
            half_life=args.replan_half_life,
            min_queries=args.replan_min_queries,
            slack_tiles=args.slack_tiles,
        )
    from repro.serve.faults import RetryPolicy

    tiers_cfg = None
    if args.capacity_frac is not None or args.capacity_tiles is not None:
        from repro.serve.tiers import TierConfig

        tiers_cfg = TierConfig(
            capacity_tiles=args.capacity_tiles,
            capacity_frac=args.capacity_frac,
            hysteresis=args.tier_hysteresis,
            host_batch=args.host_batch,
            host_deadline=args.host_deadline,
        )
    fault_plan = build_fault_plan(args, list(tables), args.requests)
    server = ShardedEmbeddingServer(
        tables, histories,
        num_shards=args.shards, mesh=mesh,
        q_block=args.q_block, group_size=args.group_size,
        batch_size=args.batch_size,
        combine=args.combine, combine_chunks=args.combine_chunks,
        replan=replan_cfg,
        flush_policy=args.flush_policy,
        union_budget=args.union_budget,
        flush_deadline=args.flush_deadline,
        owner_set_max=args.owner_set_max,
        max_in_flight=args.max_in_flight,
        threaded=args.threaded,
        retry=RetryPolicy(max_retries=args.max_retries,
                          watchdog_s=args.watchdog,
                          seed=args.inject_seed),
        faults=fault_plan,
        tiers=tiers_cfg,
    )

    stream = zipf_queries(args.rows, args.requests, args.mean_bag, seed=1234)
    if args.drift:
        # hot-set rotation: remap every row id through a fixed permutation
        # for the tail of the stream (serve-time drift the offline plan
        # never saw; the replanner must chase it incrementally)
        cut = int(len(stream) * args.drift_at)
        perm = np.random.default_rng(args.drift_seed).permutation(args.rows)
        stream = stream[:cut] + [
            perm[np.asarray(q, dtype=np.int64)] for q in stream[cut:]
        ]
    names = list(tables)
    # per-table arrival replay: uniform round robin at skew 1, weighted
    # choice otherwise (table i's arrival rate ∝ skew^-i) — tables fill
    # at different rates, so per-shard unions fill at different rates
    if args.skew != 1.0:
        w = np.power(float(args.skew), -np.arange(len(names)))
        pick = np.random.default_rng(5).choice(
            len(names), size=len(stream), p=w / w.sum()
        )
    else:
        pick = np.arange(len(stream)) % len(names)
    flushed = 0
    import time
    if args.producers > 1:
        # multi-producer front door (DESIGN.md §10): the stream splits
        # round-robin, each producer thread submits under its own label
        # (= its own sequence space) and the full drain at the end
        # merges the streams deterministically
        if args.flush_policy == "global":
            raise SystemExit("--producers > 1 requires an async "
                             "--flush-policy (per-shard/deadline/"
                             "owner-set)")
        import threading

        labels = [f"p{i}" for i in range(args.producers)]
        slices = {
            lab: [(names[int(pick[i])], stream[i])
                  for i in range(len(stream))
                  if i % args.producers == p]
            for p, lab in enumerate(labels)
        }
        # registration order pins producer ids (the merge tiebreak)
        # independently of which thread wins the first stamp
        for lab in labels:
            server.register_producer(lab)

        def run(lab):
            for name, q in slices[lab]:
                server.submit(name, q, producer=lab)

        t0 = time.perf_counter()
        threads = [
            threading.Thread(target=run, args=(lab,), name=lab)
            for lab in labels
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if server.drain():
            flushed += 1
        wall = time.perf_counter() - t0
    else:
        t0 = time.perf_counter()
        for i, q in enumerate(stream):
            out = server.submit(names[int(pick[i])], q)
            if out:
                flushed += 1
        if server.flush():
            flushed += 1
        wall = time.perf_counter() - t0

    server.close()
    report = server.report()
    report["flushes"] = flushed
    report["replay_wall_s"] = wall
    report["producers"] = args.producers
    print(json.dumps(report, indent=1, default=str))


if __name__ == "__main__":
    _args = parse_args()
    if not _args.emulate:
        # must precede the first jax import (inside main)
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={max(_args.shards, 1)} "
            + os.environ.get("XLA_FLAGS", "")
        )
    main(_args)
