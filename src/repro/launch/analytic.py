"""Analytic FLOP / HBM-byte accounting per (arch × shape).

``cost_analysis()`` on scan-based HLO counts each loop body ONCE (XLA
cost analysis does not multiply by trip count), so compiled-artifact FLOPs
under-count deep models by ~L×.  The roofline therefore uses these exact
analytic formulas for the compute and memory terms — standard 6ND-style
accounting extended with attention, MoE routing and cache traffic — and
keeps the raw artifact numbers alongside for transparency
(EXPERIMENTS.md §Roofline documents the discrepancy).

Conventions:
  * bf16 params/activations (2 B), f32 optimizer moments (4 B);
  * train FLOPs = 3× forward (fwd + 2× bwd), remat adds +1× forward of
    recomputation inside the bwd when enabled (factor 4 instead of 3);
  * causal attention counts the full s² score work for the chunked
    implementation (it does not skip fully-masked blocks — recorded as a
    known optimization target in §Perf).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class CellCost:
    flops: float
    hbm_bytes: float
    notes: str = ""


def _attn_flops_fwd(cfg: ModelConfig, b: int, s: int, causal_skip: bool) -> float:
    """QKVO projections + score/value matmuls for one forward pass, all layers."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.kv_heads
    L = _attn_layer_count(cfg)
    proj = 2 * b * s * d * (H * hd + 2 * KV * hd + H * hd)
    pair_factor = 0.5 if causal_skip else 1.0
    scores = 2 * b * H * s * s * hd * pair_factor * 2  # qk^T and attn@v
    return L * (proj + scores)


def _attn_layer_count(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.shared_attn_period  # shared block applications
    if cfg.family == "vlm":
        period = cfg.cross_attn_period
        return cfg.num_layers  # self layers + cross layers ≈ num_layers total
    if cfg.family == "ssm":
        return 0
    return cfg.num_layers


def _ffn_flops_fwd(cfg: ModelConfig, tokens: float) -> float:
    mats = 3 if cfg.act in ("swiglu", "geglu") else 2
    if cfg.moe:
        per_tok = 2 * mats * cfg.d_model * cfg.d_ff * cfg.moe.top_k
        router = 2 * cfg.d_model * cfg.moe.num_experts
        n_ffn = cfg.num_layers
        return tokens * n_ffn * (per_tok + router)
    if cfg.d_ff == 0 or cfg.family == "hybrid":
        return 0.0
    n_ffn = cfg.num_layers if cfg.family != "vlm" else cfg.num_layers
    return tokens * n_ffn * 2 * mats * cfg.d_model * cfg.d_ff


def _recurrent_flops_fwd(cfg: ModelConfig, b: int, s: int) -> float:
    d = cfg.d_model
    if cfg.family == "ssm":
        # mLSTM/sLSTM: 4 d×d projections + per-step d_head² memory update
        hd = d // cfg.num_heads
        per_tok = 2 * 4 * d * d + 2 * cfg.num_heads * hd * hd * 2
        return cfg.num_layers * b * s * per_tok
    if cfg.family == "hybrid":
        d_inner = 2 * d
        N = cfg.ssm_state
        heads = d_inner // 64
        per_tok = (
            2 * d * (2 * d_inner + 2 * N + heads)   # in-proj
            + 2 * d_inner * d                        # out-proj
            + 2 * heads * 64 * N * 2                 # state update + readout
        )
        return cfg.num_layers * b * s * per_tok
    return 0.0


def _embed_head_flops_fwd(cfg: ModelConfig, tokens: float) -> float:
    heads = cfg.num_codebooks or 1
    return 2 * tokens * cfg.d_model * cfg.padded_vocab * heads


def forward_flops(cfg: ModelConfig, b: int, s: int, *, causal_skip: bool = False) -> float:
    tokens = float(b) * s
    return (
        _attn_flops_fwd(cfg, b, s, causal_skip)
        + _ffn_flops_fwd(cfg, tokens)
        + _recurrent_flops_fwd(cfg, b, s)
        + _embed_head_flops_fwd(cfg, tokens)
    )


def _param_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * 2.0  # bf16


def _act_traffic_fwd(cfg: ModelConfig, b: int, s: int) -> float:
    """HBM activation traffic of one forward pass (reads+writes), bf16."""
    d = cfg.d_model
    per_tok_per_layer = (
        4 * d            # residual stream reads/writes
        + 4 * d          # attn/block in+out
        + (6 * cfg.d_ff * (cfg.moe.top_k / 1 if cfg.moe else 1) if cfg.d_ff else 8 * d)
    )
    return 2.0 * b * s * cfg.num_layers * per_tok_per_layer


def train_cost(cfg: ModelConfig, shape: ShapeConfig, *, remat: bool = True,
               optimizer: str = "adamw") -> CellCost:
    b, s = shape.global_batch, shape.seq_len
    fwd = forward_flops(cfg, b, s)
    flops = fwd * (4.0 if remat else 3.0)
    opt_bytes_per_param = 24.0 if optimizer == "adamw" else 8.5
    p = cfg.param_count()
    hbm = (
        p * (2 + 2 + 2)                    # params read (fwd+bwd) + grads write
        + p * opt_bytes_per_param          # optimizer read/write
        + _act_traffic_fwd(cfg, b, s) * (3.0 if remat else 2.0)
    )
    return CellCost(flops=flops, hbm_bytes=hbm,
                    notes=f"remat={remat} optimizer={optimizer}")


def prefill_cost(cfg: ModelConfig, shape: ShapeConfig) -> CellCost:
    b, s = shape.global_batch, shape.seq_len
    return CellCost(
        flops=forward_flops(cfg, b, s),
        hbm_bytes=_param_bytes(cfg) + _act_traffic_fwd(cfg, b, s)
        + 2.0 * b * s * _attn_layer_count(cfg) * cfg.kv_heads
        * cfg.resolved_head_dim * 2 * 2,  # KV cache write
        notes="prefill",
    )


def decode_cost(cfg: ModelConfig, shape: ShapeConfig, *, window: int | None = None,
                kv_dtype_bytes: float = 2.0) -> CellCost:
    """kv_dtype_bytes: 2.0 bf16, 1.125 for int8 + per-head scales."""
    b, S = shape.global_batch, shape.seq_len
    ctx = min(S, window) if window else S
    flops = forward_flops(cfg, b, 1)
    # attention over the cache: 2 matmuls of (1 × ctx × hd) per head
    L_attn = _attn_layer_count(cfg)
    flops += L_attn * 2 * b * cfg.num_heads * ctx * cfg.resolved_head_dim * 2
    kv_bytes = L_attn * b * ctx * cfg.kv_heads * cfg.resolved_head_dim * 2 * kv_dtype_bytes
    state_bytes = 0.0
    if cfg.family in ("ssm", "hybrid"):
        d_inner = 2 * cfg.d_model
        if cfg.family == "ssm":
            hd = cfg.d_model // cfg.num_heads
            state_bytes = cfg.num_layers * b * cfg.num_heads * hd * hd * 4 * 2
        else:
            heads = d_inner // 64
            state_bytes = cfg.num_layers * b * heads * 64 * cfg.ssm_state * 4 * 2
    hbm = _param_bytes(cfg) + kv_bytes + state_bytes
    return CellCost(flops=flops, hbm_bytes=hbm, notes=f"decode ctx={ctx}")


def cell_cost(cfg: ModelConfig, shape: ShapeConfig, **kw) -> CellCost:
    if shape.kind == "train":
        return train_cost(cfg, shape, **kw)
    if shape.kind == "prefill":
        return prefill_cost(cfg, shape)
    return decode_cost(cfg, shape, **kw)
