"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it runs smoke-scale configs on a 1×1 mesh; on a real
cluster the same entrypoint takes ``--mesh single|multi`` and the
production mesh from launch/mesh.py.  Features exercised end-to-end:
deterministic sharded data pipeline, mixed-precision train step,
grad accumulation, checkpoint/restart (auto-resume from latest), async
saves, heartbeat + straggler bookkeeping.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import TokenBatcher
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.transformer import init_lm
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import HeartbeatMonitor, StragglerDetector
from repro.train.loop import init_train_state, make_train_step
from repro.train.optimizer import AdamW, make_schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    print(f"arch={cfg.name} family={cfg.family} params~{cfg.param_count()/1e6:.1f}M (full-config count)")

    rng = jax.random.PRNGKey(0)
    opt = AdamW(schedule=make_schedule(cfg.schedule, args.lr, args.steps))
    step_fn = jax.jit(make_train_step(
        cfg, opt, microbatches=args.microbatches, has_enc=(cfg.family == "vlm")
    ))

    latest = ckpt.latest_step(args.ckpt_dir)
    params = init_lm(rng, cfg)
    state = init_train_state(params, opt)
    start = 0
    if latest is not None:
        state = ckpt.restore(args.ckpt_dir, latest, jax.eval_shape(lambda: state))
        start = latest
        print(f"resumed from step {latest}")

    data = TokenBatcher(cfg.vocab_size, args.batch, args.seq, seed=0)
    hb = HeartbeatMonitor()
    stragglers = StragglerDetector()
    pending = None

    for step in range(start, args.steps):
        t0 = time.time()
        tokens, labels = data.batch(step)
        batch = {"tokens": tokens, "labels": labels}
        if cfg.family == "vlm":
            batch["enc"] = np.zeros(
                (args.batch, cfg.num_image_tokens, cfg.d_model), np.float32
            ).astype(cfg.jnp_dtype)
        if cfg.family == "audio":
            k = cfg.num_codebooks
            batch["tokens"] = np.stack([tokens] * k, axis=1)
            batch["labels"] = np.stack([labels] * k, axis=1)
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        hb.beat(0, step)
        stragglers.record(0, dt)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if (step + 1) % args.save_every == 0:
            if pending is not None:
                pending.wait()
            pending = ckpt.save_async(args.ckpt_dir, step + 1, state)
    if pending is not None:
        pending.wait()
    print("done; dead hosts:", hb.dead_hosts(), "stragglers:", stragglers.stragglers())


if __name__ == "__main__":
    main()
