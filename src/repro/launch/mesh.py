"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set
XLA_FLAGS before the first jax init, and tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1×1 mesh on the local device (smoke tests, examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def chips(mesh) -> int:
    return mesh.devices.size
