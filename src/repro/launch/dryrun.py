"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: for each
cell, the train/serve step is jit-lowered with ShapeDtypeStruct inputs
(no allocation), compiled for the 256-chip single-pod mesh and the
512-chip two-pod mesh, and the compiled artifact's memory / cost /
collective footprint is recorded for §Dry-run and §Roofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch grok-1-314b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch dlrm-recross

Results are cached as JSON under experiments/dryrun/ (one file per cell);
``--force`` recomputes.
"""

# The host platform must present 512 devices BEFORE jax initializes —
# these two lines must stay the very first executable statements.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, supported_shapes
from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import (
    LOGICAL_RULES_MULTI_POD,
    LOGICAL_RULES_SINGLE_POD,
    activation_sharding_ctx,
    param_specs_for,
    sanitize_spec,
    sanitize_specs_tree,
)
from repro.launch.analytic import cell_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyse, model_flops_for
from repro.models.transformer import init_lm
from repro.serve.decode import decode_step
from repro.serve.kvcache import init_cache
from repro.train.loop import TrainState, make_train_step
from repro.train.optimizer import AdamW, Adafactor, make_schedule

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

# decode cells for huge KV caches use a bounded cache window per shape
DECODE_WINDOW = {"long_500k": 4096}


def pick_microbatches(cfg: ModelConfig, shape: ShapeConfig, dp: int,
                      *, target_gib: float = 9.0) -> int:
    """Grad-accumulation factor so saved activations fit next to params.

    Estimate: remat keeps ~4 residual-stream-sized tensors per layer per
    microbatch (layer input carry + attention/MLP block I/O), bf16.
    """
    b_local = max(shape.global_batch // dp, 1)
    per_mb_gib = (
        cfg.num_layers * b_local * shape.seq_len * cfg.d_model * 2 * 4 / 2**30
    )
    mb = 1
    while per_mb_gib / mb > target_gib and mb < shape.global_batch // dp and mb < 64:
        mb *= 2
    return mb


def pick_optimizer(cfg: ModelConfig):
    """Adafactor for ≥30B params (optimizer bytes/chip), AdamW otherwise."""
    sched = make_schedule(cfg.schedule, 3e-4, 10_000)
    if cfg.param_count() >= 30e9:
        return Adafactor(schedule=sched)
    return AdamW(schedule=sched)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.family == "audio":
            toks = jax.ShapeDtypeStruct((b, cfg.num_codebooks, s), i32)
            labels = jax.ShapeDtypeStruct((b, cfg.num_codebooks, s), i32)
        else:
            toks = jax.ShapeDtypeStruct((b, s), i32)
            labels = jax.ShapeDtypeStruct((b, s), i32)
        batch = {"tokens": toks, "labels": labels}
        if cfg.family == "vlm":
            batch["enc"] = jax.ShapeDtypeStruct(
                (b, cfg.num_image_tokens, cfg.d_model), cfg.jnp_dtype
            )
        return batch
    if shape.kind == "prefill":
        if cfg.family == "audio":
            toks = jax.ShapeDtypeStruct((b, cfg.num_codebooks, s), i32)
        else:
            toks = jax.ShapeDtypeStruct((b, s), i32)
        out = {"tokens": toks}
        if cfg.family == "vlm":
            out["enc"] = jax.ShapeDtypeStruct(
                (b, cfg.num_image_tokens, cfg.d_model), cfg.jnp_dtype
            )
        return out
    # decode: one new token against a seq_len cache
    if cfg.family == "audio":
        toks = jax.ShapeDtypeStruct((b, cfg.num_codebooks, 1), i32)
    else:
        toks = jax.ShapeDtypeStruct((b, 1), i32)
    out = {"tokens": toks}
    if cfg.family == "vlm":
        out["enc"] = jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, cfg.d_model), cfg.jnp_dtype
        )
    return out


# ------------------------------------------------------ sharding of state --


def _dp_axis(rules):
    return rules["batch"]


def batch_specs(batch_avals, rules, mesh):
    dp = _dp_axis(rules)

    def spec(a):
        parts = [dp] + [None] * (len(a.shape) - 1)
        return sanitize_spec(P(*parts), a.shape, mesh)

    return jax.tree.map(spec, batch_avals)


def opt_state_specs(opt_state_avals, params_specs, mesh):
    """Moments inherit param specs; factored/absent dims fall back cleanly."""
    p_leaves = jax.tree.leaves(params_specs, is_leaf=lambda x: isinstance(x, P))

    def for_moment_tree(tree_avals):
        leaves, treedef = jax.tree.flatten(tree_avals)
        out = []
        for aval, pspec in zip(leaves, p_leaves):
            parts = list(pspec)[: len(aval.shape)]
            out.append(sanitize_spec(P(*parts), aval.shape, mesh))
        return treedef.unflatten(out)

    if hasattr(opt_state_avals, "mu"):
        return type(opt_state_avals)(
            step=P(),
            mu=for_moment_tree(opt_state_avals.mu),
            nu=for_moment_tree(opt_state_avals.nu),
        )
    # Adafactor
    return type(opt_state_avals)(
        step=P(),
        vr=for_moment_tree(opt_state_avals.vr),
        vc=for_moment_tree(opt_state_avals.vc),
    )


_CACHE_MODEL_DIM_PRIORITY = {
    # key name -> candidate dims (index into shape) to shard by model.
    # K/V: kv-heads first, then SEQUENCE — never head_dim: a d-contracted
    # cache forces GSPMD to all-gather the whole cache every layer
    # (measured 98 GB/step on minicpm decode_32k, §Perf), while seq-sharded
    # caches reduce to output-sized psums.
    "k": (3, 2), "v": (3, 2), "k_scale": (3, 2), "v_scale": (3, 2), "pos": (),
    "h": (2, 3), "conv": (3,),
    "m_C": (2, 3), "m_n": (2, 3), "m_m": (2,),
    "s_c": (2,), "s_n": (2,), "s_h": (2,), "s_m": (2,),
}
_CACHE_BATCH_DIM = {
    "k": 1, "v": 1, "pos": 1, "h": 1, "conv": 1,
    "m_C": 1, "m_n": 1, "m_m": 1, "s_c": 1, "s_n": 1, "s_h": 1, "s_m": 1,
}


def cache_specs(cache_avals, rules, mesh, *, priority_override: dict | None = None):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = sizes.get("model", 1)
    dp = _dp_axis(rules)
    prio = dict(_CACHE_MODEL_DIM_PRIORITY)
    if priority_override:
        prio.update(priority_override)

    def visit(path, aval):
        key = None
        for p in reversed(path):
            if hasattr(p, "key"):
                key = p.key
                break
        shape = aval.shape
        if not shape or key in (None, "len"):
            return P()
        parts = [None] * len(shape)
        bdim = _CACHE_BATCH_DIM.get(key)
        if bdim is not None and bdim < len(shape):
            parts[bdim] = dp
        for cand in prio.get(key, ()):
            if cand < len(shape) and shape[cand] % model_n == 0 and parts[cand] is None:
                parts[cand] = "model"
                break
        return sanitize_spec(P(*parts), shape, mesh)

    return jax.tree_util.tree_map_with_path(visit, cache_avals)


# ------------------------------------------------------------- the cells --


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool,
    results_dir: str = RESULTS_DIR,
    force: bool = False,
    remat: bool = True,
    variant: dict | None = None,
) -> dict:
    """One dry-run cell.  ``variant`` (hillclimb A/B knobs):
      name: str            — suffix for the result file
      rules: dict          — logical-rule overrides (e.g. {"seq": "model"} = SP)
      kv_quant: bool       — int8 KV cache (decode cells)
      readonly_cache: bool — batched-cache-write decode path
      cfg_overrides: dict  — dataclasses.replace overrides on the ModelConfig
      microbatches: int    — force a grad-accumulation factor
    """
    variant = variant or {}
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    if variant.get("name"):
        cell_id += f"__{variant['name']}"
    os.makedirs(results_dir, exist_ok=True)
    out_path = os.path.join(results_dir, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    t0 = time.time()
    cfg = get_config(arch)
    if variant.get("cfg_overrides"):
        cfg = dataclasses.replace(cfg, **variant["cfg_overrides"])
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = LOGICAL_RULES_MULTI_POD if multi_pod else LOGICAL_RULES_SINGLE_POD
    if variant.get("rules"):
        rules = dict(rules, **variant["rules"])
    nchips = mesh.devices.size

    rng = jax.random.PRNGKey(0)
    params_avals = jax.eval_shape(lambda r: init_lm(r, cfg), rng)
    p_specs = sanitize_specs_tree(
        param_specs_for(params_avals, rules, moe=cfg.moe is not None),
        params_avals, mesh,
    )
    p_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), p_specs,
        is_leaf=lambda x: isinstance(x, P),
    )

    record = {
        "cell": cell_id, "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": nchips, "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "kind": shape.kind,
    }

    with activation_sharding_ctx(mesh, rules):
        if shape.kind == "train":
            optimizer = pick_optimizer(cfg)
            opt_avals = jax.eval_shape(optimizer.init, params_avals)
            o_specs = opt_state_specs(opt_avals, p_specs, mesh)
            state_avals = TrainState(
                params=params_avals, opt_state=opt_avals,
                step=jax.ShapeDtypeStruct((), jnp.int32),
            )
            state_shardings = TrainState(
                params=p_shardings,
                opt_state=jax.tree.map(
                    lambda s: NamedSharding(mesh, s), o_specs,
                    is_leaf=lambda x: isinstance(x, P),
                ),
                step=NamedSharding(mesh, P()),
            )
            batch_avals = input_specs(cfg, shape)
            b_specs = batch_specs(batch_avals, rules, mesh)
            b_shardings = jax.tree.map(
                lambda s: NamedSharding(mesh, s), b_specs,
                is_leaf=lambda x: isinstance(x, P),
            )
            dp_total = nchips // dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
            microbatches = variant.get("microbatches") or pick_microbatches(cfg, shape, dp_total)
            record["microbatches"] = microbatches
            accum_dtype = jnp.bfloat16 if variant.get("accum_bf16") else jnp.float32
            step_fn = make_train_step(
                cfg, optimizer, remat=remat, microbatches=microbatches,
                has_enc=(cfg.family == "vlm"), accum_dtype=accum_dtype,
            )
            jitted = jax.jit(
                step_fn,
                in_shardings=(state_shardings, b_shardings),
                out_shardings=(state_shardings, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_avals, batch_avals)
            record["optimizer"] = type(optimizer).__name__

        else:  # prefill / decode → serve path
            batch_avals = input_specs(cfg, shape)
            b_specs = batch_specs(batch_avals, rules, mesh)
            b_shardings = jax.tree.map(
                lambda s: NamedSharding(mesh, s), b_specs,
                is_leaf=lambda x: isinstance(x, P),
            )
            if shape.kind == "prefill":
                from repro.models.transformer import forward

                def serve_prefill(params, batch):
                    logits, _ = forward(
                        params, cfg, batch["tokens"], enc=batch.get("enc")
                    )
                    return logits

                jitted = jax.jit(
                    serve_prefill,
                    in_shardings=(p_shardings, b_shardings),
                )
                lowered = jitted.lower(params_avals, batch_avals)
            else:  # decode
                window = DECODE_WINDOW.get(shape_name, shape.seq_len)
                kv_quant = bool(variant.get("kv_quant"))
                cache_avals = jax.eval_shape(
                    lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                                       window=window, quant=kv_quant)
                )
                prio = None
                if variant.get("cache_seq_shard"):
                    # shard K/V caches on the sequence axis: attention over
                    # the cache contracts seq, so the collective payload is
                    # output-sized psums instead of gathered caches
                    prio = {
                        "k": (2,), "v": (2,),
                        "k_scale": (2,), "v_scale": (2,),
                    }
                c_specs = cache_specs(cache_avals, rules, mesh,
                                      priority_override=prio)
                c_shardings = jax.tree.map(
                    lambda s: NamedSharding(mesh, s), c_specs,
                    is_leaf=lambda x: isinstance(x, P),
                )

                # fleet default: read-only-cache decode (batched cache
                # writes; see §Perf decode iterations). The legacy
                # scan-carried-cache path remains selectable for A/B.
                readonly = bool(variant.get("readonly_cache", True)) or kv_quant

                def serve_decode(params, cache, batch):
                    return decode_step(
                        params, cfg, batch["tokens"], cache, enc=batch.get("enc"),
                        readonly_cache=readonly,
                    )

                jitted = jax.jit(
                    serve_decode,
                    in_shardings=(p_shardings, c_shardings, b_shardings),
                    out_shardings=(None, c_shardings),
                    donate_argnums=(1,),
                )
                lowered = jitted.lower(params_avals, cache_avals, batch_avals)

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    record["memory_analysis"] = {
        "argument_size_gib": mem.argument_size_in_bytes / 2**30,
        "output_size_gib": mem.output_size_in_bytes / 2**30,
        "temp_size_gib": mem.temp_size_in_bytes / 2**30,
        "alias_size_gib": mem.alias_size_in_bytes / 2**30,
        # donated outputs alias their arguments — subtract once
        "per_device_total_gib": (
            mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes
        ) / 2**30,
    }
    cost_kw = {}
    if shape.kind == "train":
        cost_kw = {"remat": remat, "optimizer": record.get("optimizer", "adamw").lower()}
    elif shape.kind == "decode":
        cost_kw = {"window": DECODE_WINDOW.get(shape_name)}
        if variant.get("kv_quant"):
            cost_kw["kv_dtype_bytes"] = 1.125
    acost = cell_cost(cfg, shape, **cost_kw)
    rep = analyse(
        arch=arch, shape=shape_name, mesh_name=mesh_name, chips=nchips,
        compiled=compiled, model_flops=model_flops_for(cfg, shape),
        analytic_flops=acost.flops, analytic_bytes=acost.hbm_bytes,
    )
    record["roofline"] = rep.to_dict()
    record["compile_seconds"] = time.time() - t0

    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def run_dlrm_cell(*, multi_pod: bool, results_dir: str = RESULTS_DIR, force=False,
                  variant: dict | None = None) -> dict:
    """DLRM train-step dry-run (the paper's own model) on the big meshes.

    variant {"name": "hotrep", "hot_fraction": 0.02} enables the ReCross
    Eq.-1 replication applied as a SHARDING strategy: the hottest rows
    (remapped to low ids by the offline grouping phase) are stored
    REPLICATED across model shards — their gathers become collective-free;
    only the cold tail pays the sharded-gather exchange.
    """
    variant = variant or {}
    hot_fraction = float(variant.get("hot_fraction", 0.0))
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"dlrm-recross__train_rec__{mesh_name}"
    if variant.get("name"):
        cell_id += f"__{variant['name']}"
    os.makedirs(results_dir, exist_ok=True)
    out_path = os.path.join(results_dir, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    t0 = time.time()
    from repro.configs.dlrm_recross import FULL as dcfg
    from repro.models.dlrm import init_dlrm

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = LOGICAL_RULES_MULTI_POD if multi_pod else LOGICAL_RULES_SINGLE_POD
    dp = rules["batch"]
    rng = jax.random.PRNGKey(0)
    R, D = dcfg.rows_per_table, dcfg.embed_dim
    # pad tables to a 256 multiple so every sharding divides (standard)
    R = ((R + 255) // 256) * 256
    dcfg = dataclasses.replace(dcfg, rows_per_table=R)
    # hot rows occupy ids [0, H): the offline grouping phase remaps hot
    # groups to the head of the physical id space (frequency-descending),
    # so a Zipf-weighted query's lookups hit the replicated head w.p.
    # ~hot_coverage >> hot_fraction.
    H = int(R * hot_fraction)
    H = (H // 256) * 256

    params_avals = jax.eval_shape(lambda r: init_dlrm(r, dcfg), rng)
    if H:
        def split_tables(p):
            tabs = {}
            for k, v in p["tables"].items():
                tabs[k] = {"hot": v[:H], "cold": v[H:]}
            return dict(p, tables=tabs)

        params_avals = jax.eval_shape(split_tables, params_avals)

    def dlrm_spec(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if "/hot" in name or name.endswith("hot"):
            return P()  # replicated hot shard — Eq.1 at the sharding level
        if "tables" in name:
            return sanitize_spec(P("model", None), leaf.shape, mesh)
        if name.endswith("/w"):
            return sanitize_spec(P(None, "model"), leaf.shape, mesh)
        return P()

    p_specs = jax.tree_util.tree_map_with_path(dlrm_spec, params_avals)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                           is_leaf=lambda x: isinstance(x, P))
    B = 8192
    batch_avals = {
        "dense": jax.ShapeDtypeStruct((B, dcfg.dense_features), jnp.float32),
        "labels": jax.ShapeDtypeStruct((B,), jnp.float32),
        "sparse": {
            f"t{t}": jax.ShapeDtypeStruct((B, dcfg.max_bag), jnp.int32)
            for t in range(dcfg.num_tables)
        },
    }
    b_specs = jax.tree.map(
        lambda a: sanitize_spec(P(*([dp] + [None] * (len(a.shape) - 1))), a.shape, mesh),
        batch_avals,
    )
    b_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs,
                           is_leaf=lambda x: isinstance(x, P))

    shardmap_bag = bool(variant.get("shardmap_bag"))
    model_n = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)

    def _smbag(table, idx, rows):
        """shard_map sharded embedding bag: each model shard reduces its
        local rows, one psum of the (B_local, D) partials combines — the
        collective payload is OUTPUT-sized (B·D), not TABLE-sized."""

        def local(table_loc, idx_loc):
            shard = jax.lax.axis_index("model")
            r_loc = table_loc.shape[0]
            rel = idx_loc - shard * r_loc
            ok = (rel >= 0) & (rel < r_loc) & (idx_loc >= 0)
            take = table_loc[jnp.clip(rel, 0, r_loc - 1)] * ok[..., None].astype(table_loc.dtype)
            return jax.lax.psum(take.sum(axis=1), "model")

        try:
            shard_map = jax.shard_map
        except AttributeError:  # jax < 0.5
            from jax.experimental.shard_map import shard_map

        return shard_map(
            local, mesh=mesh,
            in_specs=(P("model", None), P(dp, None)),
            out_specs=P(dp, None),
        )(table, idx)

    def embed_bag(table_p, idx):
        """Padded gather+sum; hot/cold split when replicated head enabled;
        shard_map lookup when the smbag variant is on."""
        mask = (idx >= 0)[..., None].astype(jnp.float32)
        if H and shardmap_bag:
            # hot head: replicated, gathered locally with no collective;
            # cold tail: shard_map bag (psum of output-sized partials)
            hot, cold = table_p["hot"], table_p["cold"]
            is_hot = (idx < H) & (idx >= 0)
            e_hot = (hot[jnp.clip(idx, 0, H - 1)] * (is_hot[..., None] & (idx >= 0)[..., None])).sum(axis=1)
            cold_idx = jnp.where(is_hot | (idx < 0), -1, idx - H)
            return e_hot + _smbag(cold, cold_idx, R - H)
        if shardmap_bag:
            return _smbag(table_p, idx, R)
        if H:
            hot, cold = table_p["hot"], table_p["cold"]
            is_hot = (idx < H) & (idx >= 0)
            e_hot = hot[jnp.clip(idx, 0, H - 1)] * is_hot[..., None]
            e_cold = cold[jnp.clip(idx - H, 0, R - H - 1)] * (~is_hot)[..., None]
            take = (e_hot + e_cold) * mask
        else:
            take = table_p[jnp.clip(idx, 0, R - 1)] * mask
        return take.sum(axis=1)

    def loss_fn(params, batch):
        x = batch["dense"]
        for pl_ in params["bottom"]:
            x = jax.nn.relu(x @ pl_["w"] + pl_["b"])
        embs = [x] + [
            embed_bag(params["tables"][f"t{t}"], batch["sparse"][f"t{t}"])
            for t in range(dcfg.num_tables)
        ]
        stack = jnp.stack(embs, axis=1)
        inter = jnp.einsum("bnd,bmd->bnm", stack, stack)
        iu = jnp.triu_indices(stack.shape[1], k=1)
        top_in = jnp.concatenate([x, inter[:, iu[0], iu[1]]], axis=-1)
        for i, pl_ in enumerate(params["top"]):
            top_in = top_in @ pl_["w"] + pl_["b"]
            if i < len(params["top"]) - 1:
                top_in = jax.nn.relu(top_in)
        logits = top_in[:, 0]
        labels = batch["labels"]
        return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                        + jnp.log1p(jnp.exp(-jnp.abs(logits))))

    def train_step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
        return new_params, loss

    with activation_sharding_ctx(mesh, rules):
        jitted = jax.jit(
            train_step, in_shardings=(p_shard, b_shard),
            out_shardings=(p_shard, None), donate_argnums=(0,),
        )
        lowered = jitted.lower(params_avals, batch_avals)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    rep = analyse(arch="dlrm-recross", shape="train_rec", mesh_name=mesh_name,
                  chips=mesh.devices.size, compiled=compiled)
    record = {
        "cell": cell_id, "arch": "dlrm-recross", "shape": "train_rec",
        "mesh": mesh_name, "chips": mesh.devices.size,
        "memory_analysis": {
            "per_device_total_gib": (
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes) / 2**30,
        },
        "roofline": rep.to_dict(),
        "compile_seconds": time.time() - t0,
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--results-dir", default=RESULTS_DIR)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS + ["dlrm-recross"]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        if arch == "dlrm-recross":
            for mp in meshes:
                try:
                    rec = run_dlrm_cell(multi_pod=mp, results_dir=args.results_dir,
                                        force=args.force)
                    print(f"OK  {rec['cell']}  ({rec['compile_seconds']:.0f}s)")
                except Exception as e:
                    failures.append(("dlrm-recross", str(e)))
                    traceback.print_exc()
            continue
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else supported_shapes(cfg)
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   results_dir=args.results_dir, force=args.force)
                    r = rec["roofline"]
                    print(
                        f"OK  {rec['cell']:60s} compile={rec['compile_seconds']:6.0f}s "
                        f"dom={r['dominant']:10s} frac={r['roofline_fraction']:.3f} "
                        f"mem/dev={rec['memory_analysis']['per_device_total_gib']:.1f}GiB"
                    )
                except Exception as e:
                    failures.append((f"{arch}/{shape}/mp={mp}", repr(e)))
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for cell, err in failures:
            print(" ", cell, err[:200])
        raise SystemExit(1)
    print("\nall requested dry-run cells passed")


if __name__ == "__main__":
    main()
