"""Serving launcher: continuous-batched decode of a smoke-scale LM.

``python -m repro.launch.serve --arch chatglm3-6b --requests 16``
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.transformer import forward, init_lm
from repro.serve.batching import Request, RequestBatcher
from repro.serve.decode import decode_step
from repro.serve.kvcache import init_cache


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.family in ("audio",):
        raise SystemExit("serve demo targets text LMs; musicgen uses examples/")
    rng = jax.random.PRNGKey(0)
    params = init_lm(rng, cfg)
    cache = init_cache(cfg, args.slots, args.max_seq)
    enc = (
        jnp.zeros((args.slots, cfg.num_image_tokens, cfg.d_model), cfg.jnp_dtype)
        if cfg.family == "vlm" else None
    )
    dstep = jax.jit(lambda p, c, t: decode_step(p, cfg, t, c, enc=enc))

    state = {"cache": cache}

    def prefill_fn(slot, prompt):
        # smoke-scale: feed prompt tokens through decode steps for the slot
        nonlocal state
        tok = np.zeros((args.slots, 1), np.int32)
        last = 0
        for t in prompt:
            tok[slot, 0] = int(t)
            logits, state["cache"] = dstep(params, state["cache"], jnp.asarray(tok))
            last = int(jnp.argmax(logits[slot, -1, : cfg.vocab_size]))
        return last

    def decode_fn(active, last_tokens):
        tok = jnp.asarray(last_tokens[:, None])
        logits, state["cache"] = dstep(params, state["cache"], tok)
        return np.asarray(jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1))

    batcher = RequestBatcher(args.slots, eos_id=-1)
    rng_np = np.random.default_rng(0)
    for uid in range(args.requests):
        batcher.submit(Request(
            uid=uid,
            prompt=rng_np.integers(1, cfg.vocab_size, size=4).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    ticks = 0
    while not batcher.idle:
        batcher.tick(prefill_fn, decode_fn)
        ticks += 1
        if ticks > args.requests * (args.max_new + 8):
            raise RuntimeError("serving did not drain")
    print("served:", batcher.metrics.summary(), f"ticks={ticks}")


if __name__ == "__main__":
    main()
