"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs            / (chips × 197e12 bf16 FLOP/s)
    memory     = HLO_bytes_accessed   / (chips × 819e9  B/s HBM)
    collective = collective_bytes     / (chips × 50e9   B/s ICI link)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
NOT in cost_analysis: :func:`collective_bytes_from_hlo` parses the
optimized HLO text and sums operand bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.

Also computes MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat & redundancy).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.core.energy import TPUCostModel, DEFAULT_TPU

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"(\((?:[^()]|\([^()]*\))*\)|[\w\[\],{}]+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
    re.MULTILINE,
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string like ``bf16[256,4096]{1,0}``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_COMPUTATION_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->", re.MULTILINE)
_WHILE_LINE_RE = re.compile(r"=\s*(?:\([^=]*\)\s+)?while\(", )
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_CALL_RE = re.compile(r"(?:call|async-start)\([^)]*\),\s*to_apply=%?([\w.\-]+)")
_COND_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUE_FALSE_RE = re.compile(
    r"true_computation=%?([\w.\-]+),\s*false_computation=%?([\w.\-]+)"
)


_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """computation name -> body text (optimized HLO module).

    A computation header is a non-indented line ``[ENTRY] %name (args) ->
    type {`` — parameter lists may contain nested parens, so only the name
    prefix is parsed and the line must end with '{' and contain '->'.
    """
    marks = []
    pos = 0
    for line in hlo_text.splitlines(keepends=True):
        stripped = line.rstrip()
        if (stripped.endswith("{") and "->" in stripped
                and not line.startswith((" ", "\t", "}"))):
            m = _COMP_HEADER_RE.match(stripped)
            if m:
                marks.append((pos, m.group(1)))
        pos += len(line)
    out = {}
    for i, (p, name) in enumerate(marks):
        end = marks[i + 1][0] if i + 1 < len(marks) else len(hlo_text)
        out[name] = hlo_text[p:end]
    return out


def _computation_multipliers(comps: Dict[str, str], entry: str) -> Dict[str, float]:
    """Execution count of each computation: while bodies × known_trip_count."""
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry in mult:
        mult[entry] = 1.0
    # propagate (the call graph is acyclic; iterate to fixed point)
    for _ in range(64):
        changed = False
        for name, body in comps.items():
            m = mult.get(name, 0.0)
            if m <= 0:
                continue
            for line in body.splitlines():
                # tuple result types may contain /*index=N*/ comments, so a
                # structural regex on the lhs is fragile — gate on the two
                # tokens that always appear on a while op line
                if "while(" not in line or "body=" not in line:
                    continue
                cond_m = _WHILE_COND_RE.search(line)
                body_m = _WHILE_BODY_RE.search(line)
                trip_m = _TRIP_RE.search(line)
                if not body_m:
                    continue
                n = float(trip_m.group(1)) if trip_m else 1.0
                targets = [(body_m.group(1), n)]
                if cond_m:
                    targets.append((cond_m.group(1), n + 1))
                for target, times in targets:
                    if target in mult:
                        new = m * times
                        if mult[target] < new:
                            mult[target] = new
                            changed = True
            for c in _CALL_RE.finditer(body):
                t = c.group(1)
                if t in mult and mult[t] < m:
                    mult[t] = m
                    changed = True
            for c in list(_COND_RE.finditer(body)) + list(_TRUE_FALSE_RE.finditer(body)):
                names = [s.strip().lstrip("%") for s in re.split(r"[,\s]+", c.group(0)) ]
                for t in names:
                    if t in mult and mult[t] < m:
                        mult[t] = m
                        changed = True
        if not changed:
            break
    return mult


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sums result-shape bytes of every collective op, by op kind,
    multiplied by the executing computation's loop trip count.

    Collectives inside ``lax.scan`` while-bodies execute trip-count times
    but appear once in the HLO text; the multiplier graph (ENTRY=1, while
    body ×= known_trip_count) corrects that.  Uses the *result* shape
    (per-participant output) as the per-chip payload approximation —
    consistent across before/after comparisons.
    """
    comps = _split_computations(hlo_text)
    entry = None
    em = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.MULTILINE)
    if em:
        entry = em.group(1)
    if not comps or entry not in comps:
        # flat module: fall back to uncorrected scan
        out: Dict[str, int] = {}
        for m in _COLLECTIVE_RE.finditer(hlo_text):
            out[m.group(2)] = out.get(m.group(2), 0) + _shape_bytes(m.group(1))
        return out
    mult = _computation_multipliers(comps, entry)
    out = {}
    for name, body in comps.items():
        k = mult.get(name, 0.0)
        if k <= 0:
            continue
        for m in _COLLECTIVE_RE.finditer(body):
            kind, byts = m.group(2), _shape_bytes(m.group(1))
            out[kind] = out.get(kind, 0) + int(byts * k)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float               # raw cost_analysis (undercounts scan bodies)
    hlo_bytes: float               # raw cost_analysis
    collective_bytes: float        # loop-corrected HLO parse
    collective_breakdown: Dict[str, int]
    model_flops: Optional[float] = None
    bytes_per_device: Optional[float] = None
    analytic_flops: Optional[float] = None   # exact formula (compute term)
    analytic_bytes: Optional[float] = None   # exact formula (memory term)
    tpu: TPUCostModel = dataclasses.field(default_factory=lambda: DEFAULT_TPU)

    @property
    def compute_s(self) -> float:
        f = self.analytic_flops if self.analytic_flops else self.hlo_flops
        return self.tpu.compute_time(f, self.chips)

    @property
    def memory_s(self) -> float:
        b = self.analytic_bytes if self.analytic_bytes else self.hlo_bytes
        return self.tpu.memory_time(b, self.chips)

    @property
    def collective_s(self) -> float:
        return self.tpu.collective_time(self.collective_bytes, self.chips)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute_term / max(all terms): 1.0 = perfectly compute-bound."""
        t = self.bound_time_s
        return self.compute_s / t if t > 0 else 0.0

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        """MODEL_FLOPS (6·N_active·D) / analytic compiled FLOPs."""
        denom = self.analytic_flops or self.hlo_flops
        if self.model_flops is None or not denom:
            return None
        return self.model_flops / denom

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_breakdown": self.collective_breakdown,
            "model_flops": self.model_flops,
            "analytic_flops": self.analytic_flops,
            "analytic_bytes": self.analytic_bytes,
            "bytes_per_device": self.bytes_per_device,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "roofline_fraction": self.roofline_fraction,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analyse(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    compiled,
    lowered_text: Optional[str] = None,
    model_flops: Optional[float] = None,
    analytic_flops: Optional[float] = None,
    analytic_bytes: Optional[float] = None,
) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = lowered_text if lowered_text is not None else compiled.as_text()
    breakdown = collective_bytes_from_hlo(text)
    coll = float(sum(breakdown.values()))

    bytes_per_device = None
    try:
        ma = compiled.memory_analysis()
        bytes_per_device = float(
            ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes
        )
    except Exception:
        pass

    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts, collective_bytes=coll,
        collective_breakdown=breakdown, model_flops=model_flops,
        bytes_per_device=bytes_per_device,
        analytic_flops=analytic_flops, analytic_bytes=analytic_bytes,
    )


def model_flops_for(cfg, shape_cfg) -> float:
    """6·N_active·D for a train step (fwd+bwd); fwd-only for serving."""
    n = cfg.active_param_count()
    tokens = shape_cfg.global_batch * (
        shape_cfg.seq_len if shape_cfg.kind != "decode" else 1
    )
    if shape_cfg.kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens
