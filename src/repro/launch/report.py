"""Renders EXPERIMENTS.md §Dry-run / §Roofline tables from the per-cell
JSON records produced by repro.launch.dryrun.

``python -m repro.launch.report [--dir experiments/dryrun]`` prints
markdown; the EXPERIMENTS.md sections are generated with this tool.
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load_cells(results_dir: str, *, include_variants: bool = False) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            c = json.load(f)
        # variant cells carry a 4th "__"-separated component
        if not include_variants and c.get("cell", "").count("__") > 2:
            continue
        cells.append(c)
    return cells


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_table(cells: list[dict], mesh: str = "pod16x16") -> str:
    """§Roofline: single-pod only (per the spec); multi-pod proves sharding."""
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "roofline frac | 6ND/analytic | mem/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"])):
        if c["mesh"] != mesh:
            continue
        r = c["roofline"]
        ratio = r.get("useful_flops_ratio")
        lines.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| **{r['dominant']}** | {r['roofline_fraction']:.3f} "
            f"| {(f'{ratio:.2f}' if ratio else 'n/a')} "
            f"| {c['memory_analysis']['per_device_total_gib']:.1f}GiB |"
        )
    return "\n".join(lines)


def dryrun_table(cells: list[dict]) -> str:
    lines = [
        "| cell | chips | compile | mem/dev | collective GB (corrected) | "
        "breakdown |",
        "|---|---|---|---|---|---|",
    ]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        r = c["roofline"]
        bd = ", ".join(
            f"{k}:{v / 2**30:.1f}" for k, v in sorted(
                r.get("collective_breakdown", {}).items(), key=lambda kv: -kv[1]
            )[:3]
        )
        lines.append(
            f"| {c['cell']} | {c['chips']} | {c.get('compile_seconds', 0):.0f}s "
            f"| {c['memory_analysis']['per_device_total_gib']:.1f}GiB "
            f"| {r['collective_bytes'] / 2**30:.1f} | {bd} |"
        )
    return "\n".join(lines)


def pick_hillclimb_cells(cells: list[dict]) -> dict:
    """worst roofline fraction / most collective-bound / paper-representative."""
    single = [c for c in cells if c["mesh"] == "pod16x16" and "roofline" in c]
    if not single:
        return {}
    worst = min(single, key=lambda c: c["roofline"]["roofline_fraction"])
    coll = max(single, key=lambda c: c["roofline"]["collective_s"]
               / max(c["roofline"]["compute_s"], 1e-12))
    rep = next((c for c in single if c["arch"] == "dlrm-recross"), None)
    return {"worst_fraction": worst["cell"], "most_collective": coll["cell"],
            "paper_representative": rep["cell"] if rep else None}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join("experiments", "dryrun"))
    ap.add_argument("--section", choices=["roofline", "dryrun", "pick"], default="roofline")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    if args.section == "roofline":
        print(roofline_table(cells))
    elif args.section == "dryrun":
        print(dryrun_table(cells))
    else:
        print(json.dumps(pick_hillclimb_cells(cells), indent=1))


if __name__ == "__main__":
    main()
