"""KV / recurrent-state caches for decode, as plain pytrees.

Attention families carry ``(L, b, max_seq, kv_heads, head_dim)`` K/V
buffers plus a scalar length; recurrent families (ssm/hybrid) carry O(1)
state per layer.  ``long_500k`` uses the same structures: recurrent
states are length-independent, and the hybrid's shared-attention cache is
a *sliding window* ring buffer (``window`` slots, absolute positions
stored alongside) so cache memory is O(window), not O(seq).

Caches are created from shapes only (ShapeDtypeStruct-compatible), so the
dry-run can lower ``serve_step`` without allocating 500 k-token buffers.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.mamba2 import CONV_W


def make_attn_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
                    layers: int | None = None, quant: bool = False):
    """(k, v, length) cache for a stack of attention layers.

    ``quant=True`` stores int8 entries + per-(token, kv-head) bf16 scales:
    4x less HBM per cached token and ~2x less read traffic per decode step
    than bf16 (the decode memory-term optimization in §Perf).
    """
    L = layers if layers is not None else cfg.num_layers
    hd = cfg.resolved_head_dim
    shape = (L, batch, max_seq, cfg.kv_heads, hd)
    if quant:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
            "v_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
            "len": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros(shape, cfg.jnp_dtype),
        "v": jnp.zeros(shape, cfg.jnp_dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def make_ring_cache(cfg: ModelConfig, batch: int, window: int, *, layers: int):
    """Sliding-window ring cache (hybrid shared attention, long_500k)."""
    hd = cfg.resolved_head_dim
    shape = (layers, batch, window, cfg.kv_heads, hd)
    return {
        "k": jnp.zeros(shape, cfg.jnp_dtype),
        "v": jnp.zeros(shape, cfg.jnp_dtype),
        "pos": jnp.full((layers, batch, window), -1, jnp.int32),
        "len": jnp.zeros((), jnp.int32),
    }


def make_mamba_state(cfg: ModelConfig, batch: int, *, layers: int, head_dim: int = 64):
    """Zero-initialized Mamba SSM + conv state for ``layers`` layers."""
    d_inner = 2 * cfg.d_model
    heads = d_inner // head_dim
    return {
        "h": jnp.zeros((layers, batch, heads, head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((layers, batch, CONV_W - 1, d_inner), cfg.jnp_dtype),
    }


def make_xlstm_state(cfg: ModelConfig, batch: int, *, n_slstm: int, n_mlstm: int):
    """Zero-initialized xLSTM state (mLSTM matrix + sLSTM vectors)."""
    d, H = cfg.d_model, cfg.num_heads
    hd = d // H
    return {
        "m_C": jnp.zeros((n_mlstm, batch, H, hd, hd), jnp.float32),
        "m_n": jnp.zeros((n_mlstm, batch, H, hd), jnp.float32),
        "m_m": jnp.full((n_mlstm, batch, H), -1e30, jnp.float32),
        "s_c": jnp.zeros((n_slstm, batch, d), jnp.float32),
        "s_n": jnp.ones((n_slstm, batch, d), jnp.float32),
        "s_h": jnp.zeros((n_slstm, batch, d), jnp.float32),
        "s_m": jnp.zeros((n_slstm, batch, d), jnp.float32),
        "len": jnp.zeros((), jnp.int32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, *, window: int = 4096,
               quant: bool = False) -> Dict[str, Any]:
    """Family-dispatching cache constructor for serve_step."""
    if cfg.family in ("dense", "moe", "audio"):
        return make_attn_cache(cfg, batch, max_seq, quant=quant)
    if cfg.family == "vlm":
        period = cfg.cross_attn_period
        n_super = cfg.num_layers // (period + 1)
        return make_attn_cache(cfg, batch, max_seq, layers=n_super * period)
    if cfg.family == "ssm":
        period = cfg.slstm_every or (cfg.num_layers + 1)
        n_s = sum(1 for i in range(cfg.num_layers) if cfg.slstm_every and i % period == 0)
        return make_xlstm_state(cfg, batch, n_slstm=n_s, n_mlstm=cfg.num_layers - n_s)
    if cfg.family == "hybrid":
        period = cfg.shared_attn_period
        n_super = cfg.num_layers // period
        n_tail = cfg.num_layers - n_super * period
        w = min(window, max_seq)
        return {
            "mamba": make_mamba_state(cfg, batch, layers=n_super * period),
            "tail": make_mamba_state(cfg, batch, layers=max(n_tail, 1)),
            "shared": make_ring_cache(cfg, batch, w, layers=n_super),
            "len": jnp.zeros((), jnp.int32),
        }
    raise ValueError(f"no cache for family {cfg.family}")


def cache_bytes(cache) -> int:
    """Total bytes across every array leaf of a cache pytree."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
