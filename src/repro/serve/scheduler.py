"""Shard-aware flush scheduling for the sharded embedding server.

The policy half of the asynchronous serving engine (DESIGN.md §7).  The
global flush path (PR 2/3) batches every table into one fused compile:
every shard waits for the slowest table's block union to fill, and the
host compiles flush *n+1* only after flush *n* returns.  This module
decides *which queries can flush together early*:

  * **routing** — a query's sharded-once groups pin it to their owner
    shards.  A query whose owners collapse to one shard (or whose groups
    are all replicated-everywhere) is servable by a *single* shard: that
    shard holds every tile the query activates, so its reduction
    completes with no cross-shard combine at all.  Multi-owner queries
    route by their frozen **owner set**: under ``"owner-set"`` each
    distinct set is its own home — ``take()`` returns exactly that set
    as flush participants, so a 2-owner query on an 8-shard mesh
    compiles (and combines over) a 2-shard subset instead of waiting in
    a near-mesh-wide pool; under ``"per-shard"``/``"deadline"`` they
    collapse into the single :data:`POOL` home, flushed over the union
    of its queries' owners (the PR-4 behavior).
  * **union-fill accounting** — one
    :class:`~repro.core.reduction.BlockUnionTracker` per (home, table)
    maintains the grid a flush-now would run, without compiling
    anything (per table because the fused compile's blocks never span
    tables; a home's fill is the sum over its tables).  A home flushes
    independently when its union fill crosses ``union_budget``, when its
    pending count reaches ``batch_size``, or — whenever the policy
    carries a ``deadline`` — when its oldest query has waited
    ``deadline`` submissions.

A *home* is therefore either an ``int`` (one shard: single-owner and
replicated-only queries), the :data:`POOL` sentinel, or a sorted
``tuple`` of shard ids (an owner-set home).  Owner-set homes are
created lazily as sets are first seen; the population is bounded by the
distinct owner sets in the traffic, not ``2^S`` (skewed production
traffic concentrates on few sets, and the deadline bound keeps any
cold set from waiting unboundedly).

The scheduler is pure host bookkeeping — it never touches device state.
Dispatch, the bounded in-flight queue and the double-buffered
host-compile / device-execute pipelining live in
:class:`repro.serve.sharded.ShardedEmbeddingServer`; the patch-barrier
rule for online replanning (a staged :class:`~repro.dist.replan.
PlanPatch` applies only when the pipeline is drained) is specified in
DESIGN.md §7.3.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.reduction import BlockUnionTracker
from repro.serve.producers import DEFAULT_PRODUCER

#: pseudo-home for pooled multi-owner queries, flushed over their owner
#: union: all of them under ``per-shard`` / ``deadline``, only those
#: whose owner set exceeds ``owner_set_max`` under ``owner-set``
POOL = -1

_KINDS = ("global", "per-shard", "deadline", "owner-set")


@dataclasses.dataclass
class FlushPolicy:
    """When does a pending query batch flush, and how deep may the
    dispatch pipeline run (DESIGN.md §7.1).

    Attributes:
      kind: ``"global"`` — the PR-2 synchronous path (one fused flush at
        ``batch_size`` buffered, blocking serve); ``"per-shard"`` —
        shards flush independently on their own union-fill /
        batch-size triggers, multi-owner queries pool into one
        :data:`POOL` home; ``"deadline"`` — per-shard plus a default
        age bound so a query on a cold shard can never wait
        unboundedly; ``"owner-set"`` — multi-owner queries route to a
        home per frozen owner set and flush over exactly that subset
        (deadline defaults on, since owner-set homes fragment the
        pending stream and cold sets would otherwise starve).
      batch_size: per-home pending-query trigger (defaults to the
        server's ``batch_size``).
      union_budget: per-home block-union fill trigger (Σ union widths
        the pending stream would DMA); ``None`` disables the fill
        trigger and leaves batch-size/deadline only.
      deadline: max submissions (global ticks) the oldest pending query
        of a home may wait before a forced flush; consulted whenever
        set, on any async kind.  ``parse`` defaults it to
        ``4 × batch_size`` for the ``deadline`` and ``owner-set`` kinds
        and leaves it ``None`` (trigger off) for ``per-shard``.
      deadline_s: max WALL-CLOCK seconds the oldest pending query of a
        home may wait before a forced flush (``None`` = trigger off).
        The tick deadline bounds waiting in *submissions*, which under
        an open-loop arrival process is rate-independent — a home on a
        quiet stream can still hold a query for an arbitrarily long
        wall time.  A wall deadline is what an SLO actually bounds.
        Only the thread driver can FIRE it while traffic is idle (its
        idle loop services due homes); the inline engine consults it at
        submit/flush boundaries only.
      owner_set_max: (``owner-set`` kind) owner sets LARGER than this
        collapse into the :data:`POOL` home instead of getting their
        own.  The subset-flush win scales with how far an owner set
        falls short of the mesh, while fragmentation cost grows with
        the distinct-set population (which peaks at sets of size
        ``S/2``) — a cap of 2-3 keeps the high-value small-set homes
        and pools the near-mesh tail.  ``None`` (default) keys every
        multi-owner set.
      max_in_flight: bound on dispatched-but-unretired flushes; the
        oldest blocks (``block_until_ready``) when the bound is hit —
        with the inline driver that block happens inside ``submit()``,
        with the thread driver it happens on the driver thread.
      threaded: run the engine's dispatch/retire loop on a driver
        thread (DESIGN.md §7.2): ``submit()`` only validates, stamps a
        sequence id and enqueues onto a bounded hand-off queue — it
        never blocks on a full in-flight pipeline.
      handoff_depth: bound of the thread driver's hand-off queue
        (defaults to ``8 × batch_size``); the producer blocks only if
        it outruns the driver by this many undispatched queries.
    """

    kind: str = "global"
    batch_size: int | None = None
    union_budget: int | None = None
    deadline: int | None = None
    deadline_s: float | None = None
    owner_set_max: int | None = None
    max_in_flight: int = 2
    threaded: bool = False
    handoff_depth: int | None = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown flush policy {self.kind!r}; use {_KINDS}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be positive (None = trigger off)")
        if self.max_in_flight < 1:
            raise ValueError("max_in_flight must be >= 1")
        if self.threaded and self.kind == "global":
            raise ValueError("the thread driver requires an async kind")
        if self.owner_set_max is not None and self.owner_set_max < 2:
            raise ValueError("owner_set_max must be >= 2 (a 1-owner query "
                             "already routes to its single owner shard)")

    @classmethod
    def parse(cls, policy, *, batch_size: int) -> "FlushPolicy":
        """Normalizes a kind string (or a ready policy) against server
        defaults: ``batch_size`` falls back to the server's, ``deadline``
        to ``4 × batch_size`` (``deadline`` / ``owner-set`` kinds), the
        hand-off bound to ``8 × batch_size``."""
        if isinstance(policy, str):
            policy = cls(kind=policy)
        p = dataclasses.replace(policy)
        if p.batch_size is None:
            p.batch_size = batch_size
        if p.kind in ("deadline", "owner-set") and p.deadline is None:
            p.deadline = 4 * p.batch_size
        if p.handoff_depth is None:
            p.handoff_depth = 8 * p.batch_size
        return p

    @property
    def is_async(self) -> bool:
        """True for every policy but the synchronous ``"global"``."""
        return self.kind != "global"

    @property
    def owner_set_routing(self) -> bool:
        """True when flush homes are owner-set tuples, not shards."""
        return self.kind == "owner-set"


#: a flush home: one shard (int), the :data:`POOL` sentinel, or a
#: sorted owner-set tuple (``owner-set`` routing)
Home = object


class FlushScheduler:
    """Routes queries to flush homes and tracks per-home fill state.

    One *home* per shard (single-owner and replicated-only queries) plus
    either the :data:`POOL` home (pooled kinds) or one lazily-created
    home per distinct frozen owner set (``owner-set`` kind) for
    multi-owner queries.  All state is host NumPy/sets; ``route``/
    ``push`` are O(rows in the query).

    Args:
      plan: the live :class:`~repro.dist.shard_plan.ShardPlan` (only
        ``num_shards`` / ``shard_of_group`` / ``tables`` are read).
      layouts: per-table :class:`~repro.core.mapping.CrossbarLayout` in
        the same (sorted-name) order as ``plan.tables``.
      names: table names in that order.
      q_block: the server's query block size (union accounting unit).
      policy: a normalized :class:`FlushPolicy`.
      seq_decode: ``seq -> (producer label, local seq)`` decoder for
        the packed per-producer sequence ids (DESIGN.md §10) — feeds
        the per-producer accounting in :meth:`state`.  ``None`` treats
        every seq as the default producer's (raw local ids).
    """

    def __init__(self, plan, layouts, names: Sequence[str], q_block: int,
                 policy: FlushPolicy,
                 seq_decode: Optional[Callable] = None):
        self.q_block = q_block
        self.policy = policy
        self.names = list(names)
        self._seq_decode = (
            seq_decode if seq_decode is not None
            else (lambda s: (DEFAULT_PRODUCER, int(s)))
        )
        #: cumulative pushes per producer label (per-producer share of
        #: the routed stream; pending_by_producer in :meth:`state` is
        #: the instantaneous complement)
        self.pushed_by_producer: Dict[str, int] = {}
        self._group_of = {
            name: np.asarray(layout.group_of, dtype=np.int64)
            for name, layout in zip(self.names, layouts)
        }
        self.rebuild(plan)
        # POOL exists under every async kind: the pooled kinds route all
        # multi-owner queries there, owner-set routing only those whose
        # sets exceed ``owner_set_max`` (never, when the cap is unset)
        homes: List[Home] = list(range(self.num_shards)) + [POOL]
        self._pending: Dict[Home, List[Tuple[str, int, list]]] = {
            h: [] for h in homes
        }
        # one tracker per (home, table): the fused compile never lets a
        # block span tables, so per-table block accounting is what the
        # flush would actually run; a home's fill sums over its tables
        self._trackers: Dict[Home, Dict[str, BlockUnionTracker]] = {
            h: {} for h in homes
        }
        self._first_tick: Dict[Home, int] = {}
        # wall-clock twin of _first_tick, for the deadline_s trigger
        self._first_wall: Dict[Home, float] = {}
        self._tick = 0
        self._rr = 0
        self._pool_owners: set = set()
        #: failure-path accounting (DESIGN.md §8): batches put back by a
        #: failed dispatch, and queries permanently dropped after
        #: offender bisection isolated them
        self.requeues = 0
        self.quarantined = 0

    # ------------------------------------------------------------ routing --

    def rebuild(self, plan) -> None:
        """Re-derives the routing tables from a (possibly patched) plan.

        Called at build and after every applied plan patch — promotion /
        demotion changes group ownership, so row→home routing must
        follow.  Only legal when nothing is pending (the patch-barrier
        rule guarantees it: pending work flushed under the old plan
        before the patch applies).
        """
        self.num_shards = int(plan.num_shards)
        shard_of_group = np.asarray(plan.shard_of_group, dtype=np.int64)
        self._owner_of_row = {}
        self._fused_group_of_row = {}
        for seg in plan.tables:
            gof = self._group_of[seg.name] + seg.group_offset
            self._fused_group_of_row[seg.name] = gof
            self._owner_of_row[seg.name] = shard_of_group[gof]

    def route(self, table: str, query: Sequence[int]) -> Tuple[Home, np.ndarray]:
        """Home of one query + its distinct fused group ids (a PEEK —
        does not advance the replicated-work round robin; only
        :meth:`push` consumes a round-robin slot).

        Owners = owning shards of the query's sharded-once groups:
        none → any shard serves it (round-robin keeps replicated work
        spread, the degenerate form of the block-level round robin);
        one → that shard; several → the sorted owner-set tuple under
        ``owner-set`` routing, else the cross-shard :data:`POOL`.
        """
        home, groups, _ = self._route(table, query, advance=False)
        return home, groups

    def _route(
        self, table: str, query, *, advance: bool
    ) -> Tuple[Home, np.ndarray, np.ndarray]:
        rows = np.unique(np.asarray(query, dtype=np.int64))
        groups = np.unique(self._fused_group_of_row[table][rows])
        owners = np.unique(self._owner_of_row[table][rows])
        if owners.size and owners[0] == -2:
            # COLD sentinel (repro.dist.shard_plan): no shard holds the
            # tile, so no flush home can serve it — the server must have
            # detoured this query to its host fetch queue before routing
            raise ValueError(
                f"query on table {table!r} touches a cold (host-tier) "
                "group; cold queries take the host path, not a flush home"
            )
        owners = owners[owners >= 0]
        if owners.size == 0:
            home: Home = self._rr
            if advance:
                self._rr = (self._rr + 1) % self.num_shards
        elif owners.size == 1:
            home = int(owners[0])
        elif (self.policy.owner_set_routing
              and (self.policy.owner_set_max is None
                   or owners.size <= self.policy.owner_set_max)):
            # np.unique already sorted the owners: the tuple is the
            # canonical frozen owner set, one home per distinct set.
            # Sets wider than owner_set_max fall through to the pool —
            # the subset win shrinks as a set approaches the mesh while
            # home fragmentation grows, so the tail is not worth keying.
            home = tuple(int(o) for o in owners)
        else:
            home = POOL
        return home, groups, owners

    def push(self, table: str, seq: int, query: Sequence[int]) -> Home:
        """Routes and enqueues one query; returns its home (owner-set
        homes are created lazily on first sight)."""
        home, groups, owners = self._route(table, query, advance=True)
        if home == POOL:
            self._pool_owners.update(int(o) for o in owners)
        label = str(self._seq_decode(seq)[0])
        self.pushed_by_producer[label] = (
            self.pushed_by_producer.get(label, 0) + 1
        )
        self._pending.setdefault(home, []).append((table, seq, list(query)))
        self._trackers.setdefault(home, {}).setdefault(
            table, BlockUnionTracker(self.q_block)
        ).add(groups)
        self._first_tick.setdefault(home, self._tick)
        self._first_wall.setdefault(home, time.monotonic())
        self._tick += 1
        return home

    def first_tick(self, home: Home):
        """Submission tick of the home's oldest pending query (None if
        empty) — captured by the server before a flush so a failed
        dispatch can requeue without resetting the deadline clock."""
        return self._first_tick.get(home)

    def first_wall(self, home: Home):
        """Wall-clock (``time.monotonic``) twin of :meth:`first_tick`,
        captured/restored for the same requeue reason when the policy
        carries a ``deadline_s``."""
        return self._first_wall.get(home)

    def requeue(
        self,
        home: Home,
        entries: List[Tuple[str, int, list]],
        first_tick: int | None = None,
        first_wall: float | None = None,
    ) -> None:
        """Puts a taken batch back at the FRONT of its home's queue.

        The failed-dispatch retry path: a compile error (e.g. one
        malformed query) must not drop the batch — the async analogue
        of the sync flush's leave-buffered-on-failure contract.  The
        fill trackers and (for the pool) the owner union rebuild from
        the merged queue so a later flush compiles correctly, and
        ``first_tick`` (captured before the take) restores the deadline
        clock so surviving queries never wait past the policy bound.
        """
        if not entries:
            return
        self.requeues += 1
        self._pending[home] = list(entries) + self._pending.get(home, [])
        self._trackers[home] = {}
        for table, _seq, query in self._pending[home]:
            rows = np.unique(np.asarray(query, dtype=np.int64))
            self._trackers[home].setdefault(
                table, BlockUnionTracker(self.q_block)
            ).add(np.unique(self._fused_group_of_row[table][rows]))
            if home == POOL:
                owners = np.unique(self._owner_of_row[table][rows])
                self._pool_owners.update(
                    int(o) for o in owners if o >= 0
                )
        if first_tick is not None:
            self._first_tick[home] = min(
                first_tick, self._first_tick.get(home, first_tick)
            )
        else:
            self._first_tick.setdefault(home, self._tick)
        if first_wall is not None:
            self._first_wall[home] = min(
                first_wall, self._first_wall.get(home, first_wall)
            )
        else:
            self._first_wall.setdefault(home, time.monotonic())

    def record_quarantine(self, n: int) -> None:
        """Counts ``n`` queries permanently dropped by the server's
        offender bisection (they were already taken, so there is no
        pending state to unwind — this is pure accounting)."""
        self.quarantined += int(n)

    # ----------------------------------------------------------- triggers --

    def due_reason(self, home: Home) -> str | None:
        """Why ``home`` should flush now (``None`` = not due).

        Returns ``"batch"`` (pending count), ``"union"`` (block-union
        fill crossed the budget) or ``"deadline"`` (oldest pending query
        aged out — checked whenever the policy carries a deadline),
        in that order.
        """
        n = len(self._pending[home])
        if n == 0:
            return None
        if n >= self.policy.batch_size:
            return "batch"
        if (self.policy.union_budget is not None
                and self.fill(home) >= self.policy.union_budget):
            return "union"
        if (self.policy.deadline is not None
                and self._tick - self._first_tick[home] >= self.policy.deadline):
            return "deadline"
        if (self.policy.deadline_s is not None
                and home in self._first_wall
                and time.monotonic() - self._first_wall[home]
                >= self.policy.deadline_s):
            return "deadline"
        return None

    def due(self, home: Home) -> bool:
        """Whether ``home`` should flush now under the policy."""
        return self.due_reason(home) is not None

    def due_homes(self) -> List[Home]:
        """Homes whose pending work should flush now."""
        return [h for h in self._pending if self.due(h)]

    def fill(self, home: Home) -> int:
        """Σ block-union widths over the home's pending per-table
        streams — the tile-DMA count a flush-now would run."""
        return sum(tr.fill for tr in self._trackers[home].values())

    def homes_with_pending(self) -> List[Home]:
        """Homes holding at least one undelivered query."""
        return [h for h, q in self._pending.items() if q]

    def pending_total(self) -> int:
        """Queries buffered across every home (0 = quiesced)."""
        return sum(len(q) for q in self._pending.values())

    # --------------------------------------------------------------- take --

    def take(self, home: Home) -> Tuple[List[Tuple[str, int, list]], List[int] | None]:
        """Pops a home's pending batch and its flush participants.

        Returns ``(entries, participants)``: per-shard homes flush with
        ``participants=[home]`` (no cross-shard combine); an owner-set
        home flushes with exactly its frozen set; the pool flushes over
        the union of its queries' owner shards.  ``None`` (the full
        stack) is returned only when the set covers the mesh.
        """
        entries = self._pending[home]
        self._pending[home] = []
        self._trackers[home] = {}
        self._first_tick.pop(home, None)
        self._first_wall.pop(home, None)
        if home == POOL:
            owners = sorted(self._pool_owners)
            self._pool_owners = set()
            if not owners or len(owners) == self.num_shards:
                return entries, None
            return entries, owners
        if isinstance(home, tuple):
            if len(home) == self.num_shards:
                return entries, None
            return entries, list(home)
        return entries, [home]

    def state(self) -> Dict[str, object]:
        """Pending/fill snapshot for :meth:`ShardedEmbeddingServer.report`.

        Safe to call from a monitoring thread while the thread driver
        routes traffic: the dict views are materialized with C-level
        (GIL-atomic) ``list()`` copies before iteration, so a
        concurrently-created owner-set home can never raise
        ``dictionary changed size during iteration`` — the snapshot is
        merely allowed to be one push stale.
        """
        pending_items = list(self._pending.items())
        union_fill = {}
        pending_by_producer: Dict[str, int] = {}
        for h, q in pending_items:
            if q:
                trackers = list(self._trackers.get(h, {}).values())
                union_fill[str(h)] = sum(tr.fill for tr in trackers)
                for _t, seq, _q in list(q):
                    label = str(self._seq_decode(seq)[0])
                    pending_by_producer[label] = (
                        pending_by_producer.get(label, 0) + 1
                    )
        return {
            "pending": {str(h): len(q) for h, q in pending_items if q},
            "union_fill": union_fill,
            "tick": self._tick,
            "requeues": self.requeues,
            "quarantined": self.quarantined,
            "pending_by_producer": pending_by_producer,
            "pushed_by_producer": dict(self.pushed_by_producer),
        }
