"""Tiered host↔device embedding storage (DESIGN.md §9).

Real DLRM tables run 10–100x larger than crossbar (device) capacity —
the gap software-defined-memory serving systems close with a managed
hierarchy.  This module makes the stacked shard images a **hot tier**:
a fixed per-shard ``capacity_tiles`` budget caches the hottest groups
of the host-resident fused master image, and everything else is
**cold** — served exactly (gather+sum over the host tables, the PR 6
degrade path's inline kernel) and eligible to page in when the drift
tracker's decayed loads say it warmed up.

Three pieces live here; the placement/patch math they drive lives in
:mod:`repro.dist.shard_plan` / :mod:`repro.dist.replan`:

  * :class:`TierConfig` — user-facing knobs (budget as tiles or as a
    fraction of the uncapped image, hysteresis, host-queue batching).
  * :class:`ResidencyIndex` — O(rows-per-query) submit-time answer to
    "does this query touch any cold group?", rebuilt at each patch
    barrier (residency only changes at barriers, so routing is always
    consistent with the images a flush will run against).
  * :class:`HostFetchQueue` — the deadline-batched queue cold queries
    wait in, mirroring the device path's batch/deadline flush triggers
    so a cold query's latency is bounded by the same contract.

Invariants (pinned by ``tests/test_tiers.py``):

  * a compiled (device) batch never references a cold tile —
    ``shard_block_queries`` raises if the router lets one through;
  * the host path computes the same distinct-row gather+sum as the
    kernels, so a capacity-bounded server is bit-identical to the
    uncapped all-resident oracle on integer-valued tables;
  * paging happens only at flush barriers, via a
    :class:`~repro.dist.replan.PlanPatch` carrying ``fetched`` /
    ``evicted`` move lists, hysteresis-gated against thrash.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.dist.replan import PagingPolicy
from repro.dist.shard_plan import ShardPlan


@dataclasses.dataclass
class TierConfig:
    """Hot-tier knobs for :class:`~repro.serve.sharded.ShardedEmbeddingServer`.

    Exactly one of ``capacity_tiles`` / ``capacity_frac`` must be set.

    Attributes:
      capacity_tiles: absolute per-shard hot-tier budget, in tiles.
      capacity_frac: budget as a fraction of the per-shard image depth
        an *uncapped* plan of the same tables would need (the launcher's
        ``--capacity-frac 0.1`` = "device holds a tenth of the table").
      hysteresis: load ratio a cold group must beat over its eviction
        victim to swap in (> 1; see
        :class:`~repro.dist.replan.PagingPolicy`).
      max_fetch_tiles: cap on tiles paged in per patch barrier (bounds
        the barrier's DMA stall; None = unbounded).
      min_fetch_load: decayed-load floor below which a cold group never
        pages in (0.0 = any observed traffic qualifies).
      host_batch: cold queries buffered before the host path serves
        them as one batch (None: the server's ``batch_size``).
      host_deadline: max submissions (to the whole server) a queued
        cold query waits before a forced host flush (None: 4x
        ``host_batch``, mirroring the device deadline default).
    """

    capacity_tiles: int | None = None
    capacity_frac: float | None = None
    hysteresis: float = 1.5
    max_fetch_tiles: int | None = None
    min_fetch_load: float = 0.0
    host_batch: int | None = None
    host_deadline: int | None = None

    def __post_init__(self):
        if (self.capacity_tiles is None) == (self.capacity_frac is None):
            raise ValueError(
                "set exactly one of capacity_tiles / capacity_frac"
            )
        if self.capacity_frac is not None and not (
            0.0 < self.capacity_frac <= 1.0
        ):
            raise ValueError("capacity_frac must be in (0, 1]")
        if self.hysteresis < 1.0:
            raise ValueError(
                "hysteresis < 1 invites paging thrash (an evicted group "
                "could immediately displace its displacer)"
            )

    def resolve_capacity(self, uncapped_depth: int) -> int:
        """Budget in tiles, given the uncapped plan's per-shard depth."""
        if self.capacity_tiles is not None:
            return int(self.capacity_tiles)
        return max(1, int(np.floor(self.capacity_frac * uncapped_depth)))

    def paging_policy(self, capacity_tiles: int) -> PagingPolicy:
        """Resolved per-plan paging policy at a concrete capacity."""
        return PagingPolicy(
            capacity_tiles=int(capacity_tiles),
            hysteresis=float(self.hysteresis),
            max_fetch_tiles=self.max_fetch_tiles,
            min_fetch_load=float(self.min_fetch_load),
        )


class ResidencyIndex:
    """Submit-time row → hot/cold routing for a capacity-bounded plan.

    Holds the per-table ``row → fused group`` map (frozen: the grouping
    never changes at serve time) and a snapshot of the plan's resident
    mask (refreshed at each patch barrier via :meth:`refresh` — never
    mid-pipeline, so every query routed hot was routed against the
    residency its flush will execute under).
    """

    def __init__(
        self, plan: ShardPlan, fused_group_of_row: Dict[str, np.ndarray]
    ):
        self._fused_group_of_row = {
            name: np.asarray(g, dtype=np.int64)
            for name, g in fused_group_of_row.items()
        }
        self._resident = plan.resident_group
        self.num_groups = plan.num_groups

    def refresh(self, plan: ShardPlan) -> None:
        """Re-snapshots residency after a plan patch (barrier only)."""
        self._resident = plan.resident_group

    @property
    def any_cold(self) -> bool:
        """True when at least one group lives outside the hot tier."""
        return not bool(self._resident.all())

    def groups_of(self, table: str, query: np.ndarray) -> np.ndarray:
        """Distinct fused group ids a query's rows touch."""
        rows = np.asarray(query, dtype=np.int64)
        if rows.size == 0:
            return rows
        return np.unique(self._fused_group_of_row[table][rows])

    def is_resident(self, table: str, query: np.ndarray) -> bool:
        """True iff every row of the query lives in the hot tier."""
        if not self.any_cold:
            return True
        groups = self.groups_of(table, query)
        return bool(self._resident[groups].all())

    def host_group_loads(
        self, entries: List[Tuple[str, int, np.ndarray]]
    ) -> np.ndarray:
        """Per-fused-group active-row counts of a host-path batch.

        The host-side twin of
        :func:`repro.core.reduction.fused_group_loads` — cold queries
        never compile, but their loads MUST feed the drift tracker or a
        cold group could never warm up and page in.  Same semantics: a
        query touching *k* distinct rows of a group counts *k*.
        """
        loads = np.zeros(self.num_groups, dtype=np.float64)
        for table, _seq, query in entries:
            rows = np.unique(np.asarray(query, dtype=np.int64))
            if rows.size:
                np.add.at(
                    loads, self._fused_group_of_row[table][rows], 1.0
                )
        return loads


class HostFetchQueue:
    """Deadline-batched buffer for cold-routed queries.

    Mirrors the device scheduler's triggers: a host flush is due when
    ``batch`` entries buffered OR the oldest entry has waited
    ``deadline`` submissions.  Ticks are the server's submission
    counter (every submit, hot or cold, advances time — so a trickle of
    cold queries in a hot-dominated stream still meets its deadline).
    """

    def __init__(self, batch: int, deadline: int):
        self.batch = max(1, int(batch))
        self.deadline = max(1, int(deadline))
        self._entries: List[Tuple[str, int, np.ndarray]] = []
        self._first_tick: int | None = None

    def __len__(self) -> int:
        return len(self._entries)

    def push(self, table: str, seq: int, query: np.ndarray, tick: int) -> None:
        """Buffers one cold-routed query for the host gather."""
        if self._first_tick is None:
            self._first_tick = int(tick)
        self._entries.append((table, int(seq), query))

    def due(self, tick: int) -> str | None:
        """"batch" / "deadline" when a flush is due, else None."""
        if not self._entries:
            return None
        if len(self._entries) >= self.batch:
            return "batch"
        if int(tick) - self._first_tick >= self.deadline:
            return "deadline"
        return None

    def take(self) -> List[Tuple[str, int, np.ndarray]]:
        """Drains and returns every buffered entry (resets deadline)."""
        out = self._entries
        self._entries = []
        self._first_tick = None
        return out

    def state(self) -> dict:
        """Queue depth + policy snapshot for reports."""
        return {"pending": len(self._entries),
                "first_tick": self._first_tick,
                "batch": self.batch, "deadline": self.deadline}
