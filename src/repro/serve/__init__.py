from repro.serve.decode import decode_step
from repro.serve.kvcache import cache_bytes, init_cache
from repro.serve.batching import RequestBatcher, ServeMetrics
from repro.serve.drift import DriftTracker, LoadObservationCache, ReplanConfig
from repro.serve.faults import (
    ErrorLedger,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    FlushTimeout,
    InjectedFault,
    PoisonedQueryError,
    RetryPolicy,
)
from repro.serve.producers import (
    DEFAULT_PRODUCER,
    SEQ_STRIDE,
    ProducerRegistry,
)
from repro.serve.scheduler import POOL, FlushPolicy, FlushScheduler
from repro.serve.sharded import ShardedEmbeddingServer, ShardedServeStats
from repro.serve.tiers import HostFetchQueue, ResidencyIndex, TierConfig

__all__ = [
    "decode_step", "init_cache", "cache_bytes", "RequestBatcher",
    "ServeMetrics", "ShardedEmbeddingServer", "ShardedServeStats",
    "DriftTracker", "LoadObservationCache", "ReplanConfig",
    "FlushPolicy", "FlushScheduler", "POOL",
    "TierConfig", "ResidencyIndex", "HostFetchQueue",
    "ProducerRegistry", "DEFAULT_PRODUCER", "SEQ_STRIDE",
    "FaultPlan", "FaultSpec", "FaultInjector", "RetryPolicy",
    "ErrorLedger", "FlushTimeout", "InjectedFault", "PoisonedQueryError",
]
