"""Sharded multi-table embedding serving driver (DESIGN.md §4).

Glues the offline pipeline to the sharded online path for a *set* of
DLRM embedding tables:

  per table: history → co-occurrence → grouping (Alg. 1) → Eq.-1
  replication → layout, then one :class:`~repro.dist.shard_plan.
  ShardPlan` over the fused tile space decides replicated-everywhere vs
  sharded-once tiles and one stacked shard image feeds the kernel.

Serving batches per-shard queries: requests accumulate per table in the
driver's buffer; a flush compiles each table's batch (block-granular
replica choice), rebases into the fused tile space, block-compiles one
:class:`~repro.core.reduction.ShardedBlockedQueries` per flush, and runs
:func:`repro.kernels.crossbar_reduce_tables` — emulation on one device,
``shard_map`` when a mesh is installed.  Every flush records the
observability contract of the sharded path: per-shard grid cells,
per-shard union widths, and cross-shard combine bytes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    build_cooccurrence,
    build_layout,
    compile_queries,
    concat_compiled_queries,
    correlation_aware_grouping,
    offset_compiled_queries,
    plan_replication,
    shard_block_queries,
)
from repro.dist.shard_plan import ShardPlan, build_fused_image, plan_shards
from repro.kernels.sharded import combine_bytes_per_batch, crossbar_reduce_tables


@dataclasses.dataclass
class ShardedServeStats:
    """Accumulated per-flush accounting of the sharded datapath."""

    num_shards: int
    q_block: int
    batches: int = 0
    queries: int = 0
    blocks: int = 0
    grid_cells_per_shard: int = 0          # Σ over flushes of nb × max_tiles
    max_grid_cells_per_flush: int = 0
    max_shard_width: int = 0               # widest per-shard block union seen
    combine_bytes: int = 0
    wall_s: float = 0.0

    def record(self, sbq, dim: int, wall_s: float, queries: int) -> None:
        cells = sbq.grid_cells_per_shard()
        self.batches += 1
        self.queries += queries
        self.blocks += sbq.num_blocks
        self.grid_cells_per_shard += cells
        self.max_grid_cells_per_flush = max(self.max_grid_cells_per_flush, cells)
        self.max_shard_width = max(
            self.max_shard_width, int(np.max(sbq.shard_widths, initial=0))
        )
        self.combine_bytes += combine_bytes_per_batch(
            sbq.num_blocks * sbq.q_block, dim, self.num_shards
        )
        self.wall_s += wall_s

    def summary(self) -> Dict[str, float]:
        return {
            "num_shards": self.num_shards,
            "q_block": self.q_block,
            "batches": self.batches,
            "queries": self.queries,
            "blocks": self.blocks,
            "grid_cells_per_shard": self.grid_cells_per_shard,
            "max_grid_cells_per_flush": self.max_grid_cells_per_flush,
            "max_shard_width": self.max_shard_width,
            "combine_bytes": self.combine_bytes,
            "wall_s": self.wall_s,
        }


class ShardedEmbeddingServer:
    """Multi-table embedding-reduction server over the ``model`` axis.

    Args:
      tables: ``{name: (rows, dim) float array}`` logical tables.
      histories: ``{name: ragged lookup history}`` driving the offline
        pipeline (grouping + Eq.-1 replication) per table.
      num_shards: model-parallel degree to plan for.
      mesh: optional mesh whose ``axis_name`` axis has ``num_shards``
        devices → the flush runs under shard_map; ``None`` emulates the
        shard loop on the local device (identical numerics).
      q_block: queries per kernel block (DMA amortization factor).
      group_size: crossbar height (tile rows).
      batch_size: auto-flush threshold for :meth:`submit`.
      batch_size_for_eq1: Eq. 1's ``batch`` (replication aggressiveness);
        defaults to ``batch_size``.
    """

    def __init__(
        self,
        tables: Dict[str, np.ndarray],
        histories: Dict[str, Sequence[Sequence[int]]],
        *,
        num_shards: int = 1,
        mesh=None,
        axis_name: str = "model",
        q_block: int = 8,
        group_size: int = 64,
        batch_size: int = 256,
        batch_size_for_eq1: int | None = None,
        combine: str = "psum_scatter",
        combine_chunks: int = 2,
        dynamic_switch: bool = True,
        interpret: bool | None = None,
    ):
        if set(tables) != set(histories):
            raise ValueError("tables and histories must cover the same names")
        if not tables:
            raise ValueError("need at least one table")
        self.names = sorted(tables)
        self.num_shards = num_shards
        self.mesh = mesh
        self.axis_name = axis_name
        self.q_block = q_block
        self.batch_size = batch_size
        self.combine = combine
        self.combine_chunks = combine_chunks
        self.dynamic_switch = dynamic_switch
        self.interpret = interpret

        eq1_batch = batch_size_for_eq1 or batch_size
        self.layouts, plans, gfreqs = [], [], []
        dims = set()
        for name in self.names:
            table = np.asarray(tables[name])
            hist = histories[name]
            graph = build_cooccurrence(hist, table.shape[0])
            grouping = correlation_aware_grouping(graph, group_size)
            plan = plan_replication(grouping, graph.freq, eq1_batch)
            self.layouts.append(build_layout(grouping, plan, table.shape[1]))
            plans.append(plan)
            gfreqs.append(grouping.group_freq(graph.freq))
            dims.add(table.shape[1])
        if len(dims) != 1:
            raise ValueError("fused serving requires a uniform embedding dim")
        self.dim = dims.pop()

        self.plan: ShardPlan = plan_shards(
            self.layouts, plans, num_shards,
            names=self.names, group_freqs=gfreqs,
        )
        fused = build_fused_image(
            self.layouts, [np.asarray(tables[n]) for n in self.names]
        )
        self.shard_images = jnp.asarray(self.plan.build_shard_images(fused))
        self.stats = ShardedServeStats(num_shards=num_shards, q_block=q_block)
        self._buffer: Dict[str, List[Sequence[int]]] = {n: [] for n in self.names}
        self._buffered = 0

    # ------------------------------------------------------------ serving --

    def serve(
        self, queries_by_table: Dict[str, Sequence[Sequence[int]]]
    ) -> Dict[str, jax.Array]:
        """One synchronous batch: compile, reduce, combine, account."""
        t0 = time.perf_counter()
        unknown = set(queries_by_table) - set(self.names)
        if unknown:
            raise KeyError(f"unknown tables {sorted(unknown)!r}")
        cqs = []
        served = [n for n in self.names if queries_by_table.get(n)]
        if not served:
            return {}
        for name in served:
            i = self.names.index(name)
            seg = self.plan.tables[i]
            cq = compile_queries(
                self.layouts[i], queries_by_table[name],
                replica_block=self.q_block,
            )
            cqs.append(offset_compiled_queries(cq, seg.tile_offset))
        fused_cq, spans = concat_compiled_queries(cqs, self.q_block)
        sbq = shard_block_queries(fused_cq, self.plan, self.q_block)
        outs = crossbar_reduce_tables(
            self.shard_images, sbq, spans,
            mesh=self.mesh, axis_name=self.axis_name,
            combine=self.combine, combine_chunks=self.combine_chunks,
            dynamic_switch=self.dynamic_switch, interpret=self.interpret,
        )
        outs = [jax.block_until_ready(o) for o in outs]
        n_queries = sum(len(queries_by_table[n]) for n in served)
        self.stats.record(sbq, self.dim, time.perf_counter() - t0, n_queries)
        return dict(zip(served, outs))

    # ----------------------------------------------------------- batching --

    def submit(self, table: str, query: Sequence[int]) -> Dict[str, jax.Array]:
        """Buffers one query; auto-flushes at ``batch_size`` buffered.

        Returns the flush result when a flush fired, else ``{}``.
        """
        if table not in self._buffer:
            raise KeyError(f"unknown table {table!r}")
        self._buffer[table].append(list(query))
        self._buffered += 1
        if self._buffered >= self.batch_size:
            return self.flush()
        return {}

    def flush(self) -> Dict[str, jax.Array]:
        """Serves and clears the buffered per-table batches.

        The buffer is cleared only after a successful serve, so a failed
        flush (e.g. one malformed query) leaves every buffered request
        intact for retry after the offender is removed.
        """
        if self._buffered == 0:
            return {}
        batch = {n: q for n, q in self._buffer.items() if q}
        out = self.serve(batch)
        self._buffer = {n: [] for n in self.names}
        self._buffered = 0
        return out

    # ------------------------------------------------------------- report --

    def report(self) -> Dict[str, object]:
        """Serving + placement accounting for dashboards and benches."""
        return {
            "tables": self.names,
            "plan": self.plan.memory_summary(),
            "serve": self.stats.summary(),
            "mode": "shard_map" if self.mesh is not None else "emulated",
        }
