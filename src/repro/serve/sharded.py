"""Sharded multi-table embedding serving driver (DESIGN.md §4, §6).

Glues the offline pipeline to the sharded online path for a *set* of
DLRM embedding tables:

  per table: history → co-occurrence → grouping (Alg. 1) → Eq.-1
  log-scaled replication (``num_copies(g) = floor(log f_g / log f_total
  · log batch)``) → layout, then one :class:`~repro.dist.shard_plan.
  ShardPlan` over the fused tile space decides replicated-everywhere vs
  sharded-once tiles and one stacked shard image feeds the kernel.

Serving batches per-shard queries: requests accumulate per table in the
driver's buffer; a flush compiles each table's batch (block-granular
replica choice), rebases into the fused tile space, block-compiles one
:class:`~repro.core.reduction.ShardedBlockedQueries` per flush, and runs
:func:`repro.kernels.crossbar_reduce_tables` — emulation on one device,
``shard_map`` when a mesh is installed.  Every flush records the
observability contract of the sharded path: per-shard grid cells,
per-shard union widths, and cross-shard combine bytes.

**Online replanning** (opt-in via ``replan=``, DESIGN.md §6): each flush
also feeds the compiled batch's per-group loads to a
:class:`~repro.serve.drift.DriftTracker`.  When the decayed observation
drifts past the configured total-variation threshold, the server stages
an incremental :class:`~repro.dist.replan.PlanPatch` — computed on the
host *while the flush's kernel executes on device* — and applies it at
the start of the next flush: placement arrays swap, and only the moved
tiles DMA into the image stack
(:func:`repro.kernels.sharded.patch_shard_images`).  The full
``plan_shards`` + ``build_fused_image`` rebuild never reruns.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    build_cooccurrence,
    build_layout,
    compile_queries,
    concat_compiled_queries,
    correlation_aware_grouping,
    offset_compiled_queries,
    plan_replication,
    shard_block_queries,
)
from repro.core.reduction import CompiledQueries, fused_group_loads
from repro.dist.replan import (
    PlanPatch,
    apply_plan_patch,
    compute_plan_patch,
    rescale_load_to_plan,
)
from repro.dist.shard_plan import ShardPlan, build_fused_image, plan_shards
from repro.kernels.sharded import (
    combine_bytes_per_batch,
    crossbar_reduce_tables,
    patch_shard_images,
)
from repro.serve.drift import DriftTracker, ReplanConfig


@dataclasses.dataclass
class ShardedServeStats:
    """Accumulated per-flush accounting of the sharded datapath."""

    num_shards: int
    q_block: int
    batches: int = 0
    queries: int = 0
    blocks: int = 0
    grid_cells_per_shard: int = 0          # Σ over flushes of nb × max_tiles
    max_grid_cells_per_flush: int = 0
    max_shard_width: int = 0               # widest per-shard block union seen
    combine_bytes: int = 0
    wall_s: float = 0.0
    # ---- online replanning (DESIGN.md §6) ----
    replans: int = 0                       # patches applied (moves > 0)
    rebases: int = 0                       # no-op patches (load reanchor only)
    patched_tiles: int = 0                 # Σ tiles DMA'd by applied patches
    promoted_groups: int = 0
    demoted_groups: int = 0

    def record(self, sbq, dim: int, wall_s: float, queries: int) -> None:
        cells = sbq.grid_cells_per_shard()
        self.batches += 1
        self.queries += queries
        self.blocks += sbq.num_blocks
        self.grid_cells_per_shard += cells
        self.max_grid_cells_per_flush = max(self.max_grid_cells_per_flush, cells)
        self.max_shard_width = max(
            self.max_shard_width, int(np.max(sbq.shard_widths, initial=0))
        )
        self.combine_bytes += combine_bytes_per_batch(
            sbq.num_blocks * sbq.q_block, dim, self.num_shards
        )
        self.wall_s += wall_s

    def record_patch(self, patch: PlanPatch) -> None:
        if patch.is_noop():
            self.rebases += 1
            return
        self.replans += 1
        self.patched_tiles += patch.num_moved_tiles
        self.promoted_groups += len(patch.promoted)
        self.demoted_groups += len(patch.demoted)

    def summary(self) -> Dict[str, float]:
        return {
            "num_shards": self.num_shards,
            "q_block": self.q_block,
            "batches": self.batches,
            "queries": self.queries,
            "blocks": self.blocks,
            "grid_cells_per_shard": self.grid_cells_per_shard,
            "max_grid_cells_per_flush": self.max_grid_cells_per_flush,
            "max_shard_width": self.max_shard_width,
            "combine_bytes": self.combine_bytes,
            "wall_s": self.wall_s,
            "replans": self.replans,
            "rebases": self.rebases,
            "patched_tiles": self.patched_tiles,
            "promoted_groups": self.promoted_groups,
            "demoted_groups": self.demoted_groups,
        }


class ShardedEmbeddingServer:
    """Multi-table embedding-reduction server over the ``model`` axis.

    Args:
      tables: ``{name: (rows, dim) float array}`` logical tables.
      histories: ``{name: ragged lookup history}`` driving the offline
        pipeline (grouping + Eq.-1 replication) per table.
      num_shards: model-parallel degree to plan for.
      mesh: optional mesh whose ``axis_name`` axis has ``num_shards``
        devices → the flush runs under shard_map; ``None`` emulates the
        shard loop on the local device (identical numerics).
      axis_name: mesh axis the image shards over (default ``"model"``).
      q_block: queries per kernel block (DMA amortization factor).
      group_size: crossbar height (tile rows).
      batch_size: auto-flush threshold for :meth:`submit`.
      batch_size_for_eq1: Eq. 1's ``batch`` (replication aggressiveness);
        defaults to ``batch_size``.  Online replanning re-evaluates
        Eq. 1 at this batch size unless ``replan.eq1_batch`` overrides.
      combine: cross-shard combine collective — ``"psum_scatter"``
        (reduce-scatter over dim + all-gather) or ``"psum"``.
      combine_chunks: block-axis chunks for combine/DMA overlap.
      dynamic_switch: enable the paper's §III-D READ/MAC switch.
      interpret: force Pallas interpret mode (``None`` = auto off-TPU).
      replan: optional :class:`~repro.serve.drift.ReplanConfig` enabling
        drift-triggered incremental replanning (DESIGN.md §6).
    """

    def __init__(
        self,
        tables: Dict[str, np.ndarray],
        histories: Dict[str, Sequence[Sequence[int]]],
        *,
        num_shards: int = 1,
        mesh=None,
        axis_name: str = "model",
        q_block: int = 8,
        group_size: int = 64,
        batch_size: int = 256,
        batch_size_for_eq1: int | None = None,
        combine: str = "psum_scatter",
        combine_chunks: int = 2,
        dynamic_switch: bool = True,
        interpret: bool | None = None,
        replan: ReplanConfig | None = None,
    ):
        if set(tables) != set(histories):
            raise ValueError("tables and histories must cover the same names")
        if not tables:
            raise ValueError("need at least one table")
        self.names = sorted(tables)
        self.num_shards = num_shards
        self.mesh = mesh
        self.axis_name = axis_name
        self.q_block = q_block
        self.batch_size = batch_size
        self.combine = combine
        self.combine_chunks = combine_chunks
        self.dynamic_switch = dynamic_switch
        self.interpret = interpret

        eq1_batch = batch_size_for_eq1 or batch_size
        self.layouts, plans, gfreqs = [], [], []
        dims = set()
        for name in self.names:
            table = np.asarray(tables[name])
            hist = histories[name]
            graph = build_cooccurrence(hist, table.shape[0])
            grouping = correlation_aware_grouping(graph, group_size)
            plan = plan_replication(grouping, graph.freq, eq1_batch)
            self.layouts.append(build_layout(grouping, plan, table.shape[1]))
            plans.append(plan)
            gfreqs.append(grouping.group_freq(graph.freq))
            dims.add(table.shape[1])
        if len(dims) != 1:
            raise ValueError("fused serving requires a uniform embedding dim")
        self.dim = dims.pop()

        self.plan: ShardPlan = plan_shards(
            self.layouts, plans, num_shards,
            names=self.names, group_freqs=gfreqs,
        )
        # host-resident master image: the serve-time DMA source for
        # incremental plan patches (kept even without replan so a later
        # enable_replan-style extension stays cheap; it is the same bytes
        # a parameter server would hold anyway)
        self._fused = build_fused_image(
            self.layouts, [np.asarray(tables[n]) for n in self.names]
        )
        images = self.plan.build_shard_images(self._fused)
        self.replan_cfg = replan
        self._eq1_batch = (
            replan.eq1_batch if replan and replan.eq1_batch else eq1_batch
        )
        if replan is not None and replan.slack_tiles > 0:
            # zero-tile headroom so early promotions fill slack instead
            # of growing (reallocating) the device image stack
            pad = np.zeros(
                (num_shards, replan.slack_tiles) + images.shape[2:],
                dtype=images.dtype,
            )
            images = np.concatenate([images, pad], axis=1)
        self.shard_images = jnp.asarray(images)
        self._tile_group = np.repeat(
            np.arange(self.plan.num_groups, dtype=np.int64),
            self.plan.group_copies,
        )
        # per-table training-time load mass: Eq. 1 is evaluated at this
        # magnitude at replan time (see rescale_load_to_plan) — constant
        # across rebases, since rescaled snapshots carry the same totals
        self._segments = [
            (s.group_offset, s.group_offset + s.num_groups)
            for s in self.plan.tables
        ]
        self._seg_load_totals = [
            float(self.plan.group_load[a:b].sum()) for a, b in self._segments
        ]
        self.tracker: Optional[DriftTracker] = (
            DriftTracker(
                self.plan.group_load,
                half_life=replan.half_life,
                min_queries=replan.min_queries,
            )
            if replan is not None
            else None
        )
        self._staged: Optional[PlanPatch] = None
        self.stats = ShardedServeStats(num_shards=num_shards, q_block=q_block)
        self._buffer: Dict[str, List[Sequence[int]]] = {n: [] for n in self.names}
        self._buffered = 0

    # ------------------------------------------------------------ serving --

    def serve(
        self, queries_by_table: Dict[str, Sequence[Sequence[int]]]
    ) -> Dict[str, jax.Array]:
        """Serves one synchronous multi-table batch.

        Pipeline per call: apply any staged plan patch (see
        :meth:`_apply_staged_patch` — this is flush *n+1* of the
        double-buffered ordering), compile each table's ragged queries
        (block-granular replica choice), rebase into the fused tile
        space, block-compile per shard, dispatch the sharded kernel,
        then — while the device executes — observe drift and stage the
        next patch, and finally block on the outputs and record stats.

        Args:
          queries_by_table: ``{table name: ragged row-id queries}``;
            tables absent or mapped to an empty list are skipped.

        Returns:
          ``{table name: (batch, dim) reduction}`` for every table that
          had at least one query (padding rows already sliced off).

        Raises:
          KeyError: a key names an unknown table.
        """
        t0 = time.perf_counter()
        unknown = set(queries_by_table) - set(self.names)
        if unknown:
            raise KeyError(f"unknown tables {sorted(unknown)!r}")
        served = [n for n in self.names if queries_by_table.get(n)]
        if not served:
            return {}
        self._apply_staged_patch()
        cqs = []
        for name in served:
            i = self.names.index(name)
            seg = self.plan.tables[i]
            cq = compile_queries(
                self.layouts[i], queries_by_table[name],
                replica_block=self.q_block,
            )
            cqs.append(offset_compiled_queries(cq, seg.tile_offset))
        fused_cq, spans = concat_compiled_queries(cqs, self.q_block)
        # one host materialization serves both the per-shard block
        # compiler and the drift observation — without it, each would
        # pull the batch back from the device separately
        host_cq = CompiledQueries(
            tile_ids=np.asarray(fused_cq.tile_ids),
            bitmaps=np.asarray(fused_cq.bitmaps),
            max_tiles=fused_cq.max_tiles,
        )
        sbq = shard_block_queries(host_cq, self.plan, self.q_block)
        outs = crossbar_reduce_tables(
            self.shard_images, sbq, spans,
            mesh=self.mesh, axis_name=self.axis_name,
            combine=self.combine, combine_chunks=self.combine_chunks,
            dynamic_switch=self.dynamic_switch, interpret=self.interpret,
        )
        n_queries = sum(len(queries_by_table[n]) for n in served)
        # double buffering: the kernel above is dispatched but NOT yet
        # blocked on — drift bookkeeping and patch computation are pure
        # host work and overlap the device execution of this flush
        self._observe_and_stage(host_cq, n_queries)
        outs = [jax.block_until_ready(o) for o in outs]
        self.stats.record(sbq, self.dim, time.perf_counter() - t0, n_queries)
        return dict(zip(served, outs))

    # --------------------------------------------------------- replanning --

    def _apply_staged_patch(self) -> None:
        """Swaps in the patch staged during the previous flush.

        Runs at the top of :meth:`serve`, before anything is compiled
        against the plan — flush *n*'s outputs were produced entirely
        under the old plan, flush *n+1* runs entirely under the new one
        (no torn state).  Image update DMAs only the moved tiles.
        """
        if self._staged is None:
            return
        patch, self._staged = self._staged, None
        self.shard_images = patch_shard_images(
            self.shard_images, patch, self._fused
        )
        self.plan = apply_plan_patch(self.plan, patch)
        self.stats.record_patch(patch)

    def _observe_and_stage(self, fused_cq, n_queries: int) -> None:
        """Feeds the tracker and stages a patch when drift crosses.

        Host-only work scheduled between kernel dispatch and
        ``block_until_ready``.  A no-op (class-unchanged) patch is
        applied immediately as a load rebase — it touches no device
        state, so there is nothing to double-buffer.
        """
        if self.tracker is None:
            return
        loads = fused_group_loads(
            fused_cq, self._tile_group, self.plan.num_groups
        )
        self.tracker.observe(loads, n_queries)
        if self._staged is not None or not self.tracker.ready:
            return
        drift = self.tracker.drift_from(
            self.plan.group_load, segments=self._segments
        )
        if drift < self.replan_cfg.threshold:
            return
        # Eq. 1 is magnitude-sensitive: evaluate the observed
        # distribution at the training-time mass, not the tracker's
        drifted = rescale_load_to_plan(
            self.tracker.load(), self.plan, self._seg_load_totals
        )
        patch = compute_plan_patch(
            self.plan, drifted,
            eq1_batch=self._eq1_batch,
            capacity=int(self.shard_images.shape[1]),
        )
        if patch.is_noop():
            # drift without a class change: reanchor group_load so the
            # greedy demotion targets and the drift statistic both track
            # the observed distribution
            self.plan = apply_plan_patch(self.plan, patch)
            self.stats.record_patch(patch)
            return
        self._staged = patch

    # ----------------------------------------------------------- batching --

    def submit(self, table: str, query: Sequence[int]) -> Dict[str, jax.Array]:
        """Buffers one query; auto-flushes at ``batch_size`` buffered.

        Args:
          table: table name the query reduces over.
          query: ragged row ids (an embedding-bag lookup).

        Returns:
          The flush result (see :meth:`flush`) when this submission
          tripped the ``batch_size`` threshold, else ``{}``.

        Raises:
          KeyError: ``table`` is not a served table.
        """
        if table not in self._buffer:
            raise KeyError(f"unknown table {table!r}")
        self._buffer[table].append(list(query))
        self._buffered += 1
        if self._buffered >= self.batch_size:
            return self.flush()
        return {}

    def flush(self) -> Dict[str, jax.Array]:
        """Serves and clears the buffered per-table batches.

        The buffer is cleared only after a successful serve, so a failed
        flush (e.g. one malformed query) leaves every buffered request
        intact for retry after the offender is removed.

        Returns:
          ``{table name: (buffered batch, dim) reduction}`` for every
          table with buffered queries; ``{}`` when nothing is buffered.
          Row order within a table is submission order.
        """
        if self._buffered == 0:
            return {}
        batch = {n: q for n, q in self._buffer.items() if q}
        out = self.serve(batch)
        self._buffer = {n: [] for n in self.names}
        self._buffered = 0
        return out

    # ------------------------------------------------------------- report --

    def report(self) -> Dict[str, object]:
        """Serving + placement accounting for dashboards and benches.

        Returns a dict with:
          * ``tables`` — served table names (sorted).
          * ``plan`` — tile residency / replication overhead of the
            *current* (possibly patched) plan
            (:meth:`ShardPlan.memory_summary`).
          * ``serve`` — cumulative flush stats
            (:meth:`ShardedServeStats.summary`), including the replan
            counters.
          * ``mode`` — ``"shard_map"`` or ``"emulated"``.
          * ``replan`` — drift/replanning state (only when enabled):
            current drift vs the live plan, tracker readiness, staged
            patch summary if one is waiting for the next flush.
        """
        rep: Dict[str, object] = {
            "tables": self.names,
            "plan": self.plan.memory_summary(),
            "serve": self.stats.summary(),
            "mode": "shard_map" if self.mesh is not None else "emulated",
        }
        if self.tracker is not None:
            rep["replan"] = {
                "threshold": self.replan_cfg.threshold,
                "half_life": self.replan_cfg.half_life,
                "drift": self.tracker.drift_from(
                    self.plan.group_load, segments=self._segments
                ),
                "observed_queries": self.tracker.observed_queries,
                "ready": self.tracker.ready,
                "staged": (
                    self._staged.summary() if self._staged is not None else None
                ),
                "image_capacity": int(self.shard_images.shape[1]),
            }
        return rep
