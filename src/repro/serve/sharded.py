"""Sharded multi-table embedding serving driver (DESIGN.md §4, §6).

Glues the offline pipeline to the sharded online path for a *set* of
DLRM embedding tables:

  per table: history → co-occurrence → grouping (Alg. 1) → Eq.-1
  log-scaled replication (``num_copies(g) = floor(log f_g / log f_total
  · log batch)``) → layout, then one :class:`~repro.dist.shard_plan.
  ShardPlan` over the fused tile space decides replicated-everywhere vs
  sharded-once tiles and one stacked shard image feeds the kernel.

Serving batches per-shard queries: requests accumulate per table in the
driver's buffer; a flush compiles each table's batch (block-granular
replica choice), rebases into the fused tile space, block-compiles one
:class:`~repro.core.reduction.ShardedBlockedQueries` per flush, and runs
:func:`repro.kernels.crossbar_reduce_tables` — emulation on one device,
``shard_map`` when a mesh is installed.  Every flush records the
observability contract of the sharded path: per-shard grid cells,
per-shard union widths, and cross-shard combine bytes.

**Online replanning** (opt-in via ``replan=``, DESIGN.md §6): each flush
also feeds the compiled batch's per-group loads to a
:class:`~repro.serve.drift.DriftTracker`.  When the decayed observation
drifts past the configured total-variation threshold, the server stages
an incremental :class:`~repro.dist.replan.PlanPatch` — computed on the
host *while the flush's kernel executes on device* — and applies it at
the start of the next flush: placement arrays swap, and only the moved
tiles DMA into the image stack
(:func:`repro.kernels.sharded.patch_shard_images`).  The full
``plan_shards`` + ``build_fused_image`` rebuild never reruns.

**Async flush scheduling** (opt-in via ``flush_policy=``, DESIGN.md §7):
under ``"per-shard"`` / ``"deadline"`` / ``"owner-set"`` the synchronous
loop above becomes a pipelined engine.  Queries route to homes
(:class:`~repro.serve.scheduler.FlushScheduler`) — one per shard, plus
(owner-set routing) one per distinct frozen owner set — homes flush
independently as their block unions fill, subset flushes compile with
``participants=`` exactly the home's shards (a single-shard flush
combines nothing; a 2-owner flush rings 2 shards via grouped psum), and
each dispatch is non-blocking: the host compiles flush *n+1* while
flush *n* executes on device, ``block_until_ready`` runs only at result
hand-off (bounded in-flight queue /
:meth:`ShardedEmbeddingServer.drain`).  A staged plan patch then
applies only at a pipeline **barrier** — never between in-flight
flushes.

**Thread driver** (opt-in via ``threaded=``, DESIGN.md §7.2): the
engine's dispatch/retire loop moves to a dedicated driver thread.
``submit()`` then only validates the query, stamps its sequence id and
enqueues onto a bounded hand-off queue — it never blocks on a full
in-flight pipeline (the ``max_in_flight`` hand-off block happens on the
driver).  ``drain()``/``flush()``/``serve()`` post a barrier token and
join the driver at it; plan patches still apply only at such barriers.
A flush failure on the driver requeues its batch (same retry contract)
and surfaces at the next ``submit()``/``drain()``.

**Multi-producer front door** (DESIGN.md §10): ``submit()`` is safe
under N concurrent producer threads.  Each producer (the ``producer=``
label, lazily registered) owns a per-table **sequence space**; a stamp
packs ``(local_seq, producer_id)`` into the one int64 sequence id the
whole engine already carries (:mod:`repro.serve.producers`), so
per-producer FIFO is preserved end to end and a full :meth:`drain`
merges streams in the deterministic ``(local_seq, producer_id)``
order — a pure function of what was submitted, never of thread
scheduling.  ``drain(producer=...)`` hands back only that producer's
rows (no cross-producer head-of-line mixing); :meth:`close` racing
concurrent submits gives late submitters a clean ``RuntimeError`` and
lands drained work in the ledger's ``lost_work``.

**Self-healing failure policy** (DESIGN.md §8, default on via
``retry=``): a failed compile/dispatch retries in place with bounded
exponential backoff + seeded jitter; a batch that keeps failing is
**bisected** so a single poisoned query is quarantined with its error
(recorded in the :class:`~repro.serve.faults.ErrorLedger`) instead of
wedging its home; a flush that exceeds the ``watchdog_s`` deadline is
timed out and **degraded** to the inline host/reference path, so
``drain()`` never blocks forever on hung device work.
``RetryPolicy.legacy()`` restores the requeue-and-re-raise contract.
The ``faults=`` hook accepts a :class:`~repro.serve.faults.FaultPlan`
— a deterministic, seeded fault-injection layer wrapping the compile,
dispatch, retire and patch-apply seams (chaos replay, CI smoke).

**Tiered host↔device storage** (opt-in via ``tiers=``, DESIGN.md §9):
a :class:`~repro.serve.tiers.TierConfig` caps the per-shard image
depth — the device images become a **hot tier** over the host-resident
master image, planned capacity-bounded so only the hottest groups are
resident and the cold tail lives host-side only
(``shard_of_group == COLD``).  Every query routes by residency at
submit time: resident queries flow through the crossbar kernels
unchanged, cold queries detour into a deadline-batched host queue
served by the same gather+sum the degrade path uses (bit-identical on
integer tables).  Cold traffic feeds the drift tracker too, so when a
cold group warms past the hysteresis-gated paging policy the next
patch barrier **fetches** its tiles into free slots (DMA from the host
master) and **evicts** colder victims (slots reclaimed through the
free-list, no data movement — the host master stays authoritative).
Residency snapshots refresh only at those barriers, so routing is
always consistent with the images a flush executes against.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import queue
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    build_cooccurrence,
    build_layout,
    compile_queries,
    concat_compiled_queries,
    correlation_aware_grouping,
    offset_compiled_queries,
    plan_replication,
    shard_block_queries,
)
from repro.core.reduction import CompiledQueries
from repro.dist.replan import (
    PlanPatch,
    apply_plan_patch,
    compute_plan_patch,
    rescale_load_to_plan,
)
from repro.dist.shard_plan import ShardPlan, build_fused_image, plan_shards
from repro.kernels.sharded import (
    combine_bytes_per_batch,
    crossbar_reduce_tables,
    dispatch_cache_stats,
    patch_shard_images,
)
from repro.serve.drift import DriftTracker, LoadObservationCache, ReplanConfig
from repro.serve.faults import (
    ErrorLedger,
    FaultInjector,
    FlushTimeout,
    RetryPolicy,
    latency_percentiles as _latency_percentiles,
)
from repro.serve.producers import ProducerRegistry
from repro.serve.scheduler import POOL, FlushPolicy, FlushScheduler
from repro.serve.tiers import HostFetchQueue, ResidencyIndex, TierConfig


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unretired flush (DESIGN.md §7.2)."""

    outs: List[jax.Array]                  # lazy per-table kernel outputs
    sbq: object                            # the flush's ShardedBlockedQueries
    served: List[str]                      # table names, outs order
    seqs: Dict[str, np.ndarray]            # per-table submission sequence ids
    t0: float                              # host compile start (perf_counter)
    n_queries: int
    host_cq: object = None                 # host-materialized fused batch
    # ---- healing metadata (DESIGN.md §8): the raw batch so a retire-
    # time fault can re-dispatch it and a watchdog timeout can degrade
    # it to the host path ----
    home: object = None
    entries: Optional[List[tuple]] = None  # raw (table, seq, query) triples
    participants: Optional[List[int]] = None
    t_dispatch: float = 0.0                # kernel dispatch (perf_counter)
    hang_s: Optional[float] = None         # injected hang (None = healthy)


#: bound of the driver-failure stash (first-in surfaces first; overflow
#: is counted, never silently dropped) — see _stash_driver_error
_MAX_STASHED_ERRORS = 8


@dataclasses.dataclass
class ShardedServeStats:
    """Accumulated per-flush accounting of the sharded datapath.

    Under an async flush policy (DESIGN.md §7) ``wall_s`` is the sum of
    per-flush dispatch→retire latencies, which OVERLAP — end-to-end wall
    clock is what the scheduler bench measures; the pipelining gain
    shows up here as ``hidden_compile_s`` (host compile time that ran
    while a previous flush executed on device) over ``host_compile_s``.
    Latency samples are kept raw (one float per flush / per submit) so
    ``summary()`` can report percentiles; at serving-bench scales this
    is a few KB — a reservoir is not worth the accounting distortion.
    """

    num_shards: int
    q_block: int
    policy: str = "global"
    batches: int = 0
    queries: int = 0
    blocks: int = 0
    grid_cells_per_shard: int = 0          # Σ over flushes of nb × max_tiles
    max_grid_cells_per_flush: int = 0
    max_shard_width: int = 0               # widest per-shard block union seen
    combine_bytes: int = 0
    wall_s: float = 0.0
    # ---- async flush scheduling (DESIGN.md §7) ----
    shard_flushes: Dict[object, int] = dataclasses.field(default_factory=dict)
    participant_sizes: Dict[int, int] = dataclasses.field(default_factory=dict)
    barrier_flushes: int = 0               # pipeline drains (patch/explicit)
    deadline_flushes: int = 0              # flushes forced by query age
    host_compile_s: float = 0.0            # Σ per-flush host compile time
    hidden_compile_s: float = 0.0          # … of which overlapped device exec
    in_flight_peak: int = 0                # deepest dispatch queue seen
    flush_wall: List[float] = dataclasses.field(default_factory=list)
    submit_wall: List[float] = dataclasses.field(default_factory=list)
    # submit-stamp → result-materialized, one sample per async query
    # (quarantined queries never complete, so they never sample)
    e2e_wall: List[float] = dataclasses.field(default_factory=list)
    # ---- online replanning (DESIGN.md §6) ----
    replans: int = 0                       # patches applied (moves > 0)
    rebases: int = 0                       # no-op patches (load reanchor only)
    patched_tiles: int = 0                 # Σ tiles DMA'd by applied patches
    promoted_groups: int = 0
    demoted_groups: int = 0
    # ---- tiered host/device storage (DESIGN.md §9) ----
    hot_queries: int = 0                   # routed through the crossbar path
    host_queries: int = 0                  # routed to the host (cold) path
    host_flushes: int = 0                  # host-queue batches served
    host_deadline_flushes: int = 0         # … of which forced by query age
    sync_cold_batches: int = 0             # sync serve()'s inline cold splits
    fetched_tiles: int = 0                 # Σ tiles paged INTO the hot tier
    evicted_tiles: int = 0                 # Σ tiles paged OUT (slots freed)
    paging_bytes: int = 0                  # Σ host→device bytes of fetches
    load_obs_hits: int = 0                 # drift-observation memo hits
    load_obs_misses: int = 0
    # ---- failure/recovery accounting (DESIGN.md §8) ----
    ledger: ErrorLedger = dataclasses.field(default_factory=ErrorLedger)

    def record(self, sbq, dim: int, wall_s: float, queries: int) -> None:
        """Accounts one served batch: grid cells, widths, combine
        traffic (scaled to the flush's participant set), wall time."""
        cells = sbq.grid_cells_per_shard()
        self.batches += 1
        self.queries += queries
        self.blocks += sbq.num_blocks
        self.grid_cells_per_shard += cells
        self.max_grid_cells_per_flush = max(self.max_grid_cells_per_flush, cells)
        self.max_shard_width = max(
            self.max_shard_width, int(np.max(sbq.shard_widths, initial=0))
        )
        # combine traffic scales with the flush's PARTICIPANTS, not the
        # mesh: a single-participant flush skips the collective entirely
        # (zero interconnect), and a multi-shard subset whose size
        # divides the mesh rings only its participants (grouped psum,
        # kernels.sharded — equal index-group sizes are a TPU lowering
        # requirement); any other subset falls back to the full-axis
        # ring with zero payloads from non-participants.  sbq.num_shards
        # IS the participant count (the stack depth of the subset
        # compile).
        p = sbq.num_shards
        ring = p if (p == 1 or self.num_shards % p == 0) else self.num_shards
        self.combine_bytes += combine_bytes_per_batch(
            sbq.num_blocks * sbq.q_block, dim, ring
        )
        self.participant_sizes[sbq.num_shards] = (
            self.participant_sizes.get(sbq.num_shards, 0) + 1
        )
        self.wall_s += wall_s
        self.flush_wall.append(wall_s)

    def record_flush_home(self, home) -> None:
        """Counts one dispatched flush against its home (an int shard,
        the POOL sentinel -1, or an owner-set tuple)."""
        self.shard_flushes[home] = self.shard_flushes.get(home, 0) + 1

    def record_submit(self, seconds: float) -> None:
        """Accounts one submit() call's host latency (µs-scale under
        the thread driver — the never-blocks contract the percentiles
        in :meth:`summary` make auditable)."""
        self.submit_wall.append(seconds)

    def record_compile(self, seconds: float, *, hidden: bool) -> None:
        """Accounts one flush's host compile; ``hidden`` when at least
        one earlier flush was still executing on device while it ran."""
        self.host_compile_s += seconds
        if hidden:
            self.hidden_compile_s += seconds

    @property
    def overlap_fraction(self) -> float:
        """Fraction of host compile time hidden behind device execution."""
        return (self.hidden_compile_s / self.host_compile_s
                if self.host_compile_s > 0 else 0.0)

    def record_patch(self, patch: PlanPatch, tile_bytes: int = 0) -> None:
        """Accounts one applied plan patch (replan vs rebase, moved
        tiles, promotions/demotions, paging traffic)."""
        # paging accounting rides every applied patch: fetches DMA host
        # master bytes onto the device, evictions only free slots
        fetched = len(getattr(patch, "fetch_dma", ()) or ())
        self.fetched_tiles += fetched
        self.evicted_tiles += int(getattr(patch, "evicted_tiles", 0) or 0)
        self.paging_bytes += fetched * int(tile_bytes)
        if patch.is_noop():
            self.rebases += 1
            return
        self.replans += 1
        self.patched_tiles += patch.num_moved_tiles + patch.num_relocated_tiles
        self.promoted_groups += len(patch.promoted)
        self.demoted_groups += len(patch.demoted)

    def summary(self) -> Dict[str, float]:
        """Flat metrics dict for reports/benches (counters, latency
        percentiles, paging and failure accounting)."""
        return {
            "num_shards": self.num_shards,
            "q_block": self.q_block,
            "flush_policy": self.policy,
            "batches": self.batches,
            "queries": self.queries,
            "blocks": self.blocks,
            "grid_cells_per_shard": self.grid_cells_per_shard,
            "max_grid_cells_per_flush": self.max_grid_cells_per_flush,
            "max_shard_width": self.max_shard_width,
            "combine_bytes": self.combine_bytes,
            "wall_s": self.wall_s,
            "shard_flushes": {
                str(k): v for k, v in sorted(
                    self.shard_flushes.items(), key=lambda kv: str(kv[0])
                )
            },
            "participant_sizes": {
                str(k): v for k, v in sorted(self.participant_sizes.items())
            },
            "flush_latency_s": _latency_percentiles(self.flush_wall),
            "submit_latency_s": _latency_percentiles(self.submit_wall),
            "e2e_latency_s": _latency_percentiles(self.e2e_wall),
            "barrier_flushes": self.barrier_flushes,
            "deadline_flushes": self.deadline_flushes,
            "host_compile_s": self.host_compile_s,
            "hidden_compile_s": self.hidden_compile_s,
            "overlap_fraction": self.overlap_fraction,
            "in_flight_peak": self.in_flight_peak,
            "replans": self.replans,
            "rebases": self.rebases,
            "patched_tiles": self.patched_tiles,
            "promoted_groups": self.promoted_groups,
            "demoted_groups": self.demoted_groups,
            "tiers": self.tier_summary(),
            "faults": self.ledger.summary(),
        }

    def tier_summary(self) -> Dict[str, object]:
        """Hot-tier effectiveness metrics (DESIGN.md §9).

        ``hot_tier_hit_rate`` is the fraction of routed queries served
        entirely from the device images (1.0 when tiering is off or no
        query has been routed yet); ``host_path_fraction`` is its
        complement — the tier bench's steady-state acceptance metric.
        """
        routed = self.hot_queries + self.host_queries
        return {
            "hot_queries": self.hot_queries,
            "host_queries": self.host_queries,
            "hot_tier_hit_rate": (
                self.hot_queries / routed if routed else 1.0
            ),
            "host_path_fraction": (
                self.host_queries / routed if routed else 0.0
            ),
            "host_flushes": self.host_flushes,
            "host_deadline_flushes": self.host_deadline_flushes,
            "sync_cold_batches": self.sync_cold_batches,
            "fetched_tiles": self.fetched_tiles,
            "evicted_tiles": self.evicted_tiles,
            "paged_tiles": self.fetched_tiles + self.evicted_tiles,
            "paging_bytes": self.paging_bytes,
            "load_obs_hits": self.load_obs_hits,
            "load_obs_misses": self.load_obs_misses,
        }


class ShardedEmbeddingServer:
    """Multi-table embedding-reduction server over the ``model`` axis.

    Args:
      tables: ``{name: (rows, dim) float array}`` logical tables.
      histories: ``{name: ragged lookup history}`` driving the offline
        pipeline (grouping + Eq.-1 replication) per table.
      num_shards: model-parallel degree to plan for.
      mesh: optional mesh whose ``axis_name`` axis has ``num_shards``
        devices → the flush runs under shard_map; ``None`` emulates the
        shard loop on the local device (identical numerics).
      axis_name: mesh axis the image shards over (default ``"model"``).
      q_block: queries per kernel block (DMA amortization factor).
      group_size: crossbar height (tile rows).
      batch_size: auto-flush threshold for :meth:`submit`.
      batch_size_for_eq1: Eq. 1's ``batch`` (replication aggressiveness);
        defaults to ``batch_size``.  Online replanning re-evaluates
        Eq. 1 at this batch size unless ``replan.eq1_batch`` overrides.
      combine: cross-shard combine collective — ``"psum_scatter"``
        (reduce-scatter over dim + all-gather) or ``"psum"``.
      combine_chunks: block-axis chunks for combine/DMA overlap.
      dynamic_switch: enable the paper's §III-D READ/MAC switch.
      interpret: force Pallas interpret mode (``None`` = auto off-TPU).
      replan: optional :class:`~repro.serve.drift.ReplanConfig` enabling
        drift-triggered incremental replanning (DESIGN.md §6).
      flush_policy: ``"global"`` (the synchronous PR-2 path, default) or
        an async policy — ``"per-shard"`` / ``"deadline"`` /
        ``"owner-set"`` kind strings or a full
        :class:`~repro.serve.scheduler.FlushPolicy`.  Async policies
        flush homes independently as their block unions fill and
        pipeline host compile against device execution; ``"owner-set"``
        additionally keys multi-owner homes by their frozen owner set
        so a flush's participants are exactly its queries' owners.
        Results are collected with :meth:`drain` (or :meth:`flush`,
        which is a barrier in async mode).  DESIGN.md §7.
      union_budget / flush_deadline / flush_deadline_s / owner_set_max /
        max_in_flight: async policy knobs
        (see :class:`~repro.serve.scheduler.FlushPolicy`); ignored under
        ``"global"``.
      threaded: run the async engine on a dedicated driver thread
        (DESIGN.md §7.2): :meth:`submit` validates + enqueues onto a
        bounded hand-off queue and never blocks on a full in-flight
        pipeline; call :meth:`close` (or use the server as a context
        manager) to stop the driver.  Requires an async flush policy.
      retry: the self-healing policy (DESIGN.md §8) — bounded retries
        with backoff + jitter, offender bisection/quarantine, and the
        flush watchdog.  ``None`` uses the :class:`~repro.serve.faults.
        RetryPolicy` defaults (healing on, watchdog off);
        ``RetryPolicy.legacy()`` restores requeue-and-re-raise.
      faults: optional :class:`~repro.serve.faults.FaultPlan` (or a
        ready injector) wrapping the compile / dispatch / retire /
        patch-apply seams with deterministic, seeded fault injection —
        chaos replays and the driver-fault-branch tests.
      tiers: optional :class:`~repro.serve.tiers.TierConfig` making the
        shard images a capacity-bounded **hot tier** (DESIGN.md §9):
        the plan admits only the hottest groups up to the budget, cold
        queries serve through a deadline-batched host gather+sum path,
        and drift-driven plan patches page groups in/out at flush
        barriers.  Enables replanning implicitly (a default
        :class:`~repro.serve.drift.ReplanConfig`) when ``replan`` is
        not given — paging needs the drift tracker.  ``replan.
        slack_tiles`` / ``shrink_streak`` are ignored under tiering:
        the image depth IS the (fixed) capacity.
    """

    def __init__(
        self,
        tables: Dict[str, np.ndarray],
        histories: Dict[str, Sequence[Sequence[int]]],
        *,
        num_shards: int = 1,
        mesh=None,
        axis_name: str = "model",
        q_block: int = 8,
        group_size: int = 64,
        batch_size: int = 256,
        batch_size_for_eq1: int | None = None,
        combine: str = "psum_scatter",
        combine_chunks: int = 2,
        dynamic_switch: bool = True,
        interpret: bool | None = None,
        replan: ReplanConfig | None = None,
        flush_policy: str | FlushPolicy = "global",
        union_budget: int | None = None,
        flush_deadline: int | None = None,
        flush_deadline_s: float | None = None,
        owner_set_max: int | None = None,
        max_in_flight: int = 2,
        threaded: bool = False,
        retry: RetryPolicy | None = None,
        faults=None,
        tiers: TierConfig | None = None,
    ):
        if set(tables) != set(histories):
            raise ValueError("tables and histories must cover the same names")
        if not tables:
            raise ValueError("need at least one table")
        self.names = sorted(tables)
        self.num_shards = num_shards
        self.mesh = mesh
        self.axis_name = axis_name
        self.q_block = q_block
        self.batch_size = batch_size
        self.combine = combine
        self.combine_chunks = combine_chunks
        self.dynamic_switch = dynamic_switch
        self.interpret = interpret

        eq1_batch = batch_size_for_eq1 or batch_size
        self.layouts, plans, gfreqs = [], [], []
        dims = set()
        for name in self.names:
            table = np.asarray(tables[name])
            hist = histories[name]
            graph = build_cooccurrence(hist, table.shape[0])
            grouping = correlation_aware_grouping(graph, group_size)
            plan = plan_replication(grouping, graph.freq, eq1_batch)
            self.layouts.append(build_layout(grouping, plan, table.shape[1]))
            plans.append(plan)
            gfreqs.append(grouping.group_freq(graph.freq))
            dims.add(table.shape[1])
        if len(dims) != 1:
            raise ValueError("fused serving requires a uniform embedding dim")
        self.dim = dims.pop()

        self.tiers = tiers
        if tiers is not None and replan is None:
            # paging rides the drift tracker: tiering without an explicit
            # replan config still needs one to ever page a group in
            replan = ReplanConfig()
        self._capacity_tiles: Optional[int] = None
        if tiers is not None:
            # the budget is resolved against what an UNCAPPED plan of
            # the same tables would need — capacity_frac=0.1 means "the
            # device holds a tenth of the working set"
            uncapped = plan_shards(
                self.layouts, plans, num_shards,
                names=self.names, group_freqs=gfreqs,
            )
            self._capacity_tiles = tiers.resolve_capacity(
                uncapped.max_local_tiles
            )
        self.plan: ShardPlan = plan_shards(
            self.layouts, plans, num_shards,
            names=self.names, group_freqs=gfreqs,
            capacity_tiles=self._capacity_tiles,
        )
        # host-resident master image: the serve-time DMA source for
        # incremental plan patches (kept even without replan so a later
        # enable_replan-style extension stays cheap; it is the same bytes
        # a parameter server would hold anyway)
        self._fused = build_fused_image(
            self.layouts, [np.asarray(tables[n]) for n in self.names]
        )
        images = self.plan.build_shard_images(self._fused)
        self.replan_cfg = replan
        self._eq1_batch = (
            replan.eq1_batch if replan and replan.eq1_batch else eq1_batch
        )
        if self._capacity_tiles is not None:
            # the hot tier is FIXED at its budget: pad the image stack
            # to capacity so every free slot is fetchable from day one
            # (slack_tiles growth/shrink is a no-tier concern)
            extra = self._capacity_tiles - images.shape[1]
            if extra > 0:
                pad = np.zeros(
                    (num_shards, extra) + images.shape[2:],
                    dtype=images.dtype,
                )
                images = np.concatenate([images, pad], axis=1)
        elif replan is not None and replan.slack_tiles > 0:
            # zero-tile headroom so early promotions fill slack instead
            # of growing (reallocating) the device image stack
            pad = np.zeros(
                (num_shards, replan.slack_tiles) + images.shape[2:],
                dtype=images.dtype,
            )
            images = np.concatenate([images, pad], axis=1)
        self.shard_images = jnp.asarray(images)
        #: host→device bytes of one fused tile — the paging_bytes unit
        self._tile_bytes = int(self._fused[0].nbytes) if len(self._fused) else 0
        self._tile_group = np.repeat(
            np.arange(self.plan.num_groups, dtype=np.int64),
            self.plan.group_copies,
        )
        # per-table training-time load mass: Eq. 1 is evaluated at this
        # magnitude at replan time (see rescale_load_to_plan) — constant
        # across rebases, since rescaled snapshots carry the same totals
        self._segments = [
            (s.group_offset, s.group_offset + s.num_groups)
            for s in self.plan.tables
        ]
        self._seg_load_totals = [
            float(self.plan.group_load[a:b].sum()) for a, b in self._segments
        ]
        self.tracker: Optional[DriftTracker] = (
            DriftTracker(
                self.plan.group_load,
                half_life=replan.half_life,
                min_queries=replan.min_queries,
            )
            if replan is not None
            else None
        )
        self._staged: Optional[PlanPatch] = None
        self._demote_streak = 0
        # per-flush drift-observation memo (content-keyed): replayed /
        # steady-state streams re-flush byte-identical compiled batches
        self._load_obs: Optional[LoadObservationCache] = (
            LoadObservationCache() if replan is not None else None
        )
        # ---- tiered storage state (DESIGN.md §9); None when untiered --
        self._residency: Optional[ResidencyIndex] = None
        self._host_queue: Optional[HostFetchQueue] = None
        self._tick = 0
        if tiers is not None:
            name_to_layout = dict(zip(self.names, self.layouts))
            self._residency = ResidencyIndex(self.plan, {
                seg.name: np.asarray(
                    name_to_layout[seg.name].group_of, dtype=np.int64
                ) + seg.group_offset
                for seg in self.plan.tables
            })
            hb = tiers.host_batch or batch_size
            self._host_queue = HostFetchQueue(
                hb, tiers.host_deadline or 4 * hb
            )
        knobs_set = (union_budget is not None or flush_deadline is not None
                     or flush_deadline_s is not None
                     or owner_set_max is not None or max_in_flight != 2
                     or threaded)
        if isinstance(flush_policy, str):
            if knobs_set:
                flush_policy = FlushPolicy(
                    kind=flush_policy, union_budget=union_budget,
                    deadline=flush_deadline, deadline_s=flush_deadline_s,
                    owner_set_max=owner_set_max,
                    max_in_flight=max_in_flight, threaded=threaded,
                )
        elif knobs_set:
            raise ValueError(
                "pass the flush knobs inside the FlushPolicy instance OR "
                "as keyword args with a policy-kind string, not both"
            )
        self.policy = FlushPolicy.parse(flush_policy, batch_size=batch_size)
        self.stats = ShardedServeStats(
            num_shards=num_shards, q_block=q_block, policy=self.policy.kind
        )
        self._buffer: Dict[str, List[Sequence[int]]] = {n: [] for n in self.names}
        self._buffered = 0
        # ---- async flush engine state (DESIGN.md §7); inert under
        # the synchronous "global" policy ----
        # ---- per-producer sequence spaces (DESIGN.md §10): every
        # stamped id packs (local_seq, producer_id), so the engine's
        # int64 seq plumbing carries the producer dimension for free --
        self._registry = ProducerRegistry()
        self.scheduler: Optional[FlushScheduler] = (
            FlushScheduler(self.plan, self.layouts, self.names,
                           q_block, self.policy,
                           seq_decode=self._registry.decode)
            if self.policy.is_async else None
        )
        self._in_flight: collections.deque = collections.deque()
        self._completed: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {
            n: [] for n in self.names
        }
        # per-table row counts: submit()-time validation rejects
        # out-of-range ids BEFORE anything is enqueued, so a malformed
        # query can never poison a buffered batch (the retry contract's
        # "remove the offender" happens at the door)
        self._num_rows: Dict[str, int] = {
            n: int(np.asarray(tables[n]).shape[0]) for n in self.names
        }
        # ---- self-healing failure policy + fault injection (§8) ----
        self.retry = RetryPolicy.parse(retry)
        self._injector = FaultInjector.parse(faults)
        if self._injector is not None:
            # poison keying speaks (table, producer, LOCAL seq): the
            # injector decodes the packed ids the engine hands it
            self._injector.bind_decoder(self._registry.decode)
        self._retry_rng = np.random.default_rng(self.retry.seed)
        # host copies of the logical tables: the watchdog's degraded
        # flush recomputes its rows here (reference gather+sum) — the
        # same bytes a parameter server holds, like self._fused
        self._host_tables: Dict[str, np.ndarray] = {
            n: np.asarray(tables[n]) for n in self.names
        }
        self._patch_fail_streak = 0
        # ---- thread driver state (DESIGN.md §7.2); started lazily on
        # the first submit under a threaded policy ----
        self._handoff: Optional[queue.Queue] = None
        self._driver: Optional[threading.Thread] = None
        self._driver_stop = threading.Event()
        # driver failures stash into a BOUNDED deque: the first error is
        # surfaced first (with the count of others), overflow beyond the
        # bound is counted in the ledger instead of silently overwriting
        self._driver_errors: collections.deque = collections.deque()
        self._suppressed_errors = 0
        # ---- multi-producer front door state (DESIGN.md §10) ----
        # stamp lock: registration + seq stamp + closed check + driver
        # start are one atomic step, so two producers' first submits
        # cannot race two drivers into existence and a stamp can never
        # interleave with close() or the drain-time seq reset
        # lock order (DESIGN.md §5): 3rd — after engine/results, before
        # the registry's lock
        self._stamp_lock = threading.Lock()
        # engine lock: serializes the INLINE engine (ingest/flush/
        # barrier) under concurrent producers; the thread driver never
        # takes it (the hand-off queue is its serialization)
        # lock order (DESIGN.md §5): outermost — taken before any other
        self._engine_lock = threading.RLock()
        # results lock: _completed appends (driver/host flush) vs the
        # drain-time extract-and-swap
        # lock order (DESIGN.md §5): 2nd — after engine, before stamp
        self._results_lock = threading.Lock()
        self._closed = False
        # submits past the stamp but not yet delivered (hand-off put in
        # flight, or inline ingest running) — the seq-reset guard and
        # close()'s drain loop both key off this being zero
        self._pending_submits = 0
        # submit-stamp timestamps, popped when the row materializes —
        # the e2e_latency_s samples (async paths only)
        self._e2e_t0: Dict[Tuple[str, int], float] = {}

    # ------------------------------------------------------------ serving --

    def serve(
        self, queries_by_table: Dict[str, Sequence[Sequence[int]]]
    ) -> Dict[str, jax.Array]:
        """Serves one synchronous multi-table batch.

        Pipeline per call: apply any staged plan patch (see
        :meth:`_apply_staged_patch` — this is flush *n+1* of the
        double-buffered ordering), compile each table's ragged queries
        (block-granular replica choice), rebase into the fused tile
        space, block-compile per shard, dispatch the sharded kernel,
        then — while the device executes — observe drift and stage the
        next patch, and finally block on the outputs and record stats.

        Args:
          queries_by_table: ``{table name: ragged row-id queries}``;
            tables absent or mapped to an empty list are skipped.

        Returns:
          ``{table name: (batch, dim) reduction}`` for every table that
          had at least one query (padding rows already sliced off).

        Raises:
          KeyError: a key names an unknown table.
        """
        t0 = time.perf_counter()
        unknown = set(queries_by_table) - set(self.names)
        if unknown:
            raise KeyError(f"unknown tables {sorted(unknown)!r}")
        served = [n for n in self.names if queries_by_table.get(n)]
        if not served:
            return {}
        # a synchronous serve is a barrier: async-pending queries flush
        # under the plan they were routed against and the pipeline
        # drains (the barrier applies any staged patch), so a patch can
        # never land mid-pipeline or orphan stale routing (DESIGN.md
        # §7.3).  In global mode nothing is ever in flight and the
        # staged patch applies here.
        if self.scheduler is not None:
            self._barrier()
        else:
            self._apply_staged_patch()
        # ---- residency split (DESIGN.md §9): a compiled batch may
        # never reference a cold tile, so cold queries peel off to the
        # host gather+sum path here, against the *post-patch* plan ----
        queries_of = {n: list(queries_by_table[n]) for n in served}
        parts: Dict[str, tuple] = {}
        if self._residency is not None and self._residency.any_cold:
            for n in served:
                hot_idx: List[int] = []
                cold_idx: List[int] = []
                for i, q in enumerate(queries_of[n]):
                    arr = np.asarray(list(q), dtype=np.int64)
                    if self._residency.is_resident(n, arr):
                        hot_idx.append(i)
                    else:
                        cold_idx.append(i)
                parts[n] = (hot_idx, cold_idx)
            cold_entries = [
                (n, i, queries_of[n][i])
                for n in served for i in parts[n][1]
            ]
            if cold_entries:
                self.stats.host_queries += len(cold_entries)
                # NOT host_flushes: that counter means "HostFetchQueue
                # batches served" — the sync path's inline cold
                # sub-batch never enters the queue
                self.stats.sync_cold_batches += 1
                if self.tracker is not None:
                    # cold queries never compile, but their loads must
                    # feed the tracker or a cold group can never warm
                    self.tracker.observe(
                        self._residency.host_group_loads(cold_entries),
                        len(cold_entries),
                    )
            self.stats.hot_queries += sum(
                len(parts[n][0]) for n in served
            )
        elif self._residency is not None:
            # fully-resident tiered plan: everything is a hot-tier hit
            self.stats.hot_queries += sum(
                len(queries_of[n]) for n in served
            )
        hot_of = {
            n: ([queries_of[n][i] for i in parts[n][0]]
                if n in parts else queries_of[n])
            for n in served
        }
        served_dev = [n for n in served if hot_of[n]]
        outs: List[np.ndarray] = []
        sbq = None
        if served_dev:
            tc = time.perf_counter()
            host_cq, sbq, spans = self._compile_batch(
                served_dev, {n: hot_of[n] for n in served_dev}
            )
            # synchronous compile sits squarely on the serving critical
            # path — never hidden (the §7 engine's motivating cost)
            self.stats.record_compile(time.perf_counter() - tc, hidden=False)
            outs = crossbar_reduce_tables(
                self.shard_images, sbq, spans,
                mesh=self.mesh, axis_name=self.axis_name,
                combine=self.combine, combine_chunks=self.combine_chunks,
                dynamic_switch=self.dynamic_switch, interpret=self.interpret,
            )
            n_queries = sum(len(hot_of[n]) for n in served_dev)
            # double buffering: the kernel above is dispatched but NOT
            # yet blocked on — drift bookkeeping and patch computation
            # are pure host work overlapping this flush's device time
            self._observe_and_stage(host_cq, n_queries)
            outs = [jax.block_until_ready(o) for o in outs]
        elif self.tracker is not None:
            # an all-cold batch still observed loads above — give the
            # drift statistic its chance to stage a paging patch
            self._maybe_stage()
        out: Dict[str, jax.Array] = {}
        dev_out = dict(zip(served_dev, outs))
        for n in served:
            if n not in parts or not parts[n][1]:
                out[n] = dev_out[n]
                continue
            hot_idx, cold_idx = parts[n]
            full = np.zeros(
                (len(queries_of[n]), self.dim),
                dtype=self._host_tables[n].dtype,
            )
            if hot_idx:
                full[np.asarray(hot_idx)] = np.asarray(dev_out[n])
            full[np.asarray(cold_idx)] = self._serve_cold_rows(
                n, [queries_of[n][i] for i in cold_idx]
            )
            out[n] = jnp.asarray(full)
        if sbq is not None:
            self.stats.record(
                sbq, self.dim, time.perf_counter() - t0,
                sum(len(hot_of[n]) for n in served_dev),
            )
        return out

    def _compile_batch(self, served, queries_of, participants=None):
        """Fused host compile shared by the sync and async paths.

        Per-table compile (block-granular replica choice) → rebase into
        the fused tile space → concat (blocks never span tables) → one
        host materialization serving both the per-shard block compiler
        and the drift observation (without it, each would pull the
        batch back from the device separately).

        Returns ``(host_cq, sbq, spans)``.
        """
        cqs = []
        for name in served:
            i = self.names.index(name)
            seg = self.plan.tables[i]
            cq = compile_queries(
                self.layouts[i], queries_of[name],
                replica_block=self.q_block,
            )
            cqs.append(offset_compiled_queries(cq, seg.tile_offset))
        fused_cq, spans = concat_compiled_queries(cqs, self.q_block)
        host_cq = CompiledQueries(
            tile_ids=np.asarray(fused_cq.tile_ids),
            bitmaps=np.asarray(fused_cq.bitmaps),
            max_tiles=fused_cq.max_tiles,
        )
        sbq = shard_block_queries(
            host_cq, self.plan, self.q_block, participants=participants
        )
        return host_cq, sbq, spans

    # --------------------------------------------------------- replanning --

    def _apply_staged_patch(self) -> None:
        """Swaps in the patch staged during the previous flush.

        Runs at the top of :meth:`serve`, before anything is compiled
        against the plan — flush *n*'s outputs were produced entirely
        under the old plan, flush *n+1* runs entirely under the new one
        (no torn state).  Image update DMAs only the moved tiles.

        A patch-apply failure (injected or real, before any state
        mutates) keeps the patch staged and retries it at the next
        barrier, up to ``retry.patch_retries`` times — then the patch is
        dropped (recorded) and serving continues under the live plan.
        Under the legacy policy the failure re-raises instead.
        """
        if self._staged is None:
            return
        assert not self._in_flight, (
            "plan patch applied mid-pipeline — barrier rule violated"
        )
        if self._injector is not None:
            try:
                self._injector.on_patch()
            except Exception:
                self.stats.ledger.patch_failures += 1
                self._patch_fail_streak += 1
                if not self.retry.quarantine:
                    raise
                if self._patch_fail_streak > self.retry.patch_retries:
                    self.stats.ledger.patches_dropped += 1
                    dropped, self._staged = self._staged, None
                    self._patch_fail_streak = 0
                    if self.tracker is not None and dropped.promoted:
                        # the drop discards promotions whose Eq.-1
                        # target status may persist: restore their
                        # drift marks so the next evaluation sees them
                        self.tracker.mark_drifted(dropped.promoted)
                return
        patch, self._staged = self._staged, None
        self._patch_fail_streak = 0
        self.shard_images = patch_shard_images(
            self.shard_images, patch, self._fused
        )
        self.plan = apply_plan_patch(self.plan, patch)
        self.stats.record_patch(patch, tile_bytes=self._tile_bytes)
        if self._residency is not None:
            # paging moved groups across the hot/cold boundary: routing
            # re-snapshots residency HERE and only here (barrier rule),
            # so every routed query matches the images its flush sees
            self._residency.refresh(self.plan)
        # slack age-out bookkeeping (DESIGN.md §6.2): demotion-only
        # patches extend the streak, any promotion resets it
        if patch.promoted:
            self._demote_streak = 0
        elif patch.demoted:
            self._demote_streak += 1
        if self.scheduler is not None:
            # ownership moved: re-derive row→home routing (pending work
            # was flushed under the old plan before we got here)
            self.scheduler.rebuild(self.plan)

    def _observe_and_stage(self, fused_cq, n_queries: int) -> None:
        """Feeds the tracker and stages a patch when drift crosses.

        Host-only work scheduled between kernel dispatch and
        ``block_until_ready``.  A no-op (class-unchanged) patch is
        applied immediately as a load rebase — it touches no device
        state, so there is nothing to double-buffer.
        """
        if self.tracker is None:
            return
        # content-keyed memo: steady-state / replayed streams re-flush
        # byte-identical compiled batches, whose loads are identical too
        loads = self._load_obs.loads(
            fused_cq, self._tile_group, self.plan.num_groups
        )
        self.stats.load_obs_hits = self._load_obs.hits
        self.stats.load_obs_misses = self._load_obs.misses
        self.tracker.observe(loads, n_queries)
        self._maybe_stage()

    def _maybe_stage(self) -> None:
        """Stages a patch when the tracked drift crosses the threshold.

        Shared by the compiled-batch observation above and the host
        (cold) path's flush — under tiering, cold-only traffic must
        still be able to trigger the paging patch that warms it up.
        """
        if self._staged is not None or not self.tracker.ready:
            return
        drift = self.tracker.drift_from(
            self.plan.group_load, segments=self._segments
        )
        if drift < self.replan_cfg.threshold:
            return
        # Eq. 1 is magnitude-sensitive: evaluate the observed
        # distribution at the training-time mass, not the tracker's
        drifted = rescale_load_to_plan(
            self.tracker.load(), self.plan, self._seg_load_totals
        )
        # long demotion streaks: age the accumulated slack back out so
        # the image stack shrinks to the live working set + headroom
        # (untiered only — the hot tier's capacity is fixed)
        shrink = (
            self.replan_cfg.slack_tiles
            if self.tiers is None
            and self.replan_cfg.shrink_streak
            and self._demote_streak >= self.replan_cfg.shrink_streak
            else None
        )
        paging = (
            self.tiers.paging_policy(self._capacity_tiles)
            if self.tiers is not None else None
        )
        # scale-invariant patch math: only the groups with observed
        # traffic since the last evaluation (plus the replicated set,
        # added inside) can change replication class — every other
        # group's estimate merely decayed (DESIGN.md §11)
        candidates = self.tracker.drifted_groups()
        self.tracker.reset_drifted()
        patch = compute_plan_patch(
            self.plan, drifted,
            eq1_batch=self._eq1_batch,
            capacity=int(self.shard_images.shape[1]),
            shrink_slack=shrink,
            paging=paging,
            candidates=candidates,
        )
        if patch.deferred:
            # deferred promotions stay candidates: their Eq.-1 target
            # status outlives the marks this evaluation consumed
            self.tracker.mark_drifted(patch.deferred)
        if patch.fetched:
            # freshly-resident groups may already be Eq.-1 targets; the
            # next evaluation must reconsider them even if untouched
            self.tracker.mark_drifted([g for g, _ in patch.fetched])
        if patch.is_noop():
            # drift without a class change: reanchor group_load so the
            # greedy demotion targets and the drift statistic both track
            # the observed distribution
            self.plan = apply_plan_patch(self.plan, patch)
            self.stats.record_patch(patch, tile_bytes=self._tile_bytes)
            return
        self._staged = patch

    # ----------------------------------------------------------- batching --

    def submit(
        self,
        table: str,
        query: Sequence[int],
        *,
        producer=None,
    ) -> Dict[str, jax.Array]:
        """Buffers one query; flush behavior depends on the policy.

        Under ``"global"``: auto-flushes (synchronously) at
        ``batch_size`` buffered and returns that flush's results.
        Under an async policy: the query routes to its home, any due
        homes flush *asynchronously* (dispatch only — no blocking on
        results), and the return value is always ``{}``; collect
        results with :meth:`drain` / :meth:`flush`.  With the thread
        driver the call only validates, stamps a sequence id and
        enqueues onto the bounded hand-off queue — dispatch and retire
        run on the driver, so submit never blocks on a full in-flight
        pipeline.

        ``submit()`` is safe under N concurrent producer threads
        (DESIGN.md §10): ``producer=`` names the calling stream (any
        hashable; ``None`` is the default producer), lazily registered
        on first stamp.  Each producer owns its own per-table sequence
        space, so one stream's FIFO order never depends on another's
        thread scheduling; a full :meth:`drain` merges streams in
        deterministic ``(local_seq, producer_id)`` order and
        ``drain(producer=...)`` returns one stream's rows alone.

        The query is validated HERE, before anything is enqueued or a
        sequence id is consumed: a malformed query (row ids outside
        the table) raises and leaves every buffer/queue untouched, so
        retrying the pending work never replays the offender.
        Per-call host latency is recorded (``submit_latency_s``
        percentiles in the stats summary).

        Args:
          table: table name the query reduces over.
          query: ragged row ids (an embedding-bag lookup).
          producer: producer-stream label (async policies; ``None`` =
            the default stream).

        Returns:
          The flush result (see :meth:`flush`) when a synchronous flush
          tripped, else ``{}``.

        Raises:
          KeyError: ``table`` is not a served table.
          IndexError: a row id falls outside ``[0, rows)``.
          RuntimeError: the server was :meth:`close`\\ d.
        """
        t0 = time.perf_counter()
        try:
            return self._submit(table, query, producer)
        finally:
            self.stats.record_submit(time.perf_counter() - t0)

    def _submit(
        self, table: str, query: Sequence[int], producer=None
    ) -> Dict[str, jax.Array]:
        if table not in self._buffer:  # unlocked: key set frozen at init
            raise KeyError(f"unknown table {table!r}")
        ids = np.asarray(list(query), dtype=np.int64)
        if ids.size:
            lo, hi = int(ids.min()), int(ids.max())
            if lo < 0 or hi >= self._num_rows[table]:
                raise IndexError(
                    f"query row ids [{lo}, {hi}] out of range "
                    f"[0, {self._num_rows[table]}) for table {table!r}"
                )
        if self.scheduler is not None:
            self._raise_driver_error()
            if self.policy.threaded:
                with self._stamp_lock:
                    # closed-check + stamp + driver-start are one
                    # atomic step: a close() cannot slip between a
                    # granted stamp and its hand-off accounting, and
                    # two producers' first submits cannot race two
                    # drivers into existence
                    if self._closed:
                        raise RuntimeError(
                            "submit() on a closed server: close() "
                            "stopped the driver; drain() still serves "
                            "what was already submitted"
                        )
                    seq = self._registry.stamp(producer, table)
                    if self._driver is None:
                        self._start_driver()
                    handoff = self._handoff
                    self._e2e_t0[(table, seq)] = time.perf_counter()
                    self._pending_submits += 1
                try:
                    handoff.put(("query", table, seq, list(query)))
                finally:
                    with self._stamp_lock:
                        self._pending_submits -= 1
                return {}
            with self._stamp_lock:
                if self._closed:
                    raise RuntimeError("submit() on a closed server")
                seq = self._registry.stamp(producer, table)
                self._e2e_t0[(table, seq)] = time.perf_counter()
                self._pending_submits += 1
            try:
                # the inline engine is not re-entrant: concurrent
                # producers serialize here (they may block behind a
                # flush — the never-blocks contract is the thread
                # driver's, not the inline engine's)
                with self._engine_lock:
                    self._ingest(table, seq, query)
            finally:
                with self._stamp_lock:
                    self._pending_submits -= 1
            return {}
        with self._engine_lock:
            self._buffer[table].append(list(query))
            self._buffered += 1
            if self._buffered >= self.batch_size:
                return self.flush()
        return {}

    def register_producer(self, producer=None) -> int:
        """Pre-registers a producer label, returning its pid.

        Optional — a first ``submit(producer=...)`` registers lazily —
        but registration order is the cross-producer merge tiebreak
        (DESIGN.md §10), so benches/tests that want a reproducible
        interleave register all labels up front, before any thread
        races a first stamp.
        """
        return self._registry.register(producer)

    def next_seq(self, table: str, producer=None) -> int:
        """Next LOCAL sequence id ``producer`` (default stream when
        ``None``) would stamp on ``table``; 0 for a producer that
        never submitted or after a quiesced drain's reset."""
        return self._registry.next_seq(table, producer)

    def producers(self) -> List:
        """Registered producer labels in pid (merge-tiebreak) order."""
        return self._registry.producers()

    def flush(self) -> Dict[str, jax.Array]:
        """Serves and clears all buffered work.

        Under ``"global"`` this serves the buffered per-table batches
        synchronously; the buffer is cleared only after a successful
        serve, so a failed flush (e.g. one malformed query) leaves every
        buffered request intact for retry after the offender is removed.
        Under an async policy this is a **barrier**: every pending home
        flushes, the in-flight pipeline drains, a staged plan patch
        applies, and all results accumulated since the last hand-off are
        returned (see :meth:`drain`).

        Returns:
          ``{table name: (batch, dim) reduction}`` per table with
          results; ``{}`` when nothing is buffered or in flight.  Row
          order within a table is submission order.
        """
        if self.scheduler is not None:
            return self.drain()
        # engine lock: a user-called flush must not interleave with a
        # concurrent global-mode submit() appending to the buffer
        with self._engine_lock:
            if self._buffered == 0:
                return {}
            batch = {n: q for n, q in self._buffer.items() if q}
            out = self.serve(batch)
            self._buffer = {n: [] for n in self.names}
            self._buffered = 0
            return out

    # ------------------------------------------- tiered host path (§9) ----

    def _ingest(self, table: str, seq: int, query) -> None:
        """Routes one stamped query by residency, then into the engine.

        The single entry point shared by the inline async submit path
        and the thread driver's loop — residency routing must happen
        where ``_completed`` is owned (the driver thread, when running),
        because a due host flush appends results directly.
        """
        if self._route_host(table, seq, query):
            return
        self.scheduler.push(table, seq, query)
        self._maybe_flush()

    def _route_host(self, table: str, seq: int, query) -> bool:
        """Detours a cold query into the host fetch queue.

        Every submission (hot or cold) advances the tier tick, so a
        queued cold query's deadline fires even in a hot-dominated
        stream.  Returns True when the query was queued host-side.
        """
        if self._residency is None:
            return False
        self._tick += 1
        arr = np.asarray(list(query), dtype=np.int64)
        if self._residency.is_resident(table, arr):
            self._maybe_flush_host()
            # the host flush above may have hit a patch barrier, which
            # pages groups and refreshes residency — re-check under the
            # post-patch plan: pushing a query whose group was just
            # evicted into the scheduler would raise on the cold group
            # instead of detouring host-side
            if self._residency.is_resident(table, arr):
                self.stats.hot_queries += 1
                return False
        self.stats.host_queries += 1
        self._host_queue.push(table, seq, arr, self._tick)
        self._maybe_flush_host()
        return True

    def _maybe_flush_host(self) -> None:
        reason = self._host_queue.due(self._tick)
        if reason is None:
            return
        if reason == "deadline":
            self.stats.host_deadline_flushes += 1
        self._flush_host_queue()

    def _flush_host_queue(self, *, forced: bool = False) -> None:
        """Serves every queued cold query via the host gather+sum path.

        The cold tier's compute: the same distinct-rows-summed oracle
        semantics the kernels are pinned against (and the watchdog's
        degrade path uses), so a capacity-bounded server stays
        bit-identical to the uncapped one on integer tables.  The
        batch's loads feed the drift tracker FIRST — host traffic is
        how a cold group earns its way in — and when that staged a
        paging patch on an un-forced flush, a barrier is triggered so
        cold-only traffic still reaches a patch-application point.
        ``forced`` marks the barrier's own drain (never re-enters).
        """
        if self._host_queue is None or len(self._host_queue) == 0:
            return
        entries = self._host_queue.take()
        self.stats.host_flushes += 1
        if self.tracker is not None:
            self.tracker.observe(
                self._residency.host_group_loads(entries), len(entries)
            )
            self._maybe_stage()
        rows_of: Dict[str, Tuple[List[int], List[np.ndarray]]] = {}
        for table, seq, query in entries:
            seqs, rows = rows_of.setdefault(table, ([], []))
            seqs.append(seq)
            rows.append(self._cold_row(table, query))
        for table, (seqs, rows) in rows_of.items():
            self._record_completed(
                table, np.asarray(seqs, dtype=np.int64), np.stack(rows)
            )
        if not forced and self._staged is not None:
            # cold-dominated traffic may never trip a device flush — the
            # staged paging patch would otherwise wait forever
            self._barrier()

    def _cold_row(self, table: str, query) -> np.ndarray:
        """One query's host gather+sum row (distinct rows, zeros when
        empty) — the cold-tier twin of the degrade path's kernel."""
        ids = np.unique(np.asarray(query, dtype=np.int64))
        tab = self._host_tables[table]
        row = (tab[ids].sum(axis=0) if ids.size
               else np.zeros(self.dim, dtype=tab.dtype))
        return row.astype(tab.dtype, copy=False)

    def _serve_cold_rows(self, table: str, queries) -> np.ndarray:
        """Stacked host rows for the sync path's cold sub-batch."""
        return np.stack([self._cold_row(table, q) for q in queries])

    # ------------------------------------------------- async flush engine --

    def _maybe_flush(self) -> None:
        """Dispatches every home the policy says is due.

        If a plan patch is staged, the next trigger forces a **barrier**
        instead (DESIGN.md §7.3): the pipeline drains under the old
        plan, the patch applies atomically, and traffic resumes under
        the new one — a patch never lands between in-flight flushes.
        """
        due = self.scheduler.due_homes()
        if not due:
            return
        if self._staged is not None:
            self._barrier()
            return
        for home in due:
            self._flush_home(home)

    def _flush_home(self, home: int, *, forced: bool = False) -> None:
        """Compiles and dispatches one home's pending batch (no block).

        The dispatch goes through the self-healing loop
        (:meth:`_heal_dispatch`, DESIGN.md §8): transient failures
        retry in place with backoff, persistent failures bisect down to
        (and quarantine) single offenders.  Only an error the policy
        does not absorb (``quarantine=False``, the legacy contract)
        requeues the whole batch in submission order — with its
        deadline clock intact — before re-raising.  ``forced`` marks
        barrier flushes, which are not policy-triggered and must not
        count as deadline firings.
        """
        if not forced and self.scheduler.due_reason(home) == "deadline":
            self.stats.deadline_flushes += 1
        first_tick = self.scheduler.first_tick(home)
        first_wall = self.scheduler.first_wall(home)
        entries, participants = self.scheduler.take(home)
        if not entries:
            return
        try:
            admitted = self._heal_dispatch(home, entries, participants)
        except Exception:
            self.scheduler.requeue(home, entries, first_tick=first_tick,
                                   first_wall=first_wall)
            raise
        # admission is OUTSIDE the requeue guard: a retire failure while
        # trimming the pipeline must not requeue a batch that is already
        # in flight (it would be served twice)
        for entry in admitted:
            self._admit(home, entry)

    def _heal_dispatch(self, home, entries, participants) -> List[_InFlight]:
        """Self-healing dispatch of one batch (DESIGN.md §8).

        State machine: up to ``max_retries`` in-place re-dispatches with
        jittered exponential backoff; a batch that still fails and
        holds > 1 queries **bisects** (both halves heal independently —
        repeated failure converges on single offenders in
        ``O(log batch)`` rounds); a single query that still fails is
        **quarantined** with its error in the ledger and dropped, so
        one poisoned query can never wedge its home.  Under the legacy
        policy (``quarantine=False``) the terminal error re-raises
        instead and the caller requeues.  Returns the successfully
        dispatched entries (metadata attached) for the caller to admit;
        a healed transient records its first-failure→dispatch recovery
        latency.
        """
        policy = self.retry
        ledger = self.stats.ledger
        t_first = None
        last: Optional[Exception] = None
        for attempt in range(policy.max_retries + 1):
            try:
                entry = self._compile_and_dispatch(entries, participants)
            except Exception as e:
                last = e
                if t_first is None:
                    t_first = time.perf_counter()
                if attempt < policy.max_retries:
                    pause = policy.backoff_s(attempt, self._retry_rng)
                    ledger.retries += 1
                    ledger.backoff_s += pause
                    if pause > 0:
                        time.sleep(pause)
                continue
            if t_first is not None:
                ledger.record_recovery(time.perf_counter() - t_first)
            entry.home = home
            entry.entries = entries
            entry.participants = participants
            return [entry]
        if policy.quarantine and policy.bisect and len(entries) > 1:
            ledger.bisections += 1
            mid = len(entries) // 2
            return (self._heal_dispatch(home, entries[:mid], participants)
                    + self._heal_dispatch(home, entries[mid:], participants))
        if policy.quarantine:
            # terminal: drop the offender(s), keep the home serving.
            # With bisection on, entries is a single isolated query;
            # with it off, the whole batch quarantines (recorded).
            for table, seq, _query in entries:
                prod, local = self._registry.decode(seq)
                ledger.quarantine(table, local, last, producer=prod)
                self._e2e_t0.pop((table, seq), None)
            self.scheduler.record_quarantine(len(entries))
            return []
        raise last

    def _admit(self, home, entry: _InFlight) -> None:
        """Enqueues one dispatched flush and trims the pipeline."""
        self._in_flight.append(entry)
        # peak is sampled at APPEND time — the queue transiently holds
        # max_in_flight + 1 entries before the retire loop below trims
        # it, and that transient depth is exactly what the stat reports
        self.stats.in_flight_peak = max(
            self.stats.in_flight_peak, len(self._in_flight)
        )
        self.stats.record_flush_home(home)
        # drift bookkeeping is pure host work: it overlaps this flush's
        # device execution exactly like the next flush's compile does
        self._observe_and_stage(entry.host_cq, entry.n_queries)
        while len(self._in_flight) > self.policy.max_in_flight:
            self._retire_oldest()

    def _device_busy(self) -> bool:
        """Whether any in-flight flush is still executing on device.

        Feeds the ``hidden_compile_s`` accounting, whose contract is a
        conservative LOWER bound on genuinely-overlapped compile time —
        so an array type without ``is_ready`` (e.g. an already-
        materialized NumPy output from a stubbed dispatch) counts as
        idle, never as busy.
        """
        return any(not self._entry_ready(e) for e in self._in_flight)

    def _compile_and_dispatch(
        self,
        entries: List[tuple],
        participants: List[int] | None,
    ) -> _InFlight:
        """Host-compiles a batch and dispatches its kernel, non-blocking.

        The double-buffered ordering (DESIGN.md §7.2): this host compile
        runs while any earlier flush still executes on device — the
        ``record_compile(hidden=...)`` accounting below is exactly that
        overlap, sampled at compile END so a compile only counts as
        hidden if device work was genuinely still running when it
        finished (a conservative lower bound).  ``block_until_ready``
        happens only at result hand-off (:meth:`_retire_oldest`).

        Mutates no engine state besides stats — a raise anywhere leaves
        the pipeline exactly as it was (the caller retries or requeues).
        The fault injector's compile seam fires before the compile and
        its dispatch seam between compile and kernel dispatch
        (DESIGN.md §8); an injected hang tags the entry so readiness
        polling simulates the hung device.
        """
        t0 = time.perf_counter()
        if self._injector is not None:
            self._injector.on_compile(entries)
        by_table: Dict[str, Tuple[List[int], List[list]]] = {}
        for table, seq, query in entries:
            seqs, qs = by_table.setdefault(table, ([], []))
            seqs.append(seq)
            qs.append(query)
        served = [n for n in self.names if n in by_table]
        host_cq, sbq, spans = self._compile_batch(
            served, {n: by_table[n][1] for n in served},
            participants=participants,
        )
        self.stats.record_compile(
            time.perf_counter() - t0, hidden=self._device_busy()
        )
        hang_s = (
            self._injector.on_dispatch() if self._injector is not None
            else None
        )
        outs = crossbar_reduce_tables(
            self.shard_images, sbq, spans,
            mesh=self.mesh, axis_name=self.axis_name,
            combine=self.combine, combine_chunks=self.combine_chunks,
            dynamic_switch=self.dynamic_switch, interpret=self.interpret,
        )
        return _InFlight(
            outs=outs, sbq=sbq, served=served,
            seqs={n: np.asarray(by_table[n][0], dtype=np.int64)
                  for n in served},
            t0=t0, n_queries=sum(len(by_table[n][1]) for n in served),
            host_cq=host_cq,
            t_dispatch=time.perf_counter(), hang_s=hang_s,
        )

    def _retire_oldest(self) -> None:
        """Retires the oldest in-flight flush and stashes its rows.

        The §8 failure seams live here: a watchdog timeout (hung device
        work) degrades the flush to the host path instead of blocking
        forever; a retire-time device fault re-enters the healing loop
        (re-compile + re-dispatch of the same batch) under the default
        policy, or requeues + re-raises under the legacy one.
        """
        e = self._in_flight.popleft()
        try:
            if self._injector is not None:
                self._injector.on_retire()
            outs = self._wait_outputs(e)
        except FlushTimeout:
            self._degrade(e)
            return
        except Exception:
            if self.retry.quarantine and e.entries is not None:
                # late device fault: the outputs are lost but the raw
                # batch is not — heal it like a dispatch-time failure
                self.stats.ledger.retries += 1
                for entry in self._heal_dispatch(
                    e.home, e.entries, e.participants
                ):
                    self._admit(e.home, entry)
                return
            if e.entries is not None:
                # legacy contract: the batch goes back to its home so
                # the next barrier retries it, then the error surfaces
                self.scheduler.requeue(e.home, e.entries)
            raise
        self.stats.record(
            e.sbq, self.dim, time.perf_counter() - e.t0, e.n_queries
        )
        for name, out in zip(e.served, outs):
            self._record_completed(name, e.seqs[name], np.asarray(out))

    def _record_completed(
        self, table: str, seqs: np.ndarray, rows: np.ndarray
    ) -> None:
        """Stashes one flush's rows for :meth:`drain`, samples e2e
        latency, under the results lock (a drain on another thread may
        be extracting concurrently)."""
        now = time.perf_counter()
        for s in seqs:
            t0 = self._e2e_t0.pop((table, int(s)), None)
            if t0 is not None:
                self.stats.e2e_wall.append(now - t0)
        with self._results_lock:
            self._completed[table].append((seqs, rows))

    def _wait_outputs(self, e: _InFlight) -> List[np.ndarray]:
        """Blocks on one flush's outputs, bounded by the watchdog.

        Without a watchdog (and without an injected hang) this is a
        plain ``block_until_ready``.  With one, readiness is polled and
        :class:`FlushTimeout` raises once ``watchdog_s`` has elapsed
        since the flush's kernel DISPATCH — a flush that hung long
        before the barrier reached it times out immediately.  An
        injected infinite hang with no watchdog configured also times
        out (degrading is always preferred to wedging ``drain()``).
        """
        wd = self.retry.watchdog_s
        if wd is None and e.hang_s is None:
            return [jax.block_until_ready(o) for o in e.outs]
        while not self._entry_ready(e):
            waited = time.perf_counter() - e.t_dispatch
            if wd is not None and waited >= wd:
                raise FlushTimeout(
                    f"flush not ready {waited:.3f}s after dispatch "
                    f"(watchdog {wd}s)"
                )
            if wd is None and e.hang_s == math.inf:
                raise FlushTimeout(
                    "flush hung forever with no watchdog configured"
                )
            time.sleep(self.retry.watchdog_poll_s)
        return [jax.block_until_ready(o) for o in e.outs]

    def _degrade(self, e: _InFlight) -> None:
        """Serves one timed-out flush via the inline host/reference path.

        The graceful half of the watchdog: the hung device outputs are
        abandoned and every query in the flush is recomputed as a host
        gather+sum over the logical table (the oracle semantics the
        kernels are pinned against — distinct rows summed, empty bags
        zero), so ``drain()`` still returns every row.  Recorded as a
        degraded + timed-out flush in the ledger.
        """
        ledger = self.stats.ledger
        ledger.timed_out_flushes += 1
        ledger.degraded_flushes += 1
        if e.entries is None:  # no raw batch — nothing to recompute from
            raise FlushTimeout(
                "timed-out flush carries no raw batch to degrade with"
            )
        rows_of: Dict[str, Tuple[List[int], List[np.ndarray]]] = {}
        for table, seq, query in e.entries:
            ids = np.unique(np.asarray(list(query), dtype=np.int64))
            tab = self._host_tables[table]
            row = (tab[ids].sum(axis=0) if ids.size
                   else np.zeros(self.dim, dtype=tab.dtype))
            seqs, rows = rows_of.setdefault(table, ([], []))
            seqs.append(seq)
            rows.append(row.astype(tab.dtype, copy=False))
        for table, (seqs, rows) in rows_of.items():
            self._record_completed(
                table, np.asarray(seqs, dtype=np.int64), np.stack(rows)
            )
        self.stats.record(
            e.sbq, self.dim, time.perf_counter() - e.t0, e.n_queries
        )

    def _barrier(self) -> None:
        """Flush-everything + drain + apply any staged patch atomically.

        Pending queries were routed (and are compiled here) under the
        plan they were submitted against; only after every dispatched
        flush retires does the staged patch swap placement arrays and
        the scheduler re-derive its routing.

        With the thread driver running, a caller on any other thread
        posts a barrier token onto the hand-off queue and joins the
        driver at it: the driver first drains every earlier hand-off
        item (FIFO), then runs this barrier inline — so the ordering
        guarantees are identical to the inline engine's.
        """
        driver = self._driver
        if (driver is not None
                and threading.current_thread() is not driver):
            handoff = self._handoff
            if handoff is not None:
                done = threading.Event()
                handoff.put(("barrier", done))
                # never wait forever on a driver that died or was
                # closed under us — poll its liveness while waiting
                while not done.wait(0.1):
                    if self._driver is not driver or not driver.is_alive():
                        break
                self._raise_driver_error()
                return
        for home in self.scheduler.homes_with_pending():
            self._flush_home(home, forced=True)
        while self._in_flight:
            self._retire_oldest()
        # queued cold work drains with the pipeline (host rows read the
        # master image, so ordering vs the patch below is immaterial —
        # but a drain must hand back every submitted query's row)
        self._flush_host_queue(forced=True)
        self._apply_staged_patch()
        self.stats.barrier_flushes += 1

    # ------------------------------------------------------ thread driver --

    def _start_driver(self) -> None:
        self._handoff = queue.Queue(maxsize=self.policy.handoff_depth)
        self._driver_stop = threading.Event()
        self._driver = threading.Thread(
            target=self._driver_loop, name="recross-flush-driver", daemon=True
        )
        self._driver.start()

    def _driver_loop(self) -> None:
        """Dispatch/retire loop of the thread driver (DESIGN.md §7.2).

        Pops hand-off items FIFO: a query item routes + maybe-flushes
        (exactly the inline engine's submit path), a barrier token runs
        :meth:`_barrier` inline and wakes its waiter.  While the queue
        is idle, in-flight flushes whose outputs are already
        materialized retire opportunistically, so result hand-off
        latency does not wait for the next submission.  A flush failure
        leaves its batch requeued (the :meth:`_flush_home` contract)
        and is stashed for :meth:`_raise_driver_error` to surface on
        the caller's thread.
        """
        while not self._driver_stop.is_set():
            try:
                item = self._handoff.get(timeout=0.005)
            except queue.Empty:
                try:
                    self._retire_ready()
                    # a wall deadline (policy.deadline_s) must fire even
                    # when no submission arrives to consult the trigger —
                    # the idle loop is the only clock a quiet stream has
                    if self.policy.deadline_s is not None:
                        self._maybe_flush()
                except Exception as e:  # device fault surfacing at retire
                    self._stash_driver_error(e)
                continue
            if item[0] == "barrier":
                done = item[1]
                try:
                    self._barrier()
                except Exception as e:
                    self._stash_driver_error(e)
                finally:
                    # task_done BEFORE waking the waiter: the seq-reset
                    # guard reads unfinished_tasks right after a drain's
                    # barrier returns, and this token must not count
                    self._handoff.task_done()
                    done.set()
                continue
            _, table, seq, query_list = item
            try:
                self._ingest(table, seq, query_list)
            except Exception as e:
                # the batch is already requeued; surface the failure at
                # the caller's next submit()/drain() (retry contract)
                self._stash_driver_error(e)
            finally:
                # a popped-but-unprocessed item is invisible to both
                # empty() and the scheduler — unfinished_tasks is the
                # counter that still sees it (seq-reset guard)
                self._handoff.task_done()

    def _retire_ready(self) -> None:
        """Retires in-flight flushes whose outputs are already
        materialized, oldest-first (hand-off order preserved).  With a
        watchdog configured, a hung HEAD entry past its deadline is
        retired proactively here (taking the timeout/degrade path) so
        a stuck flush degrades while the driver idles, not only when a
        barrier finally reaches it."""
        while self._in_flight and self._entry_ready(self._in_flight[0]):
            self._retire_oldest()
        wd = self.retry.watchdog_s
        if (wd is not None and self._in_flight
                and time.perf_counter() - self._in_flight[0].t_dispatch >= wd):
            self._retire_oldest()

    @staticmethod
    def _entry_ready(e: _InFlight) -> bool:
        # an injected hang simulates a device that never reports ready
        # until hang_s has elapsed since dispatch (math.inf = never) —
        # the watchdog path is exercised without wedging real hardware
        if e.hang_s is not None and (
            time.perf_counter() - e.t_dispatch
        ) < e.hang_s:
            return False
        for o in e.outs:
            try:
                if not o.is_ready():
                    return False
            except AttributeError:  # no is_ready ⇒ already materialized
                continue
        return True

    def _stash_driver_error(self, e: BaseException) -> None:
        """Stashes one driver failure for the caller's thread, bounded.

        The first failure is what the caller sees first; later ones
        queue behind it (up to :data:`_MAX_STASHED_ERRORS`) instead of
        silently overwriting, and overflow beyond the bound is counted
        in the ledger — never dropped without trace.
        """
        if len(self._driver_errors) < _MAX_STASHED_ERRORS:
            self._driver_errors.append(e)
        else:
            self._suppressed_errors += 1
            self.stats.ledger.driver_errors_suppressed += 1

    def _raise_driver_error(self) -> None:
        """Re-raises the OLDEST failure stashed by the driver thread.

        The message carries the count of further failures still stashed
        (and of any suppressed past the bound) so a burst of errors is
        never mistaken for a single one; each later
        ``submit()``/``drain()`` surfaces the next.
        """
        if not self._driver_errors:
            return
        err = self._driver_errors.popleft()
        more = len(self._driver_errors) + self._suppressed_errors
        if more and err.args and isinstance(err.args[0], str):
            suppressed = (
                f", {self._suppressed_errors} suppressed past the stash "
                f"bound" if self._suppressed_errors else ""
            )
            err.args = (
                f"{err.args[0]} [+{more} more driver failure(s) "
                f"stashed{suppressed}]",
            ) + err.args[1:]
        raise err

    #: driver join bound at close(); a driver stuck in un-watchdogged
    #: device work is abandoned (daemon thread) rather than wedging the
    #: caller, and the leak is recorded in the ledger's lost-work summary
    _CLOSE_JOIN_S = 30.0

    def close(self) -> None:
        """Stops the thread driver (if running) and closes the front
        door: any later :meth:`submit` — including one already racing
        this call on another thread — gets a clean ``RuntimeError``
        instead of work that would silently never flush.  Hand-off
        items the driver had not yet popped are pushed back into the
        scheduler, so no submitted query (or its stamped sequence id)
        is ever dropped — a later :meth:`drain` serves them inline
        (the driver does not restart).

        Idempotent and bounded: a second ``close()`` is a no-op, the
        driver join can never hang past :data:`_CLOSE_JOIN_S` (a driver
        wedged in un-watchdogged device work is abandoned — it is a
        daemon thread — and recorded), and a producer blocked in a
        full hand-off ``put()`` is unblocked by the push-back loop
        below (its item is drained like the rest), so close can never
        deadlock against concurrent submitters.  Work still unserved
        at close (requeued batches, pushed-back hand-off items,
        unretired in-flight flushes) is summarized into the ledger's
        ``lost_work`` instead of silently discarded.
        """
        with self._stamp_lock:
            already = self._closed
            self._closed = True
        if already:
            return
        leaked = False
        if self._driver is not None:
            self._driver_stop.set()
            self._driver.join(timeout=self._CLOSE_JOIN_S)
            leaked = self._driver.is_alive()
            self._driver = None
        pushed_back = 0
        if self._handoff is not None:
            # drain until no producer is still inside put(): every get
            # below frees a slot, so a submitter blocked on the full
            # queue completes its put and exits via _pending_submits
            while True:
                try:
                    item = self._handoff.get_nowait()
                except queue.Empty:
                    with self._stamp_lock:
                        if (self._pending_submits == 0
                                and self._handoff.empty()):
                            break
                    time.sleep(0.001)
                    continue
                if item[0] == "barrier":
                    # a concurrent drain()'s token: wake the waiter
                    # (its barrier re-runs inline once the driver is
                    # observed gone)
                    item[1].set()
                else:
                    _, table, seq, query_list = item
                    self.scheduler.push(table, seq, query_list)
                    pushed_back += 1
            self._handoff = None
        if self.scheduler is not None:
            requeued = self.scheduler.pending_total()
        else:
            # engine lock: snapshot vs a concurrent global-mode submit
            with self._engine_lock:
                requeued = self._buffered
        unserved = {
            "requeued": requeued,
            "handoff_pushed_back": pushed_back,
            "in_flight": len(self._in_flight),
            "host_pending": (len(self._host_queue)
                             if self._host_queue is not None else 0),
            "stashed_errors": len(self._driver_errors),
            "driver_leaked": int(leaked),
        }
        if any(unserved.values()):
            self.stats.ledger.lost_work = unserved

    def __enter__(self) -> "ShardedEmbeddingServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def drain(self, producer=None) -> Dict[str, jax.Array]:
        """Barrier + result hand-off for async policies.

        Flushes every pending home, retires the whole in-flight queue,
        applies a staged plan patch (the only legal application point
        besides a triggered barrier), and returns everything served
        since the previous hand-off.  Under the thread driver this
        joins the driver at a barrier token; a failure stashed by the
        driver (or one raised by the barrier's own retry of requeued
        work) surfaces here — retry by draining again once the
        transient clears.

        With ``producer=None`` (a FULL drain) every completed row is
        returned, merged per table in the deterministic ``(local_seq,
        producer_id)`` order (DESIGN.md §10) — single-producer streams
        see exactly the pre-§10 submission order.  With ``producer=``
        a label, only that producer's rows return (in ITS submission
        order); every other stream's completed work stays stashed for
        its own drain — no cross-producer head-of-line result mixing.

        Returns:
          ``{table: (n_queries, dim)}`` arrays; ``{}`` for tables with
          no completed work (for this producer).
        """
        if self.scheduler is None:
            if producer is not None:
                raise ValueError(
                    "drain(producer=...) needs an async flush policy"
                )
            return self.flush()
        self._raise_driver_error()
        if self._driver is not None:
            self._barrier()
        else:
            # inline engine: serialize against concurrent submits
            with self._engine_lock:
                self._barrier()
        out: Dict[str, jax.Array] = {}
        with self._results_lock:
            if producer is None:
                for name in self.names:
                    chunks = self._completed[name]
                    if not chunks:
                        continue
                    seqs = np.concatenate([c[0] for c in chunks])
                    rows = np.concatenate([c[1] for c in chunks])
                    # packed ids sort as (local_seq, producer_id): the
                    # cross-producer merge is deterministic, and within
                    # one producer it is that producer's FIFO
                    out[name] = jnp.asarray(rows[np.argsort(seqs)])
                self._completed = {n: [] for n in self.names}
            else:
                pid = self._registry.pid(producer)
                stride = self._registry.stride
                for name in self.names:
                    chunks = self._completed[name]
                    if not chunks or pid is None:
                        continue
                    seqs = np.concatenate([c[0] for c in chunks])
                    rows = np.concatenate([c[1] for c in chunks])
                    mine = (seqs % stride) == pid
                    if mine.any():
                        sel = seqs[mine]
                        out[name] = jnp.asarray(
                            rows[mine][np.argsort(sel)]
                        )
                    rest = ~mine
                    self._completed[name] = (
                        [(seqs[rest], rows[rest])] if rest.any() else []
                    )
        # sequence ids restart ONLY at full quiescence — nothing
        # pending, in flight, queued host-side, stashed for another
        # producer's drain, or still inside a submit()'s stamped-but-
        # undelivered window (the hand-off's unfinished_tasks counts
        # popped-but-unprocessed items too).  Resetting any earlier
        # would hand new submissions colliding packed seqs and
        # scramble a later drain's merge order.  Per-producer drains
        # never reset: other streams' counters are always live.
        if producer is None:
            with self._results_lock:
                with self._stamp_lock:
                    handoff = self._handoff
                    busy = (
                        self._pending_submits > 0
                        or (handoff is not None
                            and handoff.unfinished_tasks > 0)
                    )
                    if (not busy
                            and self.scheduler.pending_total() == 0
                            and not self._in_flight
                            and (self._host_queue is None
                                 or len(self._host_queue) == 0)
                            and not any(self._completed.values())):
                        # opt-in structural validation at quiescence
                        # (RECROSS_VALIDATE=1, DESIGN.md §12) — the
                        # one moment every invariant must hold at once
                        from repro.analysis.invariants import (
                            validation_enabled,
                        )

                        if validation_enabled():
                            from repro.analysis.invariants import (
                                validate_server_state,
                            )

                            validate_server_state(self, quiesced=True)
                        self._registry.reset_seqs()
        return out

    # ------------------------------------------------------------- report --

    def _snapshot_closed(self) -> bool:
        """Reads the closed flag under the stamp lock that guards it."""
        with self._stamp_lock:
            return self._closed

    def report(self) -> Dict[str, object]:
        """Serving + placement accounting for dashboards and benches.

        Returns a dict with:
          * ``tables`` — served table names (sorted).
          * ``plan`` — tile residency / replication overhead of the
            *current* (possibly patched) plan
            (:meth:`ShardPlan.memory_summary`).
          * ``serve`` — cumulative flush stats
            (:meth:`ShardedServeStats.summary`), including the replan
            counters.
          * ``mode`` — ``"shard_map"`` or ``"emulated"``.
          * ``retry`` — the live :class:`~repro.serve.faults.
            RetryPolicy` knobs; the matching error ledger rides inside
            ``serve["faults"]`` (retries, backoff, quarantined queries,
            degraded/timed-out flushes, lost work at close).
          * ``faults`` — fault-injection plan + per-seam attempt/
            injection counters (only when a ``faults=`` plan is set).
          * ``replan`` — drift/replanning state (only when enabled):
            current drift vs the live plan, tracker readiness, staged
            patch summary if one is waiting for the next flush.
        """
        rep: Dict[str, object] = {
            "tables": self.names,
            "plan": self.plan.memory_summary(),
            "serve": self.stats.summary(),
            "mode": "shard_map" if self.mesh is not None else "emulated",
            "retry": dataclasses.asdict(self.retry),
            # process-global jit-dispatch cache pressure (bounded LRUs
            # in kernels.sharded) — participants churn shows up here
            "dispatch_cache": dispatch_cache_stats(),
        }
        if self.tiers is not None:
            rep["tiers"] = {
                "capacity_tiles": self._capacity_tiles,
                "hysteresis": self.tiers.hysteresis,
                "cold_groups": int(self.plan.cold_groups.size),
                "cold_tiles": self.plan.cold_tiles,
                "resident_groups": int(self.plan.resident_group.sum()),
                "host_queue": self._host_queue.state(),
            }
        if self._injector is not None:
            rep["faults"] = self._injector.summary()
        if self.scheduler is not None:
            rep["scheduler"] = {
                "policy": self.policy.kind,
                "batch_size": self.policy.batch_size,
                "union_budget": self.policy.union_budget,
                "deadline": self.policy.deadline,
                "deadline_s": self.policy.deadline_s,
                "max_in_flight": self.policy.max_in_flight,
                "in_flight": len(self._in_flight),
                "threaded": self.policy.threaded,
                "handoff_depth": self.policy.handoff_depth,
                "handoff_pending": (
                    self._handoff.qsize() if self._handoff is not None else 0
                ),
                "closed": self._snapshot_closed(),
                **self.scheduler.state(),
                "producers": self._registry.state(),
            }
        if self.tracker is not None:
            rep["replan"] = {
                "threshold": self.replan_cfg.threshold,
                "half_life": self.replan_cfg.half_life,
                "drift": self.tracker.drift_from(
                    self.plan.group_load, segments=self._segments
                ),
                "observed_queries": self.tracker.observed_queries,
                "ready": self.tracker.ready,
                "staged": (
                    self._staged.summary() if self._staged is not None else None
                ),
                "image_capacity": int(self.shard_images.shape[1]),
                # free headroom above the highest allocated slot — what
                # slack age-out (shrink_streak) reclaims
                "slack_slots": int(
                    self.shard_images.shape[1] - self.plan.max_local_tiles
                ),
                "demote_streak": self._demote_streak,
            }
        return rep
