"""Serve-time access-frequency drift tracking (DESIGN.md §6).

The observation half of online replanning: the shard plan was balanced
and Eq.-1-replicated for *training-time* group frequencies, but serving
traffic drifts (hour-of-day shifts, new hot items, flash crowds — the
locality-aware-placement literature's motivating observation).  The
tracker maintains an exponentially decayed per-fused-group load estimate
from the batches the server actually compiles, and reports a drift
statistic against the load the live plan was built for.  When the
statistic crosses :attr:`ReplanConfig.threshold`, the server asks
:func:`repro.dist.replan.compute_plan_patch` for an incremental patch.

The drift statistic is total-variation distance between the *normalized*
decayed observation and the *normalized* plan load:

    drift = ½ · Σ_g | p̂_g − p_g |   ∈ [0, 1]

TV is scale-free (training counts and per-flush counts differ by orders
of magnitude), bounded (a threshold has a meaning independent of table
size), and exactly the quantity the plan cares about: the fraction of
serving mass sitting on groups the plan placed for a different mass.

The decayed estimate is seeded with the plan's own load, so an
undrifted workload starts at drift ≈ 0 and the training prior fades
with a half-life of ``half_life`` flushes as real observations arrive.

:class:`LoadObservationCache` memoizes the per-batch
``fused_group_loads`` observation by compiled-batch content: replayed
streams and steady-state serving re-flush identical compiled batches,
and the bincount-over-bitmaps observation is several passes over the
``(batch, max_tiles, tile_rows)`` stack while a content digest is one —
so the observation cost stops scaling with the flush rate.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib

import numpy as np

from repro.core.reduction import fused_group_loads


@dataclasses.dataclass
class ReplanConfig:
    """Online-replanning knobs for the sharded embedding server.

    Attributes:
      threshold: total-variation drift that triggers a plan patch
        (0 = patch on any wobble, 1 = never; 0.25 means a quarter of
        the serving mass has moved to differently-placed groups).
      half_life: flushes after which an observation's weight halves in
        the decayed load estimate (also how fast the training-time
        prior fades).
      min_queries: observed queries required before the first patch may
        trigger (guards against replanning on a cold, noisy estimate).
      eq1_batch: Eq. 1's ``batch`` for the replicate-vs-shard threshold
        at replan time; ``None`` uses the server's offline
        ``batch_size_for_eq1``.
      slack_tiles: extra zero tiles of per-shard image headroom
        allocated at build, so early promotions reuse slack instead of
        growing (reallocating) the device image stack.
      shrink_streak: consecutive demotion-only patches after which
        slack capacity ages out — the next patch also shrinks the image
        stack back to the highest allocated slot + ``slack_tiles``
        headroom, so the slot free-list stops growing monotonically
        under a cooling workload.  0 disables age-out (capacity stays
        at its high-water mark forever).
    """

    threshold: float = 0.25
    half_life: float = 8.0
    min_queries: int = 64
    eq1_batch: int | None = None
    slack_tiles: int = 0
    shrink_streak: int = 0


class DriftTracker:
    """Decayed per-group load estimate + total-variation drift statistic.

    Pure host-side NumPy; all methods are O(G) and run between a
    flush's kernel dispatch and its ``block_until_ready`` (see the
    double-buffered ordering in DESIGN.md §6).
    """

    def __init__(
        self,
        baseline_load: np.ndarray,
        *,
        half_life: float = 8.0,
        min_queries: int = 64,
    ):
        base = np.asarray(baseline_load, dtype=np.float64)
        self.decayed = base.copy()
        self.half_life = float(half_life)
        self.min_queries = int(min_queries)
        self.observed_queries = 0
        self.observations = 0
        self._alpha = 0.5 ** (1.0 / max(self.half_life, 1e-9))
        # groups with any observed traffic since the last replan
        # evaluation — the candidate set compute_plan_patch needs to
        # stay scale-invariant (everything else only decayed)
        self._dirty = np.zeros(base.shape[0], dtype=bool)

    @property
    def ready(self) -> bool:
        """Whether enough traffic has been seen to trust the estimate."""
        return self.observed_queries >= self.min_queries

    def observe(self, group_loads: np.ndarray, num_queries: int) -> None:
        """Folds one flush's per-group loads into the decayed estimate.

        Args:
          group_loads: ``(G,)`` active-row counts of the flush
            (:func:`repro.core.reduction.fused_group_loads`).
          num_queries: queries the flush served (gates ``ready``).
        """
        loads = np.asarray(group_loads, dtype=np.float64)
        if loads.shape != self.decayed.shape:
            raise ValueError(
                f"observation has shape {loads.shape}, tracker has "
                f"{self.decayed.shape}"
            )
        self.decayed = self._alpha * self.decayed + loads
        self._dirty |= loads > 0.0
        self.observed_queries += int(num_queries)
        self.observations += 1

    def load(self) -> np.ndarray:
        """Snapshot of the decayed ``(G,)`` load estimate."""
        return self.decayed.copy()

    def drifted_groups(self) -> np.ndarray:
        """Fused group ids with observed traffic since the last
        :meth:`reset_drifted` — the exact ``candidates`` set for
        :func:`repro.dist.replan.compute_plan_patch`: every other
        group's estimate has only decayed, so its Eq.-1 copy count
        cannot have risen (DESIGN.md §11)."""
        return np.nonzero(self._dirty)[0]

    def reset_drifted(self) -> None:
        """Clears the drift marks — call when a replan evaluation has
        consumed them (whether or not the patch changed anything)."""
        self._dirty[:] = False

    def mark_drifted(self, group_ids) -> None:
        """Re-marks groups as drift candidates: deferred promotions and
        dropped patches leave groups whose Eq.-1 target status must
        survive the evaluation that consumed their marks."""
        ids = np.asarray(group_ids, dtype=np.int64)
        if ids.size:
            self._dirty[ids] = True

    def drift_from(self, reference_load, segments=None) -> float:
        """Total-variation distance to a reference load, both normalized.

        Args:
          reference_load: ``(G,)`` load the live plan was placed for.
          segments: optional ``(start, end)`` group-id ranges (one per
            table).  When given, the TV distance is computed *per
            segment* and the maximum is returned.  This matters for
            multi-table serving: each table's mass decays on every
            flush, so a table that simply receives no traffic would
            shift the *global* distribution and register as standing
            drift even though no table's own access pattern moved — and
            an idle table's decayed estimate is a scaled copy of its
            reference, which normalizes to exactly zero segment drift.

        Returns 0.0 for (segments of) zero mass on either side (nothing
        observed yet, or a plan built with all-zero frequencies) — no
        drift signal is derivable, so no replan triggers.
        """
        q = np.asarray(reference_load, dtype=np.float64)
        if segments is None:
            segments = [(0, self.decayed.shape[0])]
        drift = 0.0
        for start, end in segments:
            p_s = self.decayed[start:end]
            q_s = q[start:end]
            ps, qs = float(p_s.sum()), float(q_s.sum())
            if ps <= 0.0 or qs <= 0.0:
                continue
            drift = max(
                drift, 0.5 * float(np.abs(p_s / ps - q_s / qs).sum())
            )
        return drift


class LoadObservationCache:
    """Content-keyed LRU memo for the per-flush load observation.

    Keyed on a BLAKE2b digest of the compiled batch's ``tile_ids`` +
    ``bitmaps`` bytes (shapes included), NOT on object identity or
    shape alone: two flushes with the same shape but different queries
    have different loads, while a replayed/steady-state flush with
    byte-identical schedules has byte-identical loads.  The digest is a
    single pass over the stack; a miss additionally runs the real
    :func:`~repro.core.reduction.fused_group_loads` (boolean indexing +
    popcount + bincount — several passes plus allocations).

    Returned arrays are shared with the cache — callers must not
    mutate them (``DriftTracker.observe`` does not).
    """

    def __init__(self, maxsize: int = 64):
        self.maxsize = int(maxsize)
        self.hits = 0
        self.misses = 0
        self._memo: collections.OrderedDict = collections.OrderedDict()

    @staticmethod
    def _key(cq) -> bytes:
        ids = np.ascontiguousarray(cq.tile_ids)
        bms = np.ascontiguousarray(cq.bitmaps)
        h = hashlib.blake2b(digest_size=16)
        h.update(repr((ids.shape, str(ids.dtype),
                       bms.shape, str(bms.dtype))).encode())
        h.update(ids.tobytes())
        h.update(bms.tobytes())
        return h.digest()

    def loads(self, cq, tile_group: np.ndarray, num_groups: int) -> np.ndarray:
        """Memoized ``fused_group_loads(cq, tile_group, num_groups)``."""
        key = self._key(cq)
        hit = self._memo.get(key)
        if hit is not None:
            self.hits += 1
            self._memo.move_to_end(key)
            return hit
        self.misses += 1
        out = fused_group_loads(cq, tile_group, num_groups)
        self._memo[key] = out
        while len(self._memo) > self.maxsize:
            self._memo.popitem(last=False)
        return out
