"""serve_step: one-token decode for every family, cache-carrying.

``decode_step(params, cfg, tokens, cache, enc=None)`` consumes the newest
token(s) and returns (logits, cache').  Layer stacks are scanned with the
per-layer cache rows as scan inputs/outputs, so decode lowers to one block
body like the forward pass.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import maybe_shard
from repro.models import attention as attn
from repro.models import mamba2, xlstm
from repro.models.layers import apply_mlp, apply_norm
from repro.models.moe import apply_moe


def _attn_block_decode(p, x, kc, vc, length, cfg: ModelConfig):
    h, kc, vc = attn.decode_attention(
        p["attn"], apply_norm(p["norm_attn"], x, cfg.norm), kc, vc, length,
        num_heads=cfg.num_heads, kv_heads=cfg.kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        rope_partial=cfg.rope_2d,
    )
    x = x + h
    x = _block_ffn(p, x, cfg)
    return x, kc, vc


def _block_ffn(p, x, cfg: ModelConfig):
    if cfg.moe:
        y, _ = apply_moe(p["moe"], apply_norm(p["norm_mlp"], x, cfg.norm), cfg.moe, cfg.act)
        x = x + y
    elif cfg.d_ff:
        x = x + apply_mlp(p["mlp"], apply_norm(p["norm_mlp"], x, cfg.norm), cfg.act)
    return x


def _attn_block_decode_readonly(p, x, kc, vc, length, cfg: ModelConfig, kv_scale=None):
    """Read-only cache variant: returns (x, k_new, v_new) — cache writes are
    batched outside the layer scan (decode memory optimization, §Perf)."""
    h, k_new, v_new = attn.decode_attention_readonly(
        p["attn"], apply_norm(p["norm_attn"], x, cfg.norm), kc, vc, length,
        num_heads=cfg.num_heads, kv_heads=cfg.kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        rope_partial=cfg.rope_2d, kv_scale=kv_scale,
    )
    x = x + h
    x = _block_ffn(p, x, cfg)
    return x, k_new, v_new


def decode_step(
    params: Dict[str, Any],
    cfg: ModelConfig,
    tokens: jax.Array,                  # (b, 1) or (b, K, 1) audio
    cache: Dict[str, Any],
    *,
    enc: Optional[jax.Array] = None,
    readonly_cache: bool = True,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step, dispatched by model family.

    Returns ``(logits, updated cache)``; with ``readonly_cache`` the
    attention families return the input cache untouched (donation-free
    serving path)."""
    if cfg.family in ("dense", "moe", "audio"):
        if readonly_cache:
            return _decode_attn_family_readonly(params, cfg, tokens, cache)
        return _decode_attn_family(params, cfg, tokens, cache)
    if cfg.family == "vlm":
        return _decode_vlm(params, cfg, tokens, cache, enc)
    if cfg.family == "ssm":
        return _decode_xlstm(params, cfg, tokens, cache)
    if cfg.family == "hybrid":
        return _decode_zamba(params, cfg, tokens, cache)
    raise ValueError(cfg.family)


def _embed_tokens(params, cfg: ModelConfig, tokens):
    if cfg.family == "audio":
        return sum(
            params[f"embed_{c}"][tokens[:, c]] for c in range(cfg.num_codebooks)
        )
    return params["embed"][tokens]


def _project_logits(params, cfg: ModelConfig, x):
    if cfg.family == "audio":
        return jnp.stack(
            [x @ params[f"head_{c}"] for c in range(cfg.num_codebooks)], axis=1
        )
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head


def _decode_attn_family_readonly(params, cfg, tokens, cache):
    """Layer scan reads caches; all layers' new K/V are written in ONE
    dynamic_update_slice after the scan (in-place with donation).  Supports
    int8-quantized caches (keys k_scale/v_scale present)."""
    x = _embed_tokens(params, cfg, tokens)          # (b, 1, d)
    length = cache["len"]
    quant = "k_scale" in cache

    def body(carry, xs):
        if quant:
            layer_p, kc, vc, ks, vs = xs
            h, k_new, v_new = _attn_block_decode_readonly(
                layer_p, carry, kc, vc, length, cfg, kv_scale=(ks, vs)
            )
        else:
            layer_p, kc, vc = xs
            h, k_new, v_new = _attn_block_decode_readonly(
                layer_p, carry, kc, vc, length, cfg
            )
        return h, (k_new, v_new)

    xs = (params["layers"], cache["k"], cache["v"])
    if quant:
        xs = xs + (cache["k_scale"], cache["v_scale"])
    x, (k_new, v_new) = jax.lax.scan(body, x, xs)   # k_new: (L, b, 1, kvh, hd)

    if quant:
        ks_new = jnp.max(jnp.abs(k_new), axis=-1) / 127.0 + 1e-8   # (L,b,1,kvh)
        vs_new = jnp.max(jnp.abs(v_new), axis=-1) / 127.0 + 1e-8
        kq = jnp.round(k_new.astype(jnp.float32) / ks_new[..., None]).astype(jnp.int8)
        vq = jnp.round(v_new.astype(jnp.float32) / vs_new[..., None]).astype(jnp.int8)
        new_cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq, (0, 0, length, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq, (0, 0, length, 0, 0)),
            "k_scale": jax.lax.dynamic_update_slice(
                cache["k_scale"], ks_new.astype(cache["k_scale"].dtype),
                (0, 0, length, 0)),
            "v_scale": jax.lax.dynamic_update_slice(
                cache["v_scale"], vs_new.astype(cache["v_scale"].dtype),
                (0, 0, length, 0)),
            "len": length + 1,
        }
    else:
        new_cache = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], k_new.astype(cache["k"].dtype), (0, 0, length, 0, 0)),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], v_new.astype(cache["v"].dtype), (0, 0, length, 0, 0)),
            "len": length + 1,
        }
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _project_logits(params, cfg, x)
    return logits, new_cache


def _decode_attn_family(params, cfg, tokens, cache):
    x = _embed_tokens(params, cfg, tokens)          # (b, 1, d)
    length = cache["len"]

    def body(carry, xs):
        layer_p, kc, vc = xs
        h, kc, vc = _attn_block_decode(layer_p, carry, kc, vc, length, cfg)
        return h, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _project_logits(params, cfg, x)
    return logits, {"k": ks, "v": vs, "len": length + 1}


def _decode_vlm(params, cfg, tokens, cache, enc):
    assert enc is not None
    x = params["embed"][tokens]
    length = cache["len"]
    period = cfg.cross_attn_period
    n_super = cfg.num_layers // (period + 1)
    # self-attn caches reshaped per superblock
    k5 = cache["k"].reshape(n_super, period, *cache["k"].shape[1:])
    v5 = cache["v"].reshape(n_super, period, *cache["v"].shape[1:])

    def superblock(carry, xs):
        self_p, cross_p, kc, vc = xs

        def body(c, inner):
            lp, k1, v1 = inner
            h, k1, v1 = _attn_block_decode(lp, c, k1, v1, length, cfg)
            return h, (k1, v1)

        h, (kc, vc) = jax.lax.scan(body, carry, (self_p, kc, vc))
        hn = apply_norm(cross_p["norm"], h, cfg.norm)
        h = h + attn.cross_attention(
            cross_p["xattn"], hn, enc, num_heads=cfg.num_heads,
            kv_heads=cfg.kv_heads, head_dim=cfg.resolved_head_dim,
        )
        h = h + apply_mlp(cross_p["mlp"], apply_norm(cross_p["norm_mlp"], h, cfg.norm), cfg.act)
        return h, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        superblock, x,
        (params["layers"]["super"], params["layers"]["cross"], k5, v5),
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _project_logits(params, cfg, x)
    new_cache = {
        "k": ks.reshape(cache["k"].shape),
        "v": vs.reshape(cache["v"].shape),
        "len": length + 1,
    }
    return logits, new_cache


def _decode_xlstm(params, cfg, tokens, cache):
    x = params["embed"][tokens]
    layers = params["layers"]
    period = cfg.slstm_every or (cfg.num_layers + 1)
    n_s = cache["s_c"].shape[0]
    n_m_per = period - 1

    if n_s:
        m_view = lambda a: a.reshape(n_s, n_m_per, *a.shape[1:])
        ml = jax.tree.map(m_view, layers["mlstm"])
        mC = m_view(cache["m_C"]); mn = m_view(cache["m_n"]); mm = m_view(cache["m_m"])

        def superblock(carry, xs):
            s_p, m_p, sc, sn, sh, sm, C, n, m = xs
            y, (sc, sn, sh, sm) = xlstm.slstm_scan(
                s_p["cell"], apply_norm(s_p["norm"], carry, cfg.norm),
                cfg.num_heads, init_state=(sc, sn, sh, sm),
            )
            carry = carry + y

            def mbody(c, inner):
                mp, C1, n1, m1 = inner
                y1, (C1, n1, m1) = xlstm.mlstm_scan(
                    mp["cell"], apply_norm(mp["norm"], c, cfg.norm),
                    cfg.num_heads, init_state=(C1, n1, m1),
                )
                return c + y1, (C1, n1, m1)

            carry, (C, n, m) = jax.lax.scan(mbody, carry, (m_p, C, n, m))
            return carry, (sc, sn, sh, sm, C, n, m)

        x, (sc, sn, sh, sm, C, n, m) = jax.lax.scan(
            superblock, x,
            (layers["slstm"], ml, cache["s_c"], cache["s_n"], cache["s_h"],
             cache["s_m"], mC, mn, mm),
        )
        new_cache = {
            "m_C": C.reshape(cache["m_C"].shape),
            "m_n": n.reshape(cache["m_n"].shape),
            "m_m": m.reshape(cache["m_m"].shape),
            "s_c": sc, "s_n": sn, "s_h": sh, "s_m": sm,
            "len": cache["len"] + 1,
        }
    else:
        def mbody(c, inner):
            mp, C1, n1, m1 = inner
            y1, (C1, n1, m1) = xlstm.mlstm_scan(
                mp["cell"], apply_norm(mp["norm"], c, cfg.norm),
                cfg.num_heads, init_state=(C1, n1, m1),
            )
            return c + y1, (C1, n1, m1)

        x, (C, n, m) = jax.lax.scan(
            mbody, x, (layers["mlstm"], cache["m_C"], cache["m_n"], cache["m_m"])
        )
        new_cache = dict(cache, m_C=C, m_n=n, m_m=m, len=cache["len"] + 1)

    x = apply_norm(params["final_norm"], x, cfg.norm)
    return _project_logits(params, cfg, x), new_cache


def _decode_zamba(params, cfg, tokens, cache):
    x = params["embed"][tokens]
    layers = params["layers"]
    length = cache["len"]
    period = cfg.shared_attn_period
    n_super = layers["super"]["norm"]["scale"].shape[0]

    mamba_st = cache["mamba"]
    h5 = mamba_st["h"].reshape(n_super, period, *mamba_st["h"].shape[1:])
    c5 = mamba_st["conv"].reshape(n_super, period, *mamba_st["conv"].shape[1:])

    ring = cache["shared"]
    shared_p = params["layers"]["shared_attn"]

    def mamba_block(c, inner):
        mp, h1, cv1 = inner
        y, h1, cv1 = mamba2.mamba2_decode_step(
            mp["mamba"], apply_norm(mp["norm"], c, cfg.norm), h1, cv1,
            ssm_state=cfg.ssm_state,
        )
        return c + y, (h1, cv1)

    def superblock(x_in, xs):
        mp, hs, cvs, rk, rv, rp = xs
        h, (hs, cvs) = jax.lax.scan(mamba_block, x_in, (mp, hs, cvs))
        h, rk, rv, rp = _ring_attention_at(
            shared_p, h, rk, rv, rp, length, cfg
        )
        return h, (hs, cvs, rk, rv, rp)

    x, (hs, cvs, rk, rv, rp) = jax.lax.scan(
        superblock, x,
        (layers["super"], h5, c5, ring["k"], ring["v"], ring["pos"]),
    )
    new_mamba = {
        "h": hs.reshape(mamba_st["h"].shape),
        "conv": cvs.reshape(mamba_st["conv"].shape),
    }
    tail_st = cache["tail"]
    if "tail" in layers:
        x, (th, tc) = jax.lax.scan(
            mamba_block, x, (layers["tail"], tail_st["h"], tail_st["conv"])
        )
        tail_st = {"h": th, "conv": tc}

    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _project_logits(params, cfg, x)
    new_cache = {
        "mamba": new_mamba,
        "tail": tail_st,
        "shared": {"k": rk, "v": rv, "pos": rp, "len": ring["len"] + 1},
        "len": length + 1,
    }
    return logits, new_cache


def _ring_attention_at(p, x, kc, vc, pc, length, cfg: ModelConfig):
    """Ring-buffer shared attention for one (scanned) layer instance."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    W = kc.shape[1]
    pos = jnp.full((b, 1), length, jnp.int32)
    xn = apply_norm(p["norm"], x, cfg.norm)
    q, k, v = attn._project(p["attn"], xn, cfg.num_heads, cfg.kv_heads, hd)
    from repro.models.rope import apply_rope

    q = apply_rope(q, pos, theta=cfg.rope_theta)
    k = apply_rope(k, pos, theta=cfg.rope_theta)

    slot = length % W
    kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
    pc = jax.lax.dynamic_update_slice(pc, jnp.full((b, 1), length, jnp.int32), (0, slot))
    scores = attn._gqa_scores(q, kc).astype(jnp.float32) / math.sqrt(hd)
    valid = (pc >= 0) & (pc <= length)
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = attn._gqa_out(w, vc) @ p["attn"]["wo"]
    return x + out, kc, vc, pc
