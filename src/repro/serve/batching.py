"""Continuous request batching for decode serving.

A fixed pool of ``batch_size`` slots; requests join free slots, finished
requests (EOS or length limit) leave, and every engine tick decodes one
token for all occupied slots.  Per-slot state lives in the shared KV
cache at the slot's batch index, so admission is a cache write, not a
recompile — the standard continuous-batching design, minus speculative
scheduling.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request moving through the continuous batcher."""

    uid: int
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


@dataclasses.dataclass
class ServeMetrics:
    """Request-level serving metrics (TTFT, latency, token counts)."""

    completed: int = 0
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    latency_s: List[float] = dataclasses.field(default_factory=list)
    tokens_out: int = 0

    def summary(self) -> Dict[str, float]:
        """Mean TTFT/latency plus completion counters."""
        return {
            "completed": self.completed,
            "tokens_out": self.tokens_out,
            "mean_ttft_s": float(np.mean(self.ttft_s)) if self.ttft_s else 0.0,
            "mean_latency_s": float(np.mean(self.latency_s)) if self.latency_s else 0.0,
        }


class RequestBatcher:
    """Slot-based continuous batcher around a (prefill_fn, decode_fn) pair.

    prefill_fn(slot, prompt) -> first_token
    decode_fn(active_mask, last_tokens) -> next_tokens (batch,)
    """

    def __init__(self, batch_size: int, eos_id: int = 0):
        self.batch_size = batch_size
        self.eos_id = eos_id
        self.queue: Deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.metrics = ServeMetrics()
        self.last_tokens = np.zeros(batch_size, np.int32)

    def submit(self, req: Request) -> None:
        """Enqueues a request for admission on the next tick."""
        req.submitted_at = time.time()
        self.queue.append(req)

    def _admit(self, prefill_fn) -> None:
        for slot in range(self.batch_size):
            if self.slots[slot] is None and self.queue:
                req = self.queue.popleft()
                req.slot = slot
                first = int(prefill_fn(slot, req.prompt))
                req.generated.append(first)
                req.first_token_at = time.time()
                self.last_tokens[slot] = first
                self.slots[slot] = req

    def tick(self, prefill_fn: Callable, decode_fn: Callable) -> int:
        """One engine iteration. Returns number of active slots."""
        self._admit(prefill_fn)
        active = np.array([r is not None for r in self.slots])
        if not active.any():
            return 0
        nxt = decode_fn(active, self.last_tokens.copy())
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.generated.append(tok)
            self.last_tokens[slot] = tok
            self.metrics.tokens_out += 1
            if tok == self.eos_id or len(req.generated) >= req.max_new_tokens:
                req.done_at = time.time()
                self.metrics.completed += 1
                self.metrics.ttft_s.append(req.first_token_at - req.submitted_at)
                self.metrics.latency_s.append(req.done_at - req.submitted_at)
                self.slots[slot] = None
        return int(active.sum())

    @property
    def idle(self) -> bool:
        """True when no request is queued or occupying a slot."""
        return not self.queue and all(s is None for s in self.slots)
