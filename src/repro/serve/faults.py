"""Deterministic fault injection + self-healing policy for the sharded
serving engine (DESIGN.md §8).

Production DLRM serving treats failure handling as a first-class
concern: a compile failure, a transient device fault, a hung flush or
one poisoned query must degrade a *flush*, never the *server*.  This
module is the whole failure half of that contract:

* :class:`FaultPlan` / :class:`FaultInjector` — a seeded, deterministic
  fault-injection layer.  A plan is a list of :class:`FaultSpec`\\ s,
  each naming a seam of the engine (compile, kernel dispatch, device
  retire, patch apply), the attempt index at that seam on which the
  fault fires, and how many consecutive attempts it poisons.  The
  injector is consulted by :class:`~repro.serve.sharded.
  ShardedEmbeddingServer` at exactly those seams; with the same plan
  and the same replay, the same faults fire — chaos runs are
  replayable and CI-stable.
* :class:`RetryPolicy` — the self-healing knobs: bounded per-flush
  retries with exponential backoff + seeded jitter, offender bisection
  (split a repeatedly-failing batch and retry the halves, so one
  poisoned query is quarantined with its error instead of wedging its
  home), and a flush watchdog deadline that times out hung device work
  and degrades the flush to the inline host/reference path.
  ``RetryPolicy.legacy()`` restores the pre-§8 requeue-and-re-raise
  contract (used by the driver-branch tests and available to callers
  who want failures loud).
* :class:`ErrorLedger` — the observability half: retries, backoff
  seconds, bisections, quarantined queries (with their errors),
  degraded / timed-out flushes, patch failures, recovery latency
  samples and the lost-work summary from :meth:`~repro.serve.sharded.
  ShardedEmbeddingServer.close`, threaded through
  ``ShardedServeStats.summary()`` and ``report()``.

The injector never touches device state and injects *errors*, not
corruption: a "poisoned query" is a (table, seq) pair whose containing
batch always fails its compile seam — exactly how a malformed-but-
undetected query presents in production (the batch dies, nothing names
the offender; bisection has to find it).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.producers import DEFAULT_PRODUCER


# --------------------------------------------------------------- errors --


class InjectedFault(RuntimeError):
    """Base class of all injector-raised faults (so tests and the
    healing loop can tell injected chaos from real engine errors)."""


class InjectedCompileFault(InjectedFault):
    """Transient host-compile failure (e.g. an OOM during tracing)."""


class InjectedDeviceFault(InjectedFault):
    """Device-side failure, at dispatch or surfacing late at retire."""


class PoisonedQueryError(InjectedFault):
    """A batch containing a poisoned (table, seq) query failed.  The
    error deliberately does NOT name the offender — bisection must
    isolate it, as with a real undiagnosed poisoned batch."""


class InjectedPatchFault(InjectedFault):
    """A plan-patch image DMA / placement swap failure."""


class FlushTimeout(RuntimeError):
    """A flush exceeded the watchdog deadline (hung device work).  Not
    an :class:`InjectedFault`: the watchdog fires identically for a
    real hang."""


#: seam names a :class:`FaultSpec` may target
KINDS = ("compile", "device", "device-late", "hang", "poison", "patch")


def latency_percentiles(samples: Sequence[float]) -> Dict[str, float]:
    """p50/p95/p99 of a latency sample list (seconds; zeros when empty)."""
    if not samples:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    a = np.asarray(samples, dtype=np.float64)
    return {
        "p50": float(np.percentile(a, 50)),
        "p95": float(np.percentile(a, 95)),
        "p99": float(np.percentile(a, 99)),
    }


# ----------------------------------------------------------- fault plan --


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    Attributes:
      kind: the seam — ``"compile"`` (host compile raises), ``"device"``
        (kernel dispatch raises), ``"device-late"`` (the fault surfaces
        at retire, after the flush was dispatched), ``"hang"`` (the
        dispatched flush never reports ready until ``hang_s`` elapses —
        ``None`` hangs forever, the watchdog's job), ``"poison"`` (a
        specific (table, seq) query makes every batch containing it
        fail compile), ``"patch"`` (the staged plan patch fails to
        apply).
      tick: the 0-based attempt index AT THAT SEAM on which the fault
        starts firing (each seam keeps its own monotone attempt
        counter, so retries advance it deterministically).  Ignored for
        ``"poison"`` (keyed by (table, seq) instead).
      times: how many consecutive attempts fail (transient faults heal
        after ``times`` retries; poison is permanent regardless).
      table / seq: the poisoned query's table name and per-table
        submission sequence id (``"poison"`` only).  ``seq`` is the
        producer-LOCAL id (DESIGN.md §10) — what ``submit()`` number
        within that producer's stream is poisoned.
      producer: the poisoned query's producer label (``"poison"``
        only); ``None`` targets the default producer, so
        single-producer plans read exactly as before.
      hang_s: simulated hang duration in seconds (``"hang"`` only);
        ``None`` = forever.
    """

    kind: str
    tick: int = 0
    times: int = 1
    table: Optional[str] = None
    seq: Optional[int] = None
    producer: Optional[object] = None
    hang_s: Optional[float] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; use {KINDS}")
        if self.kind == "poison" and (self.table is None or self.seq is None):
            raise ValueError("poison faults need table= and seq=")
        if self.times < 1:
            raise ValueError("times must be >= 1")


class FaultPlan:
    """A deterministic, seeded schedule of :class:`FaultSpec`\\ s.

    Build one explicitly (``FaultPlan().add("compile", tick=2)``) or
    draw a random-but-reproducible schedule with :meth:`random`.  The
    plan is inert data; :class:`FaultInjector` gives it runtime state.
    """

    def __init__(self, specs: Sequence[FaultSpec] = (), *, seed: int = 0):
        self.seed = seed
        self.specs: List[FaultSpec] = list(specs)

    def add(self, kind: str, **kw) -> "FaultPlan":
        """Appends one fault spec; chainable."""
        self.specs.append(FaultSpec(kind, **kw))
        return self

    @classmethod
    def random(
        cls,
        seed: int,
        counts: Dict[str, int],
        *,
        horizon: int = 16,
        tables: Sequence[str] = (),
        max_seq: int = 64,
        times: int = 1,
        hang_s: Optional[float] = None,
        producers: Sequence = (),
    ) -> "FaultPlan":
        """Draws ``counts[kind]`` faults per kind with seam ticks
        uniform in ``[0, horizon)`` and poison targets uniform over
        ``producers × tables × [0, max_seq)`` — same seed, same
        schedule.  An empty ``producers`` targets the default producer
        (the single-producer plans of PR 6 draw identically).
        """
        rng = np.random.default_rng(seed)
        plan = cls(seed=seed)
        for kind in sorted(counts):
            n = counts[kind]
            if kind not in KINDS:
                raise ValueError(f"unknown fault kind {kind!r}; use {KINDS}")
            for _ in range(n):
                if kind == "poison":
                    if not tables:
                        raise ValueError("poison faults need tables=")
                    plan.add(
                        kind,
                        table=str(rng.choice(list(tables))),
                        seq=int(rng.integers(0, max(1, max_seq))),
                        **({"producer": list(producers)[
                                int(rng.integers(0, len(producers)))]}
                           if len(producers) else {}),
                    )
                else:
                    plan.add(
                        kind,
                        tick=int(rng.integers(0, max(1, horizon))),
                        times=times,
                        **({"hang_s": hang_s} if kind == "hang" else {}),
                    )
        return plan

    def poisoned(self) -> List[Tuple[str, int]]:
        """The (table, local seq) pairs this plan poisons (chaos
        benches use it to exclude exactly the offenders from the
        oracle).  Producer-blind — multi-producer chaos wants
        :meth:`poisoned_by_producer`."""
        return sorted(
            (s.table, s.seq) for s in self.specs if s.kind == "poison"
        )

    def poisoned_by_producer(self) -> List[Tuple[object, str, int]]:
        """``(producer label, table, local seq)`` poison triples;
        ``producer=None`` specs read as the default producer."""
        return sorted(
            (DEFAULT_PRODUCER if s.producer is None else s.producer,
             s.table, s.seq)
            for s in self.specs if s.kind == "poison"
        )

    def summary(self) -> Dict[str, object]:
        """Fault counts by kind plus the poisoned-key list."""
        by_kind: Dict[str, int] = {}
        for s in self.specs:
            by_kind[s.kind] = by_kind.get(s.kind, 0) + 1
        return {"seed": self.seed, "faults": by_kind,
                "poisoned": [list(p) for p in self.poisoned()]}


class FaultInjector:
    """Runtime half of a :class:`FaultPlan`: per-seam attempt counters
    plus the poison set, consulted by the server at each seam.

    Each seam keeps its own monotone attempt counter; a spec with
    ``tick=t, times=k`` fails attempts ``t .. t+k-1`` at that seam.
    All hooks run on whichever thread drives the engine (the caller
    inline, or the driver thread) — never concurrently.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._fail_at: Dict[str, Dict[int, FaultSpec]] = {
            k: {} for k in KINDS
        }
        for s in plan.specs:
            if s.kind == "poison":
                continue
            for t in range(s.tick, s.tick + s.times):
                self._fail_at[s.kind].setdefault(t, s)
        # poison keys are (table, producer label, LOCAL seq): the seq
        # decoder bound by the server unpacks the engine's packed ids;
        # unbound (standalone use), a seq is the default producer's
        self._poison = {
            (s.table,
             DEFAULT_PRODUCER if s.producer is None else s.producer,
             s.seq)
            for s in plan.specs if s.kind == "poison"
        }
        self._decode: Callable = lambda s: (DEFAULT_PRODUCER, int(s))
        self._attempts: Dict[str, int] = {k: 0 for k in KINDS}
        self.injected: Dict[str, int] = {k: 0 for k in KINDS}

    def bind_decoder(self, decode: Callable) -> None:
        """Installs the server's ``seq -> (producer, local seq)``
        decoder (DESIGN.md §10) so poison matching is producer-aware."""
        self._decode = decode

    @classmethod
    def parse(cls, faults) -> Optional["FaultInjector"]:
        """None | FaultPlan | FaultInjector → Optional[FaultInjector]."""
        if faults is None:
            return None
        if isinstance(faults, FaultInjector):
            return faults
        if isinstance(faults, FaultPlan):
            return cls(faults)
        raise TypeError(f"faults must be a FaultPlan or FaultInjector, "
                        f"got {type(faults).__name__}")

    def _due(self, seam: str) -> Optional[FaultSpec]:
        t = self._attempts[seam]
        self._attempts[seam] = t + 1
        spec = self._fail_at[seam].get(t)
        if spec is not None:
            self.injected[seam] += 1
        return spec

    # ------------------------------------------------------------- seams --

    def on_compile(self, entries: Sequence[Tuple[str, int, list]]) -> None:
        """Compile seam: raises for a poisoned batch (always) or a
        scheduled transient compile fault (this attempt).  Poison
        matching decodes each entry's packed seq — only the named
        producer's (table, local seq) fires, never another stream's
        query that happens to share the local id."""
        hit = [
            (t, s) for t, s, _q in entries
            if (t,) + self._decode(s) in self._poison
        ]
        if hit:
            self.injected["poison"] += 1
            raise PoisonedQueryError(
                f"injected: compile failed on a batch of {len(entries)}"
            )
        if self._due("compile") is not None:
            raise InjectedCompileFault("injected: transient compile failure")

    def on_dispatch(self) -> Optional[float]:
        """Dispatch seam: raises a scheduled device fault, else returns
        the simulated hang duration for this dispatch (``math.inf`` =
        forever; ``None`` = healthy)."""
        if self._due("device") is not None:
            raise InjectedDeviceFault("injected: device fault at dispatch")
        spec = self._fail_at["hang"].get(self._attempts["hang"])
        self._attempts["hang"] += 1
        if spec is None:
            return None
        self.injected["hang"] += 1
        return math.inf if spec.hang_s is None else float(spec.hang_s)

    def on_retire(self) -> None:
        """Retire seam: a device fault surfacing only when the flush's
        outputs are handed off (the late-detection case)."""
        if self._due("device-late") is not None:
            raise InjectedDeviceFault("injected: device fault at retire")

    def on_patch(self) -> None:
        """Patch-apply seam: the staged-plan image DMA fails."""
        if self._due("patch") is not None:
            raise InjectedPatchFault("injected: plan patch apply failure")

    def summary(self) -> Dict[str, object]:
        """Plan summary plus per-seam attempt/injection counters."""
        return {
            "plan": self.plan.summary(),
            "attempts": dict(self._attempts),
            "injected": dict(self.injected),
        }


# --------------------------------------------------------- retry policy --


@dataclasses.dataclass
class RetryPolicy:
    """Self-healing knobs of the flush pipeline (DESIGN.md §8).

    Attributes:
      max_retries: in-place re-dispatch attempts per batch after the
        first failure (exponential backoff between attempts).  ``0``
        fails on first error.
      backoff_base / backoff_mult / backoff_max: retry *n* sleeps
        ``min(base · mult**n, max)`` seconds (before jitter).
      jitter: uniform multiplicative jitter fraction (a draw in
        ``[1-jitter, 1+jitter]``) from a ``seed``-ed generator, so two
        homes that fail together do not retry in lockstep — yet a
        replay is still deterministic.
      seed: the jitter RNG seed.
      bisect: after retries are exhausted on a batch of > 1 queries,
        split it and heal the halves independently — repeated failures
        converge on single offenders instead of wedging the home.
      quarantine: terminal failures of a single query are recorded in
        the :class:`ErrorLedger` (with the error) and the query is
        dropped; the home keeps serving.  ``False`` restores the legacy
        requeue-and-re-raise contract (the batch goes back to its home
        and the error surfaces at the next ``submit()``/``drain()``).
      watchdog_s: per-flush deadline measured from kernel dispatch; a
        flush not ready by then is timed out and degraded to the inline
        host/reference path (``None`` disables the watchdog — but an
        *injected* infinite hang still degrades rather than blocking
        forever).
      watchdog_poll_s: readiness poll interval while waiting under the
        watchdog.
      patch_retries: barriers a failing staged patch is retried at
        before it is dropped (the server keeps serving under the live
        plan; the drop is recorded).
    """

    max_retries: int = 2
    backoff_base: float = 0.005
    backoff_mult: float = 2.0
    backoff_max: float = 0.25
    jitter: float = 0.25
    seed: int = 0
    bisect: bool = True
    quarantine: bool = True
    watchdog_s: Optional[float] = None
    watchdog_poll_s: float = 0.002
    patch_retries: int = 2

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError("jitter must be in [0, 1)")
        if self.watchdog_s is not None and self.watchdog_s <= 0:
            raise ValueError("watchdog_s must be positive (None disables)")

    @classmethod
    def parse(cls, policy) -> "RetryPolicy":
        """``None`` → defaults; a RetryPolicy passes through."""
        if policy is None:
            return cls()
        if isinstance(policy, RetryPolicy):
            return policy
        raise TypeError(f"retry must be a RetryPolicy, "
                        f"got {type(policy).__name__}")

    @classmethod
    def legacy(cls) -> "RetryPolicy":
        """The pre-§8 contract: first failure requeues the batch and
        re-raises at the caller — no retries, no bisection, no
        quarantine, no watchdog."""
        return cls(max_retries=0, bisect=False, quarantine=False)

    def backoff_s(self, attempt: int, rng: np.random.Generator) -> float:
        """Jittered exponential backoff before retry ``attempt`` (0-based)."""
        base = min(self.backoff_base * self.backoff_mult ** attempt,
                   self.backoff_max)
        if self.jitter:
            base *= 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
        return base


# ---------------------------------------------------------- error ledger --


@dataclasses.dataclass
class ErrorLedger:
    """Cumulative failure/recovery accounting of one server's lifetime,
    threaded through ``ShardedServeStats.summary()`` / ``report()``.

    ``recovery_s`` samples the time from a batch's FIRST failed dispatch
    attempt to its successful dispatch (healed transients only —
    quarantines are not recoveries).
    """

    retries: int = 0                      # re-dispatch attempts after failures
    backoff_s: float = 0.0                # Σ backoff slept between retries
    bisections: int = 0                   # batch splits hunting an offender
    quarantined: List[tuple] = dataclasses.field(
        default_factory=list
    )                                     # (table, local seq, error repr,
                                          #  producer label)
    degraded_flushes: int = 0             # served via the host path
    timed_out_flushes: int = 0            # watchdog firings
    patch_failures: int = 0               # staged-patch apply failures
    patches_dropped: int = 0              # … that exhausted patch_retries
    recovery_s: List[float] = dataclasses.field(default_factory=list)
    driver_errors_suppressed: int = 0     # stashed beyond the deque bound
    lost_work: Optional[Dict[str, int]] = None   # unserved at close()

    def quarantine(
        self, table: str, seq: int, err: BaseException, producer=None
    ) -> None:
        """Records one dropped query.  ``seq`` is the producer-LOCAL
        id; the error repr stays at index 2 (the shape summary() and
        the chaos benches pin), with the producer label appended."""
        self.quarantined.append((
            table, int(seq), repr(err),
            DEFAULT_PRODUCER if producer is None else producer,
        ))

    def record_recovery(self, seconds: float) -> None:
        """Accounts one fault-to-healthy recovery interval."""
        self.recovery_s.append(seconds)

    def quarantined_keys(self) -> List[Tuple[str, int]]:
        """Producer-blind ``(table, local seq)`` pairs — the
        single-producer chaos contract (matches
        :meth:`FaultPlan.poisoned` for default-producer plans)."""
        return sorted((q[0], q[1]) for q in self.quarantined)

    def quarantined_keys_by_producer(self) -> List[Tuple[object, str, int]]:
        """``(producer label, table, local seq)`` triples — matches
        :meth:`FaultPlan.poisoned_by_producer`."""
        return sorted((q[3], q[0], q[1]) for q in self.quarantined)

    def summary(self) -> Dict[str, object]:
        """Failure/recovery counters for reports and chaos benches."""
        return {
            "retries": self.retries,
            "backoff_s": self.backoff_s,
            "bisections": self.bisections,
            "quarantined": [list(q[:3]) for q in self.quarantined],
            "quarantined_by_producer": [
                [str(q[3]), q[0], q[1]] for q in self.quarantined
            ],
            "degraded_flushes": self.degraded_flushes,
            "timed_out_flushes": self.timed_out_flushes,
            "patch_failures": self.patch_failures,
            "patches_dropped": self.patches_dropped,
            "recoveries": len(self.recovery_s),
            "recovery_latency_s": latency_percentiles(self.recovery_s),
            "driver_errors_suppressed": self.driver_errors_suppressed,
            "lost_work": self.lost_work,
        }
