"""Per-producer sequence spaces for the multi-producer front door
(DESIGN.md §10).

The thread driver (§7.2) made ``submit()`` cheap — validate, stamp a
sequence id, enqueue — but the sequence id itself was a single global
per-table counter, which assumed exactly ONE producer thread.  Under N
concurrent producers a global counter forces either a lock-ordered
total order (whoever wins the lock owns the next row of every drain)
or torn stamps.  Production serving (RecNMP's many concurrent request
streams) wants neither: each stream needs FIFO over ITS OWN requests,
and the merge across streams must be deterministic — not an artifact
of thread scheduling.

This module is the whole of that contract:

* every producer owns a **sequence space**: a per-``(producer,
  table)`` local counter, advanced only by that producer's stamps;
* a stamped id packs ``(local_seq, producer_id)`` into one int —
  ``gseq = local_seq * SEQ_STRIDE + pid`` — so every downstream
  structure that already carried an int64 seq (scheduler pending
  entries, in-flight metadata, completed-chunk arrays, the drain
  argsort) carries the producer dimension for free;
* the **merge order** of a full drain is the numeric order of those
  packed ids: lexicographic ``(local_seq, producer_id)``.  Producer
  streams interleave round-robin by local position, ties broken by
  registration order — a pure function of what was submitted, never
  of how the OS scheduled the submitting threads;
* ``decode()`` recovers ``(producer label, local seq)`` — the fault
  injector's poison keying, the error ledger and the scheduler's
  per-producer accounting all speak decoded ids.

Registration is lazy (first stamp under an unseen label registers it)
but :meth:`ProducerRegistry.register` allows explicit pre-registration
when a test or bench wants pid order pinned independently of which
thread stamps first.
"""

from __future__ import annotations

import threading
from typing import Dict, Hashable, List, Optional, Tuple

#: packing stride of one sequence id: ``gseq = local_seq * SEQ_STRIDE +
#: producer_id``.  2**20 producers per server is far beyond any
#: plausible front door, and int64 still holds ~2**43 local seqs.
SEQ_STRIDE = 1 << 20

#: label a ``submit(producer=None)`` stamp registers under — the
#: single-producer path is just the default producer's sequence space
DEFAULT_PRODUCER = "default"


def producer_of(gseq: int, stride: int = SEQ_STRIDE) -> int:
    """Producer id of a packed sequence id."""
    return int(gseq) % stride


def local_seq_of(gseq: int, stride: int = SEQ_STRIDE) -> int:
    """Local (per-producer) sequence of a packed sequence id."""
    return int(gseq) // stride


class ProducerRegistry:
    """Thread-safe producer registration + per-space sequence stamping.

    Args:
      stride: the packing stride (tests shrink it to exercise the
        capacity guard; servers use :data:`SEQ_STRIDE`).

    All mutation happens under one internal lock; ``decode`` and the
    snapshot helpers read registration state that only ever grows, so
    a decode can never see a pid it cannot name.
    """

    def __init__(self, *, stride: int = SEQ_STRIDE):
        self.stride = int(stride)
        # lock order (DESIGN.md §5): innermost — acquired after any of
        # the server's three locks, never holds another lock inside
        self._lock = threading.Lock()
        self._pid: Dict[Hashable, int] = {}
        self._label: List[Hashable] = []
        # pid -> {table: next local seq}; one dict per registered space
        self._next: List[Dict[str, int]] = []

    # -------------------------------------------------------- registration --

    def register(self, producer: Optional[Hashable] = None) -> int:
        """Registers (or looks up) a producer label, returning its pid.

        Lazy registration means first-stamp order normally assigns
        pids; calling this up front pins them explicitly (the merge
        tiebreak is pid order, so benches that want a reproducible
        cross-producer interleave register before starting threads).
        """
        with self._lock:
            return self._register_locked(producer)

    def _register_locked(self, producer: Optional[Hashable]) -> int:
        label = DEFAULT_PRODUCER if producer is None else producer
        pid = self._pid.get(label)
        if pid is None:
            pid = len(self._label)
            if pid >= self.stride:
                raise RuntimeError(
                    f"producer capacity exhausted: {pid} registered "
                    f"spaces at stride {self.stride}"
                )
            self._pid[label] = pid
            self._label.append(label)
            self._next.append({})
        return pid

    # ------------------------------------------------------------ stamping --

    def stamp(self, producer: Optional[Hashable], table: str) -> int:
        """Stamps one submission: registers the producer if unseen,
        advances its (producer, table) local counter, returns the
        packed ``gseq``."""
        with self._lock:
            pid = self._register_locked(producer)
            space = self._next[pid]
            local = space.get(table, 0)
            # packed gseq = local * stride + pid must stay in int64:
            # past the boundary two submissions would alias the same
            # gseq and the drain merge would silently reorder
            if (local + 1) * self.stride > (1 << 63) - 1:
                raise OverflowError(
                    f"sequence capacity exhausted: local seq {local} at "
                    f"stride {self.stride} would overflow the packed gseq"
                )
            space[table] = local + 1
            return local * self.stride + pid

    def decode(self, gseq: int) -> Tuple[Hashable, int]:
        """``gseq -> (producer label, local seq)``.

        Ids this registry never stamped (raw ints handed straight to
        engine internals by tests/tools) decode as the default
        producer's rather than raising — their pid names no space.
        """
        pid = int(gseq) % self.stride
        if pid < len(self._label):  # unlocked: _label is append-only
            return self._label[pid], int(gseq) // self.stride  # unlocked: see above
        return DEFAULT_PRODUCER, int(gseq) // self.stride

    def pid(self, producer: Optional[Hashable]) -> Optional[int]:
        """pid of a label, ``None`` when it never registered."""
        label = DEFAULT_PRODUCER if producer is None else producer
        return self._pid.get(label)  # unlocked: _pid only ever grows

    def next_seq(self, table: str, producer: Optional[Hashable] = None) -> int:
        """Next LOCAL sequence the label would stamp on ``table`` (0
        for unregistered producers) — the test-facing counter view."""
        p = self.pid(producer)
        if p is None:
            return 0
        with self._lock:
            return self._next[p].get(table, 0)

    def reset_seqs(self) -> None:
        """Restarts every space's local counters (registrations — and
        therefore pids and the merge tiebreak — are kept).  Only legal
        fully quiesced: the server guards this exactly like the PR-5
        global reset, extended to every space at once."""
        with self._lock:
            for space in self._next:
                space.clear()

    # ------------------------------------------------------------ snapshot --

    def producers(self) -> List[Hashable]:
        """Registered labels in pid (registration) order."""
        return list(self._label)  # unlocked: _label is append-only

    def state(self) -> Dict[str, object]:
        """Report snapshot: labels + per-space next-seq counters."""
        with self._lock:
            return {
                "producers": [str(l) for l in self._label],
                "next_seq": {
                    str(self._label[p]): dict(space)
                    for p, space in enumerate(self._next) if space
                },
            }
