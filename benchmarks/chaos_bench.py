"""Chaos-replay benchmark → the ``chaos`` section of BENCH_serving.json.

Measures the self-healing contract of the flush pipeline (DESIGN.md §8)
under a seeded, deterministic fault schedule: the SAME skewed replay
runs once fault-free (the oracle) and once on the threaded driver with
a :class:`~repro.serve.faults.FaultPlan` injecting transient compile
failures, dispatch-time and retire-time device faults, an (effectively)
infinite execution hang, and two randomly-drawn poisoned queries.  The
acceptance invariant is asserted, not just recorded:

  * ``drain()`` under chaos is **bit-identical** to the fault-free
    oracle for every non-poisoned row (integer tables — every partial
    sum exact in f32);
  * the error ledger shows nonzero retries and **exactly** the injected
    poison offenders quarantined (with their errors);
  * the hung flush trips the watchdog and is served degraded via the
    inline host path — ``drain()`` completes instead of wedging.

Recorded: chaos vs fault-free wall clock (the recovery overhead),
recovery-latency percentiles (first failed dispatch → successful
re-dispatch), the degraded-flush fraction, backoff seconds slept,
bisection count, and the injector's per-seam attempt/injected counters.
Both execution modes run when the host presents enough devices
(**emulated** single-device, **shard_map** on forced host devices — CI
forces 4); the headline record is the emulated mode, same convention as
the scheduler bench.

Env knobs: ``RECROSS_CHAOS_ROWS`` / ``RECROSS_CHAOS_HISTORY`` (defaults
12_500), ``RECROSS_CHAOS_BATCH`` (32), ``RECROSS_CHAOS_SHARDS`` (4),
``RECROSS_CHAOS_SEED`` (0, the fault-plan + jitter seed),
``RECROSS_CHAOS_WATCHDOG_S`` (10.0 — generous vs the full-scale flush
p99 so only the injected hang times out).
"""

from __future__ import annotations

import os
import time

import numpy as np
import jax

from benchmarks.common import (
    bench_is_full_scale,
    bench_json_path,
    emit,
    mesh_for,
    update_bench_json,
)
from repro.data import zipf_queries
from repro.serve import FaultPlan, RetryPolicy, ShardedEmbeddingServer

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")

NUM_ROWS = int(os.environ.get("RECROSS_CHAOS_ROWS", 12_500))
NUM_HISTORY = int(os.environ.get("RECROSS_CHAOS_HISTORY", 12_500))
SERVE_BATCH = int(os.environ.get("RECROSS_CHAOS_BATCH", 32))
NUM_SHARDS = int(os.environ.get("RECROSS_CHAOS_SHARDS", 4))
CHAOS_SEED = int(os.environ.get("RECROSS_CHAOS_SEED", 0))
#: generous vs the full-scale flush p99 (~1.4 s) so only the injected
#: hang times out; CI smoke sets its own budget for the tiny sizes
WATCHDOG_S = float(os.environ.get("RECROSS_CHAOS_WATCHDOG_S", 10.0))
MEAN_BAG = float(os.environ.get("RECROSS_PIPELINE_MEAN_BAG", 41.32))
SKEW = 3
GROUP_SIZE = 64
Q_BLOCK = 8
DIM = 128
#: committed BENCH_serving.json only updates at the full DEFAULT config
FULL_SCALE = bench_is_full_scale()


def _fault_plan(max_seq: int) -> FaultPlan:
    """The injected schedule: every retriable seam plus two poisoned
    queries drawn reproducibly from the seed (≥ 3 fault kinds, per the
    acceptance criteria)."""
    return (
        FaultPlan.random(CHAOS_SEED, {"poison": 2},
                         tables=("t0", "t1"), max_seq=max_seq)
        .add("compile", tick=0, times=2)     # transient compile failures
        .add("device", tick=2, times=1)      # device fault at dispatch
        .add("device-late", tick=1, times=1)  # ... surfacing at retire
        .add("hang", tick=4, hang_s=999.0)   # hung flush → watchdog
    )


def run() -> list:
    rows_out = []
    irng = np.random.default_rng(7)
    itables = {
        "t0": irng.integers(-8, 9, size=(NUM_ROWS, DIM)).astype(np.float32),
        "t1": irng.integers(-8, 9, size=(NUM_ROWS, DIM)).astype(np.float32),
    }
    ihistories = {
        name: zipf_queries(NUM_ROWS, NUM_HISTORY, MEAN_BAG, seed=20 + i,
                           num_baskets=max(256, NUM_HISTORY // 32))
        for i, name in enumerate(itables)
    }
    n_req = SERVE_BATCH * 8
    replay_qs = zipf_queries(NUM_ROWS, n_req, MEAN_BAG, seed=29,
                             num_baskets=max(256, NUM_HISTORY // 32))
    replay = [("t0" if i % (SKEW + 1) < SKEW else "t1", q)
              for i, q in enumerate(replay_qs)]
    per_table = {n: sum(1 for t, _ in replay if t == n) for n in itables}
    plan = _fault_plan(max_seq=min(per_table.values()))
    poisoned = set(plan.poisoned())
    S = NUM_SHARDS

    def run_replay(mesh, *, faults=None, retry=None):
        server = ShardedEmbeddingServer(
            itables, ihistories, num_shards=S, mesh=mesh,
            q_block=Q_BLOCK, group_size=GROUP_SIZE, batch_size=SERVE_BATCH,
            flush_policy="per-shard", threaded=True, max_in_flight=2,
            faults=faults, retry=retry,
        )
        t0 = time.perf_counter()
        for name, q in replay:
            server.submit(name, q)
        outs = {n: np.asarray(o) for n, o in server.drain().items()}
        wall = time.perf_counter() - t0
        server.close()
        return server, wall, outs

    modes = {"emulated": None}
    if mesh_for(S) is not None:
        modes["shard_map"] = mesh_for(S)
    mode_rec = {}
    for label, mesh in modes.items():
        # warm: the kernel dispatch is jit-cached per shape; an unwarmed
        # chaos run would bill trace+compile time as recovery latency
        run_replay(mesh)
        _, wall_ok, oracle = run_replay(mesh)
        srv, wall_chaos, outs = run_replay(
            mesh,
            faults=_fault_plan(max_seq=min(per_table.values())),
            retry=RetryPolicy(max_retries=3, seed=CHAOS_SEED,
                              watchdog_s=WATCHDOG_S),
        )
        led = srv.stats.ledger
        # ---- the acceptance invariants, asserted -----------------------
        assert led.retries > 0, "chaos replay healed nothing"
        assert set(led.quarantined_keys()) == poisoned, (
            f"quarantined {led.quarantined_keys()}, injected {poisoned}"
        )
        assert led.timed_out_flushes >= 1 and led.degraded_flushes >= 1, (
            "the hung flush never tripped the watchdog"
        )
        for n in itables:
            drop = {s for t, s in poisoned if t == n}
            keep = np.asarray([i for i in range(per_table[n])
                               if i not in drop])
            np.testing.assert_array_equal(outs[n], oracle[n][keep])
        # ----------------------------------------------------------------
        batches = srv.stats.summary()["batches"]
        fsum = srv.stats.summary()["faults"]
        mode_rec[label] = {
            "wall_s_fault_free": wall_ok,
            "wall_s_chaos": wall_chaos,
            "recovery_overhead": (wall_chaos / wall_ok
                                  if wall_ok > 0 else None),
            "retries": led.retries,
            "backoff_s": led.backoff_s,
            "bisections": led.bisections,
            "recoveries": fsum["recoveries"],
            "recovery_latency_s": fsum["recovery_latency_s"],
            "quarantined": fsum["quarantined"],
            "degraded_flushes": led.degraded_flushes,
            "timed_out_flushes": led.timed_out_flushes,
            "degraded_fraction": (led.degraded_flushes / batches
                                  if batches else None),
            "batches": batches,
            "injected": srv.report()["faults"]["injected"],
            "bit_identical_to_fault_free": True,     # asserted above
        }
        rows_out.append({
            "name": f"serving_chaos_{label}",
            "us_per_call": f"{wall_chaos * 1e6:.0f}",
            "derived": (
                f"recovery_p50_s="
                f"{fsum['recovery_latency_s']['p50']:.4f};"
                f"degraded_frac="
                f"{mode_rec[label]['degraded_fraction']:.3f};"
                f"quarantined={len(led.quarantined)};"
                f"retries={led.retries};"
                f"overhead={mode_rec[label]['recovery_overhead']:.2f}x"
            ),
        })
    head = mode_rec["emulated"]
    record = {
        "config": {
            "num_rows": NUM_ROWS, "requests": n_req, "skew": SKEW,
            "shards": S, "batch_size": SERVE_BATCH,
            "watchdog_s": WATCHDOG_S, "seed": CHAOS_SEED,
            "plan": plan.summary(),
            "devices": len(jax.devices()),
        },
        "modes": mode_rec,
        **{k: head[k] for k in (
            "recovery_latency_s", "degraded_fraction",
            "recovery_overhead", "retries",
            "bit_identical_to_fault_free",
        )},
        "mode": "emulated+shard_map" if "shard_map" in mode_rec
                else "emulated",
    }
    # merge into BENCH_serving.json (the serving bench owns the rest);
    # CI smoke sizes write to a temp path — never the committed record
    update_bench_json(
        bench_json_path(JSON_PATH, full_scale=FULL_SCALE),
        {"chaos": record},
    )
    return rows_out


def main():
    emit(run())


if __name__ == "__main__":
    main()
