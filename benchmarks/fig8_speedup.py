"""Paper Fig. 8: normalized speedup + energy efficiency of ReCross vs
naive and nMARS, across the five Amazon-Review workloads.

Paper claims (ReCross vs naive, (vs nMARS)): speedup 2.58–6.85×
(2.60–5.48×); energy efficiency 3.60–12.55× (1.39–3.65×)."""

from __future__ import annotations

from benchmarks.common import emit, prepared_workload
from repro.core import baselines, simulate_cpu_baseline
from repro.data.synthetic import WORKLOADS


def run(scale=None) -> list:
    rows = []
    for wl in WORKLOADS:
        num_rows, hist, ev, graph = prepared_workload(wl)
        batch = 256
        ev_b = ev[:batch]
        _, rx = baselines.recross_pipeline(graph, ev_b, batch_size=batch)
        _, nv = baselines.naive_pipeline(num_rows, ev_b)
        _, nm = baselines.nmars_pipeline(num_rows, ev_b)
        rows.append({
            "name": f"fig8_speedup_vs_naive[{wl}]",
            "us_per_call": rx.completion_time_ns / 1e3,
            "derived": f"{rx.speedup_over(nv):.2f}x",
        })
        rows.append({
            "name": f"fig8_speedup_vs_nmars[{wl}]",
            "us_per_call": nm.completion_time_ns / 1e3,
            "derived": f"{rx.speedup_over(nm):.2f}x",
        })
        rows.append({
            "name": f"fig8_energy_eff_vs_naive[{wl}]",
            "us_per_call": "",
            "derived": f"{rx.energy_efficiency_over(nv):.2f}x",
        })
        rows.append({
            "name": f"fig8_energy_eff_vs_nmars[{wl}]",
            "us_per_call": "",
            "derived": f"{rx.energy_efficiency_over(nm):.2f}x",
        })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
