"""Tiered host↔device storage benchmark → the ``tiers`` section of
BENCH_serving.json.

Measures the acceptance contract of the capacity-bounded hot tier
(DESIGN.md §9) on a skewed replay against a table whose hot-tier image
holds only ``RECROSS_TIER_CAPACITY_FRAC`` (default 10%) of the uncapped
working set — the "table 10× larger than the device image" regime:

  * **bit-identity** — every drained window of the capped server is
    bit-identical to an uncapped all-resident oracle fed the same
    stream (integer tables, exact f32 sums); asserted inline, a
    mismatch fails the bench.
  * **paging liveness** — a mid-replay hot-set rotation must page
    groups in (``fetched_tiles > 0``) by displacing colder residents
    (``evicted_tiles > 0``); asserted inline at every scale, so the CI
    smoke run proves the eviction path and not just the happy path.
  * **steady-state host-path fraction** — after the drift-driven
    paging converges, the fraction of queries detoured to the host
    gather+sum path in the final replay window; the committed
    full-scale record asserts ``< 5%``.
  * per-window trajectory (host fraction, cumulative paged tiles), the
    server's tier report and the paging byte accounting.

Runs under shard_map when the host presents enough devices, emulation
otherwise.  Env knobs: ``RECROSS_TIER_ROWS`` (200_000),
``RECROSS_TIER_HISTORY`` (40_000), ``RECROSS_TIER_BATCH`` (32),
``RECROSS_TIER_REQUESTS`` (1536), ``RECROSS_TIER_SHARDS`` (4),
``RECROSS_TIER_CAPACITY_FRAC`` (0.1), ``RECROSS_TIER_REPLAY_BASKETS``
(128 — the zipf-head working-set size of the replay).
"""

from __future__ import annotations

import os
import time

import numpy as np
import jax

from benchmarks.common import (
    bench_is_full_scale,
    bench_json_path,
    emit,
    mesh_for,
    update_bench_json,
)
from repro.data import zipf_queries
from repro.serve import ReplanConfig, ShardedEmbeddingServer, TierConfig

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")

NUM_ROWS = int(os.environ.get("RECROSS_TIER_ROWS", 200_000))
NUM_HISTORY = int(os.environ.get("RECROSS_TIER_HISTORY", 40_000))
BATCH = int(os.environ.get("RECROSS_TIER_BATCH", 32))
NUM_REQUESTS = int(os.environ.get("RECROSS_TIER_REQUESTS", 1536))
NUM_SHARDS = int(os.environ.get("RECROSS_TIER_SHARDS", 4))
CAPACITY_FRAC = float(os.environ.get("RECROSS_TIER_CAPACITY_FRAC", 0.1))
REPLAY_BASKETS = int(os.environ.get("RECROSS_TIER_REPLAY_BASKETS", 128))
MEAN_BAG = float(os.environ.get("RECROSS_PIPELINE_MEAN_BAG", 41.32))
#: committed BENCH_serving.json only updates at the full DEFAULT config
FULL_SCALE = bench_is_full_scale()
GROUP_SIZE = 64
Q_BLOCK = 8
DIM = 128
NUM_WINDOWS = 8
HOST_PATH_TARGET = 0.05


def _int_table(rows, dim, seed):
    return np.random.default_rng(seed).integers(
        -8, 9, size=(rows, dim)
    ).astype(np.float32)


def run() -> list:
    rows_out = []
    S = NUM_SHARDS
    baskets = max(256, NUM_HISTORY // 32)

    # the replay's phase-A stream draws from the zipf HEAD of the
    # planning history's basket pool (same seed → the generator draws
    # an identical basket prefix; a smaller num_baskets just truncates
    # the pool), at a harder skew (98% basket repeats): a live working
    # set the hot tier can plausibly hold, served against a table 10×
    # its capacity.  The steady-state question is whether the CAPACITY
    # holds that working set, not whether fresh uncorrelated draws
    # scatter over cold groups.  Phase B (the last quarter) rotates to
    # a fresh basket pool — an initially-cold working set the
    # drift-driven paging must promote, proving both pager directions;
    # its convergence is NOT the steady-state metric (measured at the
    # end of phase A).
    history = zipf_queries(NUM_ROWS, NUM_HISTORY, MEAN_BAG, seed=0,
                           num_baskets=baskets)
    n_a = NUM_REQUESTS * (NUM_WINDOWS - 2) // NUM_WINDOWS
    phase_a = zipf_queries(NUM_ROWS, n_a, MEAN_BAG, seed=0,
                           num_baskets=REPLAY_BASKETS,
                           basket_repeat_p=0.98)
    phase_b = zipf_queries(NUM_ROWS, NUM_REQUESTS - n_a, MEAN_BAG,
                           seed=101, num_baskets=REPLAY_BASKETS,
                           basket_repeat_p=0.98)
    stream = phase_a + phase_b

    tables = {"t0": _int_table(NUM_ROWS, DIM, 1)}
    histories = {"t0": history}
    mesh = mesh_for(S)
    common = dict(
        num_shards=S, mesh=mesh, q_block=Q_BLOCK, group_size=GROUP_SIZE,
        batch_size=BATCH, flush_policy="deadline",
        replan=ReplanConfig(threshold=0.08, half_life=16.0,
                            min_queries=BATCH),
    )
    t0 = time.perf_counter()
    oracle = ShardedEmbeddingServer(tables, histories, **common)
    capped = ShardedEmbeddingServer(
        tables, histories,
        tiers=TierConfig(capacity_frac=CAPACITY_FRAC, hysteresis=1.3),
        **common,
    )
    build_s = time.perf_counter() - t0
    cap_rep = capped.report()["tiers"]
    uncapped_depth = int(oracle.shard_images.shape[1])
    assert cap_rep["cold_groups"] > 0, (
        f"capacity_frac={CAPACITY_FRAC} did not bite "
        f"(uncapped depth {uncapped_depth}) — the bench needs a table "
        "larger than the hot tier"
    )

    record: dict = {
        "config": {
            "num_rows": NUM_ROWS,
            "history_queries": NUM_HISTORY,
            "requests": len(stream),
            "batch": BATCH,
            "q_block": Q_BLOCK,
            "group_size": GROUP_SIZE,
            "dim": DIM,
            "mean_bag": MEAN_BAG,
            "num_shards": S,
            "capacity_frac": CAPACITY_FRAC,
            "replay_baskets": REPLAY_BASKETS,
            "windows": NUM_WINDOWS,
            "devices": len(jax.devices()),
            "mode": "shard_map" if mesh is not None else "emulated",
        },
        "capacity": {
            "capacity_tiles": cap_rep["capacity_tiles"],
            "uncapped_depth": uncapped_depth,
            "table_to_tier_ratio":
                uncapped_depth / max(cap_rep["capacity_tiles"], 1),
            "initial_cold_tiles": cap_rep["cold_tiles"],
            "initial_cold_groups": cap_rep["cold_groups"],
        },
    }

    # ---- windowed replay: drain + compare at every window boundary ----
    win = max(1, len(stream) // NUM_WINDOWS)
    windows = []
    prev = {"hot": 0, "host": 0, "fetched": 0, "evicted": 0}
    t0 = time.perf_counter()
    for w in range(0, len(stream), win):
        chunk = stream[w:w + win]
        for q in chunk:
            capped.submit("t0", q)
            oracle.submit("t0", q)
        got, want = capped.drain(), oracle.drain()
        np.testing.assert_array_equal(
            np.asarray(got["t0"]), np.asarray(want["t0"])
        )
        ts = capped.stats.tier_summary()
        cur = {"hot": ts["hot_queries"], "host": ts["host_queries"],
               "fetched": ts["fetched_tiles"], "evicted": ts["evicted_tiles"]}
        dq = (cur["hot"] - prev["hot"]) + (cur["host"] - prev["host"])
        windows.append({
            "queries": dq,
            "host_fraction":
                (cur["host"] - prev["host"]) / max(dq, 1),
            "fetched_tiles": cur["fetched"] - prev["fetched"],
            "evicted_tiles": cur["evicted"] - prev["evicted"],
        })
        prev = cur
    replay_s = time.perf_counter() - t0
    capped.close()
    oracle.close()

    ts = capped.stats.tier_summary()
    # steady state = the last window fully inside phase A (the shared-
    # pool skewed replay, after paging has had the earlier windows to
    # converge); the phase-B tail that follows is the paging stressor
    steady_idx = max(0, (n_a // win) - 1)
    steady = windows[steady_idx]["host_fraction"]
    record["windows"] = windows
    record["steady_state_window"] = steady_idx
    record["bit_identical_to_oracle"] = True
    record["steady_state_host_fraction"] = steady
    record["tier_summary"] = ts
    record["tiers_report"] = capped.report()["tiers"]
    record["replans"] = capped.stats.replans
    record["build_s"] = build_s
    record["replay_s"] = replay_s
    record["meets_host_path_target"] = bool(steady < HOST_PATH_TARGET)

    # paging liveness: the rotation must have exercised BOTH directions
    # of the pager — a bench run that never evicted proves nothing about
    # the capacity-bounded steady state
    assert ts["fetched_tiles"] > 0, ts
    assert ts["evicted_tiles"] > 0, ts
    if FULL_SCALE:
        assert steady < HOST_PATH_TARGET, (
            f"steady-state host-path fraction {steady:.3f} >= "
            f"{HOST_PATH_TARGET} at full scale"
        )

    rows_out.append({
        "name": f"tier_replay_shards{S}",
        "us_per_call": f"{replay_s / max(len(stream), 1) * 1e6:.0f}",
        "derived": (
            f"ratio={record['capacity']['table_to_tier_ratio']:.1f}x;"
            f"steady_host={steady:.3f};"
            f"hit_rate={ts['hot_tier_hit_rate']:.3f}"
        ),
    })
    rows_out.append({
        "name": "tier_paging",
        "us_per_call": "",
        "derived": (
            f"fetched={ts['fetched_tiles']};evicted={ts['evicted_tiles']};"
            f"paging_bytes={ts['paging_bytes']};"
            f"host_flushes={ts['host_flushes']}"
        ),
    })
    rows_out.append({
        "name": "tier_host_path_target",
        "us_per_call": "",
        "derived": (
            f"steady_host={steady:.3f}<{HOST_PATH_TARGET}:"
            f"{record['meets_host_path_target']};json=BENCH_serving.json"
        ),
    })

    # merge into BENCH_serving.json (the serving bench owns the rest);
    # CI smoke sizes write to a temp path — never the committed record
    update_bench_json(
        bench_json_path(JSON_PATH, full_scale=FULL_SCALE), {"tiers": record}
    )
    return rows_out


def main():
    emit(run())


if __name__ == "__main__":
    main()
