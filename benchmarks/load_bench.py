"""Open-loop SLO replay harness → the ``load`` section of
BENCH_serving.json.

Drives the multi-producer front door (DESIGN.md §10) with an
**open-loop** load generator: N producer threads each follow a seeded
arrival schedule fixed BEFORE the run — a submission fires at its
scheduled instant whether or not the server kept up, which is what a
production SLO actually measures (a closed-loop generator would slow
down with the server and hide every queueing excursion).  Two arrival
processes per rate:

  * **poisson** — i.i.d. exponential gaps (the memoryless baseline);
  * **bursty** — back-to-back clusters of :data:`BURST` arrivals with
    exponential inter-cluster gaps at the same mean rate (the heavy
    tail a front door really sees).

The sweep runs each aggregate arrival rate through a ``per-shard``
threaded server with a WALL-CLOCK flush deadline
(``FlushPolicy.deadline_s``): a home flushes when its pending count
fills a batch or when its oldest query has aged ``DEADLINE_S`` seconds
— whichever comes first.  That makes the sweep show the **knee** the
bench exists to locate: below the knee, homes never fill inside the
deadline and the deadline timer serves everything (e2e latency pinned
near ``DEADLINE_S``); above it, batch fills take over and e2e drops to
the fill time.  The knee is reported as the aggregate rate where the
deadline-flush fraction crosses ½ (linear interpolation between swept
rates, ``None`` when the sweep never crosses — e.g. at CI smoke
sizes).

Recorded per (arrival process, rate): submit-side and per-flush
latency percentiles (µs), end-to-end submit→retire latency
percentiles (ms, the new ``e2e_latency_s`` stat), the deadline /
batch flush composition, achieved vs offered rate and the maximum
scheduler lag of the generator itself (open-loop fidelity: a lag
comparable to the mean gap means the offered rate was not actually
sustained).  Each point is the best of ``REPEATS`` replays by submit
p99 (all repeats' p99s recorded as the spread — container timing
swings the tail 2-4x under ambient load, the BENCH convention).  Every
replay's merged drain is asserted **bit-identical** to a host NumPy
oracle evaluated in the deterministic merge order (local seq, then
producer id) — integer tables make every partial sum exact in f32, so
a scheduling-dependent merge would fail the bench, not just skew it.

``--check`` is the regenerate-and-diff guard for the committed record:
it verifies the committed ``load`` section was measured at the pinned
full-scale config, that its headline ``submit_p99_us`` is still
100µs-class, then regenerates the record at the CURRENT env scale
(always routed away from the committed file) and diffs the two
records' key structure — a schema drift between the code and the
committed record fails the check before CI ever compares numbers.

Env knobs: ``RECROSS_LOAD_ROWS`` / ``RECROSS_LOAD_HISTORY`` (defaults
2_500), ``RECROSS_LOAD_BATCH`` (32), ``RECROSS_LOAD_SHARDS`` (4),
``RECROSS_LOAD_PRODUCERS`` (8), ``RECROSS_LOAD_SUBMITS`` (96 per
producer), ``RECROSS_LOAD_RATES`` (per-producer arrivals/s, default
``4,8,16,32,64``), ``RECROSS_LOAD_DEADLINE_S`` (0.1),
``RECROSS_LOAD_REPEATS`` (3), ``RECROSS_LOAD_SEED`` (0).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

import numpy as np
import jax

from benchmarks.common import (
    bench_is_full_scale,
    bench_json_path,
    emit,
    mesh_for,
    update_bench_json,
)
from repro.data import zipf_queries
from repro.serve import ShardedEmbeddingServer

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")

#: the committed-record configuration; env knobs override for smoke
#: runs, and ``--check`` pins the committed record to exactly these
_DEFAULTS = {
    # the full-scale axis of THIS bench is the front door (producers ×
    # arrival rates), not the table: kernel-scale latencies live in the
    # serving/scheduler/tiers sections.  The table is sized so the
    # interpret-mode flush (~50ms here) keeps service capacity
    # (~1300 submits/s measured) above the whole sweep — an overloaded
    # sweep is batch-bound at every rate and shows no deadline knee,
    # only handoff backpressure.
    "num_rows": 2_500,
    "num_history": 2_500,
    "batch_size": 32,
    "shards": 4,
    "producers": 8,
    "submits_per_producer": 96,
    "rates_per_producer": [4.0, 8.0, 16.0, 32.0, 64.0],
    "deadline_s": 0.1,
    "repeats": 3,
    "seed": 0,
}

NUM_ROWS = int(os.environ.get("RECROSS_LOAD_ROWS", _DEFAULTS["num_rows"]))
NUM_HISTORY = int(
    os.environ.get("RECROSS_LOAD_HISTORY", _DEFAULTS["num_history"])
)
SERVE_BATCH = int(os.environ.get("RECROSS_LOAD_BATCH", _DEFAULTS["batch_size"]))
NUM_SHARDS = int(os.environ.get("RECROSS_LOAD_SHARDS", _DEFAULTS["shards"]))
PRODUCERS = int(
    os.environ.get("RECROSS_LOAD_PRODUCERS", _DEFAULTS["producers"])
)
SUBMITS = int(
    os.environ.get("RECROSS_LOAD_SUBMITS", _DEFAULTS["submits_per_producer"])
)
RATES = [
    float(r)
    for r in os.environ.get(
        "RECROSS_LOAD_RATES",
        ",".join(str(r) for r in _DEFAULTS["rates_per_producer"]),
    ).split(",")
    if r.strip()
]
DEADLINE_S = float(
    os.environ.get("RECROSS_LOAD_DEADLINE_S", _DEFAULTS["deadline_s"])
)
REPEATS = int(os.environ.get("RECROSS_LOAD_REPEATS", _DEFAULTS["repeats"]))
SEED = int(os.environ.get("RECROSS_LOAD_SEED", _DEFAULTS["seed"]))
MEAN_BAG = float(os.environ.get("RECROSS_PIPELINE_MEAN_BAG", 41.32))
#: arrivals per bursty cluster (inter-cluster gaps keep the mean rate)
BURST = 8
GROUP_SIZE = 64
Q_BLOCK = 8
DIM = 128
TABLES = ("t0", "t1")
#: committed BENCH_serving.json only updates at the full DEFAULT config
FULL_SCALE = bench_is_full_scale()


# ------------------------------------------------------ load generation --

def _arrival_schedule(kind: str, rate: float, n: int, rng) -> np.ndarray:
    """Cumulative arrival instants (s) of one producer's ``n`` submits.

    ``poisson``: i.i.d. exponential gaps at ``rate``.  ``bursty``:
    clusters of :data:`BURST` near-simultaneous arrivals; the cluster
    head's gap is exponential with mean ``BURST/rate`` so the long-run
    rate matches the poisson process — only the variance differs.
    """
    if kind == "poisson":
        gaps = rng.exponential(1.0 / rate, size=n)
    else:
        gaps = rng.exponential(1.0 / (50.0 * rate), size=n)
        heads = np.arange(n) % BURST == 0
        gaps[heads] = rng.exponential(BURST / rate, size=int(heads.sum()))
    return np.cumsum(gaps)


def _producer_queries(rng) -> list:
    """One producer's query stream (tables alternate per submit)."""
    return list(
        zipf_queries(NUM_ROWS, SUBMITS, MEAN_BAG, seed=int(rng.integers(2**31)),
                     num_baskets=max(64, SUBMITS // 4))
    )


def _oracle(itables, queries_by_producer):
    """Expected drain per table, evaluated in the deterministic merge
    order — (local seq, producer id), the §10 contract — on the host.
    Integer tables keep every sum exact in f32, so the comparison is
    bit-level, not approximate."""
    per_table = {n: [] for n in TABLES}  # (local, pid, query)
    for pid, qs in enumerate(queries_by_producer):
        counts = {n: 0 for n in TABLES}
        for i, q in enumerate(qs):
            name = TABLES[i % len(TABLES)]
            per_table[name].append((counts[name], pid, q))
            counts[name] += 1
    out = {}
    for name, entries in per_table.items():
        entries.sort(key=lambda e: (e[0], e[1]))
        out[name] = np.stack([
            itables[name][np.unique(np.asarray(q, dtype=np.int64))].sum(axis=0)
            for _l, _p, q in entries
        ])
    return out


def _replay(itables, ihistories, queries_by_producer, kind, rate, mesh,
            expect):
    """One open-loop replay at one (arrival process, per-producer rate).

    Returns the stats record of the run; asserts the merged drain is
    bit-identical to the host oracle."""
    server = ShardedEmbeddingServer(
        itables, ihistories, num_shards=NUM_SHARDS, mesh=mesh,
        q_block=Q_BLOCK, group_size=GROUP_SIZE, batch_size=SERVE_BATCH,
        flush_policy="per-shard", threaded=True, max_in_flight=2,
        flush_deadline_s=DEADLINE_S,
    )
    # pid order pinned up front: the merge tiebreak is registration
    # order, which must not depend on which thread stamps first
    labels = [f"p{i}" for i in range(PRODUCERS)]
    for lab in labels:
        server.register_producer(lab)
    schedules = [
        _arrival_schedule(
            kind, rate, SUBMITS,
            np.random.default_rng([SEED, hash(kind) % 2**31,
                                   int(rate * 1000), p]),
        )
        for p in range(PRODUCERS)
    ]
    lags: list = []
    errs: list = []

    def body(lab, qs, sched):
        try:
            for i, (q, t_arr) in enumerate(zip(qs, sched)):
                dt = t_arr - (time.perf_counter() - t0)
                if dt > 0:
                    time.sleep(dt)
                lags.append((time.perf_counter() - t0) - t_arr)
                server.submit(TABLES[i % len(TABLES)], q, producer=lab)
        except Exception as e:  # pragma: no cover - surfaced below
            errs.append(e)

    threads = [
        threading.Thread(target=body, args=(lab, qs, sched), daemon=True)
        for lab, qs, sched in zip(labels, queries_by_producer, schedules)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    t_submit = time.perf_counter() - t0
    if errs:
        server.close()
        raise errs[0]
    outs = {n: np.asarray(o) for n, o in server.drain().items()}
    wall = time.perf_counter() - t0
    server.close()
    for name in TABLES:
        np.testing.assert_array_equal(outs[name], expect[name])

    s = server.stats.summary()
    batches = s["batches"]
    us = lambda v: {k: x * 1e6 for k, x in v.items()}
    ms = lambda v: {k: x * 1e3 for k, x in v.items()}
    total = PRODUCERS * SUBMITS
    return {
        "rate_per_producer": rate,
        "aggregate_rate_per_s": rate * PRODUCERS,
        "achieved_rate_per_s": (total / t_submit if t_submit > 0 else None),
        "wall_s": wall,
        "submit_latency_us": us(s["submit_latency_s"]),
        "flush_latency_us": us(s["flush_latency_s"]),
        "e2e_latency_ms": ms(s["e2e_latency_s"]),
        "batches": batches,
        "deadline_flushes": s["deadline_flushes"],
        "barrier_flushes": s["barrier_flushes"],
        "deadline_fraction": (s["deadline_flushes"] / batches
                              if batches else None),
        "max_sched_lag_ms": float(np.max(lags)) * 1e3 if lags else None,
        "oracle_bit_identical": True,        # asserted above
    }


def _knee(sweep):
    """Aggregate rate where the deadline-flush fraction crosses ½ —
    below it the wall deadline serves the traffic, above it batch
    fills take over.  Linear interpolation between swept rates;
    ``None`` when the sweep never crosses."""
    pts = sorted(
        (e["aggregate_rate_per_s"], e["deadline_fraction"])
        for e in sweep if e["deadline_fraction"] is not None
    )
    for (r0, f0), (r1, f1) in zip(pts, pts[1:]):
        if (f0 - 0.5) * (f1 - 0.5) <= 0 and f0 != f1:
            return r0 + (0.5 - f0) * (r1 - r0) / (f1 - f0)
    return None


# ------------------------------------------------------------- measure --

def _measure():
    """Runs the full sweep; returns ``(record, csv_rows)``."""
    irng = np.random.default_rng(7)
    itables = {
        n: irng.integers(-8, 9, size=(NUM_ROWS, DIM)).astype(np.float32)
        for n in TABLES
    }
    ihistories = {
        n: zipf_queries(NUM_ROWS, NUM_HISTORY, MEAN_BAG, seed=20 + i,
                        num_baskets=max(256, NUM_HISTORY // 32))
        for i, n in enumerate(TABLES)
    }
    qrng = np.random.default_rng(SEED)
    queries_by_producer = [_producer_queries(qrng) for _ in range(PRODUCERS)]
    expect = _oracle(itables, queries_by_producer)

    rows_out = []
    arrivals = {}
    for kind in ("poisson", "bursty"):
        sweep = []
        for rate in RATES:
            # warm per (process, rate): the kernel dispatch is
            # jit-cached per PADDED shape, and each rate produces its
            # own mix of partial-batch deadline flushes — an unwarmed
            # first replay bills XLA compiles as serving latency and
            # drowns the deadline/batch composition in compile storms
            _replay(itables, ihistories, queries_by_producer, kind,
                    rate, None, expect)
            runs = [
                _replay(itables, ihistories, queries_by_producer, kind,
                        rate, None, expect)
                for _ in range(REPEATS)
            ]
            best = min(runs, key=lambda r: r["submit_latency_us"]["p99"])
            best["submit_p99_us_runs"] = [
                r["submit_latency_us"]["p99"] for r in runs
            ]
            best["e2e_p99_ms_runs"] = [
                r["e2e_latency_ms"]["p99"] for r in runs
            ]
            best["wall_s_runs"] = [r["wall_s"] for r in runs]
            sweep.append(best)
            print(
                f"# load {kind} rate={rate * PRODUCERS:.0f}/s: "
                f"submit_p99={best['submit_latency_us']['p99']:.0f}us "
                f"e2e_p50={best['e2e_latency_ms']['p50']:.1f}ms "
                f"deadline_frac={best['deadline_fraction']}",
                file=sys.stderr,
            )
        knee = _knee(sweep)
        arrivals[kind] = {
            "sweep": sweep,
            "knee_aggregate_per_s": knee,
        }
        head = max(e["submit_latency_us"]["p99"] for e in sweep)
        fr = [e["deadline_fraction"] for e in sweep]
        rows_out.append({
            "name": f"load_{kind}",
            "us_per_call": f"{head:.0f}",
            "derived": (
                f"knee_agg_per_s="
                f"{knee:.0f};" if knee is not None else "knee_agg_per_s=none;"
            ) + (
                f"deadline_frac={fr[0]:.2f}->{fr[-1]:.2f};"
                f"e2e_p50_ms_low_rate="
                f"{sweep[0]['e2e_latency_ms']['p50']:.1f};"
                f"e2e_p50_ms_high_rate="
                f"{sweep[-1]['e2e_latency_ms']['p50']:.1f}"
            ),
        })

    record = {
        "config": {
            "num_rows": NUM_ROWS,
            "num_history": NUM_HISTORY,
            "batch_size": SERVE_BATCH,
            "shards": NUM_SHARDS,
            "producers": PRODUCERS,
            "submits_per_producer": SUBMITS,
            "rates_per_producer": list(RATES),
            "deadline_s": DEADLINE_S,
            "repeats": REPEATS,
            "seed": SEED,
            "devices": len(jax.devices()),
        },
        "arrivals": arrivals,
        # the never-blocks headline: worst submit p99 over the poisson
        # sweep (the acceptance gate tracks this number)
        "submit_p99_us": max(
            e["submit_latency_us"]["p99"]
            for e in arrivals["poisson"]["sweep"]
        ),
        "knee_aggregate_per_s": {
            k: v["knee_aggregate_per_s"] for k, v in arrivals.items()
        },
        "mode": "emulated",
    }

    # one mid-rate shard_map probe when the host presents enough
    # devices (CI forces 4): records that the front door + wall
    # deadline hold under shard_map dispatch — the sweep itself stays
    # emulated (forced host devices distort latency, not correctness)
    mesh = mesh_for(NUM_SHARDS)
    if mesh is not None:
        probe = _replay(itables, ihistories, queries_by_producer,
                        "poisson", RATES[len(RATES) // 2], mesh, expect)
        record["shard_map_probe"] = probe
        rows_out.append({
            "name": "load_shard_map_probe",
            "us_per_call": f"{probe['submit_latency_us']['p99']:.0f}",
            "derived": (
                f"e2e_p50_ms={probe['e2e_latency_ms']['p50']:.1f};"
                f"bit_identical=True"
            ),
        })
    else:
        record["shard_map_probe"] = None
    return record, rows_out


def run() -> list:
    record, rows_out = _measure()
    # merge into BENCH_serving.json (the serving bench owns the rest);
    # CI smoke sizes write to a temp path — never the committed record
    update_bench_json(
        bench_json_path(JSON_PATH, full_scale=FULL_SCALE),
        {"load": record},
    )
    return rows_out


# --------------------------------------------------------------- check --

def _key_structure_diff(committed, regenerated, path="load"):
    """Recursive key-structure diff (values ignored; a ``None`` on
    either side matches any subtree — smoke runs legitimately produce
    ``None`` knees and probes)."""
    diffs = []
    if committed is None or regenerated is None:
        return diffs
    if isinstance(committed, dict) or isinstance(regenerated, dict):
        if not (isinstance(committed, dict) and isinstance(regenerated, dict)):
            return [f"{path}: committed {type(committed).__name__} vs "
                    f"regenerated {type(regenerated).__name__}"]
        for k in sorted(set(committed) | set(regenerated)):
            if k not in regenerated:
                diffs.append(f"{path}.{k}: missing from regenerated record")
            elif k not in committed:
                diffs.append(f"{path}.{k}: missing from committed record")
            else:
                diffs += _key_structure_diff(
                    committed[k], regenerated[k], f"{path}.{k}"
                )
    elif isinstance(committed, list) and isinstance(regenerated, list):
        if committed and regenerated:
            diffs += _key_structure_diff(
                committed[0], regenerated[0], f"{path}[0]"
            )
    return diffs


def check() -> int:
    """Regenerate-and-diff guard for the committed ``load`` record.

    1. the committed record exists and was measured at the pinned
       full-scale config (a stale record from older defaults fails);
    2. its headline ``submit_p99_us`` is still 100µs-class;
    3. a regenerated record (CURRENT env scale, routed away from the
       committed file) has the same key structure — schema drift
       between code and record fails before CI compares any number.
    """
    problems = []
    try:
        with open(JSON_PATH) as f:
            committed = json.load(f)
    except (OSError, ValueError) as e:
        print(f"load-bench check: cannot read {JSON_PATH}: {e}",
              file=sys.stderr)
        return 1
    rec = committed.get("load")
    if rec is None:
        print("load-bench check: BENCH_serving.json has no 'load' section",
              file=sys.stderr)
        return 1

    cfg = rec.get("config", {})
    for key, want in _DEFAULTS.items():
        got = cfg.get(key)
        if got != want:
            problems.append(
                f"config.{key}: committed {got!r} != pinned default {want!r}"
            )
    p99 = rec.get("submit_p99_us")
    if not isinstance(p99, (int, float)) or not 0 < p99 < 10_000:
        problems.append(
            f"submit_p99_us={p99!r} is not 100µs-class (expected 0 < p99 "
            "< 10000)"
        )

    regenerated, _rows = _measure()
    # never the committed path: the regeneration exists to be compared,
    # not to overwrite the record it is checking
    update_bench_json(
        bench_json_path(JSON_PATH, full_scale=False),
        {"load": regenerated},
    )
    problems += _key_structure_diff(rec, regenerated)

    if problems:
        print("load-bench check FAILED:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print("load-bench check OK: committed record matches the pinned "
          "config and the regenerated schema", file=sys.stderr)
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--check", action="store_true",
        help="verify the committed load record (pinned config + "
             "regenerate-and-diff) instead of measuring",
    )
    args = ap.parse_args()
    if args.check:
        raise SystemExit(check())
    emit(run())


if __name__ == "__main__":
    main()
