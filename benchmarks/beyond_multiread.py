"""BEYOND-PAPER: extended dynamic-switch policy ("multi-read mode").

The paper switches READ↔MAC at popcount==1.  Under the flash-ADC energy
model, one full 6-bit MAC conversion costs ≈8.6× a 3-bit read, so
serializing up to ~8 activated rows through the READ path still beats a
single MAC on ENERGY — at a latency cost (reads serialize on the tile).

This benchmark sweeps the switch threshold and reports the energy/latency
frontier; threshold=1 is the paper's operating point, the energy-optimal
threshold is derived from the cost model at runtime
(core.dynamic_switch.energy_breakeven_rows)."""

from __future__ import annotations

from benchmarks.common import emit, prepared_workload
from repro.core import baselines, build_cooccurrence, energy_breakeven_rows, simulate_batch
from repro.core.energy import DEFAULT_RERAM

THRESHOLDS = [1, 2, 4, 8, 12]


def run() -> list:
    rows = []
    be = energy_breakeven_rows(DEFAULT_RERAM)
    rows.append({
        "name": "beyond_multiread_breakeven",
        "us_per_call": "",
        "derived": f"energy_breakeven_rows={be}",
    })
    for wl in ["software", "automotive"]:
        num_rows, hist, ev, graph = prepared_workload(wl)
        ev_b = ev[:256]
        layout, base = baselines.recross_pipeline(graph, ev_b, batch_size=256)
        for th in THRESHOLDS:
            rep = simulate_batch(layout, ev_b, switch_threshold=th)
            rows.append({
                "name": f"beyond_multiread_t{th}[{wl}]",
                "us_per_call": rep.completion_time_ns / 1e3,
                "derived": (
                    f"energy_vs_t1={base.energy_pj / rep.energy_pj:.3f}x;"
                    f"time_vs_t1={base.completion_time_ns / rep.completion_time_ns:.3f}x;"
                    f"read_frac={rep.read_fraction:.2f}"
                ),
            })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
