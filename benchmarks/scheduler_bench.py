"""Shard-aware async-serving benchmark → the ``scheduler`` section of
BENCH_serving.json.

Measures the acceptance contract of the asynchronous flush engine
(DESIGN.md §7) on a **skewed per-table arrival replay**: table ``t0``
arrives ``SKEW``× as often as ``t1``, so the global policy's fused flush
waits on the slow table's block union while the fast table's home shards
sit idle.  The same replay runs through every policy on one server
configuration:

  * **global** — the synchronous PR-2 path: one fused compile + blocking
    dispatch per ``batch_size`` buffered queries;
  * **per-shard** — the inline PR-4 engine: homes flush independently as
    they fill, host compile of flush *n+1* overlaps device execution of
    flush *n* (bounded in-flight queue, ``block_until_ready`` only at
    hand-off);
  * **owner-set** (thread driver) — multi-owner queries route to homes
    keyed by their frozen owner set (a 2-owner flush compiles and
    combines over exactly 2 shards), and the dispatch/retire loop runs
    on a driver thread so ``submit()`` only validates + enqueues —
    the recorded submit-side p99 is the never-blocks contract.

Recorded per execution mode: wall-clock of each replay and the speedup,
the host-compile time hidden behind device execution
(``overlap_fraction``, sampled conservatively at compile end via
``Array.is_ready`` — unknown array types count as idle), per-home flush
counts, flush-participant-size histograms, per-flush AND submit-side
p50/p95/p99 latencies, and per-flush grid cells for every policy.  The
per-flush-grid ≤ fused-flush-grid target applies to the POOLED
policies (a shard's unions are subsets of the fused flush's);
owner-set subsets deliberately trade per-shard grid width (replicated
work round-robins over the owner set, not the mesh) for combine
locality, so their ratio is recorded (``grid_cells_vs_global``), not
gated.
A **two-owner probe** additionally replays pure 2-owner traffic through
the owner-set policy and asserts every flush ran with exactly 2
participants — never the near-mesh-wide pool — bit-identically to the
dense oracle.  All policies are WARMED before timing — the kernel
dispatch is jit-cached per shape, so a cold-vs-warm pairing would
credit whichever policy runs second.  Integer tables make every partial
sum exact in f32, so all replays (across policies AND modes) are
asserted BIT-identical — a mismatch fails the bench.  Each policy's
wall clock is the BEST of three warmed replays (``wall_s_runs`` records
all) — the BENCH_pipeline.json convention: container timings swing
2-4x under ambient load, and a single sample routinely flips the
headline speedup in either direction.

Two modes when the host presents enough devices (CI forces 4):
**emulated** (single device) is the headline overlap demonstration —
device execution dominates, as on real hardware, and the async engine
hides the host compile behind it; **shard_map** on forced HOST devices
splits one CPU N ways, shrinking execution below the pipeline's fill
time, so the overlap there is a harness artifact to be measured on
real hardware (ROADMAP's TPU item) — it is recorded for the
bit-identity + combine accounting contract (including the grouped-psum
subset combine of owner-set flushes), not for speedup.

Env knobs: ``RECROSS_SCHED_ROWS`` / ``RECROSS_SCHED_HISTORY`` (defaults
12_500, an eighth of the serving bench's tables), ``RECROSS_SCHED_BATCH``
(32), ``RECROSS_SCHED_SHARDS`` (4), ``RECROSS_SCHED_SKEW`` (3),
``RECROSS_SCHED_POLICIES`` (comma list of async policies to replay,
default ``per-shard,owner-set``; ``global`` always runs as the
reference).
"""

from __future__ import annotations

import os
import time

import numpy as np
import jax

from benchmarks.common import (
    bench_is_full_scale,
    bench_json_path,
    emit,
    mesh_for,
    update_bench_json,
)
from repro.data import zipf_queries
from repro.serve import ShardedEmbeddingServer

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")

NUM_ROWS = int(os.environ.get("RECROSS_SCHED_ROWS", 12_500))
NUM_HISTORY = int(os.environ.get("RECROSS_SCHED_HISTORY", 12_500))
SERVE_BATCH = int(os.environ.get("RECROSS_SCHED_BATCH", 32))
NUM_SHARDS = int(os.environ.get("RECROSS_SCHED_SHARDS", 4))
SKEW = int(os.environ.get("RECROSS_SCHED_SKEW", 3))
MEAN_BAG = float(os.environ.get("RECROSS_PIPELINE_MEAN_BAG", 41.32))
#: async policies replayed against the global reference; owner-set runs
#: on the thread driver (non-blocking submit), per-shard inline (PR-4)
ASYNC_POLICIES = [
    p.strip()
    for p in os.environ.get(
        "RECROSS_SCHED_POLICIES", "per-shard,owner-set"
    ).split(",")
    if p.strip()
]
_KNOWN_POLICIES = ("per-shard", "deadline", "owner-set")
if not ASYNC_POLICIES or any(p not in _KNOWN_POLICIES for p in ASYNC_POLICIES):
    raise SystemExit(
        f"RECROSS_SCHED_POLICIES must name async policies from "
        f"{_KNOWN_POLICIES}, got {ASYNC_POLICIES!r} "
        "(global always runs as the reference)"
    )
GROUP_SIZE = 64
Q_BLOCK = 8
DIM = 128
#: committed BENCH_serving.json only updates at the full DEFAULT config
FULL_SCALE = bench_is_full_scale()


def run() -> list:
    rows_out = []
    irng = np.random.default_rng(7)
    itables = {
        "t0": irng.integers(-8, 9, size=(NUM_ROWS, DIM)).astype(np.float32),
        "t1": irng.integers(-8, 9, size=(NUM_ROWS, DIM)).astype(np.float32),
    }
    ihistories = {
        name: zipf_queries(NUM_ROWS, NUM_HISTORY, MEAN_BAG, seed=20 + i,
                           num_baskets=max(256, NUM_HISTORY // 32))
        for i, name in enumerate(itables)
    }
    n_req = SERVE_BATCH * 8
    replay_qs = zipf_queries(NUM_ROWS, n_req, MEAN_BAG, seed=29,
                             num_baskets=max(256, NUM_HISTORY // 32))
    # deterministic skewed interleave: SKEW t0 arrivals per t1 arrival
    replay = [("t0" if i % (SKEW + 1) < SKEW else "t1", q)
              for i, q in enumerate(replay_qs)]
    S = NUM_SHARDS

    def run_policy(policy, mesh, **kw):
        server = ShardedEmbeddingServer(
            itables, ihistories, num_shards=S, mesh=mesh,
            q_block=Q_BLOCK, group_size=GROUP_SIZE, batch_size=SERVE_BATCH,
            flush_policy=policy, **kw,
        )
        outs = {n: [] for n in itables}
        t0 = time.perf_counter()
        for name, q in replay:
            out = server.submit(name, q)
            for n, o in out.items():
                outs[n].append(np.asarray(o))
        for n, o in server.flush().items():
            outs[n].append(np.asarray(o))
        wall = time.perf_counter() - t0
        server.close()
        merged = {n: np.concatenate(o) for n, o in outs.items() if o}
        return server, wall, merged

    def run_policy_best(policy, mesh, repeats=3, **kw):
        """Best-of-``repeats`` warmed replays (run-to-run identity
        asserted); returns the fastest run's server/outs + all walls."""
        best, ref, walls = None, None, []
        for _ in range(repeats):
            server, wall, merged = run_policy(policy, mesh, **kw)
            walls.append(wall)
            if ref is None:
                ref = merged
            else:
                for n in itables:
                    np.testing.assert_array_equal(merged[n], ref[n])
            if best is None or wall < best[1]:
                best = (server, wall, merged)
        return best[0], best[1], best[2], walls

    #: per-policy server knobs — owner-set is the thread-driver record;
    #: owner_set_max=2 keys only the high-value 2-owner sets (the
    #: near-mesh tail pools up — see DESIGN.md §7.1 on the trade)
    POLICY_KW = {
        "per-shard": {"max_in_flight": 2},
        "deadline": {"max_in_flight": 2},
        "owner-set": {"max_in_flight": 2, "threaded": True,
                      "owner_set_max": 2},
    }

    def us(seconds):
        return seconds * 1e6

    def policy_record(server, wall):
        s = server.stats.summary()
        return {
            "wall_s": wall,
            "batches": s["batches"],
            "shard_flushes": s["shard_flushes"],
            "participant_sizes": s["participant_sizes"],
            "deadline_flushes": s["deadline_flushes"],
            "barrier_flushes": s["barrier_flushes"],
            "host_compile_s": s["host_compile_s"],
            "hidden_compile_s": s["hidden_compile_s"],
            "overlap_fraction": s["overlap_fraction"],
            "in_flight_peak": s["in_flight_peak"],
            "max_grid_cells_per_flush": s["max_grid_cells_per_flush"],
            "combine_bytes": s["combine_bytes"],
            "flush_latency_us": {
                k: us(v) for k, v in s["flush_latency_s"].items()
            },
            "submit_latency_us": {
                k: us(v) for k, v in s["submit_latency_s"].items()
            },
            "threaded": server.policy.threaded,
            "owner_set_max": server.policy.owner_set_max,
        }

    def two_owner_probe(mesh):
        """Pure 2-owner traffic through owner-set routing: every flush
        must run with exactly 2 participants (never the full mesh) and
        stay bit-identical to the dense oracle."""
        server = ShardedEmbeddingServer(
            itables, ihistories, num_shards=S, mesh=mesh,
            q_block=Q_BLOCK, group_size=GROUP_SIZE, batch_size=SERVE_BATCH,
            flush_policy="owner-set", threaded=True,
        )
        owner = server.scheduler._owner_of_row["t0"]
        by_owner = {}
        for r, o in enumerate(owner):
            if o >= 0:
                by_owner.setdefault(int(o), []).append(r)
        if len(by_owner) < 2:
            server.close()
            return None  # no 2-owner traffic constructible at this scale
        a, b = sorted(by_owner)[:2]
        qs = [
            [by_owner[a][i % len(by_owner[a])],
             by_owner[b][i % len(by_owner[b])]]
            for i in range(2 * SERVE_BATCH)
        ]
        for q in qs:
            server.submit("t0", q)
        out = np.asarray(server.drain()["t0"])
        server.close()
        sizes = server.stats.summary()["participant_sizes"]
        assert set(sizes) == {"2"}, (
            f"2-owner traffic flushed with participant sizes {sizes}"
        )
        want = np.stack([
            itables["t0"][sorted(set(q))].sum(axis=0) for q in qs
        ])
        np.testing.assert_array_equal(out, want)
        return {
            "owners": [a, b],
            "num_queries": len(qs),
            "participant_sizes": sizes,
            "max_participants": max(int(k) for k in sizes),
            "full_mesh_flushes": sizes.get(str(S), 0),
            "bit_identical_to_oracle": True,     # asserted above
        }

    modes = {"emulated": None}
    if mesh_for(S) is not None:
        modes["shard_map"] = mesh_for(S)
    mode_rec = {}
    ref_outs = None
    for label, mesh in modes.items():
        # WARM every policy before timing: the kernel dispatch is
        # jit-cached per shape, and the first replay pays every trace +
        # XLA compile — timing cold-vs-warm would credit whichever
        # policy runs second with the other's cache
        run_policy("global", mesh)
        for policy in ASYNC_POLICIES:
            run_policy(policy, mesh, **POLICY_KW[policy])
        srv_g, wall_g, outs_g, walls_g = run_policy_best("global", mesh)
        sum_g = srv_g.stats.summary()
        rec = {
            "global": {
                "wall_s": wall_g,
                "wall_s_runs": walls_g,
                "batches": sum_g["batches"],
                "host_compile_s": sum_g["host_compile_s"],
                "max_grid_cells_per_flush": sum_g["max_grid_cells_per_flush"],
                "combine_bytes": sum_g["combine_bytes"],
                "submit_latency_us": {
                    k: us(v) for k, v in sum_g["submit_latency_s"].items()
                },
            },
        }
        grid_ok = []
        for policy in ASYNC_POLICIES:
            srv_a, wall_a, outs_a, walls_a = run_policy_best(
                policy, mesh, **POLICY_KW[policy]
            )
            # bit-identity across policies AND modes (integer tables)
            for n in itables:
                np.testing.assert_array_equal(outs_a[n], outs_g[n])
                if ref_outs is not None:
                    np.testing.assert_array_equal(outs_a[n], ref_outs[n])
            key = "scheduler" if policy == "per-shard" else policy.replace("-", "_")
            rec[key] = policy_record(srv_a, wall_a)
            rec[key]["wall_s_runs"] = walls_a
            rec[f"{key}_speedup_vs_global"] = (
                wall_g / wall_a if wall_a > 0 else None
            )
            # the per-flush-grid ≤ fused-flush-grid invariant is the
            # POOLED policies' contract (a shard's unions are subsets of
            # the fused flush's).  Owner-set subsets deliberately trade
            # it away: replicated work round-robins over the owner set
            # instead of the whole mesh, so per-shard unions can widen —
            # the price of combine locality; the ratio is recorded, not
            # gated.
            if policy != "owner-set":
                grid_ok.append(
                    rec[key]["max_grid_cells_per_flush"]
                    <= sum_g["max_grid_cells_per_flush"]
                )
            else:
                rec[key]["grid_cells_vs_global"] = (
                    rec[key]["max_grid_cells_per_flush"]
                    / sum_g["max_grid_cells_per_flush"]
                    if sum_g["max_grid_cells_per_flush"] else None
                )
            rows_out.append({
                "name": f"serving_{key}_{label}",
                "us_per_call": f"{wall_a * 1e6:.0f}",
                "derived": (
                    f"speedup_vs_global="
                    f"{rec[f'{key}_speedup_vs_global']:.2f}x;"
                    f"overlap={rec[key]['overlap_fraction']:.2f};"
                    f"submit_p99_us="
                    f"{rec[key]['submit_latency_us']['p99']:.0f};"
                    f"cells/flush={rec[key]['max_grid_cells_per_flush']}"
                    f"(global={sum_g['max_grid_cells_per_flush']})"
                ),
            })
        ref_outs = outs_g
        rec["speedup_vs_global"] = rec.get("scheduler_speedup_vs_global")
        # None (not a vacuous True) when no pooled policy was measured
        rec["meets_grid_target"] = bool(all(grid_ok)) if grid_ok else None
        rec["two_owner"] = (
            two_owner_probe(mesh) if "owner-set" in ASYNC_POLICIES else None
        )
        mode_rec[label] = rec

    # headline = the emulated comparison: execution dominates there (as
    # on real hardware), so it is the honest overlap demonstration; the
    # forced-host shard_map numbers are recorded for the contract, not
    # for speedup (see module docstring)
    head = mode_rec["emulated"]
    head_async = ("scheduler" if "per-shard" in ASYNC_POLICIES
                  else ASYNC_POLICIES[0].replace("-", "_"))
    record = {
        "config": {
            "num_rows": NUM_ROWS, "requests": n_req, "skew": SKEW,
            "shards": S, "batch_size": SERVE_BATCH,
            "policies": ASYNC_POLICIES, "max_in_flight": 2,
            "devices": len(jax.devices()),
        },
        "modes": mode_rec,
        "global": head["global"],
        head_async: head[head_async],
        "speedup_vs_global": head.get(f"{head_async}_speedup_vs_global"),
        "host_compile_hidden_fraction":
            head[head_async]["overlap_fraction"],
        "bit_identical_to_sync": True,          # asserted above
        # pooled-policy per-flush grids must never exceed what the
        # synchronous fused flush would have run; None when this run
        # measured no pooled policy
        "meets_grid_target": (lambda checked: all(checked) if checked else None)(
            [m["meets_grid_target"] for m in mode_rec.values()
             if m["meets_grid_target"] is not None]
        ),
        "mode": "emulated+shard_map" if "shard_map" in mode_rec
                else "emulated",
    }
    if "owner-set" in ASYNC_POLICIES:
        record["owner_set"] = head["owner_set"]
        record["owner_set_speedup_vs_global"] = head.get(
            "owner_set_speedup_vs_global"
        )
        # the thread driver's never-blocks contract, auditable
        record["submit_p99_us"] = (
            head["owner_set"]["submit_latency_us"]["p99"]
        )
        record["two_owner"] = head.get("two_owner")

    # merge into BENCH_serving.json (the serving bench owns the rest);
    # CI smoke sizes write to a temp path — never the committed record
    update_bench_json(
        bench_json_path(JSON_PATH, full_scale=FULL_SCALE),
        {"scheduler": record},
    )
    return rows_out


def main():
    emit(run())


if __name__ == "__main__":
    main()
