"""Shard-aware async-serving benchmark → the ``scheduler`` section of
BENCH_serving.json.

Measures the acceptance contract of the asynchronous flush engine
(DESIGN.md §7) on a **skewed per-table arrival replay**: table ``t0``
arrives ``SKEW``× as often as ``t1``, so the global policy's fused flush
waits on the slow table's block union while the fast table's home shards
sit idle.  The same replay runs through both policies on one server
configuration:

  * **global** — the synchronous PR-2 path: one fused compile + blocking
    dispatch per ``batch_size`` buffered queries;
  * **per-shard** — the scheduler: homes flush independently as they
    fill, host compile of flush *n+1* overlaps device execution of
    flush *n* (bounded in-flight queue, ``block_until_ready`` only at
    hand-off).

Recorded per execution mode: wall-clock of each replay and the
speedup, the host-compile time hidden behind device execution
(``overlap_fraction``, sampled conservatively at compile end via
``Array.is_ready``), per-home flush counts, and per-flush grid cells
for both policies (the async per-flush grid must never exceed the
synchronous fused flush's).  Both policies are WARMED before timing —
the kernel dispatch is jit-cached per shape, so a cold-vs-warm pairing
would credit whichever policy runs second.  Integer tables make every
partial sum exact in f32, so all replays (across policies AND modes)
are asserted BIT-identical — a mismatch fails the bench.

Two modes when the host presents enough devices (CI forces 4):
**emulated** (single device) is the headline overlap demonstration —
device execution dominates, as on real hardware, and the async engine
hides the host compile behind it; **shard_map** on forced HOST devices
splits one CPU N ways, shrinking execution below the pipeline's fill
time, so the overlap there is a harness artifact to be measured on
real hardware (ROADMAP's TPU item) — it is recorded for the
bit-identity + combine accounting contract, not for speedup.

Env knobs: ``RECROSS_SCHED_ROWS`` / ``RECROSS_SCHED_HISTORY`` (defaults
12_500, an eighth of the serving bench's tables), ``RECROSS_SCHED_BATCH``
(32), ``RECROSS_SCHED_SHARDS`` (4), ``RECROSS_SCHED_SKEW`` (3).
"""

from __future__ import annotations

import os
import time

import numpy as np
import jax

from benchmarks.common import (
    bench_is_full_scale,
    bench_json_path,
    emit,
    mesh_for,
    update_bench_json,
)
from repro.data import zipf_queries
from repro.serve import ShardedEmbeddingServer

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")

NUM_ROWS = int(os.environ.get("RECROSS_SCHED_ROWS", 12_500))
NUM_HISTORY = int(os.environ.get("RECROSS_SCHED_HISTORY", 12_500))
SERVE_BATCH = int(os.environ.get("RECROSS_SCHED_BATCH", 32))
NUM_SHARDS = int(os.environ.get("RECROSS_SCHED_SHARDS", 4))
SKEW = int(os.environ.get("RECROSS_SCHED_SKEW", 3))
MEAN_BAG = float(os.environ.get("RECROSS_PIPELINE_MEAN_BAG", 41.32))
GROUP_SIZE = 64
Q_BLOCK = 8
DIM = 128
#: committed BENCH_serving.json only updates at the full DEFAULT config
FULL_SCALE = bench_is_full_scale()


def run() -> list:
    rows_out = []
    irng = np.random.default_rng(7)
    itables = {
        "t0": irng.integers(-8, 9, size=(NUM_ROWS, DIM)).astype(np.float32),
        "t1": irng.integers(-8, 9, size=(NUM_ROWS, DIM)).astype(np.float32),
    }
    ihistories = {
        name: zipf_queries(NUM_ROWS, NUM_HISTORY, MEAN_BAG, seed=20 + i,
                           num_baskets=max(256, NUM_HISTORY // 32))
        for i, name in enumerate(itables)
    }
    n_req = SERVE_BATCH * 8
    replay_qs = zipf_queries(NUM_ROWS, n_req, MEAN_BAG, seed=29,
                             num_baskets=max(256, NUM_HISTORY // 32))
    # deterministic skewed interleave: SKEW t0 arrivals per t1 arrival
    replay = [("t0" if i % (SKEW + 1) < SKEW else "t1", q)
              for i, q in enumerate(replay_qs)]
    S = NUM_SHARDS

    def run_policy(policy, mesh, **kw):
        server = ShardedEmbeddingServer(
            itables, ihistories, num_shards=S, mesh=mesh,
            q_block=Q_BLOCK, group_size=GROUP_SIZE, batch_size=SERVE_BATCH,
            flush_policy=policy, **kw,
        )
        outs = {n: [] for n in itables}
        t0 = time.perf_counter()
        for name, q in replay:
            out = server.submit(name, q)
            for n, o in out.items():
                outs[n].append(np.asarray(o))
        for n, o in server.flush().items():
            outs[n].append(np.asarray(o))
        wall = time.perf_counter() - t0
        merged = {n: np.concatenate(o) for n, o in outs.items() if o}
        return server, wall, merged

    modes = {"emulated": None}
    if mesh_for(S) is not None:
        modes["shard_map"] = mesh_for(S)
    mode_rec = {}
    ref_outs = None
    for label, mesh in modes.items():
        # WARM both policies before timing: the kernel dispatch is
        # jit-cached per shape, and the first replay pays every trace +
        # XLA compile — timing cold-vs-warm would credit whichever
        # policy runs second with the other's cache
        run_policy("global", mesh)
        run_policy("per-shard", mesh, max_in_flight=2)
        srv_g, wall_g, outs_g = run_policy("global", mesh)
        srv_a, wall_a, outs_a = run_policy("per-shard", mesh, max_in_flight=2)
        # bit-identity across policies AND modes (integer tables)
        for n in itables:
            np.testing.assert_array_equal(outs_a[n], outs_g[n])
            if ref_outs is not None:
                np.testing.assert_array_equal(outs_a[n], ref_outs[n])
        ref_outs = outs_g
        sum_g, sum_a = srv_g.stats.summary(), srv_a.stats.summary()
        mode_rec[label] = {
            "global": {
                "wall_s": wall_g,
                "batches": sum_g["batches"],
                "host_compile_s": sum_g["host_compile_s"],
                "max_grid_cells_per_flush": sum_g["max_grid_cells_per_flush"],
                "combine_bytes": sum_g["combine_bytes"],
            },
            "scheduler": {
                "wall_s": wall_a,
                "batches": sum_a["batches"],
                "shard_flushes": sum_a["shard_flushes"],
                "deadline_flushes": sum_a["deadline_flushes"],
                "barrier_flushes": sum_a["barrier_flushes"],
                "host_compile_s": sum_a["host_compile_s"],
                "hidden_compile_s": sum_a["hidden_compile_s"],
                "overlap_fraction": sum_a["overlap_fraction"],
                "in_flight_peak": sum_a["in_flight_peak"],
                "max_grid_cells_per_flush": sum_a["max_grid_cells_per_flush"],
                "combine_bytes": sum_a["combine_bytes"],
            },
            "speedup_vs_global": wall_g / wall_a if wall_a > 0 else None,
            "meets_grid_target": bool(
                sum_a["max_grid_cells_per_flush"]
                <= sum_g["max_grid_cells_per_flush"]
            ),
        }
        rows_out.append({
            "name": f"serving_scheduler_{label}",
            "us_per_call": f"{wall_a * 1e6:.0f}",
            "derived": (
                f"speedup_vs_global="
                f"{mode_rec[label]['speedup_vs_global']:.2f}x;"
                f"overlap={sum_a['overlap_fraction']:.2f};"
                f"cells/flush={sum_a['max_grid_cells_per_flush']}"
                f"<=global={sum_g['max_grid_cells_per_flush']}:"
                f"{mode_rec[label]['meets_grid_target']}"
            ),
        })

    # headline = the emulated comparison: execution dominates there (as
    # on real hardware), so it is the honest overlap demonstration; the
    # forced-host shard_map numbers are recorded for the contract, not
    # for speedup (see module docstring)
    head = mode_rec["emulated"]
    record = {
        "config": {
            "num_rows": NUM_ROWS, "requests": n_req, "skew": SKEW,
            "shards": S, "batch_size": SERVE_BATCH,
            "policy": "per-shard", "max_in_flight": 2,
            "devices": len(jax.devices()),
        },
        "modes": mode_rec,
        "global": head["global"],
        "scheduler": head["scheduler"],
        "speedup_vs_global": head["speedup_vs_global"],
        "host_compile_hidden_fraction":
            head["scheduler"]["overlap_fraction"],
        "bit_identical_to_sync": True,          # asserted above
        # per-shard per-flush grids must never exceed what the
        # synchronous fused flush would have run
        "meets_grid_target": all(
            m["meets_grid_target"] for m in mode_rec.values()
        ),
        "mode": "emulated+shard_map" if "shard_map" in mode_rec
                else "emulated",
    }

    # merge into BENCH_serving.json (the serving bench owns the rest);
    # CI smoke sizes write to a temp path — never the committed record
    update_bench_json(
        bench_json_path(JSON_PATH, full_scale=FULL_SCALE),
        {"scheduler": record},
    )
    return rows_out


def main():
    emit(run())


if __name__ == "__main__":
    main()
