"""Online-replanning benchmark → the ``replan`` section of BENCH_serving.json.

Measures the acceptance contract of the drift-aware replanning datapath
(DESIGN.md §6) on a synthetic hot-set rotation:

  * **patched vs rebuilt tiles** — tiles the incremental
    :func:`repro.dist.replan.compute_plan_patch` DMAs per drift event vs
    the tiles a from-scratch ``plan_shards`` + ``build_shard_images``
    rebuild would move.  The patch must stay at the moved groups' tiles,
    never the image.
  * **per-shard grid cells before/after drift** — the stale plan serving
    drifted traffic vs the patched plan serving the same traffic (hot
    groups back in the replicated round-robin set shrink the busiest
    shard's block unions).
  * **bit-identity** — the patched images + plan serve the drifted probe
    bit-identically to the fresh rebuild (integer tables, exact sums);
    asserted inline, a mismatch fails the bench.
  * an end-to-end :class:`~repro.serve.sharded.ShardedEmbeddingServer`
    drift replay recording the replan counters.

The ``patch_scale`` subsection times :func:`compute_plan_patch` alone at
100k/1M/10M rows (frequency-grouped Zipf tables, no images): best-of-3
latency for the full-scan evaluation, for the drifted-``candidates``
evaluation the server path uses (DESIGN.md §11), and for a no-op patch —
each asserted field-identical to the retained
``_reference_compute_plan_patch`` oracle.  The gate: millisecond regime
(< 100 ms) at 10M rows.

Runs per shard count (``RECROSS_REPLAN_SHARDS``, default "2,4");
emulation unless the host presents enough devices.  Env knobs:
``RECROSS_REPLAN_ROWS`` / ``RECROSS_REPLAN_HISTORY`` (default 20_000),
``RECROSS_REPLAN_BATCH`` (32), ``RECROSS_PATCH_SCALE_ROWS`` (comma
list, default "100000,1000000,10000000").
"""

from __future__ import annotations

import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (
    bench_is_full_scale,
    bench_json_path,
    emit,
    mesh_for,
    update_bench_json,
)
from repro.core import (
    build_cooccurrence,
    build_layout,
    compile_queries,
    correlation_aware_grouping,
    plan_replication,
    shard_block_queries,
)
from repro.core.cooccurrence import CoOccurrenceGraph
from repro.core.grouping import frequency_grouping
from repro.data import zipf_queries
from repro.dist import (
    apply_plan_patch,
    build_fused_image,
    compute_plan_patch,
    plan_shards,
)
from repro.dist.replan import _reference_compute_plan_patch
from repro.kernels import crossbar_reduce_sharded, patch_shard_images

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")

NUM_ROWS = int(os.environ.get("RECROSS_REPLAN_ROWS", 20_000))
NUM_HISTORY = int(os.environ.get("RECROSS_REPLAN_HISTORY", 20_000))
PROBE_BATCH = int(os.environ.get("RECROSS_REPLAN_BATCH", 32))
SHARD_COUNTS = tuple(
    int(s) for s in os.environ.get("RECROSS_REPLAN_SHARDS", "2,4").split(",")
)
PATCH_SCALE_ROWS = tuple(
    int(s)
    for s in os.environ.get(
        "RECROSS_PATCH_SCALE_ROWS", "100000,1000000,10000000"
    ).split(",")
    if s.strip()
)
MEAN_BAG = float(os.environ.get("RECROSS_PIPELINE_MEAN_BAG", 41.32))
#: committed BENCH_serving.json only updates at the full DEFAULT config
FULL_SCALE = bench_is_full_scale()
GROUP_SIZE = 64
Q_BLOCK = 8
DIM = 128
EQ1_BATCH = 256


def _patch_equal(a, b) -> bool:
    """Field-identical PlanPatch comparison (the bench's oracle gate)."""
    return (
        a.promoted == b.promoted
        and a.demoted == b.demoted
        and a.dma == b.dma
        and a.freed == b.freed
        and a.new_capacity == b.new_capacity
        and a.moved == b.moved
        and a.fetched == b.fetched
        and a.evicted == b.evicted
        and a.fetch_dma == b.fetch_dma
        and a.deferred == b.deferred
        and np.array_equal(a.drifted_load, b.drifted_load)
    )


def _best_of(fn, repeats: int = 3):
    """(best wall seconds, {min, median, max, repeats}, last result)."""
    times, out = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        times.append(time.perf_counter() - t0)
    ts = sorted(times)
    return ts[0], {
        "min": ts[0], "median": ts[len(ts) // 2], "max": ts[-1],
        "repeats": repeats,
    }, out


def _patch_scale_size(num_rows: int) -> dict:
    """compute_plan_patch latency at ``num_rows`` (no device images —
    the patch is pure plan math; capacity comes from the plan itself).

    The table is an edgeless Zipf-frequency graph put through
    :func:`frequency_grouping` — plan SHAPE at scale is what the patch
    cost depends on, not co-access structure.  Drift boosts 64 cold
    groups and collapses 64 replicated ones, so the patch does real
    promote/demote work at every size.
    """
    rng = np.random.default_rng(0)
    ranks = rng.permutation(num_rows).astype(np.float64) + 1.0
    freq = (1e7 / ranks ** 1.05).astype(np.int64) + 1
    graph = CoOccurrenceGraph(
        num_rows=num_rows,
        freq=freq,
        indptr=np.zeros(num_rows + 1, dtype=np.int64),
        indices=np.empty(0, dtype=np.int64),
        weights=np.empty(0, dtype=np.int64),
        num_queries=int(num_rows // 10),
    )
    grouping = frequency_grouping(graph, GROUP_SIZE)
    plan = plan_replication(grouping, graph.freq, EQ1_BATCH)
    t0 = time.perf_counter()
    layout = build_layout(grouping, plan, 8)
    layout_s = time.perf_counter() - t0
    gfreq = grouping.group_freq(graph.freq)
    t0 = time.perf_counter()
    sp = plan_shards([layout], [plan], 4, group_freqs=[gfreq],
                     eq1_batch=EQ1_BATCH)
    shards_s = time.perf_counter() - t0

    # the candidates contract (DESIGN.md §11) holds when segment totals
    # are preserved — the server rescales its decayed estimate to the
    # plan's training total — so the drift moves mass rather than adding
    # it: collapse 64 replicated groups and hand their mass to the 64
    # coldest
    repl = np.flatnonzero(sp.replicated_group)
    cold = np.argsort(gfreq, kind="stable")[:64]
    hot = repl[: min(64, repl.size)]
    drift = gfreq.astype(np.float64)
    drift[hot] *= 0.02
    drift[cold] += float(gfreq[hot].sum()) * 0.98 / max(cold.size, 1)
    candidates = np.union1d(cold, hot)

    t_full, sp_full, patch = _best_of(lambda: compute_plan_patch(
        sp, drift, eq1_batch=EQ1_BATCH))
    t_cand, sp_cand, patch_c = _best_of(lambda: compute_plan_patch(
        sp, drift, eq1_batch=EQ1_BATCH, candidates=candidates))
    t_noop, sp_noop, _ = _best_of(lambda: compute_plan_patch(
        sp, gfreq, eq1_batch=EQ1_BATCH, candidates=np.empty(0, np.int64)))
    t0 = time.perf_counter()
    ref = _reference_compute_plan_patch(sp, drift, eq1_batch=EQ1_BATCH)
    ref_s = time.perf_counter() - t0
    assert _patch_equal(patch, ref), "patch diverged from reference oracle"
    assert _patch_equal(patch_c, ref), "candidates patch != full-scan patch"
    return {
        "num_rows": num_rows,
        "num_groups": sp.num_groups,
        "num_tiles": int(layout.num_tiles),
        "layout_s": layout_s,
        "plan_shards_s": shards_s,
        "promoted": len(patch.promoted),
        "demoted": len(patch.demoted),
        "patch_full_scan_ms": t_full * 1e3,
        "patch_full_scan_spread_s": sp_full,
        "patch_candidates_ms": t_cand * 1e3,
        "patch_candidates_spread_s": sp_cand,
        "patch_noop_ms": t_noop * 1e3,
        "patch_noop_spread_s": sp_noop,
        "reference_ms": ref_s * 1e3,
        "speedup_vs_reference": ref_s / max(t_full, 1e-12),
        "matches_reference": True,
    }


def _stream_group_freq(stream, layout) -> np.ndarray:
    """Per-group access frequency of a query stream (unique rows/query)."""
    gf = np.zeros(layout.num_groups, dtype=np.float64)
    for q in stream:
        rows = np.unique(np.asarray(q, dtype=np.int64))
        np.add.at(gf, layout.group_of[rows], 1.0)
    return gf


def run() -> list:
    rows_out = []
    record: dict = {
        "config": {
            "num_rows": NUM_ROWS,
            "history_queries": NUM_HISTORY,
            "probe_batch": PROBE_BATCH,
            "q_block": Q_BLOCK,
            "group_size": GROUP_SIZE,
            "dim": DIM,
            "mean_bag": MEAN_BAG,
            "shard_counts": list(SHARD_COUNTS),
            "devices": len(jax.devices()),
        },
    }

    # ---- offline pipeline + a rotated-hot-set drift workload -----------
    hist = zipf_queries(NUM_ROWS, NUM_HISTORY, MEAN_BAG, seed=0,
                        num_baskets=max(256, NUM_HISTORY // 32))
    graph = build_cooccurrence(hist, NUM_ROWS)
    grouping = correlation_aware_grouping(graph, GROUP_SIZE)
    plan = plan_replication(grouping, graph.freq, EQ1_BATCH)
    layout = build_layout(grouping, plan, DIM)
    gfreq = grouping.group_freq(graph.freq)
    table = np.random.default_rng(0).integers(
        -8, 9, size=(NUM_ROWS, DIM)
    ).astype(np.float32)
    fused = build_fused_image([layout], [table])

    perm = np.random.default_rng(7).permutation(NUM_ROWS)
    drift_stream = [
        perm[np.asarray(q, dtype=np.int64)]
        for q in zipf_queries(NUM_ROWS, max(PROBE_BATCH * 8, 256), MEAN_BAG,
                              seed=11, num_baskets=max(256, NUM_HISTORY // 32))
    ]
    drift_gfreq = _stream_group_freq(drift_stream, layout)
    # Eq. 1 is magnitude-sensitive: evaluate the drifted distribution at
    # the training-history mass (what the serving driver does too)
    drift_gfreq *= gfreq.sum() / max(drift_gfreq.sum(), 1e-12)
    probe = drift_stream[:PROBE_BATCH]
    cq = compile_queries(layout, probe, replica_block=Q_BLOCK)

    shards_rec = {}
    for S in SHARD_COUNTS:
        sp = plan_shards([layout], [plan], S, group_freqs=[gfreq])
        images = jnp.asarray(sp.build_shard_images(fused))
        mesh = mesh_for(S)

        # stale plan serving drifted traffic
        sbq_before = shard_block_queries(cq, sp, Q_BLOCK)
        cells_before = sbq_before.grid_cells_per_shard()

        # incremental patch to the drifted frequencies
        t0 = time.perf_counter()
        patch = compute_plan_patch(
            sp, drift_gfreq, eq1_batch=EQ1_BATCH,
            capacity=int(images.shape[1]),
        )
        sp_patched = apply_plan_patch(sp, patch)
        compute_s = time.perf_counter() - t0
        images_patched = patch_shard_images(images, patch, fused)
        sbq_after = shard_block_queries(cq, sp_patched, Q_BLOCK)
        cells_after = sbq_after.grid_cells_per_shard()

        # from-scratch rebuild on the same drifted frequencies
        fresh = plan_shards([layout], [plan], S,
                            group_freqs=[drift_gfreq], eq1_batch=EQ1_BATCH)
        images_fresh = jnp.asarray(fresh.build_shard_images(fused))
        sbq_fresh = shard_block_queries(cq, fresh, Q_BLOCK)
        out_patched = np.asarray(crossbar_reduce_sharded(
            images_patched, sbq_after.tile_ids, sbq_after.bitmaps, mesh=mesh,
        ))[: sbq_after.batch]
        out_fresh = np.asarray(crossbar_reduce_sharded(
            images_fresh, sbq_fresh.tile_ids, sbq_fresh.bitmaps, mesh=mesh,
        ))[: sbq_fresh.batch]
        np.testing.assert_array_equal(out_patched, out_fresh)

        rebuilt_tiles = int(fresh.local_num_tiles.sum())
        shards_rec[str(S)] = {
            "patched_tiles": patch.num_moved_tiles,
            "rebuilt_tiles": rebuilt_tiles,
            "patch_fraction": patch.num_moved_tiles / max(rebuilt_tiles, 1),
            "promoted_groups": len(patch.promoted),
            "demoted_groups": len(patch.demoted),
            "freed_slots": len(patch.freed),
            "capacity_before": int(images.shape[1]),
            "capacity_after": patch.new_capacity,
            "grid_cells_per_shard_before": cells_before,
            "grid_cells_per_shard_after": cells_after,
            "compute_patch_s": compute_s,
            "bit_identical_to_rebuild": True,
            "mode": "shard_map" if mesh is not None else "emulated",
        }
        rows_out.append({
            "name": f"replan_shards{S}",
            "us_per_call": f"{compute_s * 1e6:.0f}",
            "derived": (
                f"patched={patch.num_moved_tiles}/rebuild={rebuilt_tiles};"
                f"cells_before={cells_before};cells_after={cells_after}"
            ),
        })

    record["shards"] = shards_rec
    worst = max(r["patch_fraction"] for r in shards_rec.values())
    record["never_full_rebuild"] = bool(worst < 1.0)

    # ---- compute_plan_patch latency vs table size (DESIGN.md §11) -------
    patch_scale = {"sizes": {}}
    for n in PATCH_SCALE_ROWS:
        patch_scale["sizes"][str(n)] = _patch_scale_size(n)
    patch_scale["millisecond_regime"] = all(
        s["patch_full_scan_ms"] < 100.0 and s["patch_candidates_ms"] < 100.0
        for s in patch_scale["sizes"].values()
    )
    record["patch_scale"] = patch_scale
    for n, s in patch_scale["sizes"].items():
        rows_out.append({
            "name": f"replan_patch_scale_{n}",
            "us_per_call": f"{s['patch_full_scan_ms'] * 1e3:.0f}",
            "derived": (
                f"candidates={s['patch_candidates_ms']:.2f}ms;"
                f"noop={s['patch_noop_ms']:.2f}ms;"
                f"ref={s['reference_ms']:.1f}ms"
                f"({s['speedup_vs_reference']:.1f}x);"
                f"promote={s['promoted']};demote={s['demoted']}"
            ),
        })

    # ---- end-to-end server drift replay --------------------------------
    from repro.serve import ReplanConfig, ShardedEmbeddingServer

    srv_rows = max(NUM_ROWS // 8, 256)
    srv_hist = max(NUM_HISTORY // 8, 256)
    S = max(SHARD_COUNTS)
    tables = {
        "t0": np.random.default_rng(3).integers(
            -8, 9, size=(srv_rows, DIM)
        ).astype(np.float32),
    }
    histories = {
        "t0": zipf_queries(srv_rows, srv_hist, MEAN_BAG, seed=5,
                           num_baskets=max(256, srv_hist // 32)),
    }
    server = ShardedEmbeddingServer(
        tables, histories, num_shards=S, mesh=mesh_for(S),
        q_block=Q_BLOCK, group_size=GROUP_SIZE, batch_size=PROBE_BATCH,
        replan=ReplanConfig(threshold=0.2, half_life=2.0,
                            min_queries=PROBE_BATCH, slack_tiles=8),
    )
    sperm = np.random.default_rng(13).permutation(srv_rows)
    sstream = zipf_queries(srv_rows, PROBE_BATCH * 16, MEAN_BAG, seed=17,
                           num_baskets=max(256, srv_hist // 32))
    # rotate the hot set early: most of the replay runs drifted, so the
    # decayed estimate has time to cross the threshold and the staged
    # patch has flushes left to apply in
    cut = len(sstream) // 4
    sstream = sstream[:cut] + [
        sperm[np.asarray(q, dtype=np.int64)] for q in sstream[cut:]
    ]
    for q in sstream:
        server.submit("t0", q)
    server.flush()
    record["server"] = server.report()
    srv = server.stats
    rows_out.append({
        "name": "replan_server",
        "us_per_call": f"{srv.wall_s * 1e6:.0f}",
        "derived": (
            f"replans={srv.replans};rebases={srv.rebases};"
            f"patched_tiles={srv.patched_tiles};"
            f"promoted={srv.promoted_groups};demoted={srv.demoted_groups}"
        ),
    })
    rows_out.append({
        "name": "replan_never_full_rebuild",
        "us_per_call": "",
        "derived": (
            f"worst_patch_fraction={worst:.3f}<1:"
            f"{record['never_full_rebuild']};json=BENCH_serving.json"
        ),
    })

    # merge into BENCH_serving.json (the serving bench owns the rest);
    # CI smoke sizes write to a temp path — never the committed record
    update_bench_json(
        bench_json_path(JSON_PATH, full_scale=FULL_SCALE), {"replan": record}
    )

    return rows_out


def main():
    emit(run())


if __name__ == "__main__":
    main()
