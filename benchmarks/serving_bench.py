"""Sharded-serving benchmark → BENCH_serving.json.

Builds the full-scale offline pipeline (same defaults as the pipeline
bench: 100k rows / 100k-query history, group_size 64), then serves a
``blocked_q8`` batch through the sharded datapath at shard counts
{1, 2, 4} and records the sharded path's observability contract:

  * per-shard grid cells (nb × padded per-shard union width) vs the
    single-device blocked baseline — the acceptance invariant is that
    shard-local unions never regress the global union;
  * cross-shard combine bytes (output-sized ring accounting);
  * wall time vs the 1-shard baseline (interpret mode off-TPU: a
    regression signal, not TPU performance — the grid-cell and byte
    numbers are the hardware-independent ones).

Plus a two-table fused section exercising the multi-table path end to
end through :class:`repro.serve.sharded.ShardedEmbeddingServer`.

Runs under shard_map when the host presents enough devices (CI forces
``--xla_force_host_platform_device_count=4``), single-device emulation
otherwise; numerics are identical either way.

Env knobs: ``RECROSS_SERVING_ROWS`` / ``RECROSS_SERVING_HISTORY``
(defaults 100_000), ``RECROSS_SERVING_BATCH`` (32),
``RECROSS_SERVING_SHARDS`` ("1,2,4").
"""

from __future__ import annotations

import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (
    bench_is_full_scale,
    bench_json_path,
    emit,
    mesh_for,
    update_bench_json,
)
from repro.core import (
    block_compiled_queries,
    build_cooccurrence,
    build_layout,
    compile_queries,
    correlation_aware_grouping,
    plan_replication,
    shard_block_queries,
)
from repro.data import zipf_queries
from repro.dist import build_fused_image, plan_shards
from repro.kernels import (
    combine_bytes_per_batch,
    crossbar_reduce_blocked,
    crossbar_reduce_sharded,
)

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")

NUM_ROWS = int(os.environ.get("RECROSS_SERVING_ROWS", 100_000))
NUM_HISTORY = int(os.environ.get("RECROSS_SERVING_HISTORY", 100_000))
SERVE_BATCH = int(os.environ.get("RECROSS_SERVING_BATCH", 32))
SHARD_COUNTS = tuple(
    int(s) for s in os.environ.get("RECROSS_SERVING_SHARDS", "1,2,4").split(",")
)
MEAN_BAG = float(os.environ.get("RECROSS_PIPELINE_MEAN_BAG", 41.32))
GROUP_SIZE = 64
Q_BLOCK = 8
DIM = 128
BATCH_SIZE = 256
#: committed BENCH_serving.json only updates at the full DEFAULT config
FULL_SCALE = bench_is_full_scale()


def run() -> list:
    record: dict = {
        "config": {
            "num_rows": NUM_ROWS,
            "history_queries": NUM_HISTORY,
            "serve_batch": SERVE_BATCH,
            "q_block": Q_BLOCK,
            "group_size": GROUP_SIZE,
            "dim": DIM,
            "mean_bag": MEAN_BAG,
            "shard_counts": list(SHARD_COUNTS),
            "devices": len(jax.devices()),
        },
    }
    rows_out = []

    # ---- offline pipeline (shared by every shard count) -----------------
    t0 = time.perf_counter()
    hist = zipf_queries(NUM_ROWS, NUM_HISTORY, MEAN_BAG, seed=0,
                        num_baskets=max(256, NUM_HISTORY // 32))
    graph = build_cooccurrence(hist, NUM_ROWS)
    grouping = correlation_aware_grouping(graph, GROUP_SIZE)
    plan = plan_replication(grouping, graph.freq, BATCH_SIZE)
    layout = build_layout(grouping, plan, DIM)
    gfreq = grouping.group_freq(graph.freq)
    record["offline"] = {
        "seconds": time.perf_counter() - t0,
        "num_groups": grouping.num_groups,
        "num_tiles": layout.num_tiles,
    }

    table = np.random.default_rng(0).normal(size=(NUM_ROWS, DIM)).astype(np.float32)
    fused = build_fused_image([layout], [table])
    # serve queries from the history's own basket distribution (same
    # workload as the pipeline bench's kernel section, so the grid-cell
    # numbers are directly comparable to its blocked_q8 baseline)
    ev = hist[:SERVE_BATCH]
    cq = compile_queries(layout, ev, replica_block=Q_BLOCK)

    # ---- single-device blocked baseline ---------------------------------
    bq = block_compiled_queries(cq, Q_BLOCK)
    image_j = jnp.asarray(fused)
    out_base = crossbar_reduce_blocked(image_j, bq.tile_ids, bq.bitmaps)  # warm
    t0 = time.perf_counter()
    crossbar_reduce_blocked(image_j, bq.tile_ids, bq.bitmaps).block_until_ready()
    base_us = (time.perf_counter() - t0) * 1e6
    base_cells = int(bq.num_blocks * bq.max_tiles)
    record["single_device_baseline"] = {
        "blocked_q8_grid_cells": base_cells,
        "wall_us": base_us,
    }

    # ---- sharded path per shard count -----------------------------------
    shards_rec = {}
    for S in SHARD_COUNTS:
        sp = plan_shards([layout], [plan], S, group_freqs=[gfreq])
        sbq = shard_block_queries(cq, sp, Q_BLOCK)
        images = jnp.asarray(sp.build_shard_images(fused))
        mesh = mesh_for(S)
        kw = dict(mesh=mesh, combine_chunks=2)
        out = crossbar_reduce_sharded(images, sbq.tile_ids, sbq.bitmaps, **kw)  # warm
        np.testing.assert_allclose(
            np.asarray(out[: sbq.batch]), np.asarray(out_base[: bq.batch]),
            atol=1e-4,
        )
        t0 = time.perf_counter()
        crossbar_reduce_sharded(
            images, sbq.tile_ids, sbq.bitmaps, **kw
        ).block_until_ready()
        wall_us = (time.perf_counter() - t0) * 1e6
        cells = sbq.grid_cells_per_shard()
        shards_rec[str(S)] = {
            "grid_cells_per_shard": cells,
            "max_shard_width": int(np.max(sbq.shard_widths, initial=0)),
            "shard_widths": sbq.shard_widths.tolist(),
            "replicated_tiles": sp.replicated_tiles,
            "local_num_tiles": sp.local_num_tiles.tolist(),
            "combine_bytes": combine_bytes_per_batch(
                sbq.num_blocks * Q_BLOCK, DIM, S
            ),
            "wall_us": wall_us,
            "mode": "shard_map" if mesh is not None else "emulated",
        }
        rows_out.append({
            "name": f"serving_shards{S}",
            "us_per_call": f"{wall_us:.0f}",
            "derived": (
                f"cells/shard={cells}(base={base_cells});"
                f"combine_bytes={shards_rec[str(S)]['combine_bytes']}"
            ),
        })
    # wall ratio vs the true 1-shard run (only when 1 was benchmarked)
    one = shards_rec.get("1")
    for r in shards_rec.values():
        r["wall_vs_1shard"] = r["wall_us"] / one["wall_us"] if one else None
    record["shards"] = shards_rec
    worst = max(r["grid_cells_per_shard"] for r in shards_rec.values())
    record["meets_grid_target"] = bool(worst <= base_cells)

    # ---- multi-table fused serving (driver end-to-end) ------------------
    mt_rows = max(NUM_ROWS // 8, 256)
    mt_hist = max(NUM_HISTORY // 8, 256)
    rng = np.random.default_rng(3)
    tables = {
        "t0": rng.normal(size=(mt_rows, DIM)).astype(np.float32),
        "t1": rng.normal(size=(mt_rows, DIM)).astype(np.float32),
    }
    histories = {
        name: zipf_queries(mt_rows, mt_hist, MEAN_BAG, seed=i,
                           num_baskets=max(256, mt_hist // 32))
        for i, name in enumerate(tables)
    }
    S = max(s for s in SHARD_COUNTS)
    from repro.serve import ShardedEmbeddingServer

    server = ShardedEmbeddingServer(
        tables, histories, num_shards=S, mesh=mesh_for(S),
        q_block=Q_BLOCK, group_size=GROUP_SIZE, batch_size=SERVE_BATCH,
    )
    stream = zipf_queries(mt_rows, SERVE_BATCH * 2, MEAN_BAG, seed=11,
                          num_baskets=max(256, mt_hist // 32))
    names = list(tables)
    for i, q in enumerate(stream):
        server.submit(names[i % 2], q)
    server.flush()
    record["multi_table"] = server.report()
    rows_out.append({
        "name": "serving_multi_table",
        "us_per_call": f"{server.stats.wall_s * 1e6:.0f}",
        "derived": (
            f"tables=2;shards={S};"
            f"cells/shard/flush={server.stats.max_grid_cells_per_flush};"
            f"combine_bytes={server.stats.combine_bytes}"
        ),
    })

    # whole-record writer: keep only the replan/scheduler benches'
    # foreign sections, so serving keys this version stopped emitting
    # don't linger.  CI smoke sizes write to a temp path — never the
    # committed record.
    update_bench_json(
        bench_json_path(JSON_PATH, full_scale=FULL_SCALE),
        record, preserve=["replan", "scheduler", "chaos", "tiers"],
    )

    rows_out.append({
        "name": "serving_grid_target",
        "us_per_call": "",
        "derived": (
            f"worst_cells/shard={worst}<=base={base_cells}:"
            f"{record['meets_grid_target']};json=BENCH_serving.json"
        ),
    })
    return rows_out


def main():
    emit(run())


if __name__ == "__main__":
    main()
