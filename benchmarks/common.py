"""Shared benchmark scaffolding: workload prep + CSV emission."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import build_cooccurrence
from repro.data import make_workload

# scaled-down table sizes keep the suite < ~5 min on one CPU core while
# preserving the power-law/co-occurrence statistics (scale=1.0 reproduces
# the Table I sizes exactly)
DEFAULT_SCALE = 0.02
DEFAULT_QUERIES = 768
HISTORY_FRACTION = 1 / 3  # offline co-occurrence history vs online eval split


def prepared_workload(name: str, *, scale: float = DEFAULT_SCALE,
                      num_queries: int = DEFAULT_QUERIES, seed: int = 0):
    """Returns (num_rows, history_queries, eval_queries, graph)."""
    _, rows, qs = make_workload(name, num_queries=num_queries, scale=scale, seed=seed)
    split = int(len(qs) * HISTORY_FRACTION)
    hist, ev = qs[:split], qs[split:]
    graph = build_cooccurrence(hist, rows)
    return rows, hist, ev, graph


def emit(rows: List[Dict]) -> None:
    """Prints ``name,us_per_call,derived`` CSV rows (benchmark contract)."""
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")


def time_call(fn: Callable, *args, repeats: int = 3, **kw) -> float:
    """Median wall time of fn(*args) in microseconds."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))
