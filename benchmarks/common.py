"""Shared benchmark scaffolding: workload prep + CSV emission."""

from __future__ import annotations

import json
import os
import sys
import tempfile
import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import build_cooccurrence
from repro.data import make_workload

# scaled-down table sizes keep the suite < ~5 min on one CPU core while
# preserving the power-law/co-occurrence statistics (scale=1.0 reproduces
# the Table I sizes exactly)
DEFAULT_SCALE = 0.02
DEFAULT_QUERIES = 768
HISTORY_FRACTION = 1 / 3  # offline co-occurrence history vs online eval split


def prepared_workload(name: str, *, scale: float = DEFAULT_SCALE,
                      num_queries: int = DEFAULT_QUERIES, seed: int = 0):
    """Returns (num_rows, history_queries, eval_queries, graph)."""
    _, rows, qs = make_workload(name, num_queries=num_queries, scale=scale, seed=seed)
    split = int(len(qs) * HISTORY_FRACTION)
    hist, ev = qs[:split], qs[split:]
    graph = build_cooccurrence(hist, rows)
    return rows, hist, ev, graph


def mesh_for(num_shards: int):
    """A ``(1, num_shards)`` (data, model) mesh when the host presents
    enough devices (CI forces them via XLA_FLAGS), else ``None`` →
    single-device emulation.  Shared by every sharded-serving bench so
    shard_map-vs-emulated selection can never diverge between them."""
    import jax

    if num_shards > 1 and len(jax.devices()) >= num_shards:
        return jax.make_mesh((1, num_shards), ("data", "model"))
    return None


def emit(rows: List[Dict]) -> None:
    """Prints ``name,us_per_call,derived`` CSV rows (benchmark contract)."""
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")


#: RECROSS_* env vars that do NOT change the measured workload
_NON_WORKLOAD_KNOBS = {"RECROSS_SMOKE_BENCH_DIR"}


def bench_is_full_scale() -> bool:
    """Whether this run measures the committed-record configuration.

    The committed ``BENCH_*.json`` are full-DEFAULT-config records, so
    ANY workload-shaping ``RECROSS_*`` override (sizes, batch, shard
    counts, skew, mean bag, …) makes the run non-canonical — not just
    the row/history counts.  Only knobs that don't change the workload
    (the smoke output dir itself) are exempt.
    """
    return not any(
        k.startswith("RECROSS_") and k not in _NON_WORKLOAD_KNOBS
        for k in os.environ
    )


def bench_json_path(path: str, *, full_scale: bool) -> str:
    """Routes smoke-size runs away from the committed bench records.

    Committed ``BENCH_*.json`` files are FULL-SCALE measurements — the
    perf trajectory future PRs are held against.  CI (and local smoke
    runs) shrink the workload via the ``RECROSS_*`` env knobs; letting
    those runs write the committed path would silently replace real
    records with toy numbers.  Non-full-scale runs therefore write to
    ``RECROSS_SMOKE_BENCH_DIR`` (default: a ``recross-bench-smoke``
    directory under the system temp dir), which CI uploads as its own
    artifact; a CI diff-guard additionally fails the build if any
    committed ``BENCH_*.json`` changed during the smoke runs.
    """
    if full_scale:
        return path
    out_dir = os.environ.get("RECROSS_SMOKE_BENCH_DIR") or os.path.join(
        tempfile.gettempdir(), "recross-bench-smoke"
    )
    os.makedirs(out_dir, exist_ok=True)
    out = os.path.join(out_dir, os.path.basename(path))
    print(
        f"# smoke-size bench: writing {os.path.basename(path)} to {out} "
        "(committed record untouched)",
        file=sys.stderr,
    )
    return out


def update_bench_json(
    path: str, updates: Dict, preserve: List[str] | None = None
) -> None:
    """Read-modify-write of a bench JSON shared by several benches.

    BENCH_serving.json is written by both the serving bench (its whole
    record) and the replan bench (the ``"replan"`` section); a rerun of
    one must never drop the other's recorded section.

    With ``preserve=None`` every prior top-level key survives unless
    ``updates`` replaces it (section writers).  A whole-record writer
    passes the explicit list of *foreign* keys to keep — everything
    else it owns, so keys it stopped emitting are dropped instead of
    lingering as stale data from an older code version.  An unreadable
    or missing prior file degrades to a plain write.
    """
    prior: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prior = json.load(f)
        except (OSError, ValueError):
            prior = {}
    if preserve is not None:
        prior = {k: v for k, v in prior.items() if k in preserve}
    prior.update(updates)
    with open(path, "w") as f:
        json.dump(prior, f, indent=1, default=str)


def time_call(fn: Callable, *args, repeats: int = 3, **kw) -> float:
    """Median wall time of fn(*args) in microseconds."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))
