"""Benchmark runner: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig8]``
Prints ``name,us_per_call,derived`` CSV (the harness contract), one row
per measured quantity, and a paper-claim check summary at the end.
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    beyond_multiread,
    chaos_bench,
    fig456_distributions,
    fig8_speedup,
    fig9_activations,
    fig10_duplication,
    fig11_cpu_gpu,
    kernel_bench,
    load_bench,
    pipeline_bench,
    replan_bench,
    scheduler_bench,
    serving_bench,
    tier_bench,
)
from benchmarks.common import emit

MODULES = {
    "fig8": fig8_speedup,
    "fig9": fig9_activations,
    "fig10": fig10_duplication,
    "fig11": fig11_cpu_gpu,
    "fig456": fig456_distributions,
    "kernels": kernel_bench,
    "multiread": beyond_multiread,
    "pipeline": pipeline_bench,
    "serving": serving_bench,
    # after serving: all four write BENCH_serving.json (each preserves
    # the others' sections, but keep the full-run order deterministic)
    "replan": replan_bench,
    "scheduler": scheduler_bench,
    "chaos": chaos_bench,
    "tiers": tier_bench,
    "load": load_bench,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(MODULES))
    args = ap.parse_args()

    names = [args.only] if args.only else list(MODULES)
    print("name,us_per_call,derived")
    all_rows = []
    for name in names:
        t0 = time.time()
        rows = MODULES[name].run()
        emit(rows)
        all_rows += rows
        print(f"# {name}: {len(rows)} rows in {time.time() - t0:.1f}s", file=sys.stderr)

    _claims_summary(all_rows)


def _claims_summary(rows) -> None:
    """Compares measured ratios against the paper's headline claims."""
    import re

    sp_naive = [float(r["derived"][:-1]) for r in rows
                if r["name"].startswith("fig8_speedup_vs_naive")]
    sp_nmars = [float(r["derived"][:-1]) for r in rows
                if r["name"].startswith("fig8_speedup_vs_nmars")]
    ee_naive = [float(r["derived"][:-1]) for r in rows
                if r["name"].startswith("fig8_energy_eff_vs_naive")]
    act = []
    for r in rows:
        if r["name"].startswith("fig9"):
            m = re.search(r"naive=\d+\(([\d.]+)x\)", r["derived"])
            if m:
                act.append(float(m.group(1)))
    if not sp_naive:
        return
    import numpy as np

    print("# --- paper-claim check (paper value in brackets) ---", file=sys.stderr)
    print(f"# speedup vs naive: {min(sp_naive):.2f}-{max(sp_naive):.2f}x "
          f"[paper 2.58-6.85x]", file=sys.stderr)
    print(f"# speedup vs nmars: {min(sp_nmars):.2f}-{max(sp_nmars):.2f}x "
          f"[paper 2.60-5.48x, avg 3.97x] avg={np.mean(sp_nmars):.2f}x", file=sys.stderr)
    print(f"# energy eff vs naive: {min(ee_naive):.2f}-{max(ee_naive):.2f}x "
          f"[paper 3.60-12.55x]", file=sys.stderr)
    if act:
        print(f"# activation reduction vs naive: up to {max(act):.2f}x "
              f"[paper up to 8.79x]", file=sys.stderr)


if __name__ == "__main__":
    main()
