"""Paper Fig. 10: access-aware allocation under area budgets
(Dup-0/5/10/20%): execution time and energy vs the no-duplication
simplified ReCross.  Improvement converges as duplication grows."""

from __future__ import annotations

from benchmarks.common import emit, prepared_workload
from repro.core import baselines
from repro.data.synthetic import WORKLOADS

BUDGETS = [0.0, 0.05, 0.10, 0.20]


def run() -> list:
    rows = []
    for wl in ["software", "automotive"]:
        num_rows, hist, ev, graph = prepared_workload(wl)
        ev_b = ev[:256]
        base = None
        for budget in BUDGETS:
            _, rep = baselines.recross_pipeline(
                graph, ev_b, batch_size=256, area_budget_ratio=budget
            )
            if base is None:
                base = rep
            rows.append({
                "name": f"fig10_dup{int(budget * 100)}pct[{wl}]",
                "us_per_call": rep.completion_time_ns / 1e3,
                "derived": (
                    f"speedup_vs_dup0={rep.speedup_over(base):.2f}x;"
                    f"energy_eff_vs_dup0={rep.energy_efficiency_over(base):.2f}x;"
                    f"stall_ns={rep.stall_ns:.0f}"
                ),
            })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
