"""Offline-pipeline throughput benchmark → BENCH_pipeline.json.

Measures the vectorized offline pipeline (build_cooccurrence →
grouping → replication/layout → query compile → simulate_batch) at
production scale — a 100k-query / 100k-row synthetic history by default —
and the retained ``_reference_*`` loop implementations on a subsample
(the loops cannot hold the full history: the reference bitmap path alone
would materialize a multi-GiB dense tensor).  Speedups are reported as
per-query throughput ratios measured on the same workload distribution,
plus a direct same-size comparison on the subsample.

Every stage is timed best-of-3 and the min/median/max spread is recorded
in BENCH_pipeline.json (``spread`` per stage), so the ROADMAP timing
targets (e.g. grouping < 0.8s) are judged against the spread instead of
a single shot of container noise.

Also records interpret-mode wall times for the flat vs query-blocked
Pallas kernel (regression tracking only — interpret mode is not TPU
performance; the grid-cell count is the hardware-independent signal).

The ``scale`` section times the full plan build (blocked co-occurrence →
epoch-blocked grouping → replication → layout → shard placement) at 1M
and 10M rows on a :func:`repro.data.scale_trace` template workload,
recording per-stage wall time and rows/s.  The acceptance gates: the 1M
epoch-blocked grouping rate must beat a 5x extrapolation of the 100k
batch-heap rate, and the 10M build must complete under the recorded
wall budget with O(block) peak intermediates (``block_pairs`` caps the
enumerated pair buffer; the CSR output itself is necessarily O(edges)).

Env knobs: ``RECROSS_PIPELINE_QUERIES`` / ``RECROSS_PIPELINE_ROWS``
(defaults 100_000 / 100_000), ``RECROSS_PIPELINE_REF_SAMPLE`` (500),
``RECROSS_SCALE_ROWS`` (comma list, default "1000000,10000000"),
``RECROSS_SCALE_EPOCH`` (64), ``RECROSS_SCALE_BLOCK_PAIRS`` (2**22),
``RECROSS_SCALE_EXACT_MAX`` (largest size that also runs the exact
grouping for the quality ratio; default 2_000_000).  Set
``RECROSS_PLAN_PROGRESS=1`` for live per-stage progress lines.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import bench_is_full_scale, bench_json_path, emit
from repro.core import (
    baselines,
    build_cooccurrence,
    block_compiled_queries,
    compile_activations,
    compile_queries,
    correlation_aware_grouping,
    build_layout,
    plan_replication,
    query_tile_bitmaps,
    simulate_batch,
)
from repro.core.cooccurrence import _reference_build_cooccurrence
from repro.core.grouping import grouping_quality
from repro.core.mapping import _reference_query_tile_bitmaps
from repro.core.simulator import _reference_simulate_batch
from repro.data import scale_trace, zipf_queries
from repro.dist import plan_shards
from repro.kernels import crossbar_reduce, crossbar_reduce_blocked

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_pipeline.json")

NUM_QUERIES = int(os.environ.get("RECROSS_PIPELINE_QUERIES", 100_000))
NUM_ROWS = int(os.environ.get("RECROSS_PIPELINE_ROWS", 100_000))
REF_SAMPLE = int(os.environ.get("RECROSS_PIPELINE_REF_SAMPLE", 500))
# paper Table I "Avg. Lat": bags of 41-96 lookups; software = 41.32
MEAN_BAG = float(os.environ.get("RECROSS_PIPELINE_MEAN_BAG", 41.32))
GROUP_SIZE = 64
BATCH_SIZE = 256

# ---- 1M/10M plan-build scale section (DESIGN.md §11) -------------------
SCALE_ROWS = tuple(
    int(s)
    for s in os.environ.get("RECROSS_SCALE_ROWS", "1000000,10000000").split(",")
    if s.strip()
)
SCALE_EPOCH = int(os.environ.get("RECROSS_SCALE_EPOCH", 64))
SCALE_BLOCK_PAIRS = int(os.environ.get("RECROSS_SCALE_BLOCK_PAIRS", 1 << 22))
#: largest scale size that ALSO runs the exact batch-heap grouping so the
#: hybrid's quality ratio can be pinned (the exact pass is the expensive
#: thing the epoch path exists to avoid — don't run it at 10M)
SCALE_EXACT_MAX = int(os.environ.get("RECROSS_SCALE_EXACT_MAX", 2_000_000))
SCALE_MEAN_BAG = 32.0
SCALE_SHARDS = 4


def _t(fn, *args, repeats: int = 3, **kw):
    """({min, median, max, repeats} wall times, last result).

    Best-of-N (the ``min``) is what speedups are computed from — it is
    the least noise-contaminated estimate on a shared container — but
    the full spread is recorded so a single lucky/unlucky shot can be
    told apart from a real regression (container timings swing 2-4x
    under load; see ROADMAP on the grouping target).
    """
    times = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    ts = sorted(times)
    stats = {
        "min": ts[0],
        "median": ts[len(ts) // 2],
        "max": ts[-1],
        "repeats": repeats,
    }
    return stats, out


def _scale_build(num_rows: int, extrap_rows_per_s: float) -> dict:
    """Times one full plan build at ``num_rows`` (single shot — these
    are wall-budget measurements, not microbenchmarks).

    Returns the per-size record: wall + rows/s per stage, total wall
    budget, and — when the exact grouping is affordable — the hybrid's
    intra-group edge-mass quality ratio against it.
    """
    num_queries = max(num_rows // 10, 1_000)
    rec: dict = {
        "num_rows": num_rows,
        "num_queries": num_queries,
        "mean_bag": SCALE_MEAN_BAG,
        "epoch": SCALE_EPOCH,
        "block_pairs": SCALE_BLOCK_PAIRS,
    }

    def stage(name, fn, *args, denom=num_rows, **kw):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        dt = time.perf_counter() - t0
        rec[name] = {"seconds": dt, "rows_per_s": denom / max(dt, 1e-12)}
        return out, dt

    qs, _ = stage("trace", scale_trace, num_rows, num_queries,
                  SCALE_MEAN_BAG, seed=3, denom=num_queries)
    # blocked build: the enumerated pair intermediate stays O(block_pairs)
    graph, _ = stage("build_cooccurrence", build_cooccurrence, qs, num_rows,
                     block_pairs=SCALE_BLOCK_PAIRS)
    rec["build_cooccurrence"]["edges"] = graph.edge_count()
    grouping, t_grp = stage("grouping", correlation_aware_grouping, graph,
                            GROUP_SIZE, epoch=SCALE_EPOCH)
    rate = num_rows / max(t_grp, 1e-12)
    rec["grouping"]["num_groups"] = grouping.num_groups
    rec["grouping"]["speedup_vs_batch_heap_extrapolation"] = (
        rate / max(extrap_rows_per_s, 1e-12)
    )
    if num_rows <= SCALE_EXACT_MAX:
        exact, t_exact = stage("grouping_exact", correlation_aware_grouping,
                               graph, GROUP_SIZE)
        q_hyb = grouping_quality(graph, grouping)
        q_exact = grouping_quality(graph, exact)
        rec["grouping"]["quality_ratio_vs_exact"] = q_hyb / max(q_exact, 1)
        rec["grouping"]["exact_rows_per_s"] = num_rows / max(t_exact, 1e-12)
    plan, _ = stage("replication", plan_replication, grouping, graph.freq,
                    BATCH_SIZE)
    layout, _ = stage("layout", build_layout, grouping, plan, 8)
    gfreq = grouping.group_freq(graph.freq)
    _, _ = stage("plan_shards", plan_shards, [layout], [plan], SCALE_SHARDS,
                 group_freqs=[gfreq], eq1_batch=BATCH_SIZE)
    rec["total_wall_s"] = sum(
        v["seconds"] for v in rec.values() if isinstance(v, dict)
    )
    rec["grouping_rows_per_s"] = rate
    return rec


def run() -> list:
    rows_out = []
    record: dict = {
        "config": {
            "num_queries": NUM_QUERIES,
            "num_rows": NUM_ROWS,
            "mean_bag": MEAN_BAG,
            "group_size": GROUP_SIZE,
            "ref_sample_queries": REF_SAMPLE,
        },
    }

    qs = zipf_queries(NUM_ROWS, NUM_QUERIES, MEAN_BAG, seed=0,
                      num_baskets=max(256, NUM_QUERIES // 32))
    sample = qs[:REF_SAMPLE]

    # ---- build_cooccurrence: full history vectorized vs sampled loop ----
    st_cooc, graph = _t(build_cooccurrence, qs, NUM_ROWS)
    st_cooc_ref, _ = _t(_reference_build_cooccurrence, sample, NUM_ROWS)
    t_cooc, t_cooc_ref = st_cooc["min"], st_cooc_ref["min"]
    sp_cooc = (t_cooc_ref / REF_SAMPLE) / (t_cooc / NUM_QUERIES)
    record["build_cooccurrence"] = {
        "vectorized_s_full": t_cooc,
        "spread": st_cooc,
        "queries_per_s": NUM_QUERIES / max(t_cooc, 1e-12),
        "reference_s_sample": t_cooc_ref,
        "throughput_speedup": sp_cooc,
        "edges": graph.edge_count(),
    }

    # ---- grouping / replication / layout (vectorized-consumer timing) ----
    # best-of-3 with the recorded min/median/max spread, so the < 0.8s
    # grouping target in ROADMAP is judged against the spread rather
    # than a single shot of container noise.  (The PR-1 recorded 1.95s
    # grouping baseline was single-shot; cross-PR comparisons of this
    # stage carry that protocol delta on top of the algorithmic change.)
    st_group, grouping = _t(correlation_aware_grouping, graph, GROUP_SIZE)
    st_plan, plan = _t(plan_replication, grouping, graph.freq, BATCH_SIZE)
    t_group, t_plan = st_group["min"], st_plan["min"]
    layout = build_layout(grouping, plan, dim=128)
    record["grouping"] = {
        "seconds": t_group,
        "spread": st_group,
        "rows_per_s": NUM_ROWS / max(t_group, 1e-12),
        "num_groups": grouping.num_groups,
    }
    record["replication"] = {
        "seconds": t_plan,
        "spread": st_plan,
        "rows_per_s": NUM_ROWS / max(t_plan, 1e-12),
        "num_tiles": layout.num_tiles,
    }

    # ---- epoch-blocked grouping vs the exact batch-heap at 100k ---------
    # same graph, same group size: pins the hybrid's speed AND its
    # intra-group edge-mass quality ratio on a dense history (DESIGN.md
    # §11 — the scale section re-pins quality on the 1M template trace)
    st_group_ep, grouping_ep = _t(
        correlation_aware_grouping, graph, GROUP_SIZE, epoch=SCALE_EPOCH
    )
    t_group_ep = st_group_ep["min"]
    record["grouping_epoch"] = {
        "epoch": SCALE_EPOCH,
        "seconds": t_group_ep,
        "spread": st_group_ep,
        "rows_per_s": NUM_ROWS / max(t_group_ep, 1e-12),
        "speedup_vs_exact": t_group / max(t_group_ep, 1e-12),
        "quality_ratio_vs_exact": (
            grouping_quality(graph, grouping_ep)
            / max(grouping_quality(graph, grouping), 1)
        ),
    }

    # ---- query compile: full history sparse + same-size dense vs loop ----
    st_acts, acts = _t(compile_activations, layout, qs)
    st_bm_vec, _ = _t(query_tile_bitmaps, layout, sample)
    st_bm_ref, _ = _t(_reference_query_tile_bitmaps, layout, sample)
    t_acts, t_bm_vec, t_bm_ref = st_acts["min"], st_bm_vec["min"], st_bm_ref["min"]
    sp_bm_rate = (t_bm_ref / REF_SAMPLE) / (t_acts / NUM_QUERIES)
    record["query_tile_bitmaps"] = {
        "vectorized_sparse_s_full": t_acts,
        "spread": st_acts,
        "queries_per_s": NUM_QUERIES / max(t_acts, 1e-12),
        "activations_full": acts.num_activations,
        "vectorized_dense_s_sample": t_bm_vec,
        "reference_dense_s_sample": t_bm_ref,
        "same_size_speedup": t_bm_ref / max(t_bm_vec, 1e-12),
        "throughput_speedup": sp_bm_rate,
    }

    # ---- simulate_batch: full history vectorized vs sampled loop --------
    st_sim, rep = _t(simulate_batch, layout, qs)
    st_sim_ref, _ = _t(_reference_simulate_batch, layout, sample)
    t_sim, t_sim_ref = st_sim["min"], st_sim_ref["min"]
    sp_sim = (t_sim_ref / REF_SAMPLE) / (t_sim / NUM_QUERIES)
    record["simulate_batch"] = {
        "vectorized_s_full": t_sim,
        "spread": st_sim,
        "queries_per_s": NUM_QUERIES / max(t_sim, 1e-12),
        "reference_s_sample": t_sim_ref,
        "throughput_speedup": sp_sim,
        "activations": rep.activations,
        "read_fraction": rep.read_fraction,
    }

    total_vec = t_cooc + t_group + t_plan + t_acts + t_sim
    record["pipeline_total_vectorized_s"] = total_vec
    record["min_stage_throughput_speedup"] = min(sp_cooc, sp_bm_rate, sp_sim)
    # acceptance metric: the three rewritten stages TOGETHER, per-query
    vec_rate = (t_cooc + t_acts + t_sim) / NUM_QUERIES
    ref_rate = (t_cooc_ref + t_bm_ref + t_sim_ref) / REF_SAMPLE
    record["aggregate_stage_speedup"] = ref_rate / vec_rate
    record["meets_20x_target"] = bool(ref_rate / vec_rate >= 20.0)

    # ---- kernel interpret-mode wall times (flat vs query-blocked) -------
    dim = 128
    kbatch = 32
    table = np.random.default_rng(0).normal(size=(NUM_ROWS, dim)).astype(np.float32)
    image = jnp.asarray(
        layout.build_image(table).reshape(layout.num_tiles, layout.tile_rows, dim)
    )
    cq = compile_queries(layout, qs[:kbatch])
    kern = {}
    out_flat = crossbar_reduce(image, cq.tile_ids, cq.bitmaps)  # warm
    t0 = time.perf_counter()
    crossbar_reduce(image, cq.tile_ids, cq.bitmaps).block_until_ready()
    kern["flat_us"] = (time.perf_counter() - t0) * 1e6
    kern["flat_grid_cells"] = int(cq.tile_ids.shape[0] * cq.tile_ids.shape[1])
    for qb in (4, 8):
        cq_b = compile_queries(layout, qs[:kbatch], replica_block=qb)
        bq = block_compiled_queries(cq_b, qb)
        out_blk = crossbar_reduce_blocked(image, bq.tile_ids, bq.bitmaps)  # warm
        np.testing.assert_allclose(
            np.asarray(out_blk[: bq.batch]), np.asarray(out_flat), atol=1e-4
        )
        t0 = time.perf_counter()
        crossbar_reduce_blocked(image, bq.tile_ids, bq.bitmaps).block_until_ready()
        kern[f"blocked_q{qb}_us"] = (time.perf_counter() - t0) * 1e6
        kern[f"blocked_q{qb}_grid_cells"] = int(bq.num_blocks * bq.max_tiles)
    record["kernel_interpret"] = kern

    # ---- plan build at 1M/10M rows: the blocked + epoch-blocked path ----
    # the 5x grouping gate is judged against a straight extrapolation of
    # THIS run's 100k exact batch-heap rate, so both sides carry the same
    # container noise
    extrap = NUM_ROWS / max(t_group, 1e-12)
    scale_rec: dict = {
        "batch_heap_extrapolation_rows_per_s": extrap,
        "sizes": {},
    }
    for n in SCALE_ROWS:
        scale_rec["sizes"][str(n)] = _scale_build(n, extrap)
    sizes = scale_rec["sizes"].values()
    scale_rec["meets_5x_grouping_target"] = bool(sizes) and all(
        s["grouping"]["speedup_vs_batch_heap_extrapolation"] >= 5.0
        for s in sizes
    )
    scale_rec["quality_floor"] = 0.99
    scale_rec["meets_quality_floor"] = all(
        s["grouping"].get("quality_ratio_vs_exact", 1.0) >= 0.99
        for s in sizes
    )
    record["scale"] = scale_rec

    # CI smoke configs write to a temp path — never the committed record
    with open(bench_json_path(JSON_PATH, full_scale=bench_is_full_scale()), "w") as f:
        json.dump(record, f, indent=1)

    rows_out.append({
        "name": "pipeline_build_cooccurrence",
        "us_per_call": f"{t_cooc * 1e6:.0f}",
        "derived": f"speedup_vs_ref={sp_cooc:.1f}x",
    })
    rows_out.append({
        "name": "pipeline_query_compile",
        "us_per_call": f"{t_acts * 1e6:.0f}",
        "derived": f"speedup_vs_ref={sp_bm_rate:.1f}x",
    })
    rows_out.append({
        "name": "pipeline_simulate_batch",
        "us_per_call": f"{t_sim * 1e6:.0f}",
        "derived": f"speedup_vs_ref={sp_sim:.1f}x",
    })
    rows_out.append({
        "name": "pipeline_aggregate_speedup",
        "us_per_call": "",
        "derived": (
            f"{record['aggregate_stage_speedup']:.1f}x(target>=20x);"
            "json=BENCH_pipeline.json"
        ),
    })
    rows_out.append({
        "name": "kernel_blocked_grid_shrink",
        "us_per_call": "",
        "derived": (
            f"flat={kern['flat_grid_cells']};q4={kern['blocked_q4_grid_cells']};"
            f"q8={kern['blocked_q8_grid_cells']}"
        ),
    })
    rows_out.append({
        "name": "grouping_epoch_100k",
        "us_per_call": f"{t_group_ep * 1e6:.0f}",
        "derived": (
            f"speedup={record['grouping_epoch']['speedup_vs_exact']:.2f}x;"
            f"quality={record['grouping_epoch']['quality_ratio_vs_exact']:.4f}"
        ),
    })
    for n, s in scale_rec["sizes"].items():
        g = s["grouping"]
        rows_out.append({
            "name": f"plan_build_scale_{n}",
            "us_per_call": f"{s['total_wall_s'] * 1e6:.0f}",
            "derived": (
                f"grouping={g['rows_per_s']:.0f}rows/s"
                f"({g['speedup_vs_batch_heap_extrapolation']:.1f}x"
                f" vs extrapolated batch-heap);"
                f"cooc={s['build_cooccurrence']['seconds']:.2f}s;"
                f"total={s['total_wall_s']:.1f}s"
            ),
        })
    return rows_out


def main():
    emit(run())


if __name__ == "__main__":
    main()
