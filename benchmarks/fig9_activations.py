"""Paper Fig. 9: crossbar activations — ReCross vs naive and
frequency-based mapping.  Paper claims up to 8.79× (naive) / 5.27×
(frequency-based) fewer activations."""

from __future__ import annotations

from benchmarks.common import emit, prepared_workload
from repro.core import baselines
from repro.data.synthetic import WORKLOADS


def run() -> list:
    rows = []
    for wl in WORKLOADS:
        num_rows, hist, ev, graph = prepared_workload(wl)
        ev_b = ev[:256]
        _, rx = baselines.recross_pipeline(graph, ev_b, batch_size=256)
        _, nv = baselines.naive_pipeline(num_rows, ev_b)
        _, fr = baselines.frequency_pipeline(graph, ev_b)
        rows.append({
            "name": f"fig9_activations[{wl}]",
            "us_per_call": rx.activations,
            "derived": (
                f"recross={rx.activations};naive={nv.activations}"
                f"({nv.activations / max(rx.activations,1):.2f}x);"
                f"freq={fr.activations}({fr.activations / max(rx.activations,1):.2f}x)"
            ),
        })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
