"""Kernel micro-benchmarks: crossbar_reduce (ReCross datapath) vs
embedding_bag (naive datapath) vs dense oracle, plus the dynamic-switch
MAC-FLOP savings.

Wall-times on this CPU container reflect interpret-mode execution (the
kernel body run in Python), NOT TPU performance — they are emitted for
regression tracking only; the FLOP/byte derived column is the
hardware-independent signal."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, prepared_workload, time_call
from repro.core import baselines, compile_queries
from repro.core.reduction import reduce_dense_oracle, reduce_via_layout, reduction_flops
from repro.kernels import crossbar_reduce


def run() -> list:
    rows = []
    num_rows, hist, ev, graph = prepared_workload("software")
    dim = 128
    batch = 32
    layout, _ = baselines.recross_pipeline(graph, ev[:256], dim=dim, batch_size=256)
    rng = np.random.default_rng(0)
    table = rng.normal(size=(num_rows, dim)).astype(np.float32)
    image = jnp.asarray(
        layout.build_image(table).reshape(layout.num_tiles, layout.tile_rows, dim)
    )
    cq = compile_queries(layout, ev[:batch])
    flat = image.reshape(-1, dim)

    # jit/warm the three paths
    k_fn = jax.jit(crossbar_reduce)
    l_fn = jax.jit(
        lambda img, t, b: reduce_via_layout(img, t, b, tile_rows=layout.tile_rows)
    )
    out_k = np.asarray(k_fn(image, cq.tile_ids, cq.bitmaps))
    out_l = np.asarray(l_fn(flat, cq.tile_ids, cq.bitmaps))
    ref = np.asarray(reduce_dense_oracle(jnp.asarray(table), ev[:batch]))
    assert np.allclose(out_k, ref, atol=1e-3) and np.allclose(out_l, ref, atol=1e-3)

    t_kernel = time_call(lambda: k_fn(image, cq.tile_ids, cq.bitmaps).block_until_ready())
    t_layout = time_call(lambda: l_fn(flat, cq.tile_ids, cq.bitmaps).block_until_ready())

    bm = np.asarray(cq.bitmaps)
    fl_switch = reduction_flops(bm, dim, dynamic_switch=True)
    fl_static = reduction_flops(bm, dim, dynamic_switch=False)
    rows.append({
        "name": "kernel_crossbar_reduce_interpret",
        "us_per_call": f"{t_kernel:.0f}",
        "derived": f"batch={batch};tiles={layout.num_tiles}",
    })
    rows.append({
        "name": "kernel_layout_jnp_reference",
        "us_per_call": f"{t_layout:.0f}",
        "derived": "pure-jnp tiled MAC",
    })
    rows.append({
        "name": "kernel_dynamic_switch_flop_saving",
        "us_per_call": "",
        "derived": f"mac_flops={fl_static};switched={fl_switch};"
                   f"saving={(1 - fl_switch / max(fl_static, 1)) * 100:.1f}%",
    })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
