"""Paper Figs. 2/4/5/6: workload characterization.

* Fig 2/4 — power-law access + co-occurrence distributions (alpha, max
  access count vs batch size).
* Fig 5 — copy distribution before/after log scaling (evenness).
* Fig 6 — fraction of single-embedding crossbar activations (the dynamic
  switch's opportunity: paper reports 25.9% software / 53.5% automotive
  averages across group sizes)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, prepared_workload
from repro.core import (
    baselines,
    correlation_aware_grouping,
    log_scaled_copies,
    mode_statistics,
    plan_replication,
    query_tile_bitmaps,
)
from repro.core.replication import linear_copies


def run() -> list:
    rows = []
    for wl in ["software", "automotive"]:
        num_rows, hist, ev, graph = prepared_workload(wl)
        ev_b = ev[:256]
        rows.append({
            "name": f"fig2_powerlaw_alpha[{wl}]",
            "us_per_call": "",
            "derived": f"alpha={graph.powerlaw_alpha():.2f};"
                       f"max_corr={int(graph.correlation_counts().max())}",
        })
        grouping = correlation_aware_grouping(graph, 64)
        gfreq = grouping.group_freq(graph.freq)
        rows.append({
            "name": f"fig4_group_access[{wl}]",
            "us_per_call": "",
            "derived": f"max_access={int(gfreq.max())};batch=256;"
                       f"gini={_gini(gfreq):.3f}",
        })
        lin = linear_copies(gfreq, 256)
        log = log_scaled_copies(gfreq, 256)
        rows.append({
            "name": f"fig5_copies_log_scaling[{wl}]",
            "us_per_call": "",
            "derived": (
                f"linear:max={int(lin.max())},replicated_frac={float((lin > 1).mean()):.2f};"
                f"log:max={int(log.max())},replicated_frac={float((log > 1).mean()):.2f}"
            ),
        })
        for group_size in (16, 32, 64):
            g = correlation_aware_grouping(graph, group_size)
            plan = plan_replication(g, graph.freq, 256, scheme="none")
            from repro.core.mapping import build_layout
            layout = build_layout(g, plan, 64)
            _, counts = query_tile_bitmaps(layout, ev_b)
            stats = mode_statistics(counts)
            rows.append({
                "name": f"fig6_single_access_frac[{wl},g{group_size}]",
                "us_per_call": "",
                "derived": f"read_frac={stats['read_fraction']:.3f};"
                           f"activations={stats['activations']}",
            })
    return rows


def _gini(x):
    x = np.sort(np.asarray(x, float))
    n = len(x)
    if n == 0 or x.sum() == 0:
        return 0.0
    return float((2 * np.arange(1, n + 1) - n - 1).dot(x) / (n * x.sum()))


def main():
    emit(run())


if __name__ == "__main__":
    main()
