"""Paper Fig. 11: energy efficiency of ReCross vs CPU-only and CPU-GPU.

Paper claims 363× (CPU) and 1144× (CPU-GPU) on average.  The CPU model
charges DRAM row fetches per lookup (MERCI-style accounting); the GPU
adds transfer overhead per batch — both reproduced as analytic baselines
of the same simulator."""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit, prepared_workload
from repro.core import baselines, simulate_cpu_baseline
from repro.core.energy import DEFAULT_RERAM
from repro.data.synthetic import WORKLOADS


def run() -> list:
    rows = []
    for wl in WORKLOADS:
        num_rows, hist, ev, graph = prepared_workload(wl)
        ev_b = ev[:256]
        _, rx = baselines.recross_pipeline(graph, ev_b, batch_size=256)
        cpu = simulate_cpu_baseline(ev_b)
        # CPU-GPU: embeddings still fetched from host DRAM then shipped over
        # PCIe — charge fetch + 3x transfer energy (dominant in MERCI data)
        gpu_energy = cpu.energy_pj * 3.0
        rows.append({
            "name": f"fig11_energy_vs_cpu[{wl}]",
            "us_per_call": cpu.completion_time_ns / 1e3,
            "derived": f"{cpu.energy_pj / rx.energy_pj:.0f}x",
        })
        rows.append({
            "name": f"fig11_energy_vs_cpu_gpu[{wl}]",
            "us_per_call": "",
            "derived": f"{gpu_energy / rx.energy_pj:.0f}x",
        })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
