"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs.  FULL configs are only
exercised via the dry-run (ShapeDtypeStruct, no allocation)."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import forward, init_lm
from repro.models.transformer import lm_loss
from repro.serve.decode import decode_step
from repro.serve.kvcache import init_cache
from repro.train.loop import init_train_state, make_train_step
from repro.train.optimizer import AdamW, make_schedule


def _batch(cfg, rng, b=2, s=16):
    if cfg.family == "audio":
        toks = jax.random.randint(rng, (b, cfg.num_codebooks, s + 1), 0, cfg.vocab_size)
        tokens, labels = toks[..., :-1], toks[..., 1:]
    else:
        toks = jax.random.randint(rng, (b, s + 1), 0, cfg.vocab_size)
        tokens, labels = toks[..., :-1], toks[..., 1:]
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "vlm":
        batch["enc"] = (
            jax.random.normal(rng, (b, cfg.num_image_tokens, cfg.d_model)) * 0.1
        ).astype(cfg.jnp_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    rng = jax.random.PRNGKey(0)
    params = init_lm(rng, cfg)
    batch = _batch(cfg, rng)
    logits, aux = forward(params, cfg, batch["tokens"], enc=batch.get("enc"))
    b, s = 2, 16
    if cfg.family == "audio":
        assert logits.shape == (b, cfg.num_codebooks, s, cfg.padded_vocab)
    else:
        assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    rng = jax.random.PRNGKey(1)
    params = init_lm(rng, cfg)
    opt = AdamW(schedule=make_schedule("cosine", 1e-3, 100))
    state = init_train_state(params, opt)
    step = make_train_step(cfg, opt, has_enc=(cfg.family == "vlm"))
    batch = _batch(cfg, rng)
    new_state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: loss NaN"
    assert float(metrics["grad_norm"]) > 0.0
    assert int(new_state.step) == 1
    # at least one param actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_state.params))
    )
    assert moved, f"{arch}: no parameter changed after one step"


@pytest.mark.parametrize("arch", ["minicpm-2b", "chatglm3-6b", "xlstm-125m",
                                  "zamba2-7b", "musicgen-medium"])
def test_smoke_loss_decreases(arch):
    """A few steps on a repeated batch must reduce the loss (learnability)."""
    cfg = get_config(arch, smoke=True)
    rng = jax.random.PRNGKey(2)
    params = init_lm(rng, cfg)
    opt = AdamW(schedule=lambda s: 3e-3, weight_decay=0.0)
    state = init_train_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt, has_enc=(cfg.family == "vlm")))
    batch = _batch(cfg, rng, b=4, s=32)
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], f"{arch}: loss did not decrease {losses}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_parity_with_prefill(arch):
    """Token-by-token decode must reproduce the full-sequence forward."""
    cfg = get_config(arch, smoke=True)
    if cfg.moe:
        # capacity dropping differs between prefill and decode batch sizes;
        # verify parity in the drop-free regime (inference-style capacity)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.num_experts))
        )
    rng = jax.random.PRNGKey(3)
    params = init_lm(rng, cfg)
    b, s = 2, 8
    batch = _batch(cfg, rng, b=b, s=s)
    tokens = batch["tokens"]
    enc = batch.get("enc")
    full, _ = forward(params, cfg, tokens, enc=enc)
    cache = init_cache(cfg, b, 16)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cfg, tokens[..., t : t + 1], cache, enc=enc)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=-2)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(dec, np.float32),
        atol=5e-4, rtol=5e-3,
    )


def test_dlrm_smoke_all_paths_agree():
    """DLRM forward identical through dense / layout / kernel embedding paths."""
    from repro.configs.dlrm_recross import smoke as dlrm_smoke
    from repro.core import baselines, build_cooccurrence
    from repro.core.reduction import compile_queries
    from repro.data import zipf_queries
    from repro.models.dlrm import build_images, dlrm_forward, init_dlrm

    cfg = dlrm_smoke()
    rng = jax.random.PRNGKey(0)
    params = init_dlrm(rng, cfg)
    B = 8
    qs = {f"t{t}": zipf_queries(cfg.rows_per_table, B + 64, 8.0, seed=t)
          for t in range(cfg.num_tables)}
    layouts = {}
    for t in range(cfg.num_tables):
        key = f"t{t}"
        graph = build_cooccurrence(qs[key][:64], cfg.rows_per_table)
        layouts[key], _ = baselines.recross_pipeline(
            graph, qs[key][64:], group_size=cfg.group_size, dim=cfg.embed_dim
        )
    images = build_images(params, cfg, layouts)

    dense_feats = jax.random.normal(rng, (B, cfg.dense_features))
    # dense path input: padded indices
    sparse_dense = {}
    sparse_tiles = {}
    for t in range(cfg.num_tables):
        key = f"t{t}"
        idx = np.full((B, cfg.max_bag), -1, np.int32)
        for i, q in enumerate(qs[key][64 : 64 + B]):
            take = q[: cfg.max_bag]
            idx[i, : len(take)] = take
        sparse_dense[key] = jnp.asarray(idx)
        cq = compile_queries(layouts[key], qs[key][64 : 64 + B])
        sparse_tiles[key] = (cq.tile_ids, cq.bitmaps)

    cfg_d = dataclasses.replace(cfg, embedding_path="dense")
    cfg_l = dataclasses.replace(cfg, embedding_path="layout")
    cfg_k = dataclasses.replace(cfg, embedding_path="kernel")
    out_d = dlrm_forward(params, cfg_d, dense_feats, sparse_dense)
    out_l = dlrm_forward(params, cfg_l, dense_feats, sparse_tiles, images=images)
    out_k = dlrm_forward(params, cfg_k, dense_feats, sparse_tiles, images=images)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_l), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_k), atol=1e-3, rtol=1e-3)
