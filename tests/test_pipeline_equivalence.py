"""Vectorized-pipeline equivalence: every vectorized offline stage must
reproduce its retained ``_reference_*`` loop implementation exactly, and
the query-blocked kernel must match the pure-jnp oracle in interpret mode
for q_block ∈ {1, 4, 8} on ragged/padded batches."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import (
    baselines,
    build_cooccurrence,
    block_compiled_queries,
    compile_activations,
    compile_queries,
    merge_graphs,
    query_tile_bitmaps,
    simulate_batch,
    simulate_cpu_baseline,
)
from repro.core.cooccurrence import _reference_build_cooccurrence
from repro.core.mapping import _reference_query_tile_bitmaps
from repro.core.reduction import reduce_dense_oracle
from repro.core.simulator import _reference_simulate_batch
from repro.data import zipf_queries
from repro.kernels import (
    crossbar_reduce_blocked,
    crossbar_reduce_blocked_ref,
)


def _trace(rows, n, seed, bag=6.0):
    return zipf_queries(rows, n, bag, seed=seed)


def _layout(rows, qs, group_size=16, dim=128, batch_size=64):
    g = build_cooccurrence(qs, rows)
    layout, _ = baselines.recross_pipeline(
        g, qs, group_size=group_size, dim=dim, batch_size=batch_size
    )
    return layout


def _assert_graphs_equal(a, b):
    assert a.num_rows == b.num_rows
    assert a.num_queries == b.num_queries
    np.testing.assert_array_equal(a.freq, b.freq)
    np.testing.assert_array_equal(a.indptr, b.indptr)
    np.testing.assert_array_equal(a.indices, b.indices)
    np.testing.assert_array_equal(a.weights, b.weights)


# ------------------------------------------------------ build_cooccurrence --


@given(st.integers(0, 1000), st.integers(16, 300), st.integers(8, 120))
@settings(max_examples=12, deadline=None)
def test_cooccurrence_matches_reference(seed, rows, n_queries):
    qs = _trace(rows, n_queries, seed)
    _assert_graphs_equal(
        build_cooccurrence(qs, rows), _reference_build_cooccurrence(qs, rows)
    )


def test_cooccurrence_matches_reference_with_pair_cap():
    qs = _trace(128, 60, seed=3, bag=12.0)
    for cap in (0, 1, 5, 50):
        _assert_graphs_equal(
            build_cooccurrence(qs, 128, max_pairs_per_query=cap),
            _reference_build_cooccurrence(qs, 128, max_pairs_per_query=cap),
        )


def test_cooccurrence_empty_and_degenerate_queries():
    cases = [[], [[]], [[], [3], [3, 3, 3]], [[0], [1], [2]]]
    for qs in cases:
        _assert_graphs_equal(
            build_cooccurrence(qs, 8), _reference_build_cooccurrence(qs, 8)
        )


def test_cooccurrence_rejects_out_of_range():
    with pytest.raises(ValueError):
        build_cooccurrence([[0, 9]], 8)
    with pytest.raises(ValueError):
        build_cooccurrence([[-1]], 8)


def test_merge_graphs_matches_joint_build():
    qa = _trace(96, 40, seed=1)
    qb = _trace(96, 30, seed=2)
    merged = merge_graphs(build_cooccurrence(qa, 96), build_cooccurrence(qb, 96))
    joint = build_cooccurrence(list(qa) + list(qb), 96)
    _assert_graphs_equal(merged, joint)


# ------------------------------------------------------------- grouping --


@given(st.integers(0, 800), st.integers(2, 300), st.integers(1, 80))
@settings(max_examples=10, deadline=None)
def test_grouping_batch_heap_matches_reference(seed, rows, group_size):
    """The array-backed batch-heap grouping must produce bit-identical
    groups to the retained dict + per-edge-push loop (same pick order,
    same tie-breaks) on arbitrary traces and group sizes."""
    from repro.core import correlation_aware_grouping
    from repro.core.grouping import _reference_correlation_aware_grouping

    qs = _trace(rows, 60, seed, bag=5.0)
    g = build_cooccurrence(qs, rows)
    a = correlation_aware_grouping(g, group_size)
    b = _reference_correlation_aware_grouping(g, group_size)
    assert a.groups == b.groups
    np.testing.assert_array_equal(a.group_of, b.group_of)
    np.testing.assert_array_equal(a.slot_of, b.slot_of)


# ------------------------------------------------------ query_tile_bitmaps --


@given(st.integers(0, 500), st.integers(32, 256))
@settings(max_examples=10, deadline=None)
def test_bitmaps_match_reference(seed, rows):
    hist = _trace(rows, 48, seed)
    ev = _trace(rows, 32, seed + 1)
    layout = _layout(rows, hist)
    for balance in (True, False):
        bm_v, ct_v = query_tile_bitmaps(layout, ev, balance_replicas=balance)
        bm_r, ct_r = _reference_query_tile_bitmaps(layout, ev, balance_replicas=balance)
        np.testing.assert_array_equal(bm_v, bm_r)
        np.testing.assert_array_equal(ct_v, ct_r)


def test_bitmaps_round_robin_state_is_batch_order():
    """The vectorized round robin must reproduce the loop's cross-query
    counter: with >1 copies, consecutive queries touching the same group
    land on different replicas."""
    rows = 64
    hist = [[0]] * 64
    g = build_cooccurrence(hist, rows)
    layout, _ = baselines.recross_pipeline(
        g, hist, group_size=16, dim=8, batch_size=64
    )
    ev = [[0], [0, 1], [0], [1]]
    bm_v, ct_v = query_tile_bitmaps(layout, ev)
    bm_r, ct_r = _reference_query_tile_bitmaps(layout, ev)
    np.testing.assert_array_equal(bm_v, bm_r)
    np.testing.assert_array_equal(ct_v, ct_r)


def test_activation_set_consistent_with_dense():
    rows = 128
    hist = _trace(rows, 48, seed=9)
    ev = _trace(rows, 24, seed=10)
    layout = _layout(rows, hist)
    acts = compile_activations(layout, ev)
    _, counts = query_tile_bitmaps(layout, ev)
    q, t = np.nonzero(counts)
    np.testing.assert_array_equal(acts.act_qid, q)
    np.testing.assert_array_equal(acts.act_tile, t)
    np.testing.assert_array_equal(acts.act_rows, counts[q, t])
    np.testing.assert_array_equal(
        acts.per_query_tiles(), (counts > 0).sum(axis=1)
    )


# ----------------------------------------------------------- simulate_batch --


@given(st.integers(0, 300))
@settings(max_examples=8, deadline=None)
def test_simulate_batch_matches_reference_bitexact(seed):
    rows = 192
    hist = _trace(rows, 48, seed, bag=5.0)
    ev = _trace(rows, 40, seed + 7, bag=5.0)
    layout = _layout(rows, hist)
    for dyn in (True, False):
        for bal in (True, False):
            v = simulate_batch(layout, ev, dynamic_switching=dyn, balance_replicas=bal)
            r = _reference_simulate_batch(
                layout, ev, dynamic_switching=dyn, balance_replicas=bal
            )
            assert v.activations == r.activations
            assert v.read_activations == r.read_activations
            assert v.mac_activations == r.mac_activations
            assert v.completion_time_ns == r.completion_time_ns
            assert v.energy_pj == r.energy_pj
            assert v.stall_ns == r.stall_ns
            assert v.mean_active_rows == r.mean_active_rows
            np.testing.assert_array_equal(v.per_query_tiles, r.per_query_tiles)


def test_simulate_batch_multiread_threshold_matches_reference():
    rows = 128
    hist = _trace(rows, 32, seed=4, bag=4.0)
    ev = _trace(rows, 32, seed=5, bag=4.0)
    layout = _layout(rows, hist)
    for thr in (2, 4):
        v = simulate_batch(layout, ev, switch_threshold=thr)
        r = _reference_simulate_batch(layout, ev, switch_threshold=thr)
        assert v.read_activations == r.read_activations
        assert v.energy_pj == r.energy_pj


def test_simulate_batch_empty_batch():
    layout = _layout(64, _trace(64, 16, seed=0))
    v = simulate_batch(layout, [])
    assert v.activations == 0 and v.completion_time_ns == 0.0


def test_cpu_baseline_reports_true_mean_rows():
    qs = [[0, 1, 2], [3, 3], [4]]
    rep = simulate_cpu_baseline(qs)
    # unique rows per query: 3, 1, 1 -> mean 5/3
    assert rep.mean_active_rows == pytest.approx(5 / 3)
    assert rep.activations == 5


# ----------------------------------------------------- query-blocked kernel --


def _blocked_setup(seed, batch, dim=128):
    rows = 256
    hist = _trace(rows, 64, seed)
    ev = _trace(rows, batch, seed + 1)
    layout = _layout(rows, hist, dim=dim)
    table = np.random.default_rng(seed).normal(size=(rows, dim)).astype(np.float32)
    image = jnp.asarray(
        layout.build_image(table).reshape(layout.num_tiles, layout.tile_rows, dim)
    )
    cq = compile_queries(layout, ev)
    ref = reduce_dense_oracle(jnp.asarray(table), ev)
    return image, cq, ref


@pytest.mark.parametrize("q_block", [1, 4, 8])
@pytest.mark.parametrize("batch", [8, 30])   # 30: ragged (pads to q_block)
def test_blocked_kernel_matches_ref(q_block, batch):
    image, cq, ref = _blocked_setup(11, batch)
    bq = block_compiled_queries(cq, q_block)
    assert bq.num_blocks == -(-batch // q_block)
    out = crossbar_reduce_blocked(image, bq.tile_ids, bq.bitmaps)[:bq.batch]
    oracle = crossbar_reduce_blocked_ref(image, bq.tile_ids, bq.bitmaps)[:bq.batch]
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle), atol=1e-5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("q_block", [1, 4])
def test_blocked_kernel_no_dynamic_switch_same_values(q_block):
    from repro.kernels.crossbar_reduce import crossbar_reduce_pallas

    image, cq, _ = _blocked_setup(13, 16)
    bq = block_compiled_queries(cq, q_block)
    a = crossbar_reduce_pallas(image, bq.tile_ids, bq.bitmaps, dynamic_switch=True)
    b = crossbar_reduce_pallas(image, bq.tile_ids, bq.bitmaps, dynamic_switch=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_blocked_kernel_grad_matches_ref():
    image, cq, _ = _blocked_setup(17, 12)
    bq = block_compiled_queries(cq, 4)

    gk = jax.grad(
        lambda im: (crossbar_reduce_blocked(im, bq.tile_ids, bq.bitmaps) ** 2).sum()
    )(image)
    gr = jax.grad(
        lambda im: (crossbar_reduce_blocked_ref(im, bq.tile_ids, bq.bitmaps) ** 2).sum()
    )(image)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), atol=1e-3, rtol=1e-3)


def test_blocked_kernel_bf16():
    image, cq, ref = _blocked_setup(19, 16)
    image = image.astype(jnp.bfloat16)
    bq = block_compiled_queries(cq, 4)
    out = crossbar_reduce_blocked(image, bq.tile_ids, bq.bitmaps)[:bq.batch]
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=0.3, rtol=1e-2
    )


def test_block_compiler_dedups_shared_tiles():
    """Correlated queries share tiles, so the block union must be smaller
    than the concatenation of per-query tile lists."""
    image, cq, _ = _blocked_setup(23, 32)
    bq = block_compiled_queries(cq, 8)
    flat_cells = cq.tile_ids.shape[0] * cq.tile_ids.shape[1]
    blocked_cells = bq.num_blocks * bq.max_tiles
    assert blocked_cells < flat_cells


def test_block_granular_replica_balancing():
    """replica_block=q_block must never widen the block tile union versus
    per-query round robin (identical replicas collapse to one tile) and
    must leave the numerics unchanged."""
    rows, dim, batch, qb = 512, 128, 64, 8
    hist = _trace(rows, 128, seed=31)
    ev = _trace(rows, batch, seed=32)
    g = build_cooccurrence(hist, rows)
    layout, _ = baselines.recross_pipeline(
        g, hist, group_size=16, dim=dim, batch_size=256
    )
    table = np.random.default_rng(0).normal(size=(rows, dim)).astype(np.float32)
    image = jnp.asarray(
        layout.build_image(table).reshape(layout.num_tiles, layout.tile_rows, dim)
    )
    bq_perq = block_compiled_queries(compile_queries(layout, ev), qb)
    bq_blk = block_compiled_queries(
        compile_queries(layout, ev, replica_block=qb), qb
    )
    union_perq = int((np.asarray(bq_perq.tile_ids) >= 0).sum())
    union_blk = int((np.asarray(bq_blk.tile_ids) >= 0).sum())
    assert union_blk <= union_perq
    ref = reduce_dense_oracle(jnp.asarray(table), ev)
    out = crossbar_reduce_blocked(image, bq_blk.tile_ids, bq_blk.bitmaps)[:batch]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
