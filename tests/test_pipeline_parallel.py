"""Pipeline parallelism: degenerate single-stage correctness on the local
device (the multi-stage path is exercised by examples/pipeline_parallel.py
on the 512-placeholder-device pool) + schedule math."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.dist.pipeline_parallel import bubble_fraction, pipelined_apply


def test_single_stage_equals_sequential():
    mesh = jax.make_mesh((1,), ("stage",))
    S, L, D, M, MB = 1, 4, 16, 3, 8
    w = jax.random.normal(jax.random.PRNGKey(0), (S, L, D, D)) * 0.25
    x = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))

    def body(w_stage, h):
        def layer(c, wl):
            return jnp.tanh(c @ wl), None
        out, _ = jax.lax.scan(layer, h, w_stage)
        return out

    out = pipelined_apply(w, x, body, mesh)
    ref = jax.vmap(lambda xb: body(w[0], xb))(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_bubble_fraction():
    assert bubble_fraction(8, 4) == 3 / 11
    assert bubble_fraction(1, 1) == 0.0
    assert bubble_fraction(64, 8) < 0.1  # deep pipelines need many microbatches
