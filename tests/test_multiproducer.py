"""Multi-producer front door (DESIGN.md §10): N concurrent producer
threads submitting into one server must keep every per-producer stream
FIFO, merge deterministically — the packed ``(local_seq, producer_id)``
order, never the OS thread schedule — and survive the two lifecycle
races a concurrent front door actually hits: ``drain()``'s sequence
reset while submits are still in flight, and ``close()`` racing live
submitters.

All identity checks are pinned on integer-valued float tables (every
partial sum exact in f32), so any scheduling-dependent merge, dropped
or duplicated stamp, or torn sequence counter fails as a bit-level
mismatch — not as a tolerance judgment call.
"""

import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.reduction import reduce_dense_oracle
from repro.data import zipf_queries
from repro.serve import ShardedEmbeddingServer
from repro.serve.drift import ReplanConfig

ROWS, DIM = 160, 128
TABLE_CYCLE = ("a", "b")


def _int_table(seed):
    """Integer-valued f32 table: partial sums are exact in float32."""
    return np.random.default_rng(seed).integers(
        -8, 9, size=(ROWS, DIM)
    ).astype(np.float32)


TABLES = {"a": _int_table(11), "b": _int_table(12)}
HISTORIES = {"a": zipf_queries(ROWS, 48, 5.0, seed=13),
             "b": zipf_queries(ROWS, 48, 5.0, seed=14)}


def _server(*, num_shards=2, batch_size=8, threaded=True, **kw):
    return ShardedEmbeddingServer(
        TABLES, HISTORIES, num_shards=num_shards, q_block=4,
        group_size=16, batch_size=batch_size, flush_policy="per-shard",
        threaded=threaded, **kw,
    )


def _streams(n_producers, n_submits, seed0=100):
    """One query stream per producer (tables alternate per submit)."""
    return [
        list(zipf_queries(ROWS, n_submits, 5.0, seed=seed0 + p,
                          num_baskets=max(16, n_submits // 4)))
        for p in range(n_producers)
    ]


def _submit_concurrently(srv, streams, *, labels=None, jitter=0):
    """Submits every stream from its own thread; returns per-thread
    exceptions (empty on success).  ``jitter`` sleeps every few
    submits so lifecycle races (drain/close) can interleave."""
    labels = labels or [f"p{i}" for i in range(len(streams))]
    errs = [[] for _ in streams]

    def body(idx):
        try:
            for i, q in enumerate(streams[idx]):
                if jitter and i % 8 == 7:
                    time.sleep(jitter)
                srv.submit(TABLE_CYCLE[i % 2], q, producer=labels[idx])
        except Exception as e:
            errs[idx].append(e)

    threads = [threading.Thread(target=body, args=(i,), daemon=True)
               for i in range(len(streams))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive(), "producer thread wedged"
    return labels, [e for es in errs for e in es]


def _producer_oracle(stream):
    """Expected per-table FIFO rows of ONE producer's stream."""
    per = {n: [] for n in TABLE_CYCLE}
    for i, q in enumerate(stream):
        per[TABLE_CYCLE[i % 2]].append(q)
    return {
        n: np.asarray(reduce_dense_oracle(jnp.asarray(TABLES[n]), qs))
        for n, qs in per.items() if qs
    }


# ------------------------------------------------------------- stress --


def test_multiproducer_stress_fifo_deterministic():
    """The acceptance stress: 8 producers x 512 submits on the thread
    driver.  Every producer's ``drain(producer=...)`` must hand back
    exactly its own stream, in its own submission order, bit-identical
    to the host oracle — independent of how the OS interleaved the
    submitting threads."""
    n_prod, n_sub = 8, 512
    streams = _streams(n_prod, n_sub)
    srv = _server(num_shards=4, batch_size=16)
    labels = [f"p{i}" for i in range(n_prod)]
    for lab in labels:
        srv.register_producer(lab)
    _, errs = _submit_concurrently(srv, streams, labels=labels)
    assert not errs, errs
    for lab, stream in zip(labels, streams):
        out = srv.drain(producer=lab)
        want = _producer_oracle(stream)
        assert set(out) == set(want)
        for n in want:
            np.testing.assert_array_equal(np.asarray(out[n]), want[n])
    # every stream handed off: nothing left for a final full drain
    assert srv.drain() == {}
    # the scheduler's per-producer accounting saw every submit
    pushed = srv.scheduler.pushed_by_producer
    assert all(pushed[lab] == n_sub for lab in labels), pushed
    srv.close()


@pytest.mark.parametrize("num_shards", [1, 2, 4])
@pytest.mark.parametrize("threaded", [False, True])
def test_merged_drain_bit_identical_to_single_producer_oracle(
        num_shards, threaded):
    """A full drain's cross-producer merge is the deterministic
    ``(local_seq, producer_id)`` interleave: replaying the SAME logical
    traffic through a fresh single-producer server in that exact order
    must produce a bit-identical drain, for every shard count on both
    the inline engine and the thread driver."""
    n_prod, n_sub = 4, 24
    streams = _streams(n_prod, n_sub, seed0=200)
    srv = _server(num_shards=num_shards, threaded=threaded)
    for p in range(n_prod):
        srv.register_producer(f"p{p}")
    _, errs = _submit_concurrently(srv, streams)
    assert not errs, errs
    got = {n: np.asarray(o) for n, o in srv.drain().items()}
    srv.close()

    # single-producer oracle replay in merge order: position-major,
    # producer-minor (all producers alternate tables identically, so a
    # position's table only depends on the position)
    oracle = _server(num_shards=num_shards, threaded=threaded)
    for i in range(n_sub):
        for p in range(n_prod):
            oracle.submit(TABLE_CYCLE[i % 2], streams[p][i])
    want = {n: np.asarray(o) for n, o in oracle.drain().items()}
    oracle.close()
    assert set(got) == set(want)
    for n in want:
        np.testing.assert_array_equal(got[n], want[n])


# ------------------------------------------------------ patch barrier --


def test_patch_applies_at_fifo_barrier_under_concurrent_producers():
    """The §7.3 barrier rule under N producers: a drift-staged plan
    patch may only apply with the pipeline empty — at a barrier token
    that is FIFO with every producer's hand-off traffic — and every
    producer's drained stream stays exact across the plan transition."""
    n_prod = 4
    streams = [list(zipf_queries(ROWS, 24, 5.0, seed=300 + p))
               for p in range(n_prod)]
    perm = np.random.default_rng(34).permutation(ROWS)
    # drift: every producer's tail traffic permutes to new hot rows
    streams = [
        s[:8] + [perm[np.asarray(q, np.int64)] for q in s[8:]]
        for s in streams
    ]
    srv = _server(
        num_shards=2, batch_size=8, max_in_flight=4,
        # eq1_batch large enough that Eq. 1 replicates groups even
        # under drift — otherwise every event is a rebase and nothing
        # ever stages (same setup as the single-producer spy test)
        batch_size_for_eq1=512,
        replan=ReplanConfig(threshold=0.15, half_life=1.0, min_queries=8,
                            slack_tiles=8),
    )
    applied_with_in_flight = []
    orig_apply = srv._apply_staged_patch

    def spy_apply():
        if srv._staged is not None:
            applied_with_in_flight.append(len(srv._in_flight))
        orig_apply()

    srv._apply_staged_patch = spy_apply
    labels = [f"p{i}" for i in range(n_prod)]
    errs = []

    def body(idx):
        try:
            for q in streams[idx]:
                srv.submit("a", q, producer=labels[idx])
        except Exception as e:
            errs.append(e)

    threads = [threading.Thread(target=body, args=(i,), daemon=True)
               for i in range(n_prod)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "producer thread wedged"
    assert not errs, errs
    outs = {lab: srv.drain(producer=lab) for lab in labels}
    srv.close()
    assert applied_with_in_flight, "no patch was ever applied"
    assert all(n == 0 for n in applied_with_in_flight), (
        "patch applied with flushes in flight"
    )
    assert srv.stats.barrier_flushes >= 1
    for lab, stream in zip(labels, streams):
        want = np.asarray(
            reduce_dense_oracle(jnp.asarray(TABLES["a"]), stream)
        )
        np.testing.assert_array_equal(np.asarray(outs[lab]["a"]), want)


# ---------------------------------------------------- lifecycle races --


def test_drain_seq_reset_race_with_concurrent_submits():
    """Regression: full drains racing a live submitter must never
    reset the sequence spaces while a stamp is anywhere in flight
    (stamped-but-unqueued, queued, popped-but-unprocessed, or stashed
    for a later drain).  A broken guard hands out colliding packed
    seqs and scrambles a later drain's merge — caught here as a
    bit-level mismatch of the concatenated drains against the FIFO
    oracle.  (A reset at GENUINE quiescence mid-stream is legal: the
    next epoch's seqs restart at 0 only after everything before was
    already handed off, so concatenation order is unaffected.)"""
    n_sub = 150
    stream = list(zipf_queries(ROWS, n_sub, 5.0, seed=400, num_baskets=32))
    srv = _server(num_shards=2, batch_size=4)
    done = threading.Event()
    errs = []

    def body():
        try:
            for i, q in enumerate(stream):
                srv.submit("a", q)
                if i % 8 == 7:
                    time.sleep(0.001)  # let drains interleave
        except Exception as e:
            errs.append(e)
        finally:
            done.set()

    t = threading.Thread(target=body, daemon=True)
    chunks = []
    t.start()
    while not done.is_set():
        out = srv.drain()
        if "a" in out:
            chunks.append(np.asarray(out["a"]))
    t.join(timeout=60)
    assert not t.is_alive() and not errs, errs
    out = srv.drain()
    if "a" in out:
        chunks.append(np.asarray(out["a"]))
    srv.close()
    got = np.concatenate(chunks)
    want = np.asarray(reduce_dense_oracle(jnp.asarray(TABLES["a"]), stream))
    np.testing.assert_array_equal(got, want)
    # the final drain observed full quiescence: counters restarted
    assert srv.next_seq("a") == 0


def test_close_racing_concurrent_submits():
    """close() against 4 live submitters: late submits get the clean
    RuntimeError (never a hang or a silently dropped query), work still
    queued at close is recorded in ``ledger.lost_work`` and served by a
    later inline drain, and a second close is an idempotent no-op."""
    n_prod, n_sub = 4, 60
    streams = _streams(n_prod, n_sub, seed0=500)
    # batch far above the traffic: everything stays pending, so the
    # close must find (and account) undispatched work
    srv = _server(num_shards=2, batch_size=256)
    labels = [f"p{i}" for i in range(n_prod)]
    accepted = [0] * n_prod
    rejected = [0] * n_prod
    errs = []

    def body(idx):
        try:
            for i, q in enumerate(streams[idx]):
                try:
                    srv.submit(TABLE_CYCLE[i % 2], q, producer=labels[idx])
                    accepted[idx] += 1
                except RuntimeError as e:
                    assert "closed server" in str(e)
                    rejected[idx] += 1
                time.sleep(0.0005)
        except Exception as e:
            errs.append(e)

    threads = [threading.Thread(target=body, args=(i,), daemon=True)
               for i in range(n_prod)]
    for t in threads:
        t.start()
    time.sleep(0.03)
    srv.close()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive(), "submitter deadlocked against close()"
    assert not errs, errs
    assert sum(rejected) > 0, "close() landed after every submit"
    assert sum(accepted) > 0, "close() landed before any submit"
    assert srv._driver is None and srv._handoff is None
    lost = srv.stats.ledger.lost_work
    assert lost is not None and lost["requeued"] > 0, lost
    # idempotent double close, bounded
    t0 = time.perf_counter()
    srv.close()
    assert time.perf_counter() - t0 < 2.0
    # accepted work survives the close: a later drain serves it inline
    served = 0
    for lab in labels:
        for o in srv.drain(producer=lab).values():
            served += np.asarray(o).shape[0]
    assert served == sum(accepted)


def test_wall_deadline_flushes_idle_stream():
    """FlushPolicy.deadline_s: a quiet stream's pending queries must
    flush when their wall age crosses the bound — fired by the thread
    driver's idle loop, with no further submission to consult the
    trigger — and the drained rows stay exact."""
    stream = list(zipf_queries(ROWS, 4, 5.0, seed=600, num_baskets=8))
    srv = _server(num_shards=2, batch_size=64, flush_deadline_s=0.05)
    for q in stream:
        srv.submit("a", q, producer="p0")
    deadline = time.perf_counter() + 30.0
    while (srv.stats.deadline_flushes < 1
           and time.perf_counter() < deadline):
        time.sleep(0.01)
    assert srv.stats.deadline_flushes >= 1, (
        "wall deadline never fired on the idle stream"
    )
    out = srv.drain(producer="p0")
    srv.close()
    want = np.asarray(reduce_dense_oracle(jnp.asarray(TABLES["a"]), stream))
    np.testing.assert_array_equal(np.asarray(out["a"]), want)
