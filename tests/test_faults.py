"""Fault injection + self-healing flush pipeline (DESIGN.md §8).

The invariant under test: for any injected fault schedule whose faults
are retriable (transient compile/device faults, hangs), ``drain()``
returns rows BIT-IDENTICAL to the fault-free oracle — the engine heals,
it does not drop, duplicate or reorder.  Non-retriable faults (poisoned
queries) are bisected down to the single offender and quarantined with
their error; every other row still matches the oracle.  Bit-identity is
pinned on integer-valued float tables exactly as in test_scheduler.py.

The legacy requeue-and-re-raise contract (``RetryPolicy.legacy()``)
is pinned here too, via the injector, under both inline and threaded
drivers for shards {1, 2, 4} — the driver fault branches that were
previously uncoverable.
"""

import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.reduction import reduce_dense_oracle
from repro.data import zipf_queries
from repro.serve import (
    FaultInjector,
    FaultPlan,
    FaultSpec,
    FlushTimeout,
    InjectedFault,
    PoisonedQueryError,
    RetryPolicy,
    ShardedEmbeddingServer,
)

ROWS, DIM = 160, 128


def _int_table(seed):
    """Integer-valued f32 table: partial sums are exact in float32."""
    return np.random.default_rng(seed).integers(
        -8, 9, size=(ROWS, DIM)
    ).astype(np.float32)


TABLES = {"a": _int_table(11), "b": _int_table(12)}
HISTORIES = {"a": zipf_queries(ROWS, 48, 5.0, seed=13),
             "b": zipf_queries(ROWS, 48, 5.0, seed=14)}
STREAMS = {"a": zipf_queries(ROWS, 20, 5.0, seed=15),
           "b": zipf_queries(ROWS, 12, 5.0, seed=16)}
REPLAY = ([("a", q) for q in STREAMS["a"]]
          + [("b", q) for q in STREAMS["b"]])
#: fast-backoff policy so healing tests don't sleep for real
FAST = dict(backoff_base=1e-4, backoff_max=1e-3)


def _serve(replay=REPLAY, *, num_shards=2, batch_size=4, **kw):
    srv = ShardedEmbeddingServer(
        TABLES, HISTORIES, num_shards=num_shards, q_block=4,
        group_size=16, batch_size=batch_size, flush_policy="per-shard",
        **kw,
    )
    for name, q in replay:
        srv.submit(name, q)
    out = srv.drain()
    srv.close()
    return srv, out


def _oracle():
    return {n: np.asarray(reduce_dense_oracle(jnp.asarray(TABLES[n]),
                                              STREAMS[n]))
            for n in TABLES}


ORACLE = _oracle()


# ------------------------------------------------- plan / policy units --


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("meteor")
    with pytest.raises(ValueError, match="table= and seq="):
        FaultSpec("poison")
    with pytest.raises(ValueError, match="times"):
        FaultSpec("compile", times=0)
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.random(0, {"meteor": 1})
    with pytest.raises(ValueError, match="tables="):
        FaultPlan.random(0, {"poison": 1})
    with pytest.raises(TypeError):
        FaultInjector.parse("chaos")
    with pytest.raises(TypeError):
        RetryPolicy.parse("retry hard")


def test_fault_plan_random_is_seed_deterministic():
    counts = {"compile": 2, "device": 1, "poison": 2, "hang": 1}
    mk = lambda s: FaultPlan.random(
        s, counts, horizon=8, tables=("a", "b"), max_seq=20, hang_s=9.0)
    p1, p2, p3 = mk(5), mk(5), mk(6)
    assert p1.specs == p2.specs  # FaultSpec is frozen → value equality
    assert p1.specs != p3.specs
    assert p1.poisoned() == p2.poisoned()
    assert p1.summary()["faults"] == counts


def test_injector_attempt_windows():
    """tick=t, times=k fails attempts t..t+k-1 at that seam only."""
    plan = FaultPlan([], seed=0).add("compile", tick=1, times=2)
    inj = FaultInjector(plan)
    inj.on_compile([("a", 0, [1])])  # attempt 0: healthy
    for _ in range(2):               # attempts 1, 2: injected
        with pytest.raises(InjectedFault):
            inj.on_compile([("a", 0, [1])])
    inj.on_compile([("a", 0, [1])])  # attempt 3: healed
    assert inj.injected["compile"] == 2
    # the poison set fires regardless of attempt index, forever
    inj2 = FaultInjector(FaultPlan([], seed=0).add("poison", table="a", seq=3))
    for _ in range(3):
        with pytest.raises(PoisonedQueryError):
            inj2.on_compile([("a", 3, [1]), ("a", 4, [2])])
    inj2.on_compile([("a", 4, [2])])  # offender absent: healthy


def test_retry_policy_backoff_and_legacy():
    with pytest.raises(ValueError, match="max_retries"):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError, match="jitter"):
        RetryPolicy(jitter=1.5)
    with pytest.raises(ValueError, match="watchdog_s"):
        RetryPolicy(watchdog_s=0.0)
    p = RetryPolicy(backoff_base=0.01, backoff_mult=2.0, backoff_max=0.05,
                    jitter=0.0)
    rng = np.random.default_rng(0)
    waits = [p.backoff_s(a, rng) for a in range(5)]
    assert waits[:3] == [0.01, 0.02, 0.04]
    assert waits[3] == waits[4] == 0.05  # capped
    pj = RetryPolicy(backoff_base=0.01, jitter=0.25)
    for a in range(4):
        w = pj.backoff_s(a, rng)
        base = min(0.01 * 2.0 ** a, pj.backoff_max)
        assert 0.75 * base <= w <= 1.25 * base
    leg = RetryPolicy.legacy()
    assert leg.max_retries == 0 and not leg.bisect and not leg.quarantine
    assert RetryPolicy.parse(None) == RetryPolicy()
    assert RetryPolicy.parse(leg) is leg


# ------------------------- legacy driver fault branches (satellite 3) --


@pytest.mark.parametrize("num_shards", [1, 2, 4])
@pytest.mark.parametrize("threaded", [False, True])
@pytest.mark.parametrize("kind", ["compile", "device"])
def test_legacy_requeue_and_reraise_branches(num_shards, threaded, kind):
    """The pre-§8 contract, provoked by the injector instead of
    monkeypatching: a dispatch-time fault requeues the batch, the error
    surfaces (inline: at submit; threaded: at the next drain), and a
    later drain retries the requeued work — every row served, in
    order, bit-identical to the oracle."""
    plan = FaultPlan([], seed=1).add(kind, tick=0, times=1)
    srv = ShardedEmbeddingServer(
        TABLES, HISTORIES, num_shards=num_shards, q_block=4,
        group_size=16, batch_size=4, flush_policy="per-shard",
        threaded=threaded, retry=RetryPolicy.legacy(), faults=plan,
    )
    raised = None
    for name, q in REPLAY:
        try:
            srv.submit(name, q)
        except InjectedFault as e:
            raised = e
    if threaded:
        # the failure happened on the driver thread; it surfaces at the
        # next submit()/drain() instead of the submit that tripped it
        with pytest.raises(InjectedFault):
            deadline = time.monotonic() + 10.0
            while time.monotonic() < deadline:
                srv.drain()
                time.sleep(0.005)
            # a single slow drain (cold compiles under suite-wide
            # load) can eat the whole budget AFTER the driver stashed
            # the error; drain once more so the stash still surfaces
            srv.drain()
            raise AssertionError("driver never surfaced the failure")
    else:
        assert raised is not None, "inline legacy must re-raise at submit"
    assert srv.scheduler.requeues >= 1
    out = srv.drain()  # retry: the fault was transient (times=1)
    got = {n: np.asarray(out[n]) for n in out}
    # rows served across the failed attempt + retry must total the
    # oracle, in submission order
    for n in TABLES:
        np.testing.assert_array_equal(got[n], ORACLE[n])
    led = srv.stats.ledger
    assert not led.quarantined and led.retries == 0  # legacy never heals
    srv.close()


@pytest.mark.parametrize("threaded", [False, True])
def test_legacy_late_device_fault_requeues_at_retire(threaded):
    """A device fault surfacing only at retire (outputs lost) requeues
    the already-dispatched batch under the legacy policy and re-raises;
    the next drain re-dispatches it."""
    plan = FaultPlan([], seed=2).add("device-late", tick=0, times=1)
    srv = ShardedEmbeddingServer(
        TABLES, HISTORIES, num_shards=2, q_block=4, group_size=16,
        batch_size=4, flush_policy="per-shard", threaded=threaded,
        retry=RetryPolicy.legacy(), faults=plan,
    )
    # inline: the fault can surface at a submit that trims the pipeline;
    # threaded: it is stashed and surfaces at a drain.  Either way the
    # batch requeues and a later drain must serve EVERY row exactly once.
    raised = False
    for name, q in REPLAY:
        try:
            srv.submit(name, q)
        except InjectedFault:
            raised = True
    outs = []
    deadline = time.monotonic() + 30.0
    while time.monotonic() < deadline:
        try:
            out = srv.drain()
        except InjectedFault:
            raised = True
            continue
        outs.append(out)
        if raised and srv.scheduler.pending_total() == 0:
            break
    assert raised, "retire fault never surfaced"
    assert srv.scheduler.requeues >= 1
    got = {}
    for out in outs:
        for n, rows in out.items():
            got.setdefault(n, []).append(np.asarray(rows))
    for n in TABLES:
        served = np.concatenate(got[n]) if n in got else np.empty((0, DIM))
        # all rows served exactly once; cross-drain order may interleave
        # (the requeued batch retries behind later flushes), so compare
        # as multisets of rows via lexicographic sort
        assert served.shape == ORACLE[n].shape
        np.testing.assert_array_equal(
            served[np.lexsort(served.T)], ORACLE[n][np.lexsort(ORACLE[n].T)]
        )
    srv.close()


# ------------------------------------------- self-healing bit-identity --


@pytest.mark.parametrize("num_shards", [1, 2, 4])
def test_healing_transient_faults_bit_identical(num_shards):
    """Transient compile + device + late-device faults: the default
    policy retries in place, nothing surfaces to the caller, and
    drain() is bit-identical to the fault-free oracle."""
    plan = (FaultPlan([], seed=3)
            .add("compile", tick=0, times=2)
            .add("device", tick=2, times=1)
            .add("device-late", tick=1, times=1))
    srv, out = _serve(num_shards=num_shards,
                      retry=RetryPolicy(max_retries=3, **FAST),
                      faults=plan)
    for n in TABLES:
        np.testing.assert_array_equal(np.asarray(out[n]), ORACLE[n])
    led = srv.stats.ledger
    assert led.retries >= 3
    assert led.backoff_s > 0
    assert not led.quarantined
    assert led.recovery_s, "healed transients must record recovery latency"
    summ = srv.stats.summary()["faults"]
    assert summ["recoveries"] == len(led.recovery_s)
    assert summ["recovery_latency_s"]["p50"] > 0


@pytest.mark.parametrize("threaded", [False, True])
def test_poison_bisected_and_quarantined(threaded):
    """One poisoned query fails every batch containing it without
    naming itself; bisection isolates it, quarantines it with its
    error, and every OTHER row still matches the oracle."""
    plan = FaultPlan([], seed=5).add("poison", table="a", seq=3)
    srv, out = _serve(threaded=threaded,
                      retry=RetryPolicy(max_retries=1, **FAST),
                      faults=plan)
    led = srv.stats.ledger
    assert led.quarantined_keys() == [("a", 3)]
    assert "PoisonedQueryError" in led.quarantined[0][2]
    assert led.bisections >= 1
    assert srv.scheduler.quarantined == 1
    assert srv.scheduler.state()["quarantined"] == 1
    keep = np.asarray([i for i in range(len(STREAMS["a"])) if i != 3])
    np.testing.assert_array_equal(np.asarray(out["a"]), ORACLE["a"][keep])
    np.testing.assert_array_equal(np.asarray(out["b"]), ORACLE["b"])


def test_quarantine_without_bisection_drops_whole_batch():
    """bisect=False still unwedges the home — the whole failing batch
    quarantines (every entry recorded), the rest of the replay serves."""
    plan = FaultPlan([], seed=6).add("poison", table="b", seq=0)
    srv, out = _serve(retry=RetryPolicy(max_retries=0, bisect=False, **FAST),
                      faults=plan)
    led = srv.stats.ledger
    assert led.bisections == 0
    assert ("b", 0) in led.quarantined_keys()
    assert len(led.quarantined) >= 1
    # without bisection the whole mixed batch drops — possibly entries
    # of BOTH tables; the survivors must still match the oracle rows
    for n in TABLES:
        dropped = {s for t, s in led.quarantined_keys() if t == n}
        keep = np.asarray([i for i in range(len(STREAMS[n]))
                           if i not in dropped])
        np.testing.assert_array_equal(np.asarray(out[n]), ORACLE[n][keep])


# ------------------------------------------------- watchdog / degrade --


@pytest.mark.parametrize("threaded", [False, True])
def test_watchdog_degrades_hung_flush(threaded):
    """An (effectively) infinite hang trips the watchdog: the flush is
    served via the inline host path instead of blocking drain()
    forever, and the rows are STILL bit-identical to the oracle."""
    plan = FaultPlan([], seed=7).add("hang", tick=1, hang_s=999.0)
    t0 = time.monotonic()
    srv, out = _serve(threaded=threaded,
                      retry=RetryPolicy(max_retries=1, watchdog_s=0.2,
                                        **FAST),
                      faults=plan)
    assert time.monotonic() - t0 < 60.0, "watchdog failed to bound drain"
    led = srv.stats.ledger
    assert led.timed_out_flushes >= 1
    assert led.degraded_flushes >= 1
    for n in TABLES:
        np.testing.assert_array_equal(np.asarray(out[n]), ORACLE[n])


def test_infinite_hang_without_watchdog_still_degrades():
    """hang_s=None simulates a device that never reports ready; with no
    watchdog configured the engine must still degrade (an injected
    infinite hang may never wedge drain())."""
    plan = FaultPlan([], seed=8).add("hang", tick=0)
    srv, out = _serve(retry=RetryPolicy(max_retries=0, **FAST), faults=plan)
    assert srv.stats.ledger.degraded_flushes >= 1
    for n in TABLES:
        np.testing.assert_array_equal(np.asarray(out[n]), ORACLE[n])


def test_short_hang_recovers_without_degrade():
    """A hang shorter than the watchdog deadline just waits it out —
    no timeout, no degrade, device outputs used."""
    plan = FaultPlan([], seed=9).add("hang", tick=0, hang_s=0.05)
    srv, out = _serve(retry=RetryPolicy(watchdog_s=5.0, **FAST),
                      faults=plan)
    led = srv.stats.ledger
    assert led.timed_out_flushes == 0 and led.degraded_flushes == 0
    for n in TABLES:
        np.testing.assert_array_equal(np.asarray(out[n]), ORACLE[n])


# ------------------------------------------------------- patch seam --


def _patch_barrier(srv):
    srv._staged = object()  # sentinel: dropped/kept, never applied
    srv._apply_staged_patch()


def test_patch_fault_retries_then_drops():
    """A failing staged patch is retried at the next barriers, then
    dropped (recorded) — the server keeps serving under the live plan.
    The sentinel staged object must never reach the real apply path."""
    plan = FaultPlan([], seed=10).add("patch", tick=0, times=3)
    srv = ShardedEmbeddingServer(
        TABLES, HISTORIES, num_shards=2, q_block=4, group_size=16,
        batch_size=4, flush_policy="per-shard",
        retry=RetryPolicy(patch_retries=1, **FAST), faults=plan,
    )
    staged = object()
    srv._staged = staged
    srv._apply_staged_patch()                 # failure 1: kept staged
    assert srv._staged is staged
    srv._apply_staged_patch()                 # failure 2 > patch_retries
    assert srv._staged is None
    led = srv.stats.ledger
    assert led.patch_failures == 2 and led.patches_dropped == 1
    # legacy policy: the patch failure re-raises instead
    srv2 = ShardedEmbeddingServer(
        TABLES, HISTORIES, num_shards=2, q_block=4, group_size=16,
        batch_size=4, flush_policy="per-shard",
        retry=RetryPolicy.legacy(),
        faults=FaultPlan([], seed=11).add("patch", tick=0),
    )
    srv2._staged = object()
    with pytest.raises(InjectedFault):
        srv2._apply_staged_patch()


# ---------------------------------- error stashing + close (sat. 1/2) --


def test_driver_error_stash_is_bounded_and_ordered():
    """A burst of driver failures: the FIRST surfaces first with the
    count of the rest; the deque is bounded and overflow is counted,
    never silently dropped; later calls surface the rest in order."""
    srv = ShardedEmbeddingServer(
        TABLES, HISTORIES, num_shards=1, q_block=4, group_size=16,
        batch_size=4, flush_policy="per-shard",
    )
    for i in range(12):
        srv._stash_driver_error(RuntimeError(f"boom {i}"))
    assert len(srv._driver_errors) == 8
    assert srv._suppressed_errors == 4
    assert srv.stats.ledger.driver_errors_suppressed == 4
    with pytest.raises(RuntimeError, match=r"boom 0.*\+11 more.*4 suppressed"):
        srv._raise_driver_error()
    with pytest.raises(RuntimeError, match=r"boom 1.*\+10 more"):
        srv._raise_driver_error()
    for i in range(2, 8):
        with pytest.raises(RuntimeError, match=f"boom {i}"):
            srv._raise_driver_error()
    srv._raise_driver_error()  # empty: no-op
    assert srv.stats.summary()["faults"]["driver_errors_suppressed"] == 4


def test_close_is_idempotent_and_reports_lost_work():
    """close() with work still queued: bounded, idempotent, and the
    unserved work is summarized into the ledger instead of silently
    discarded — a later drain() still serves every row inline."""
    srv = ShardedEmbeddingServer(
        TABLES, HISTORIES, num_shards=2, q_block=4, group_size=16,
        batch_size=10_000, flush_policy="per-shard", threaded=True,
    )
    for name, q in REPLAY:
        srv.submit(name, q)
    t0 = time.monotonic()
    srv.close()
    srv.close()  # idempotent
    assert time.monotonic() - t0 < ShardedEmbeddingServer._CLOSE_JOIN_S
    assert srv._driver is None and srv._handoff is None
    lost = srv.stats.ledger.lost_work
    assert lost is not None
    assert lost["requeued"] + lost["handoff_pushed_back"] >= len(REPLAY) \
        or srv.scheduler.pending_total() == len(REPLAY)
    assert lost["driver_leaked"] == 0
    assert srv.report()["serve"]["faults"]["lost_work"] == lost
    # nothing was dropped: the inline drain serves the whole backlog
    out = srv.drain()
    for n in TABLES:
        np.testing.assert_array_equal(np.asarray(out[n]), ORACLE[n])
    # close on a never-threaded server is a clean no-op
    srv2 = ShardedEmbeddingServer(
        TABLES, HISTORIES, num_shards=1, q_block=4, group_size=16,
        batch_size=4, flush_policy="per-shard",
    )
    srv2.close()
    srv2.close()
    assert srv2.stats.ledger.lost_work is None


# ------------------------------------------------ acceptance scenario --


def test_chaos_replay_threaded_acceptance():
    """ISSUE 6 acceptance: >= 3 fault kinds (transient device fault,
    compile failure, poisoned query) + a hung flush, on the THREADED
    driver.  drain() completes bit-identical to the fault-free oracle
    minus exactly the injected offender; the ledger shows nonzero
    retries and exactly the offenders quarantined; the hang degrades
    via the watchdog instead of blocking drain() forever."""
    plan = (FaultPlan([], seed=3)
            .add("compile", tick=0, times=2)
            .add("device", tick=2, times=1)
            .add("poison", table="a", seq=5)
            .add("hang", tick=4, hang_s=999.0))
    t0 = time.monotonic()
    srv, out = _serve(threaded=True,
                      retry=RetryPolicy(max_retries=3, watchdog_s=0.2,
                                        **FAST),
                      faults=plan)
    assert time.monotonic() - t0 < 120.0
    led = srv.stats.ledger
    assert led.retries > 0
    assert led.quarantined_keys() == plan.poisoned() == [("a", 5)]
    assert led.timed_out_flushes >= 1 and led.degraded_flushes >= 1
    keep = np.asarray([i for i in range(len(STREAMS["a"])) if i != 5])
    np.testing.assert_array_equal(np.asarray(out["a"]), ORACLE["a"][keep])
    np.testing.assert_array_equal(np.asarray(out["b"]), ORACLE["b"])
    rep = srv.report()
    assert rep["retry"]["max_retries"] == 3
    inj = rep["faults"]["injected"]
    assert inj["compile"] >= 2 and inj["device"] >= 1
    assert inj["poison"] >= 1 and inj["hang"] >= 1
    assert rep["serve"]["faults"]["quarantined"] == [["a", 5,
        led.quarantined[0][2]]]


# ---------------------------------------------- multi-producer chaos --


def test_poison_quarantines_only_offending_producer():
    """Multi-producer chaos replay (DESIGN.md §10): producers A and B
    submit the SAME stream concurrently, and a poison spec keyed to
    producer A's (table, local seq) must quarantine only A's offender —
    B's copy of the very same query serves, and B's drained stream
    stays bit-identical to the fault-free oracle."""
    import threading

    plan = FaultPlan([], seed=9).add("poison", table="a", seq=3,
                                     producer="A")
    assert plan.poisoned_by_producer() == [("A", "a", 3)]
    srv = ShardedEmbeddingServer(
        TABLES, HISTORIES, num_shards=2, q_block=4, group_size=16,
        batch_size=4, flush_policy="per-shard", threaded=True,
        retry=RetryPolicy(max_retries=1, **FAST), faults=plan,
    )
    for lab in ("A", "B"):
        srv.register_producer(lab)
    errs = []

    def body(lab):
        try:
            for q in STREAMS["a"]:
                srv.submit("a", q, producer=lab)
        except Exception as e:
            errs.append(e)

    threads = [threading.Thread(target=body, args=(lab,), daemon=True)
               for lab in ("A", "B")]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "producer thread wedged"
    assert not errs, errs
    out = {lab: srv.drain(producer=lab) for lab in ("A", "B")}
    srv.close()
    led = srv.stats.ledger
    assert led.quarantined_keys_by_producer() == [("A", "a", 3)]
    assert "PoisonedQueryError" in led.quarantined[0][2]
    keep = np.asarray([i for i in range(len(STREAMS["a"])) if i != 3])
    np.testing.assert_array_equal(np.asarray(out["A"]["a"]),
                                  ORACLE["a"][keep])
    np.testing.assert_array_equal(np.asarray(out["B"]["a"]), ORACLE["a"])
