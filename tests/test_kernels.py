"""Per-kernel correctness: shape/dtype sweeps, kernel vs pure-jnp oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import (
    crossbar_reduce,
    crossbar_reduce_ref,
    embedding_bag,
    embedding_bag_ref,
)
from repro.kernels.crossbar_reduce import crossbar_reduce_pallas


def _case(rng, T, R, D, B, S, single_hot_frac=0.3, dtype=np.float32):
    image = rng.normal(size=(T, R, D)).astype(dtype)
    ids = rng.integers(0, T, size=(B, S)).astype(np.int32)
    npad = max(1, S // 4)
    ids[:, -npad:] = -1
    bm = (rng.random((B, S, R)) < 0.08).astype(dtype)
    bm[:, -npad:] = 0
    # force a mix of READ-path (single-hot) and empty tiles
    for b in range(B):
        if rng.random() < single_hot_frac and S > npad:
            bm[b, 0] = 0
            bm[b, 0, int(rng.integers(0, R))] = 1
        if S - npad > 1:
            bm[b, 1] = 0  # activated-but-empty tile
    return jnp.asarray(image), jnp.asarray(ids), jnp.asarray(bm)


TOL = {np.dtype(np.float32): 1e-5, np.dtype(jnp.bfloat16): 0.15}


@pytest.mark.parametrize("T,R,D,B,S", [
    (4, 8, 128, 2, 4),
    (12, 16, 128, 4, 8),
    (7, 8, 256, 3, 8),
    (32, 64, 128, 8, 16),
    (3, 8, 512, 1, 4),
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_crossbar_reduce_matches_ref(T, R, D, B, S, dtype):
    rng = np.random.default_rng(T * 1000 + R + D + B + S)
    image, ids, bm = _case(rng, T, R, D, B, S, dtype=np.dtype(dtype))
    out = crossbar_reduce(image, ids, bm)
    ref = crossbar_reduce_ref(image, ids, bm)
    assert out.shape == (B, D) and out.dtype == image.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[np.dtype(dtype)], rtol=1e-2,
    )


def test_crossbar_reduce_no_dynamic_switch_same_values():
    rng = np.random.default_rng(0)
    image, ids, bm = _case(rng, 10, 16, 128, 4, 8)
    a = crossbar_reduce_pallas(image, ids, bm, dynamic_switch=True)
    b = crossbar_reduce_pallas(image, ids, bm, dynamic_switch=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_crossbar_reduce_grad_matches_ref():
    rng = np.random.default_rng(1)
    image, ids, bm = _case(rng, 8, 16, 128, 4, 8)

    def loss_k(img):
        return (crossbar_reduce(img, ids, bm) ** 2).sum()

    def loss_r(img):
        return (crossbar_reduce_ref(img, ids, bm) ** 2).sum()

    gk = jax.grad(loss_k)(image)
    gr = jax.grad(loss_r)(image)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), atol=1e-3, rtol=1e-3)


def test_crossbar_reduce_alignment_errors():
    rng = np.random.default_rng(2)
    image, ids, bm = _case(rng, 4, 8, 128, 2, 4)
    with pytest.raises(ValueError):
        crossbar_reduce_pallas(image[:, :, :100], ids, bm)  # dim not 128-mult
    with pytest.raises(ValueError):
        crossbar_reduce_pallas(image[:, :7, :], ids, bm[:, :, :7])  # rows not 8-mult


@pytest.mark.parametrize("rows,D,B,K", [
    (64, 128, 4, 8),
    (100, 128, 2, 5),     # rows not multiple of block
    (257, 256, 8, 16),
    (16, 512, 1, 3),
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_embedding_bag_matches_ref(rows, D, B, K, dtype):
    rng = np.random.default_rng(rows + D + B + K)
    table = jnp.asarray(rng.normal(size=(rows, D)).astype(np.dtype(dtype)))
    idx = rng.integers(0, rows, size=(B, K)).astype(np.int32)
    idx[:, -1] = -1
    idx = jnp.asarray(idx)
    out = embedding_bag(table, idx)
    ref = embedding_bag_ref(table, idx)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[np.dtype(dtype)], rtol=1e-2,
    )


def test_embedding_bag_grad_matches_ref():
    rng = np.random.default_rng(3)
    table = jnp.asarray(rng.normal(size=(50, 128)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 50, size=(4, 6)).astype(np.int32))
    gk = jax.grad(lambda t: (embedding_bag(t, idx) ** 2).sum())(table)
    gr = jax.grad(lambda t: (embedding_bag_ref(t, idx) ** 2).sum())(table)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), atol=1e-3, rtol=1e-3)


def test_kernel_end_to_end_with_layout():
    """crossbar_reduce through a real ReCross layout == dense oracle."""
    from repro.core import baselines, build_cooccurrence, compile_queries
    from repro.core.reduction import reduce_dense_oracle
    from repro.data import zipf_queries

    rows, dim = 512, 128
    qs = zipf_queries(rows, 128, 10.0, seed=5)
    graph = build_cooccurrence(qs[:64], rows)
    layout, _ = baselines.recross_pipeline(graph, qs[64:], group_size=16, dim=dim)
    rng = np.random.default_rng(0)
    table = rng.normal(size=(rows, dim)).astype(np.float32)
    image = layout.build_image(table).reshape(layout.num_tiles, layout.tile_rows, dim)
    cq = compile_queries(layout, qs[64:96])
    out = crossbar_reduce(jnp.asarray(image), cq.tile_ids, cq.bitmaps)
    ref = reduce_dense_oracle(jnp.asarray(table), qs[64:96])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)
